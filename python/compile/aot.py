"""AOT compile path: lower every PRINS entry point to HLO text artifacts.

Run once at build time (`make artifacts`); the rust runtime
(rust/src/runtime/) loads artifacts/*.hlo.txt via
HloModuleProto::from_text_file and executes them on the PJRT CPU client.
Python is never on the request path.

Interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True and
unwrapped with to_tuple{1,2}() on the rust side.
(See /opt/xla-example/README.md.)

Every artifact's shapes are fixed at lowering time; artifacts/manifest.json
records them so the rust side can pad/validate inputs.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import golden
from .kernels import rcam_step as k

# --- fixed AOT shapes (recorded in the manifest) ---------------------------
W = 256           # bit columns per RCAM row (paper 5.1)
NW = 2048         # u32 words per plane -> 65,536 rows per executor call
P = 128           # microprogram executor pass-table length
GOLDEN_N = 4096   # golden ED/DP rows
GOLDEN_D = 16     # golden ED/DP dims (paper: 16-dimensional DP vectors)
SPMV_NNZ = 16384  # golden SpMV nonzeros (padded COO)
SPMV_NB = 1024    # golden SpMV vector length
HIST_N = 65536    # golden histogram samples


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Entry points. Wrapped so every output is a tuple (return_tuple=True keeps
# the rust side uniform: execute -> to_tupleN).
def ep_rcam_step(planes, key, cmask, wkey, wmask):
    planes2, tags = k.rcam_step(planes, key, cmask, wkey, wmask)
    return (planes2, tags)


def ep_rcam_program(planes, passes):
    return (model.run_program(planes, passes),)


def ep_compare_count(planes, key, cmask):
    return (model.compare_count(planes, key, cmask),)


def ep_tag_field_popcount(tags, field):
    return (k.tag_field_popcount(tags, field),)


def ep_golden_ed(x, center):
    return (golden.euclidean(x, center),)


def ep_golden_dp(x, h):
    return (golden.dot_product(x, h),)


def ep_golden_hist(x):
    return (golden.histogram256(x),)


def ep_golden_spmv(rows, cols, vals, x):
    return (golden.spmv(rows, cols, vals, x),)


ENTRY_POINTS = {
    # name -> (fn, arg specs, output arity)
    "rcam_step": (
        ep_rcam_step,
        [u32(W, NW), u32(W), u32(W), u32(W), u32(W)],
        2,
    ),
    "rcam_program": (ep_rcam_program, [u32(W, NW), u32(P, 4, W)], 1),
    "compare_count": (ep_compare_count, [u32(W, NW), u32(W), u32(W)], 1),
    "tag_field_popcount": (ep_tag_field_popcount, [u32(NW), u32(NW)], 1),
    "golden_ed": (ep_golden_ed, [f32(GOLDEN_N, GOLDEN_D), f32(GOLDEN_D)], 1),
    "golden_dp": (ep_golden_dp, [f32(GOLDEN_N, GOLDEN_D), f32(GOLDEN_D)], 1),
    "golden_hist": (ep_golden_hist, [u32(HIST_N)], 1),
    "golden_spmv": (
        ep_golden_spmv,
        [i32(SPMV_NNZ), i32(SPMV_NNZ), f32(SPMV_NNZ), f32(SPMV_NB)],
        1,
    ),
}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="comma-separated entry names")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = list(ENTRY_POINTS) if args.only is None else args.only.split(",")
    manifest = {
        "W": W,
        "NW": NW,
        "P": P,
        "BLOCK_WORDS": k.BLOCK_WORDS,
        "GOLDEN_N": GOLDEN_N,
        "GOLDEN_D": GOLDEN_D,
        "SPMV_NNZ": SPMV_NNZ,
        "SPMV_NB": SPMV_NB,
        "HIST_N": HIST_N,
        "entry_points": {},
    }
    for name in names:
        fn, specs, arity = ENTRY_POINTS[name]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entry_points"][name] = {
            "file": f"{name}.hlo.txt",
            "outputs": arity,
            "args": [
                {"shape": list(s.shape), "dtype": s.dtype.name} for s in specs
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
