"""Pure-numpy/jnp oracles for the PRINS kernels.

These are the correctness references against which the Pallas kernels
(rcam_step.py, golden.py) and the composed L2 programs (model.py) are
checked by pytest + hypothesis. They implement the RCAM semantics of the
paper (section 3.1/4) directly, row by row, with no cleverness.

Bit-plane layout convention (shared with the rust simulator, see
rust/src/rcam/bitmatrix.rs): the RCAM array of N rows x W bit-columns is
stored as W planes of ceil(N/32) uint32 words; bit r of plane j is
row r's bit-column j. Row r matches the key iff for every column j with
cmask[j] == 1, plane[j] bit r == key[j].
"""
from __future__ import annotations

import numpy as np

UINT32_ALL = np.uint32(0xFFFFFFFF)


def unpack_rows(planes: np.ndarray, n_rows: int) -> np.ndarray:
    """planes [W, NW] u32 -> bits [n_rows, W] u8 (row-major view)."""
    w, nw = planes.shape
    assert n_rows <= nw * 32
    bits = np.zeros((n_rows, w), dtype=np.uint8)
    for j in range(w):
        for r in range(n_rows):
            bits[r, j] = (planes[j, r // 32] >> np.uint32(r % 32)) & np.uint32(1)
    return bits


def pack_rows(bits: np.ndarray, nw: int | None = None) -> np.ndarray:
    """bits [N, W] u8 -> planes [W, NW] u32."""
    n, w = bits.shape
    if nw is None:
        nw = (n + 31) // 32
    planes = np.zeros((w, nw), dtype=np.uint32)
    for j in range(w):
        for r in range(n):
            if bits[r, j]:
                planes[j, r // 32] |= np.uint32(1) << np.uint32(r % 32)
    return planes


def rcam_compare_ref(
    planes: np.ndarray, key: np.ndarray, cmask: np.ndarray
) -> np.ndarray:
    """Reference compare: returns tag words [NW] u32 (bit r set = row r matched).

    A column with cmask[j] == 0 is ignored ("Bit and Bit-not lines kept
    floating", paper 3.1). An all-zero cmask therefore matches every row.
    """
    w, nw = planes.shape
    tags = np.full(nw, UINT32_ALL, dtype=np.uint32)
    for j in range(w):
        if cmask[j] == 0:
            continue
        if key[j]:
            tags &= planes[j]
        else:
            tags &= ~planes[j]
    return tags


def rcam_write_ref(
    planes: np.ndarray, tags: np.ndarray, wkey: np.ndarray, wmask: np.ndarray
) -> np.ndarray:
    """Reference tagged write: for every column j with wmask[j] == 1, set
    bit-column j of every tagged row to wkey[j]. Untagged rows and unmasked
    columns are untouched (paper 3.1: two-phase write affects only tagged
    rows)."""
    w, _ = planes.shape
    out = planes.copy()
    for j in range(w):
        if wmask[j] == 0:
            continue
        if wkey[j]:
            out[j] |= tags
        else:
            out[j] &= ~tags
    return out


def rcam_step_ref(planes, key, cmask, wkey, wmask):
    """compare + tagged write (one associative pass). Returns (planes', tags)."""
    tags = rcam_compare_ref(planes, key, cmask)
    return rcam_write_ref(planes, tags, wkey, wmask), tags


def run_program_ref(planes, passes):
    """Iterate rcam_step_ref over a pass table [P, 4, W] (key,cmask,wkey,wmask)."""
    out = planes.copy()
    for p in range(passes.shape[0]):
        key, cmask, wkey, wmask = passes[p]
        out, _ = rcam_step_ref(out, key, cmask, wkey, wmask)
    return out


def popcount_ref(tags: np.ndarray, n_rows: int) -> int:
    """Reduction-tree oracle: number of tagged rows among the first n_rows."""
    total = 0
    for i, word in enumerate(tags):
        for b in range(32):
            r = i * 32 + b
            if r >= n_rows:
                break
            total += (int(word) >> b) & 1
    return total


# ---------------------------------------------------------------------------
# Golden (reference-architecture) kernels: the numeric computations PRINS
# implements associatively. Used to validate end-to-end PRINS results.
# ---------------------------------------------------------------------------

def euclidean_ref(x: np.ndarray, center: np.ndarray) -> np.ndarray:
    """Squared Euclidean distance of every sample to one center. x [N, D]."""
    d = x.astype(np.float32) - center.astype(np.float32)[None, :]
    return np.sum(d * d, axis=1, dtype=np.float32)


def dot_ref(x: np.ndarray, h: np.ndarray) -> np.ndarray:
    """Dot product of every vector with the hyperplane h. x [N, D]."""
    return (x.astype(np.float32) * h.astype(np.float32)[None, :]).sum(
        axis=1, dtype=np.float32
    )


def histogram_ref(x: np.ndarray, bins: int = 256) -> np.ndarray:
    """Paper Algorithm 3: 256-bin histogram binned on bits [31..24] of u32."""
    idx = (x.astype(np.uint32) >> np.uint32(24)).astype(np.int64)
    return np.bincount(idx, minlength=bins).astype(np.int32)


def spmv_ref(rows, cols, vals, x, n_out):
    """COO SpMV oracle: y[rows[k]] += vals[k] * x[cols[k]]."""
    y = np.zeros(n_out, dtype=np.float32)
    np.add.at(y, rows.astype(np.int64), vals.astype(np.float32) * x[cols.astype(np.int64)])
    return y
