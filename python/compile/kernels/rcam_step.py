"""L1 Pallas kernels: the PRINS compute hot-spot.

One associative pass over the RCAM bit-plane state — compare the key
against the (compare-)masked bit columns of every row in parallel, then
write the (write-)masked key bits into every tagged row. This is the
operation PRINS executes at 500 MHz on the memristive crossbar; here it is
the TPU-shaped kernel (see DESIGN.md section "Hardware-Adaptation"): bit
planes are u32 lane vectors resident in VMEM, a compare is a fused
XNOR+AND reduction across planes on the VPU, a tagged write is a
predicated blend.

All kernels use interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which both pytest and
the rust runtime (via artifacts/*.hlo.txt) can run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UINT32_ALL = jnp.uint32(0xFFFFFFFF)

# Words-per-block for the grid. 256 u32 words x W<=256 planes = 256 KB per
# block at W=256 — the VMEM working-set target from DESIGN.md.
BLOCK_WORDS = 256


def _step_kernel(key_ref, cmask_ref, wkey_ref, wmask_ref, planes_ref,
                 out_planes_ref, tags_ref):
    """One associative pass on a [W, BN] block of bit-planes.

    tag computation: tag = AND_j ( cmask_j ? (key_j ? plane_j : ~plane_j)
                                           : ALL_ONES )
    tagged write:    plane_j' = wmask_j ? (key up) blend : plane_j
    """
    planes = planes_ref[...]                       # [W, BN] u32
    key = key_ref[...]                             # [W] u32 (0/1)
    cmask = cmask_ref[...]
    wkey = wkey_ref[...]
    wmask = wmask_ref[...]

    # Select plane or complement per compare-key bit, neutralize unmasked
    # columns, then reduce with bitwise AND across planes. Constants must be
    # materialized inside the kernel (pallas rejects captured tracers).
    all_ones = jnp.full(planes.shape, 0xFFFFFFFF, jnp.uint32)
    sel = jnp.where(key[:, None] != 0, planes, ~planes)
    contrib = jnp.where(cmask[:, None] != 0, sel, all_ones)
    tags = jnp.bitwise_and.reduce(contrib, axis=0)

    # Two-phase tagged write (paper 3.1): set-then-reset, modeled as a blend.
    set_bits = planes | tags[None, :]
    clr_bits = planes & ~tags[None, :]
    written = jnp.where(wkey[:, None] != 0, set_bits, clr_bits)
    out_planes_ref[...] = jnp.where(wmask[:, None] != 0, written, planes)
    tags_ref[...] = tags


@functools.partial(jax.jit, static_argnames=("block_words",))
def rcam_step(planes, key, cmask, wkey, wmask, *, block_words=BLOCK_WORDS):
    """Apply one associative compare+write pass.

    planes: u32[W, NW]; key/cmask/wkey/wmask: u32[W] with 0/1 entries.
    Returns (planes', tags) with tags: u32[NW].
    NW must be a multiple of block_words (the rust caller pads).
    """
    w, nw = planes.shape
    assert nw % block_words == 0, (nw, block_words)
    grid = (nw // block_words,)
    return pl.pallas_call(
        _step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((w,), lambda i: (0,)),            # key
            pl.BlockSpec((w,), lambda i: (0,)),            # cmask
            pl.BlockSpec((w,), lambda i: (0,)),            # wkey
            pl.BlockSpec((w,), lambda i: (0,)),            # wmask
            pl.BlockSpec((w, block_words), lambda i: (0, i)),  # planes
        ],
        out_specs=[
            pl.BlockSpec((w, block_words), lambda i: (0, i)),
            pl.BlockSpec((block_words,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, nw), jnp.uint32),
            jax.ShapeDtypeStruct((nw,), jnp.uint32),
        ],
        interpret=True,
    )(key, cmask, wkey, wmask, planes)


def _popcount_kernel(tags_ref, out_ref):
    """Reduction tree (paper 3.1): logarithmic summation of tag bits.

    Per-block popcount; the host (or the surrounding jax graph) sums the
    per-block partials — the same two-level structure as the cascaded
    module counters in Fig. 4.
    """
    tags = tags_ref[...]
    counts = jax.lax.population_count(tags).astype(jnp.uint32)
    out_ref[...] = jnp.sum(counts, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_words",))
def tag_popcount(tags, *, block_words=BLOCK_WORDS):
    """Total number of tagged rows. tags: u32[NW] -> u32 scalar."""
    (nw,) = tags.shape
    assert nw % block_words == 0
    grid = (nw // block_words,)
    partials = pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_words,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nw // block_words,), jnp.uint32),
        interpret=True,
    )(tags)
    return jnp.sum(partials, dtype=jnp.uint32)


def _weighted_popcount_kernel(tags_ref, field_ref, out_ref):
    """Popcount of (tags AND field-plane) — the reduction-tree input used by
    the bit-serial field reduction (histogram increments, SpMV row sums)."""
    tags = tags_ref[...]
    field = field_ref[...]
    counts = jax.lax.population_count(tags & field).astype(jnp.uint32)
    out_ref[...] = jnp.sum(counts, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_words",))
def tag_field_popcount(tags, field_plane, *, block_words=BLOCK_WORDS):
    """Number of rows that are tagged AND have the field-plane bit set."""
    (nw,) = tags.shape
    assert nw % block_words == 0
    grid = (nw // block_words,)
    partials = pl.pallas_call(
        _weighted_popcount_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_words,), lambda i: (i,)),
            pl.BlockSpec((block_words,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nw // block_words,), jnp.uint32),
        interpret=True,
    )(tags, field_plane)
    return jnp.sum(partials, dtype=jnp.uint32)
