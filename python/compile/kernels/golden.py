"""Golden kernels: the reference architecture's numeric computations.

These implement the same workloads PRINS runs associatively (Euclidean
distance, dot product, histogram, SpMV) as straight dataflow kernels.
They serve two roles:

 1. Build-time oracle — pytest checks the associative L2 programs against
    them (and them against ref.py).
 2. Run-time validator — aot.py lowers them to artifacts/golden_*.hlo.txt;
    the rust `prins validate` command and the integration tests execute
    them via PJRT to cross-check PRINS results end-to-end.

ED and DP are written as Pallas kernels (they are the MXU-shaped side of
the workload; see DESIGN.md Hardware-Adaptation); histogram and SpMV use
scatter-adds, which XLA handles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256


def _ed_kernel(center_ref, x_ref, out_ref):
    """Squared Euclidean distance of a [BN, D] sample block to one center."""
    x = x_ref[...]
    c = center_ref[...]
    d = x - c[None, :]
    out_ref[...] = jnp.sum(d * d, axis=1)


@functools.partial(jax.jit, static_argnames=("row_block",))
def euclidean(x, center, *, row_block=ROW_BLOCK):
    """x: f32[N, D], center: f32[D] -> f32[N] squared distances."""
    n, d = x.shape
    assert n % row_block == 0
    return pl.pallas_call(
        _ed_kernel,
        grid=(n // row_block,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(center, x)


def _dp_kernel(h_ref, x_ref, out_ref):
    """Dot product of a [BN, D] vector block with the hyperplane h."""
    out_ref[...] = x_ref[...] @ h_ref[...]


@functools.partial(jax.jit, static_argnames=("row_block",))
def dot_product(x, h, *, row_block=ROW_BLOCK):
    """x: f32[N, D], h: f32[D] -> f32[N]."""
    n, d = x.shape
    assert n % row_block == 0
    return pl.pallas_call(
        _dp_kernel,
        grid=(n // row_block,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(h, x)


@jax.jit
def histogram256(x):
    """Paper Algorithm 3 semantics: bin on bits [31..24] of u32. x: u32[N]."""
    idx = (x >> jnp.uint32(24)).astype(jnp.int32)
    return jnp.zeros((256,), jnp.int32).at[idx].add(1)


@jax.jit
def spmv(rows, cols, vals, x):
    """COO SpMV: y[rows[k]] += vals[k] * x[cols[k]].

    rows/cols: i32[NNZ], vals: f32[NNZ], x: f32[NB] -> y: f32[NB].
    Padding convention (rust side): pad entries use vals == 0.
    """
    contrib = vals * x[cols]
    return jnp.zeros_like(x).at[rows].add(contrib)
