"""L2: the PRINS associative machine as a JAX compute graph.

The machine state is the bit-plane array u32[W, NW] (W bit-columns,
NW = N/32 words; see kernels/ref.py for the layout). One associative pass
is the L1 Pallas kernel (kernels/rcam_step.py). A *microprogram* is a pass
table u32[P, 4, W] of (key, cmask, wkey, wmask) rows; `run_program` folds
the kernel over the table with lax.scan so an entire bit-serial arithmetic
operation (e.g. a full 16-bit vector add = 128 truth-table passes, paper
section 4 / Fig. 6) lowers to ONE HLO module with no host round-trips.

The rust coordinator generates pass tables from its own microcode
assembler (rust/src/micro/) and feeds them to the AOT-compiled executor as
runtime inputs — the artifact is a *generic* microprogram executor, not a
baked-in program. A pass with wmask == 0 writes nothing and is the padding
no-op.

This module also contains a small python mirror of the rust full-adder
microcode generator (`vecadd_passes`). It exists so the scan-composed
executor can be tested end-to-end (packed vectors in, integer sums out)
against numpy, pinning down the exact pass-table convention the rust side
must emit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import rcam_step as k


@functools.partial(jax.jit, static_argnames=("block_words",))
def associative_step(planes, key, cmask, wkey, wmask, *, block_words=k.BLOCK_WORDS):
    """One compare+write pass. Thin re-export of the L1 kernel."""
    return k.rcam_step(planes, key, cmask, wkey, wmask, block_words=block_words)


@functools.partial(jax.jit, static_argnames=("block_words",))
def run_program(planes, passes, *, block_words=k.BLOCK_WORDS):
    """Fold a microprogram over the machine state.

    planes: u32[W, NW]; passes: u32[P, 4, W] -> u32[W, NW].
    """

    def body(st, pass_row):
        key, cmask, wkey, wmask = (
            pass_row[0],
            pass_row[1],
            pass_row[2],
            pass_row[3],
        )
        nxt, _tags = k.rcam_step(st, key, cmask, wkey, wmask, block_words=block_words)
        return nxt, ()

    out, _ = jax.lax.scan(body, planes, passes)
    return out


@functools.partial(jax.jit, static_argnames=("block_words",))
def compare_count(planes, key, cmask, *, block_words=k.BLOCK_WORDS):
    """compare + reduction tree: number of rows matching (key, cmask).

    This is the inner step of Algorithm 3 (histogram): compare a bin index
    against a field, count tags with the logarithmic reduction tree.
    """
    _, tags = k.rcam_step(
        planes,
        key,
        cmask,
        jnp.zeros_like(key),
        jnp.zeros_like(key),  # wmask = 0: no write
        block_words=block_words,
    )
    return k.tag_popcount(tags, block_words=block_words)


# ---------------------------------------------------------------------------
# Python mirror of the rust microcode generator (full-adder truth table).
# ---------------------------------------------------------------------------

# Full-adder truth table, paper Fig. 6(a): input (c, a, b) -> output (c', s).
#
# ORDERING MATTERS (classic associative-processing hazard, Foster 1976):
# a pass that flips the carry bit moves a row onto another entry's input
# pattern; if that entry runs later the row is processed twice and the sum
# is corrupted. Only two entries flip c: (0,1,1)->c=1 lands on (1,1,1),
# and (1,0,0)->c=0 lands on (0,0,0). Processing (1,1,1) before (0,1,1) and
# (0,0,0) before (1,0,0) makes every carry flip land on an
# already-processed pattern. rust/src/micro/add.rs uses the same order.
FULL_ADDER = [
    # (c, a, b)  ->  (c', s)
    ((1, 1, 1), (1, 1)),
    ((0, 1, 1), (1, 0)),
    ((0, 0, 0), (0, 0)),
    ((1, 0, 0), (0, 1)),
    ((0, 0, 1), (0, 1)),
    ((0, 1, 0), (0, 1)),
    ((1, 0, 1), (1, 0)),
    ((1, 1, 0), (1, 0)),
]


def _pass_row(w, key_bits, cmask_bits, wkey_bits, wmask_bits):
    row = np.zeros((4, w), dtype=np.uint32)
    for j, v in key_bits:
        row[0, j] = v
    for j, v in cmask_bits:
        row[1, j] = v
    for j, v in wkey_bits:
        row[2, j] = v
    for j, v in wmask_bits:
        row[3, j] = v
    return row


def vecadd_passes(w, a_base, b_base, s_base, c_col, m_bits):
    """Generate the pass table for S = A + B over m-bit fields.

    Matches rust/src/micro/add.rs: for each bit i (LSB first), walk the
    full-adder truth table entries; only entries whose output differs from
    a no-op need a write, but we emit all 8 for fidelity to the paper's
    cycle count ("eight steps of one compare and one write", section 4).
    Columns: a_base+i, b_base+i (inputs), s_base+i (sum), c_col (carry).
    The carry column must be zeroed by the caller beforehand.
    """
    passes = []
    for i in range(m_bits):
        a, b, s = a_base + i, b_base + i, s_base + i
        for (cin, av, bv), (cout, sv) in FULL_ADDER:
            # NOTE the write ordering subtlety: writing c in the same pass
            # that compared c is safe because the compare happens before
            # the write within a pass (tag is latched, paper 3.2).
            passes.append(
                _pass_row(
                    w,
                    key_bits=[(c_col, cin), (a, av), (b, bv)],
                    cmask_bits=[(c_col, 1), (a, 1), (b, 1)],
                    wkey_bits=[(c_col, cout), (s, sv)],
                    wmask_bits=[(c_col, 1), (s, 1)],
                )
            )
    return np.stack(passes)


def pad_program(passes, p_total):
    """Pad a pass table with no-ops (wmask == 0) to a fixed AOT length."""
    p, four, w = passes.shape
    assert four == 4 and p <= p_total, (passes.shape, p_total)
    pad = np.zeros((p_total - p, 4, w), dtype=np.uint32)
    return np.concatenate([passes, pad], axis=0)
