"""Golden (reference-architecture) kernels vs numpy oracles."""
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import golden, ref


@settings(max_examples=10, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=4),
    d=st.sampled_from([1, 4, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_euclidean(n_blocks, d, seed):
    rng = np.random.default_rng(seed)
    n = n_blocks * 256
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=d).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(golden.euclidean(x, c)), ref.euclidean_ref(x, c),
        rtol=1e-4, atol=1e-5,
    )


@settings(max_examples=10, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=4),
    d=st.sampled_from([1, 4, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_dot_product(n_blocks, d, seed):
    rng = np.random.default_rng(seed)
    n = n_blocks * 256
    x = rng.normal(size=(n, d)).astype(np.float32)
    h = rng.normal(size=d).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(golden.dot_product(x, h)), ref.dot_ref(x, h),
        rtol=1e-4, atol=1e-5,
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31),
       n=st.sampled_from([64, 1024, 65536]))
def test_histogram(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    got = np.asarray(golden.histogram256(x))
    exp = ref.histogram_ref(x)
    np.testing.assert_array_equal(got, exp)
    assert got.sum() == n


def test_histogram_bins_on_top_byte():
    """Algorithm 3 binning: bits [31..24], not the low byte."""
    x = np.array([0x00000000, 0x01FFFFFF, 0xFF000000, 0xFF0000FF], dtype=np.uint32)
    got = np.asarray(golden.histogram256(x))
    assert got[0] == 1 and got[1] == 1 and got[255] == 2


@settings(max_examples=10, deadline=None)
@given(
    nb=st.sampled_from([16, 64, 256]),
    density=st.floats(min_value=0.01, max_value=0.5),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_spmv(nb, density, seed):
    rng = np.random.default_rng(seed)
    nnz = max(1, int(nb * nb * density))
    rows = rng.integers(0, nb, nnz).astype(np.int32)
    cols = rng.integers(0, nb, nnz).astype(np.int32)
    vals = rng.normal(size=nnz).astype(np.float32)
    x = rng.normal(size=nb).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(golden.spmv(rows, cols, vals, x)),
        ref.spmv_ref(rows, cols, vals, x, nb),
        rtol=1e-4, atol=1e-4,
    )


def test_spmv_zero_padding_convention():
    """Padding entries (vals == 0) must not perturb the result even when
    their row/col indices alias real entries."""
    nb = 8
    rows = np.array([0, 1, 0, 0], dtype=np.int32)
    cols = np.array([1, 2, 0, 0], dtype=np.int32)
    vals = np.array([2.0, 3.0, 0.0, 0.0], dtype=np.float32)
    x = np.arange(nb, dtype=np.float32)
    got = np.asarray(golden.spmv(rows, cols, vals, x))
    exp = np.zeros(nb, dtype=np.float32)
    exp[0] = 2.0 * x[1]
    exp[1] = 3.0 * x[2]
    np.testing.assert_allclose(got, exp)
