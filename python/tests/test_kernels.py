"""L1 Pallas kernels vs pure-numpy oracles (ref.py).

Hypothesis sweeps the kernel's shape space (plane count W, word count NW,
block size) and dense/sparse mask patterns; every case asserts exact
bit-equality — the RCAM is a digital machine, there is no tolerance.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import rcam_step as k
from compile.kernels import ref


def mk_case(draw_ints, w, nw, seed):
    rng = np.random.default_rng(seed)
    planes = rng.integers(0, 2**32, (w, nw), dtype=np.uint32)
    key = rng.integers(0, 2, w).astype(np.uint32)
    cmask = rng.integers(0, 2, w).astype(np.uint32)
    wkey = rng.integers(0, 2, w).astype(np.uint32)
    wmask = rng.integers(0, 2, w).astype(np.uint32)
    return planes, key, cmask, wkey, wmask


@settings(max_examples=25, deadline=None)
@given(
    w=st.integers(min_value=1, max_value=64),
    nw_blocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_step_matches_ref(w, nw_blocks, block, seed):
    nw = nw_blocks * block
    planes, key, cmask, wkey, wmask = mk_case(None, w, nw, seed)
    got_planes, got_tags = k.rcam_step(
        planes, key, cmask, wkey, wmask, block_words=block
    )
    exp_planes, exp_tags = ref.rcam_step_ref(planes, key, cmask, wkey, wmask)
    np.testing.assert_array_equal(np.asarray(got_tags), exp_tags)
    np.testing.assert_array_equal(np.asarray(got_planes), exp_planes)


def test_empty_cmask_matches_all_rows():
    """Paper 3.1: floating Bit/Bit-not lines (mask = 0) never discharge the
    match line, so an all-zero compare mask tags every row."""
    rng = np.random.default_rng(7)
    planes = rng.integers(0, 2**32, (8, 4), dtype=np.uint32)
    zeros = np.zeros(8, dtype=np.uint32)
    _, tags = k.rcam_step(planes, zeros, zeros, zeros, zeros, block_words=4)
    assert np.all(np.asarray(tags) == np.uint32(0xFFFFFFFF))


def test_zero_wmask_is_noop():
    rng = np.random.default_rng(8)
    planes = rng.integers(0, 2**32, (8, 4), dtype=np.uint32)
    key = rng.integers(0, 2, 8).astype(np.uint32)
    cmask = np.ones(8, dtype=np.uint32)
    zeros = np.zeros(8, dtype=np.uint32)
    got, _ = k.rcam_step(planes, key, cmask, key, zeros, block_words=4)
    np.testing.assert_array_equal(np.asarray(got), planes)


def test_write_affects_only_tagged_rows():
    """Construct a single-row match and check only that row's bits move."""
    w, nw = 8, 2
    bits = np.zeros((64, w), dtype=np.uint8)
    bits[37, 0] = 1  # row 37 uniquely has column 0 set
    planes = ref.pack_rows(bits, nw)
    key = np.zeros(w, dtype=np.uint32)
    key[0] = 1
    cmask = np.zeros(w, dtype=np.uint32)
    cmask[0] = 1
    wkey = np.zeros(w, dtype=np.uint32)
    wkey[5] = 1
    wmask = np.zeros(w, dtype=np.uint32)
    wmask[5] = 1
    got, tags = k.rcam_step(planes, key, cmask, wkey, wmask, block_words=2)
    assert ref.popcount_ref(np.asarray(tags), 64) == 1
    obits = ref.unpack_rows(np.asarray(got), 64)
    assert obits[37, 5] == 1
    obits[37, 5] = 0
    np.testing.assert_array_equal(obits, bits)


@settings(max_examples=20, deadline=None)
@given(
    nw_blocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_popcount_matches_ref(nw_blocks, block, seed):
    nw = nw_blocks * block
    rng = np.random.default_rng(seed)
    tags = rng.integers(0, 2**32, nw, dtype=np.uint32)
    got = int(k.tag_popcount(tags, block_words=block))
    assert got == ref.popcount_ref(tags, nw * 32)


@settings(max_examples=20, deadline=None)
@given(
    nw_blocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_field_popcount_matches_ref(nw_blocks, block, seed):
    nw = nw_blocks * block
    rng = np.random.default_rng(seed)
    tags = rng.integers(0, 2**32, nw, dtype=np.uint32)
    field = rng.integers(0, 2**32, nw, dtype=np.uint32)
    got = int(k.tag_field_popcount(tags, field, block_words=block))
    assert got == ref.popcount_ref(tags & field, nw * 32)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(9)
    bits = rng.integers(0, 2, (100, 12)).astype(np.uint8)
    planes = ref.pack_rows(bits, nw=4)
    np.testing.assert_array_equal(ref.unpack_rows(planes, 100), bits)
