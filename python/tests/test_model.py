"""L2 composed microprograms (model.py) vs the numpy interpreter and vs
integer semantics — pins down the pass-table convention the rust
coordinator must emit.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def pack_operands(a, b, w, m, a_base, b_base, nw):
    n = len(a)
    bits = np.zeros((n, w), dtype=np.uint8)
    for i in range(m):
        bits[:, a_base + i] = (a >> i) & 1
        bits[:, b_base + i] = (b >> i) & 1
    return ref.pack_rows(bits, nw)


def extract_field(planes, n, base, m):
    bits = ref.unpack_rows(planes, n)
    out = np.zeros(n, dtype=np.uint64)
    for i in range(m):
        out |= bits[:, base + i].astype(np.uint64) << i
    return out


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_vecadd_program_adds(m, seed):
    """The scan-composed full-adder microprogram computes A + B mod 2^m for
    every row in parallel, regardless of row content order (paper section 4)."""
    rng = np.random.default_rng(seed)
    n, w = 64, 64
    a = rng.integers(0, 2**m, n, dtype=np.uint32)
    b = rng.integers(0, 2**m, n, dtype=np.uint32)
    planes = pack_operands(a, b, w, m, a_base=0, b_base=16, nw=2)
    passes = model.vecadd_passes(w, 0, 16, 32, 48, m).astype(np.uint32)
    assert passes.shape[0] == 8 * m  # paper: 8 compare+write steps per bit
    out = np.asarray(model.run_program(planes, passes, block_words=2))
    s = extract_field(out, n, 32, m)
    np.testing.assert_array_equal(s, (a + b).astype(np.uint64) & ((1 << m) - 1))


@settings(max_examples=10, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=12),
    w=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_random_program_matches_interpreter(p, w, seed):
    """run_program (lax.scan over the Pallas step) == numpy pass-by-pass."""
    rng = np.random.default_rng(seed)
    nw = 4
    planes = rng.integers(0, 2**32, (w, nw), dtype=np.uint32)
    passes = rng.integers(0, 2, (p, 4, w)).astype(np.uint32)
    got = np.asarray(model.run_program(planes, passes, block_words=2))
    exp = ref.run_program_ref(planes, passes)
    np.testing.assert_array_equal(got, exp)


def test_noop_padding_preserves_state():
    """pad_program's wmask == 0 no-ops leave the machine state untouched —
    the invariant the fixed-length AOT executor depends on."""
    rng = np.random.default_rng(3)
    w, nw, m = 64, 2, 8
    planes = rng.integers(0, 2**32, (w, nw), dtype=np.uint32)
    passes = model.vecadd_passes(w, 0, 16, 32, 48, m).astype(np.uint32)
    padded = model.pad_program(passes, passes.shape[0] + 32).astype(np.uint32)
    out_a = np.asarray(model.run_program(planes, passes, block_words=2))
    out_b = np.asarray(model.run_program(planes, padded, block_words=2))
    np.testing.assert_array_equal(out_a, out_b)


def test_compare_count():
    """compare + reduction tree (Algorithm 3 inner step)."""
    w, n = 16, 128
    bits = np.zeros((n, w), dtype=np.uint8)
    bits[: n // 4, 3] = 1  # 32 rows carry the pattern
    planes = ref.pack_rows(bits, nw=4)
    key = np.zeros(w, dtype=np.uint32)
    key[3] = 1
    cmask = np.zeros(w, dtype=np.uint32)
    cmask[3] = 1
    assert int(model.compare_count(planes, key, cmask, block_words=4)) == n // 4


def test_full_adder_order_is_hazard_free():
    """Every carry-flipping entry must land on an already-processed input
    pattern (see FULL_ADDER comment) — checked structurally, not by example."""
    seen = []
    for (c, a, b), (c2, _s) in model.FULL_ADDER:
        if c2 != c:  # this pass flips the carry of matching rows
            assert (c2, a, b) in seen, f"hazard: ({c},{a},{b}) lands on unprocessed ({c2},{a},{b})"
        seen.append((c, a, b))
    assert len(seen) == 8
