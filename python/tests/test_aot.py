"""The AOT path itself: every entry point lowers to parseable HLO text and
the manifest is consistent. Catches jax upgrades that would silently break
the HLO-text interchange with the rust runtime.
"""
import json
import os

import pytest

from compile import aot


@pytest.mark.parametrize("name", list(aot.ENTRY_POINTS))
def test_entry_point_lowers_to_hlo_text(name):
    import jax

    fn, specs, arity = aot.ENTRY_POINTS[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "ROOT" in text
    # return_tuple=True: the module root must be a tuple of `arity` elements.
    assert text.count("HloModule") == 1


def test_manifest_matches_artifacts_if_built():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert set(manifest["entry_points"]) == set(aot.ENTRY_POINTS)
    for name, meta in manifest["entry_points"].items():
        path = os.path.join(art, meta["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
    assert manifest["W"] == aot.W and manifest["NW"] == aot.NW
    assert manifest["P"] == aot.P


def test_shapes_are_block_aligned():
    """The executor NW must be a multiple of the kernel block size, and the
    golden shapes must match their kernels' grid constraints."""
    from compile.kernels import rcam_step as k

    assert aot.NW % k.BLOCK_WORDS == 0
    assert aot.GOLDEN_N % 256 == 0
    assert aot.HIST_N >= 1
