//! Linear-SVM batch inference with the dot-product kernel (§5.4.1): the
//! hyperplane is broadcast into the storage, every stored vector is
//! classified in one associative sweep whose latency is independent of
//! the batch size.
//!
//!   cargo run --release --example svm_inference
use prins::algorithms::dot::{dot_baseline, DotKernel, DotLayout};
use prins::controller::Controller;
use prins::rcam::PrinsArray;
use prins::storage::StorageManager;
use prins::workloads::Rng;

fn main() {
    let (n, dims) = (1024usize, 8usize);
    // a synthetic linearly-separable-ish problem
    let mut rng = Rng::seed_from(11);
    let w_true: Vec<f32> = (0..dims).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();

    let layout = DotLayout::new(dims);
    let mut array = PrinsArray::single(n, layout.width as usize);
    let mut sm = StorageManager::new(n);
    let kern = DotKernel::load(&mut sm, &mut array, &x, n, dims);
    let mut ctl = Controller::new(array);

    let res = kern.run(&mut ctl, &sm, &w_true);
    let expect = dot_baseline(&x, n, dims, &w_true);
    let mut agree = 0;
    for i in 0..n {
        if (res.dp[i] >= 0.0) == (expect[i] >= 0.0) {
            agree += 1;
        }
    }
    let pos = res.dp.iter().filter(|&&v| v >= 0.0).count();
    println!("classified {n} vectors: {pos} positive / {} negative", n - pos);
    println!("sign agreement with float baseline: {agree}/{n}");
    println!(
        "device cycles {} ({:.2} ms @500MHz) — same for 1k or 100M vectors",
        res.stats.cycles,
        res.stats.cycles as f64 / 500e6 * 1e3
    );
    assert!(agree as f64 / n as f64 > 0.99);
}
