//! END-TO-END DRIVER: exercises the full three-layer stack on real small
//! workloads and regenerates every paper table/figure (recorded in
//! EXPERIMENTS.md).
//!
//! Layers proven to compose here:
//!   L1/L2: AOT Pallas/JAX artifacts (rcam_step / rcam_program / golden_*)
//!          loaded and executed from rust via PJRT, checked bit-exact
//!          against the native simulator and numerically against PRINS.
//!   L3   : the PRINS device (controller + storage + register protocol +
//!          TCP server) running all five paper kernels.
//!
//!   cargo run --release --example end_to_end
use prins::algorithms::histogram_baseline;
use prins::controller::kernels::KernelId;
use prins::controller::registers::Status;
use prins::host::{server::Server, PrinsDevice};
use prins::model::figures;
use prins::runtime::{Golden, Runtime, XlaRcamBackend};
use prins::workloads::{synth_hist_samples, synth_samples, synth_uniform};
use std::io::{BufRead, BufReader, Write};

fn main() {
    let t0 = std::time::Instant::now();
    println!("### PRINS end-to-end driver ###\n");

    // ---- 1. cross-layer equivalence: native simulator vs Pallas kernel --
    match Runtime::open("artifacts") {
        Ok(rt) => {
            let mut xla = XlaRcamBackend::new(rt);
            let mut native = prins::rcam::PrinsArray::single(xla.rows(), 32);
            for r in 0..4096usize {
                let v = (r as u64).wrapping_mul(0x9E3779B97F4A7C15) & 0xFFFF_FFFF;
                native.load_row_bits(r, 0, 32, v);
                xla.load_row_bits(r, 0, 32, v);
            }
            let cpat = vec![(3u16, true), (17u16, false)];
            let wpat = vec![(29u16, true)];
            native.compare(&cpat);
            native.write(&wpat);
            xla.step(&cpat, &wpat).expect("xla step");
            for r in 0..4096usize {
                assert_eq!(
                    native.fetch_row_bits(r, 0, 32),
                    xla.fetch_row_bits(r, 0, 32)
                );
            }
            println!("[L1<->L3] Pallas rcam_step == native bit-sliced simulator (4096 rows) OK");
        }
        Err(e) => println!("[L1<->L3] skipped (run `make artifacts`): {e:#}"),
    }

    // ---- 2. PRINS results vs golden XLA executors ------------------------
    match Golden::open_default() {
        Ok(mut golden) => {
            let (n, dims) = (512usize, 8usize);
            let x = synth_samples(n, dims, 4, 21);
            let c = synth_uniform(dims, 22);
            // PRINS associative ED
            let layout = prins::algorithms::euclidean::EuclideanLayout::new(dims);
            let mut array = prins::rcam::PrinsArray::single(n, layout.width as usize);
            let mut sm = prins::storage::StorageManager::new(n);
            let kern =
                prins::algorithms::EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
            let mut ctl = prins::controller::Controller::new(array);
            let res = kern.run(&mut ctl, &sm, &c, 1);
            // golden (AOT JAX kernel via PJRT)
            let gd = golden.euclidean(&x, n, dims, &c).expect("golden ed");
            let mut max_rel = 0f32;
            for i in 0..n {
                let rel = (res.dists[0][i] - gd[i]).abs() / gd[i].abs().max(1.0);
                max_rel = max_rel.max(rel);
            }
            assert!(max_rel < 1e-4, "max_rel {max_rel}");
            println!(
                "[L2<->L3] PRINS associative fp32 ED == golden XLA kernel (n={n}, max rel err {max_rel:.2e}) OK"
            );
        }
        Err(e) => println!("[L2<->L3] skipped: {e:#}"),
    }

    // ---- 3. the device + TCP server path --------------------------------
    {
        let samples = synth_hist_samples(20_000, 5);
        let dev = PrinsDevice::new(20_000, 64);
        dev.load_samples_for_histogram(&samples);
        let st = dev.run_kernel(KernelId::Histogram, &[], &[]);
        assert_eq!(st, Status::Done);
        let out = dev.take_outputs();
        assert_eq!(out.u64s, histogram_baseline(&samples));
        println!(
            "[host]    register-protocol histogram on 20k samples: {} cycles, {:.1} nJ OK",
            out.cycles,
            out.energy_j * 1e9
        );

        let server = Server::spawn("127.0.0.1:0").expect("server");
        let mut conn = std::net::TcpStream::connect(server.addr).expect("connect");
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        writeln!(conn, "HIST 5000 3").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK"), "{line}");
        println!("[server]  TCP appliance protocol: {}", line.trim());
        server.shutdown();
    }

    // ---- 4. regenerate every paper figure --------------------------------
    println!();
    println!("{}", figures::fig12(figures::DIMS, 512).render());
    println!("{}", figures::fig13(1200).render());
    println!("{}", figures::fig14(1 << 10).render());
    println!("{}", figures::fig15().render());

    println!("total driver time: {:?}", t0.elapsed());
}
