//! K-means clustering with the Euclidean-distance kernel — the paper's
//! motivating ML workload (§5.4.1): the samples never leave the storage;
//! only k·d center coordinates and k·n distances cross the host interface
//! per iteration.
//!
//!   cargo run --release --example kmeans_clustering
use prins::algorithms::euclidean::{EuclideanKernel, EuclideanLayout};
use prins::controller::Controller;
use prins::rcam::PrinsArray;
use prins::storage::StorageManager;
use prins::workloads::synth_samples;

fn main() {
    let (n, dims, k, iters) = (512usize, 4usize, 3usize, 5usize);
    let x = synth_samples(n, dims, k, 7);

    let layout = EuclideanLayout::new(dims);
    let mut array = PrinsArray::single(n, layout.width as usize);
    let mut sm = StorageManager::new(n);
    let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
    let mut ctl = Controller::new(array);

    // init centers = first k samples
    let mut centers: Vec<f32> = x[..k * dims].to_vec();
    let mut assignment = vec![0usize; n];
    for it in 0..iters {
        // PRINS computes all n·k distances associatively
        let res = kern.run(&mut ctl, &sm, &centers, k);
        // host: argmin + center update (the sequential fraction, §5.3)
        for i in 0..n {
            assignment[i] = (0..k)
                .min_by(|&a, &b| res.dists[a][i].total_cmp(&res.dists[b][i]))
                .unwrap();
        }
        let mut counts = vec![0f32; k];
        let mut sums = vec![0f32; k * dims];
        for i in 0..n {
            counts[assignment[i]] += 1.0;
            for j in 0..dims {
                sums[assignment[i] * dims + j] += x[i * dims + j];
            }
        }
        for c in 0..k {
            if counts[c] > 0.0 {
                for j in 0..dims {
                    centers[c * dims + j] = sums[c * dims + j] / counts[c];
                }
            }
        }
        let inertia: f32 = (0..n).map(|i| res.dists[assignment[i]][i]).sum();
        println!(
            "iter {it}: inertia {inertia:.1}, device cycles {} ({:.2} ms @500MHz)",
            res.stats.cycles,
            res.stats.cycles as f64 / 500e6 * 1e3
        );
    }
    let mut sizes = vec![0usize; k];
    for &a in &assignment {
        sizes[a] += 1;
    }
    println!("final cluster sizes: {sizes:?}");
    assert!(sizes.iter().all(|&s| s > 0), "no empty clusters on synth data");
}
