//! Graph analytics (§5.4.4): BFS over a degree-matched stand-in for the
//! paper's hollywood-09 graph, validated against a CPU BFS, with the
//! Table 2 row format and the paper's serial associative loop.
//!
//!   cargo run --release --example graph_analytics
use prins::algorithms::bfs::{measured_teps, paper_model_teps, BfsKernel};
use prins::controller::Controller;
use prins::rcam::PrinsArray;
use prins::storage::StorageManager;
use prins::workloads::PAPER_GRAPHS;

fn main() {
    let pg = PAPER_GRAPHS[5]; // hollywood-09: avg out-degree 100
    let g = pg.synthesize(1 << 11, 9);
    println!(
        "graph: {} stand-in, |V|={}, |E|={}, avgD={:.1}, maxD={}",
        pg.name,
        g.n,
        g.edges(),
        g.avg_degree(),
        g.max_degree()
    );

    let mut array = PrinsArray::single(g.edges(), 128);
    let mut sm = StorageManager::new(g.edges());
    let kern = BfsKernel::load(&mut sm, &mut array, &g);
    let mut ctl = Controller::new(array);
    let res = kern.run(&mut ctl, 0);

    // validate against CPU BFS
    let (expect, traversed) = g.bfs(0);
    assert_eq!(res.dist, expect, "PRINS BFS distances match CPU BFS");
    let reached = res.dist.iter().filter(|&&d| d != u32::MAX).count();
    println!("reached {reached}/{} vertices in {} levels", g.n, res.levels);
    println!(
        "device: {} cycles, {} edge expansions ({:.1} cycles/edge)",
        res.stats.cycles,
        res.iterations,
        res.stats.cycles as f64 / res.iterations as f64
    );
    println!(
        "literal TEPS {:.1} M | paper vertex-serial model {:.1} GTEPS (x{:.1} vs 2.5 GTEPS)",
        measured_teps(&res, 500e6, traversed) / 1e6,
        paper_model_teps(pg.avg_d, 500e6, 3.0) / 1e9,
        paper_model_teps(pg.avg_d, 500e6, 3.0) / 2.5e9,
    );
}
