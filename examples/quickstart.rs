//! Quickstart: stand up a PRINS rack, load a dataset *into* the storage
//! once, and query it many times through the generic kernel framework —
//! the fifty-line tour of the public API (DESIGN.md §Kernel framework).
//!
//! `Resident::<K>::load` + `query` is the canonical way to drive any
//! registered kernel (hist, dp, ed, spmv, search); swap the kernel type
//! and the dataset and everything else stays the same.
//!
//!   cargo run --release --example quickstart
use prins::algorithms::{HistogramKernel, Resident, SearchKernel, SearchRange};
use prins::host::rack::PrinsRack;
use prins::workloads::synth_hist_samples;

fn main() {
    // 1. a PRINS rack (one shard device here; try PrinsRack::new(4))
    let rack = PrinsRack::new(1);

    // 2. the dataset lives in the storage (paper §5.3: "the datasets on
    //    which PRINS operates must reside in PRINS") — loaded ONCE, with
    //    the load cost charged to the device model
    let samples = synth_hist_samples(50_000, 42);
    let mut hist = Resident::<HistogramKernel>::load(&rack, &samples);
    let load = hist.load_report();
    println!("histogram dataset: {} samples resident", hist.n);
    println!("  load (paid once)  : {} cycles", load.total_cycles);

    // 3. query many: each query re-bins the SAME resident samples on a
    //    fresh 8-bit window — compare-only, zero writes, cycle count
    //    independent of the sample count
    for lo_bit in [24u16, 16, 8] {
        let out = hist.query(&lo_bit);
        let top = out.merged.iter().enumerate().max_by_key(|(_, &c)| c).unwrap();
        println!(
            "  bins [{:2}..{:2}]     : hottest bin {} ({} samples), {} cycles, {:.2} µs @500MHz",
            lo_bit + 7,
            lo_bit,
            top.0,
            top.1,
            out.rack.total_cycles,
            out.rack.total_cycles as f64 / 500e6 * 1e6
        );
    }

    // 4. a different kernel, the same three lines: associative SEARCH
    //    range-counts the same keys through the CAM's native match
    let mut search = Resident::<SearchKernel>::load(&rack, &samples);
    let out = search.query(&SearchRange::new(0, u32::MAX / 2));
    println!(
        "search [0, 2^31)    : {} of {} keys matched in {} cycles",
        out.merged,
        search.n,
        out.rack.total_cycles
    );
}
