//! Quickstart: stand up a PRINS device, store a dataset *in* it, and run
//! an associative kernel through the host register protocol — the
//! fifty-line tour of the public API.
//!
//!   cargo run --release --example quickstart
use prins::controller::kernels::KernelId;
use prins::controller::registers::Status;
use prins::host::PrinsDevice;
use prins::workloads::synth_hist_samples;

fn main() {
    // 1. a PRINS device: 64Ki rows of 64-bit RCAM storage
    let device = PrinsDevice::new(1 << 16, 64);

    // 2. the dataset lives in the storage (paper §5.3: "the datasets on
    //    which PRINS operates must reside in PRINS")
    let samples = synth_hist_samples(50_000, 42);
    device.load_samples_for_histogram(&samples);

    // 3. trigger the histogram kernel by ID and poll the status register
    let status = device.run_kernel(KernelId::Histogram, &[], &[]);
    assert_eq!(status, Status::Done);

    // 4. read results + the performance counters
    let out = device.take_outputs();
    let top = out
        .u64s
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .unwrap();
    println!("histogram over {} samples:", samples.len());
    println!("  hottest bin       : {} ({} samples)", top.0, top.1);
    println!("  device cycles     : {} (independent of sample count!)", out.cycles);
    println!(
        "  device time@500MHz: {:.2} µs",
        out.cycles as f64 / 500e6 * 1e6
    );
    println!("  energy            : {:.2} nJ", out.energy_j * 1e9);
}
