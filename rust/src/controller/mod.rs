//! The PRINS controller (paper Fig. 4, §3.3): issues associative
//! instructions to the daisy-chained RCAM modules, manages the key/mask
//! broadcast, collects reduction-tree outputs into its data buffer, and
//! exposes kernel dispatch to the host interface.

pub mod kernels;
pub mod read;
pub mod registers;

use crate::isa::{Instr, Program};
use crate::rcam::{DeviceModel, EnergyLedger, PrinsArray};

/// Execution statistics for one program/kernel invocation.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExecStats {
    /// Modeled device cycles elapsed in the stats window.
    pub cycles: u64,
    /// Instructions executed (compare + write + read + reduce + tag ops).
    pub instructions: u64,
    /// Compare+write microcode passes (equals the compare count).
    pub passes: u64,
    /// Energy-event counters accumulated in the window.
    pub ledger: EnergyLedger,
}

impl ExecStats {
    /// Stats of everything `array` executed since the `(cycles, ledger)`
    /// snapshot — the shared window arithmetic behind the controller's
    /// kernel windows ([`Controller::begin_stats`]/[`Controller::stats`])
    /// and the kernels' load-phase accounting (`XKernel::load_stats`).
    pub fn since(array: &PrinsArray, cycles0: u64, ledger0: &EnergyLedger) -> ExecStats {
        let ledger = array.ledger().minus(ledger0);
        ExecStats {
            cycles: array.cycles - cycles0,
            instructions: ledger.n_compare
                + ledger.n_write
                + ledger.n_read
                + ledger.n_reduce
                + ledger.n_tag_op,
            passes: ledger.n_compare,
            ledger,
        }
    }

    /// Wall-clock seconds under `dev`'s clock.
    pub fn runtime_s(&self, dev: &DeviceModel) -> f64 {
        dev.cycles_to_seconds(self.cycles)
    }

    /// Total energy \[J\]: dynamic events + controller static power.
    pub fn energy_j(&self, dev: &DeviceModel) -> f64 {
        self.ledger.total_energy_j(dev, self.cycles)
    }

    /// Average power \[W\] over the window.
    pub fn avg_power_w(&self, dev: &DeviceModel) -> f64 {
        self.ledger.avg_power_w(dev, self.cycles)
    }
}

/// The controller: owns the array, executes programs, buffers results.
///
/// Reduction-tree outputs and `read`/`if_match` results are pushed into
/// `buffer` in program order (the hardware's "data buffer, which stores
/// the reduction tree outputs", §3.3). A `read` that finds no tagged row
/// pushes `u64::MAX` as a sentinel (hardware would raise an exception
/// status; see `host::registers`).
pub struct Controller {
    /// The daisy-chained RCAM array this controller drives.
    pub array: PrinsArray,
    /// Data buffer: reduction/read/if_match results in program order.
    pub buffer: Vec<u64>,
    /// Cycle/ledger snapshot at the last `begin_stats` call.
    stats_cycles0: u64,
    stats_ledger0: EnergyLedger,
}

/// Sentinel pushed by a `read` that found no tagged row.
pub const READ_NO_MATCH: u64 = u64::MAX;

impl Controller {
    /// A controller owning `array`, with an empty data buffer.
    pub fn new(array: PrinsArray) -> Self {
        let l0 = array.ledger();
        let c0 = array.cycles;
        Controller {
            array,
            buffer: Vec::new(),
            stats_cycles0: c0,
            stats_ledger0: l0,
        }
    }

    /// The array's device model (timing/energy constants).
    pub fn device(&self) -> &DeviceModel {
        &self.array.device
    }

    /// Reset the stats window (kernel start).
    pub fn begin_stats(&mut self) {
        self.stats_cycles0 = self.array.cycles;
        self.stats_ledger0 = self.array.ledger();
    }

    /// Stats accumulated since the last `begin_stats`.
    pub fn stats(&self) -> ExecStats {
        ExecStats::since(&self.array, self.stats_cycles0, &self.stats_ledger0)
    }

    /// Execute one instruction; results (read/reduce/if_match) append to
    /// the data buffer.
    pub fn step(&mut self, instr: &Instr) {
        match instr {
            Instr::Compare(p) => self.array.compare(p),
            Instr::Write(p) => self.array.write(p),
            Instr::Read { base, width } => {
                let v = self
                    .array
                    .read_first(*base, *width)
                    .unwrap_or(READ_NO_MATCH);
                self.buffer.push(v);
            }
            Instr::IfMatch => {
                let m = self.array.if_match();
                self.buffer.push(m as u64);
            }
            Instr::FirstMatch => {
                self.array.first_match();
            }
            Instr::ReduceCount => {
                let n = self.array.count_tags();
                self.buffer.push(n);
            }
            Instr::ReduceField { col } => {
                let n = self.array.count_tags_and_col(*col);
                self.buffer.push(n);
            }
            Instr::SetTagsAll => self.array.set_tags_all(),
            Instr::ShiftTagsUp(h) => self.array.shift_tags_up(*h as usize),
            Instr::ShiftTagsDown(h) => self.array.shift_tags_down(*h as usize),
            Instr::ClearColumns { base, width } => {
                self.array.clear_columns(*base, *width)
            }
        }
    }

    /// Execute a straight-line program; returns the data-buffer slice it
    /// produced.
    ///
    /// On a threaded array backend the program is split into maximal
    /// data-parallel *spans* (`Program::spans`), each dispatched to the
    /// worker pool as one unit — per-instruction barriers would drown
    /// the parallelism win. Serializing instructions (reads, match
    /// queries, reductions, shifts) step one at a time between spans, so
    /// buffer contents, cycles, and ledgers are identical to the serial
    /// path (DESIGN.md §5).
    pub fn execute(&mut self, prog: &Program) -> &[u64] {
        let start = self.buffer.len();
        self.run_program(prog);
        &self.buffer[start..]
    }

    /// Execute and drain the produced buffer values.
    pub fn execute_collect(&mut self, prog: &Program) -> Vec<u64> {
        let start = self.buffer.len();
        self.run_program(prog);
        self.buffer.split_off(start)
    }

    /// Debug path of [`Controller::execute`]: run the static analyzer
    /// (`crate::analysis::check_program`) over `prog` against this
    /// array's geometry first and refuse to dispatch a program with any
    /// diagnostic. Costs one analysis pass per call — use it in tests
    /// and debugging sessions, not on the measured hot path.
    pub fn execute_checked(&mut self, prog: &Program) -> crate::error::Result<&[u64]> {
        let shape = crate::analysis::ArrayShape::of(&self.array);
        let diags = crate::analysis::check_program(prog, &shape);
        if let Some(first) = diags.first() {
            crate::error::bail!(
                "program rejected by static analysis ({} diagnostic(s)); first: {first}",
                diags.len()
            );
        }
        Ok(self.execute(prog))
    }

    fn run_program(&mut self, prog: &Program) {
        if self.array.is_threaded() {
            for span in prog.spans() {
                if span.data_parallel {
                    self.array.execute_span(span.instrs);
                } else {
                    for instr in span.instrs {
                        self.step(instr);
                    }
                }
            }
        } else {
            for instr in &prog.instrs {
                self.step(instr);
            }
        }
    }

    /// Drop all buffered results.
    pub fn clear_buffer(&mut self) {
        self.buffer.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Field;
    use crate::rcam::PrinsArray;

    fn controller(rows: usize, width: usize) -> Controller {
        Controller::new(PrinsArray::single(rows, width))
    }

    #[test]
    fn execute_collects_results_in_order() {
        let mut c = controller(64, 16);
        let f = Field::new(0, 8);
        for r in 0..10 {
            c.array.load_row_bits(r, 0, 8, 0x3C);
        }
        c.array.load_row_bits(3, 8, 8, 0x7F);
        let mut p = Program::new();
        p.compare_field(f, 0x3C);
        p.push(Instr::ReduceCount);
        p.push(Instr::IfMatch);
        p.push(Instr::Read { base: 8, width: 8 });
        let out = c.execute_collect(&p);
        assert_eq!(out, vec![10, 1, 0]); // row 0 is first match; its cols 8.. are 0
        c.array.compare(&f.pattern(0x3C));
        c.array.first_match(); // row 0
        assert_eq!(c.array.read_first(8, 8), Some(0));
    }

    #[test]
    fn read_without_match_pushes_sentinel() {
        let mut c = controller(16, 8);
        let mut p = Program::new();
        p.compare_field(Field::new(0, 4), 0xF);
        p.push(Instr::Read { base: 0, width: 4 });
        let out = c.execute_collect(&p);
        assert_eq!(out, vec![READ_NO_MATCH]);
    }

    #[test]
    fn threaded_program_execution_matches_serial() {
        use crate::rcam::ExecBackend;
        let build = |backend| {
            let mut c = Controller::new(PrinsArray::new(2, 50, 16).with_backend(backend));
            for r in 0..100 {
                c.array.load_row_bits(r, 0, 8, (r % 19) as u64);
            }
            c
        };
        let f = Field::new(0, 8);
        let mut p = Program::new();
        p.compare_field(f, 5);
        p.write_field(Field::new(8, 4), 0xA);
        p.push(Instr::ReduceCount);
        p.push(Instr::IfMatch);
        p.push(Instr::Read { base: 8, width: 4 });
        p.push(Instr::ShiftTagsUp(3));
        p.push(Instr::ReduceCount);
        p.push(Instr::ClearColumns { base: 12, width: 2 });
        let mut s = build(ExecBackend::Serial);
        let out_s = s.execute_collect(&p);
        let mut t = build(ExecBackend::Threaded(4));
        let out_t = t.execute_collect(&p);
        assert_eq!(out_s, out_t, "data-buffer results");
        assert_eq!(s.array.cycles, t.array.cycles, "cycles");
        assert_eq!(s.array.ledger(), t.array.ledger(), "energy ledger");
    }

    #[test]
    fn stats_window_isolates_kernels() {
        let mut c = controller(64, 8);
        c.array.compare(&[(0, true)]);
        c.begin_stats();
        c.array.compare(&[(0, true)]);
        c.array.write(&[(1, true)]);
        let s = c.stats();
        assert_eq!(s.cycles, 3);
        assert_eq!(s.passes, 1);
        assert_eq!(s.ledger.n_compare, 1);
        assert_eq!(s.ledger.n_write, 1);
    }
}
