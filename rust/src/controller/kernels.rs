//! Kernel registry (paper §5.3: the host triggers a kernel by ID; the
//! controller holds the kernel's associative primitive sequence).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u64)]
pub enum KernelId {
    EuclideanDistance = 1,
    DotProduct = 2,
    Histogram = 3,
    Spmv = 4,
    Bfs = 5,
}

impl KernelId {
    pub fn from_u64(v: u64) -> Option<KernelId> {
        Some(match v {
            1 => KernelId::EuclideanDistance,
            2 => KernelId::DotProduct,
            3 => KernelId::Histogram,
            4 => KernelId::Spmv,
            5 => KernelId::Bfs,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelId::EuclideanDistance => "euclidean_distance",
            KernelId::DotProduct => "dot_product",
            KernelId::Histogram => "histogram",
            KernelId::Spmv => "spmv",
            KernelId::Bfs => "bfs",
        }
    }

    pub fn all() -> [KernelId; 5] {
        [
            KernelId::EuclideanDistance,
            KernelId::DotProduct,
            KernelId::Histogram,
            KernelId::Spmv,
            KernelId::Bfs,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_roundtrip() {
        for k in KernelId::all() {
            assert_eq!(KernelId::from_u64(k as u64), Some(k));
        }
        assert_eq!(KernelId::from_u64(0), None);
        assert_eq!(KernelId::from_u64(99), None);
    }
}
