//! Kernel registry (paper §5.3: the host triggers a kernel by ID; the
//! controller holds the kernel's associative primitive sequence).

/// Kernel identifier the host writes into the kernel-ID register to
/// trigger execution (paper §5.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u64)]
pub enum KernelId {
    /// Fully associative Euclidean distance (Algorithm 1, Fig. 7).
    EuclideanDistance = 1,
    /// Fully associative dot product (Algorithm 2, Fig. 8).
    DotProduct = 2,
    /// 256-bin associative histogram (Algorithm 3, Fig. 9).
    Histogram = 3,
    /// Sparse matrix-vector multiply (Algorithm 4, Fig. 10).
    Spmv = 4,
    /// Breadth-first search (Algorithm 5, Fig. 11).
    Bfs = 5,
}

impl KernelId {
    /// Decode a kernel-ID register value; `None` for unknown ids.
    pub fn from_u64(v: u64) -> Option<KernelId> {
        Some(match v {
            1 => KernelId::EuclideanDistance,
            2 => KernelId::DotProduct,
            3 => KernelId::Histogram,
            4 => KernelId::Spmv,
            5 => KernelId::Bfs,
            _ => return None,
        })
    }

    /// Stable lower-case name (artifact manifest / reporting key).
    pub fn name(&self) -> &'static str {
        match self {
            KernelId::EuclideanDistance => "euclidean_distance",
            KernelId::DotProduct => "dot_product",
            KernelId::Histogram => "histogram",
            KernelId::Spmv => "spmv",
            KernelId::Bfs => "bfs",
        }
    }

    /// Every kernel, in id order.
    pub fn all() -> [KernelId; 5] {
        [
            KernelId::EuclideanDistance,
            KernelId::DotProduct,
            KernelId::Histogram,
            KernelId::Spmv,
            KernelId::Bfs,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_roundtrip() {
        for k in KernelId::all() {
            assert_eq!(KernelId::from_u64(k as u64), Some(k));
        }
        assert_eq!(KernelId::from_u64(0), None);
        assert_eq!(KernelId::from_u64(99), None);
    }
}
