//! Host-visible memory-mapped register file (paper §5.3): "The host uses
//! memory-mapped registers to communicate with PRINS ... transfers
//! parameters such as data starting addresses, kernel configuration
//! starting addresses, and kernel ID and triggers kernel execution ...
//! PRINS can notify the host of its execution status by writing to the
//! status registers. The host periodically polls these memory-mapped
//! status registers."

use std::sync::atomic::{AtomicU64, Ordering};

/// Device execution status as read from the status register.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u64)]
pub enum Status {
    /// No kernel triggered yet.
    Idle = 0,
    /// A kernel is executing; storage is not host-accessible.
    Running = 1,
    /// The last kernel completed successfully.
    Done = 2,
    /// The last kernel failed (see the error-code register).
    Error = 3,
}

impl Status {
    /// Decode a raw status-register value (unknown values read as
    /// [`Status::Error`]).
    pub fn from_u64(v: u64) -> Status {
        match v {
            0 => Status::Idle,
            1 => Status::Running,
            2 => Status::Done,
            _ => Status::Error,
        }
    }
}

/// Number of host-writable parameter registers.
pub const N_PARAMS: usize = 16;
/// Number of device-writable result registers.
pub const N_RESULTS: usize = 8;

/// The register file. All fields are atomics: the host side polls while
/// the device side executes, without any lock (the paper: "The status
/// register read by the host does not intervene in PRINS operation").
#[derive(Debug, Default)]
pub struct RegisterFile {
    /// Kernel to execute ([`crate::controller::kernels::KernelId`]).
    pub kernel_id: AtomicU64,
    /// Kernel parameters, host-written before trigger.
    pub params: [AtomicU64; N_PARAMS],
    /// Execution status ([`Status`]), device-written.
    pub status: AtomicU64,
    /// Scalar results, device-written before completion.
    pub results: [AtomicU64; N_RESULTS],
    /// Error code of the last failed kernel (0 on success).
    pub error_code: AtomicU64,
    /// monotonically increasing completion counter (lets the host detect
    /// back-to-back completions of the same kernel id)
    pub completions: AtomicU64,
}

impl RegisterFile {
    /// A register file with everything zeroed ([`Status::Idle`]).
    pub fn new() -> Self {
        Self::default()
    }

    // --- host side ---

    /// Host: write parameter register `i`.
    pub fn write_param(&self, i: usize, v: u64) {
        self.params[i].store(v, Ordering::Release);
    }

    /// Host: set the kernel id and flip status to Running.
    pub fn trigger(&self, kernel_id: u64) {
        self.kernel_id.store(kernel_id, Ordering::Release);
        self.status.store(Status::Running as u64, Ordering::Release);
    }

    /// Host: read the status register (non-intrusive).
    pub fn poll_status(&self) -> Status {
        Status::from_u64(self.status.load(Ordering::Acquire))
    }

    /// Host: read result register `i` after completion.
    pub fn read_result(&self, i: usize) -> u64 {
        self.results[i].load(Ordering::Acquire)
    }

    /// Busy-poll until the device leaves Running (the paper's polling
    /// protocol; the host "can accurately estimate the execution time ...
    /// thereby polling status when the kernel execution is about to
    /// finish" — we just spin-yield).
    pub fn wait_done(&self) -> Status {
        loop {
            let s = self.poll_status();
            if s != Status::Running {
                return s;
            }
            std::thread::yield_now();
        }
    }

    // --- device side ---

    /// Device: read parameter register `i`.
    pub fn read_param(&self, i: usize) -> u64 {
        self.params[i].load(Ordering::Acquire)
    }

    /// Device: read the triggered kernel id.
    pub fn kernel(&self) -> u64 {
        self.kernel_id.load(Ordering::Acquire)
    }

    /// Device: write result register `i`.
    pub fn write_result(&self, i: usize, v: u64) {
        self.results[i].store(v, Ordering::Release);
    }

    /// Device: publish completion — error code, completion counter, then
    /// the final status (Done/Error), in that order.
    pub fn complete(&self, ok: bool, error_code: u64) {
        self.error_code.store(error_code, Ordering::Release);
        self.completions.fetch_add(1, Ordering::AcqRel);
        self.status.store(
            if ok { Status::Done } else { Status::Error } as u64,
            Ordering::Release,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn host_device_handshake_across_threads() {
        let regs = Arc::new(RegisterFile::new());
        let dev = regs.clone();
        let worker = std::thread::spawn(move || {
            while dev.poll_status() != Status::Running {
                std::thread::yield_now();
            }
            assert_eq!(dev.kernel(), 42);
            let p = dev.read_param(0);
            dev.write_result(0, p * 2);
            dev.complete(true, 0);
        });
        regs.write_param(0, 21);
        regs.trigger(42);
        assert_eq!(regs.wait_done(), Status::Done);
        assert_eq!(regs.read_result(0), 42);
        worker.join().unwrap();
    }

    #[test]
    fn error_path() {
        let regs = RegisterFile::new();
        regs.trigger(1);
        regs.complete(false, 7);
        assert_eq!(regs.poll_status(), Status::Error);
        assert_eq!(regs.error_code.load(Ordering::Acquire), 7);
    }
}
