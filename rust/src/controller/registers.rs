//! Host-visible memory-mapped register file (paper §5.3): "The host uses
//! memory-mapped registers to communicate with PRINS ... transfers
//! parameters such as data starting addresses, kernel configuration
//! starting addresses, and kernel ID and triggers kernel execution ...
//! PRINS can notify the host of its execution status by writing to the
//! status registers. The host periodically polls these memory-mapped
//! status registers."

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u64)]
pub enum Status {
    Idle = 0,
    Running = 1,
    Done = 2,
    Error = 3,
}

impl Status {
    pub fn from_u64(v: u64) -> Status {
        match v {
            0 => Status::Idle,
            1 => Status::Running,
            2 => Status::Done,
            _ => Status::Error,
        }
    }
}

pub const N_PARAMS: usize = 16;
pub const N_RESULTS: usize = 8;

/// The register file. All fields are atomics: the host side polls while
/// the device side executes, without any lock (the paper: "The status
/// register read by the host does not intervene in PRINS operation").
#[derive(Debug, Default)]
pub struct RegisterFile {
    pub kernel_id: AtomicU64,
    pub params: [AtomicU64; N_PARAMS],
    pub status: AtomicU64,
    pub results: [AtomicU64; N_RESULTS],
    pub error_code: AtomicU64,
    /// monotonically increasing completion counter (lets the host detect
    /// back-to-back completions of the same kernel id)
    pub completions: AtomicU64,
}

impl RegisterFile {
    pub fn new() -> Self {
        Self::default()
    }

    // --- host side ---

    pub fn write_param(&self, i: usize, v: u64) {
        self.params[i].store(v, Ordering::Release);
    }

    pub fn trigger(&self, kernel_id: u64) {
        self.kernel_id.store(kernel_id, Ordering::Release);
        self.status.store(Status::Running as u64, Ordering::Release);
    }

    pub fn poll_status(&self) -> Status {
        Status::from_u64(self.status.load(Ordering::Acquire))
    }

    pub fn read_result(&self, i: usize) -> u64 {
        self.results[i].load(Ordering::Acquire)
    }

    /// Busy-poll until the device leaves Running (the paper's polling
    /// protocol; the host "can accurately estimate the execution time ...
    /// thereby polling status when the kernel execution is about to
    /// finish" — we just spin-yield).
    pub fn wait_done(&self) -> Status {
        loop {
            let s = self.poll_status();
            if s != Status::Running {
                return s;
            }
            std::thread::yield_now();
        }
    }

    // --- device side ---

    pub fn read_param(&self, i: usize) -> u64 {
        self.params[i].load(Ordering::Acquire)
    }

    pub fn kernel(&self) -> u64 {
        self.kernel_id.load(Ordering::Acquire)
    }

    pub fn write_result(&self, i: usize, v: u64) {
        self.results[i].store(v, Ordering::Release);
    }

    pub fn complete(&self, ok: bool, error_code: u64) {
        self.error_code.store(error_code, Ordering::Release);
        self.completions.fetch_add(1, Ordering::AcqRel);
        self.status.store(
            if ok { Status::Done } else { Status::Error } as u64,
            Ordering::Release,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn host_device_handshake_across_threads() {
        let regs = Arc::new(RegisterFile::new());
        let dev = regs.clone();
        let worker = std::thread::spawn(move || {
            while dev.poll_status() != Status::Running {
                std::thread::yield_now();
            }
            assert_eq!(dev.kernel(), 42);
            let p = dev.read_param(0);
            dev.write_result(0, p * 2);
            dev.complete(true, 0);
        });
        regs.write_param(0, 21);
        regs.trigger(42);
        assert_eq!(regs.wait_done(), Status::Done);
        assert_eq!(regs.read_result(0), 42);
        worker.join().unwrap();
    }

    #[test]
    fn error_path() {
        let regs = RegisterFile::new();
        regs.trigger(1);
        regs.complete(false, 7);
        assert_eq!(regs.poll_status(), Status::Error);
        assert_eq!(regs.error_code.load(Ordering::Acquire), 7);
    }
}
