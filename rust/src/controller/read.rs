//! Read-only query execution for shared-read concurrency (DESIGN.md
//! §Serving): a [`ReadCursor`] executes the read-only microprograms of a
//! write-free kernel query over a *shared* `&PrinsArray` borrow, so many
//! cursors — one per concurrent reader — can run over the same resident
//! rows at once.
//!
//! The cursor privately owns everything a query would otherwise mutate:
//! per-module tag registers, a local cycle counter, a local energy
//! ledger, and — for microcoded kernels — a copy-on-write **scratch
//! overlay** of bit-column planes. Tag computation reuses the exact
//! word-blocked match function behind `RcamModule::compare`, and every
//! cycle/ledger charge mirrors the mutating path counter-for-counter, so
//! collected outputs and windowed [`ExecStats`] are bit-identical to a
//! [`Controller`] running the same programs on a fresh stats window —
//! while the primary array's cycles, ledger, tags, wear counters and
//! stored planes stay untouched.
//!
//! Two execution surfaces, two contracts:
//!
//!   * [`ReadCursor::execute_collect`] — strictly read-only
//!     (`Compare`/`ReduceCount` only); anything else is refused. This is
//!     the path of the compare-only kernels (hist, search).
//!   * [`ReadCursor::execute_overlay`] — additionally accepts the
//!     data-parallel mutators (`Write`, `ClearColumns`, `SetTagsAll`),
//!     landing every written plane in the cursor's private overlay
//!     instead of the shared array. This is the path of the microcoded
//!     fp kernels (ed, dp), whose queries write only *scratch* columns:
//!     the overlay makes those writes cursor-local, so any number of
//!     concurrent overlay cursors can run over one resident dataset at
//!     once. The `prins verify` overlay contract (rule C03) proves
//!     statically that an overlay kernel's plan never writes a *stored*
//!     column, which is what makes overlay outputs equal exclusive
//!     outputs: queries never read a scratch column before writing it.
//!     Overlay writes charge the exact mutating-path cycles and ledger
//!     events but no wear — wear counters stay frozen at the load
//!     anchor, and wear is never part of a wire reply.
//!
//! [`Controller`]: crate::controller::Controller

use super::ExecStats;
use crate::analysis::{ArrayShape, QueryPlan};
use crate::error::{bail, Result};
use crate::isa::{Instr, Program};
use crate::rcam::bitvec::WORD_BITS;
use crate::rcam::device::{CYCLES_COMPARE, CYCLES_REDUCE_ISSUE, CYCLES_TAG_OP, CYCLES_WRITE};
use crate::rcam::module::compare_tags_into;
use crate::rcam::{BitVec, EnergyLedger, Pattern, PrinsArray};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Compiled-program cache (DESIGN.md §Batching & program cache): memoizes
/// [`QueryPlan`] synthesis keyed by the shard array's [`ArrayShape`] plus
/// a kernel-chosen canonical *params-class* string, so repeat resident
/// queries skip microprogram synthesis entirely and re-execute the cached
/// plan. One cache lives inside each `Resident<K>` (the kernel identity
/// is therefore implicit in the owner), shared by the exclusive
/// `query_shards` path and the concurrent shared-read `read_shards` path
/// alike — the map is behind a [`Mutex`] and plans are handed out as
/// [`Arc`]s, so any number of concurrent readers can execute one cached
/// plan at once.
///
/// Invalidation is the owner's job (the rules live with the server):
/// LOAD/DROP recreate or destroy the owning `Resident` and its cache with
/// it; arming the fault layer (`FAULTS`) and storage remap both call
/// [`ProgramCache::invalidate`], forcing re-synthesis against the new
/// array state. Hit/miss counters are cumulative across invalidations so
/// a forced re-synthesis is observable as a miss delta.
#[derive(Debug, Default)]
pub struct ProgramCache {
    plans: Mutex<HashMap<(ArrayShape, String), Arc<QueryPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ProgramCache {
    /// An empty cache with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// The cached plan for `(shape, key)`, synthesizing (and counting a
    /// miss) via `synth` on first use. The map lock is held across
    /// synthesis so concurrent shards racing for the same key synthesize
    /// once — the losers block briefly and then count hits.
    pub fn get_or_insert(
        &self,
        shape: ArrayShape,
        key: &str,
        synth: impl FnOnce() -> QueryPlan,
    ) -> Arc<QueryPlan> {
        let mut plans = self.plans.lock().expect("program cache poisoned");
        if let Some(plan) = plans.get(&(shape, key.to_string())) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(synth());
        plans.insert((shape, key.to_string()), Arc::clone(&plan));
        plan
    }

    /// Drop every cached plan (counters survive, so forced re-synthesis
    /// shows up as a miss delta). Called on FAULTS arming and storage
    /// remap; LOAD/DROP destroy the whole cache with its owner.
    pub fn invalidate(&self) {
        self.plans.lock().expect("program cache poisoned").clear();
    }

    /// Cumulative `(hits, misses)` since the cache was created.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Number of distinct `(shape, params-class)` plans currently held.
    pub fn len(&self) -> usize {
        self.plans.lock().expect("program cache poisoned").len()
    }

    /// True when no plan is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One concurrent reader's execution context over a borrowed array. See
/// the module doc for the bit-equality contract with [`Controller`] and
/// for the two execution surfaces (read-only vs scratch-overlay).
///
/// [`Controller`]: crate::controller::Controller
pub struct ReadCursor<'a> {
    array: &'a PrinsArray,
    /// Cursor-private tag registers, one per module.
    tags: Vec<BitVec>,
    /// Copy-on-write scratch planes, one map per module: bit-column →
    /// cursor-private plane, cloned from storage on first overlay write.
    /// Compares and readout resolve overlay-first, so a query sees its
    /// own scratch writes while every other reader sees the untouched
    /// stored planes.
    overlay: Vec<HashMap<u16, BitVec>>,
    cycles: u64,
    ledger: EnergyLedger,
}

impl<'a> ReadCursor<'a> {
    /// A cursor over `array` with cleared private tags, an empty scratch
    /// overlay and a zeroed stats window.
    pub fn new(array: &'a PrinsArray) -> Self {
        ReadCursor {
            array,
            tags: array
                .modules()
                .iter()
                .map(|m| BitVec::zeros(m.rows()))
                .collect(),
            overlay: vec![HashMap::new(); array.n_modules()],
            cycles: 0,
            ledger: EnergyLedger::default(),
        }
    }

    /// Compare: identical tags and charges to `PrinsArray::compare`,
    /// landed in the cursor instead of the array. Pattern columns the
    /// overlay has materialized are matched against the cursor-private
    /// planes; everything else reads the shared stored planes.
    pub fn compare(&mut self, pattern: &Pattern) {
        for (mi, (m, tags)) in self.array.modules().iter().zip(&mut self.tags).enumerate() {
            let ov = &self.overlay[mi];
            if ov.is_empty() {
                compare_tags_into(m.storage(), pattern, tags);
            } else {
                // overlay-resolving twin of `compare_tags_into`: same
                // word-blocked pass, each plane slice picked
                // overlay-first
                let nwords = tags.words().len();
                let tail = m.rows() % WORD_BITS;
                let tail_mask = if tail == 0 { u64::MAX } else { (1u64 << tail) - 1 };
                let planes: Vec<&[u64]> = pattern
                    .iter()
                    .map(|&(col, _)| match ov.get(&col) {
                        Some(p) => p.words(),
                        None => m.storage().plane(col as usize).words(),
                    })
                    .collect();
                let tw = tags.words_mut();
                for w in 0..nwords {
                    let mut t = if w + 1 == nwords { tail_mask } else { u64::MAX };
                    for (&(_, bit), plane) in pattern.iter().zip(&planes) {
                        let p = plane[w];
                        t &= if bit { p } else { !p };
                    }
                    tw[w] = t;
                }
            }
            self.ledger.n_compare += 1;
            self.ledger.compare_bit_events += (m.width() * m.rows()) as u128;
        }
        self.cycles += CYCLES_COMPARE;
    }

    /// Overlay write: identical tag-gated plane updates and charges to
    /// `PrinsArray::write`, landed in the cursor's private overlay
    /// instead of the shared stored planes. Target columns materialize
    /// copy-on-write (cloned from storage on first touch). No wear is
    /// recorded — the shared array's wear counters stay frozen.
    pub fn write(&mut self, pattern: &Pattern) {
        for (mi, m) in self.array.modules().iter().enumerate() {
            let ov = &mut self.overlay[mi];
            for &(col, _) in pattern {
                ov.entry(col)
                    .or_insert_with(|| m.storage().plane(col as usize).clone());
            }
            let tags = &self.tags[mi];
            let mut tagged: u64 = 0;
            for (w, &t) in tags.words().iter().enumerate() {
                if t == 0 {
                    continue;
                }
                tagged += t.count_ones() as u64;
                for &(col, bit) in pattern {
                    let pw = &mut ov.get_mut(&col).expect("materialized above").words_mut()[w];
                    if bit {
                        *pw |= t;
                    } else {
                        *pw &= !t;
                    }
                }
            }
            self.ledger.n_write += 1;
            self.ledger.write_bit_events += (pattern.len() as u128) * (tagged as u128);
        }
        self.cycles += CYCLES_WRITE;
    }

    /// Overlay bulk clear: identical charges to
    /// `PrinsArray::clear_columns`, landing all-zero planes in the
    /// overlay (no storage clone needed — the result is all zeros
    /// regardless of what was stored).
    pub fn clear_columns(&mut self, base: u16, width: u16) {
        for (mi, m) in self.array.modules().iter().enumerate() {
            let rows = m.rows();
            let ov = &mut self.overlay[mi];
            for col in base..base + width {
                ov.insert(col, BitVec::zeros(rows));
            }
            self.ledger.n_write += 1;
            self.ledger.write_bit_events += (width as u128) * (rows as u128);
        }
        self.cycles += CYCLES_WRITE;
    }

    /// Tag every row in the cursor's private tag registers: identical
    /// charges to `PrinsArray::set_tags_all`.
    pub fn set_tags_all(&mut self) {
        for tags in &mut self.tags {
            tags.fill(true);
            self.ledger.n_tag_op += 1;
        }
        self.cycles += CYCLES_TAG_OP;
    }

    /// Reduction-tree count over the cursor's private tags: identical
    /// result and charges to `PrinsArray::count_tags`.
    pub fn reduce_count(&mut self) -> u64 {
        let mut n = 0u64;
        for (m, tags) in self.array.modules().iter().zip(&self.tags) {
            n += tags.count_ones();
            self.ledger.n_reduce += 1;
            self.ledger.reduce_bit_events +=
                (m.rows() as u128) * (m.tree_levels() as u128);
        }
        self.cycles += CYCLES_REDUCE_ISSUE;
        n
    }

    /// Execute a read-only program (`Compare`/`ReduceCount` only),
    /// collecting reduction results in program order — the shared-read
    /// twin of `Controller::execute_collect`.
    pub fn execute_collect(&mut self, prog: &Program) -> Result<Vec<u64>> {
        let mut out = Vec::new();
        for instr in &prog.instrs {
            match instr {
                Instr::Compare(p) => self.compare(p),
                Instr::ReduceCount => out.push(self.reduce_count()),
                other => bail!(
                    "shared-read cursor refuses non-read-only instruction {other:?}"
                ),
            }
        }
        Ok(out)
    }

    /// Execute a microcoded program through the scratch overlay: the
    /// data-parallel instruction set (`Compare`, `Write`, `SetTagsAll`,
    /// `ClearColumns`) plus `ReduceCount`, with every write landing in
    /// the cursor's private overlay. Reduction results are collected in
    /// program order. Serializing instructions with no cursor-local form
    /// (`Read`, `IfMatch`, `FirstMatch`, `ReduceField`, tag shifts) are
    /// refused — the fp microcode pipeline never emits them, and the
    /// `prins verify` overlay contract proves plans clean before they
    /// reach a cursor.
    pub fn execute_overlay(&mut self, prog: &Program) -> Result<Vec<u64>> {
        let mut out = Vec::new();
        for instr in &prog.instrs {
            match instr {
                Instr::Compare(p) => self.compare(p),
                Instr::Write(p) => self.write(p),
                Instr::SetTagsAll => self.set_tags_all(),
                Instr::ClearColumns { base, width } => self.clear_columns(*base, *width),
                Instr::ReduceCount => out.push(self.reduce_count()),
                other => bail!(
                    "overlay cursor refuses instruction {other:?} (no cursor-local form)"
                ),
            }
        }
        Ok(out)
    }

    /// Uncharged row-field readout, overlay-first: bit `i` of the result
    /// is column `base + i` of `row` — the cursor's private plane where
    /// the overlay has one, the shared stored plane otherwise. The
    /// shared-read twin of `PrinsArray::fetch_row_bits` (readout slicing
    /// of microcoded query results).
    pub fn fetch_row_bits(&self, row: usize, base: usize, width: usize) -> u64 {
        let rpm = self.array.total_rows() / self.array.n_modules();
        let (mi, r) = (row / rpm, row % rpm);
        let m = &self.array.modules()[mi];
        let ov = &self.overlay[mi];
        let mut v = 0u64;
        for i in 0..width {
            let col = (base + i) as u16;
            let bit = match ov.get(&col) {
                Some(plane) => plane.get(r),
                None => m.storage().plane(col as usize).get(r),
            };
            if bit {
                v |= 1 << i;
            }
        }
        v
    }

    /// Charge cycles outside any program (pipelined reduction-tree
    /// drains — a query plan's `extra_cycles`).
    pub fn add_cycles(&mut self, n: u64) {
        self.cycles += n;
    }

    /// The cursor's windowed stats. `passes` is pinned to 0 exactly as
    /// the write-free kernels pin it on their query paths (no
    /// compare+write microcode passes).
    pub fn stats(&self) -> ExecStats {
        ExecStats {
            passes: 0,
            ..self.stats_microcoded()
        }
    }

    /// Snapshot of the cursor's cycle/ledger counters — the window
    /// anchor for [`ReadCursor::stats_since`].
    pub fn mark(&self) -> (u64, EnergyLedger) {
        (self.cycles, self.ledger.clone())
    }

    /// Stats accumulated since `mark`, in the [`ReadCursor::stats`]
    /// shape (`passes` pinned to 0). The charge counters are purely
    /// additive, so a query executed mid-cursor reports through this
    /// window exactly what it would report on a fresh cursor — the
    /// invariant the cross-connection query coalescer relies on to keep
    /// per-member replies byte-identical to solo dispatch.
    pub fn stats_since(&self, mark: &(u64, EnergyLedger)) -> ExecStats {
        let ledger = self.ledger.minus(&mark.1);
        ExecStats {
            cycles: self.cycles - mark.0,
            instructions: ledger.n_compare
                + ledger.n_write
                + ledger.n_read
                + ledger.n_reduce
                + ledger.n_tag_op,
            passes: 0,
            ledger,
        }
    }

    /// The cursor's windowed stats for the microcoded overlay path:
    /// identical to [`ReadCursor::stats`] except `passes` counts
    /// compares, exactly as `ExecStats::since` derives it for the
    /// mutating [`Controller`] path the fp kernels use.
    ///
    /// [`Controller`]: crate::controller::Controller
    pub fn stats_microcoded(&self) -> ExecStats {
        ExecStats {
            cycles: self.cycles,
            instructions: self.ledger.n_compare
                + self.ledger.n_write
                + self.ledger.n_read
                + self.ledger.n_reduce
                + self.ledger.n_tag_op,
            passes: self.ledger.n_compare,
            ledger: self.ledger.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::isa::Field;
    use crate::rcam::PrinsArray;

    fn loaded_array(n_modules: usize, rows_per_module: usize) -> PrinsArray {
        let mut a = PrinsArray::new(n_modules, rows_per_module, 16);
        for r in 0..a.total_rows() {
            a.load_row_bits(r, 0, 8, (r % 23) as u64);
        }
        a
    }

    fn probe_program() -> Program {
        let f = Field::new(0, 8);
        let mut p = Program::new();
        for v in [5u64, 13, 22, 1] {
            p.compare_field(f, v);
            p.push(Instr::ReduceCount);
        }
        p
    }

    #[test]
    fn cursor_matches_controller_bit_for_bit() {
        for (m, rpm) in [(1usize, 300usize), (3, 100)] {
            let array = loaded_array(m, rpm);
            let prog = probe_program();
            // reference: the mutating controller path on a fresh window
            let mut ctl = Controller::new(array.clone());
            ctl.begin_stats();
            let want = ctl.execute_collect(&prog);
            ctl.array.charge_reduction_latency();
            let want_stats = ctl.stats();
            // shared-read path
            let mut cur = ReadCursor::new(&array);
            let got = cur.execute_collect(&prog).unwrap();
            cur.add_cycles(array.reduction_latency_cycles());
            let stats = cur.stats();
            assert_eq!(got, want, "{m}x{rpm}: collected outputs");
            assert_eq!(stats.cycles, want_stats.cycles, "{m}x{rpm}: cycles");
            assert_eq!(stats.ledger, want_stats.ledger, "{m}x{rpm}: ledger");
            assert_eq!(stats.instructions, want_stats.instructions);
            assert_eq!(stats.passes, 0, "write-free query pins passes to 0");
        }
    }

    #[test]
    fn cursor_leaves_the_array_untouched() {
        let array = loaded_array(2, 64);
        let cycles0 = array.cycles;
        let ledger0 = array.ledger();
        let tags0: Vec<_> = array.modules().iter().map(|m| m.tags().clone()).collect();
        let mut cur = ReadCursor::new(&array);
        cur.execute_collect(&probe_program()).unwrap();
        assert_eq!(array.cycles, cycles0);
        assert_eq!(array.ledger(), ledger0);
        for (m, t0) in array.modules().iter().zip(&tags0) {
            assert_eq!(m.tags(), t0, "array tags mutated by a read cursor");
        }
    }

    /// A microcoded-style scratch program over the 16-bit test rows:
    /// stored data in columns 0..8, scratch in 8..16. Clears scratch,
    /// broadcasts into it, tag-gates more writes off stored bits, then
    /// compares over a stored/scratch mix and reduces — every overlay
    /// instruction class, with results depending on the written values.
    fn scratch_program() -> Program {
        let stored = Field::new(0, 8);
        let scratch = Field::new(8, 8);
        let mut p = Program::new();
        p.clear_field(scratch);
        p.push(Instr::SetTagsAll);
        p.write_field(scratch.slice(0, 4), 0x5);
        p.compare_field(stored.slice(0, 2), 1);
        p.write_field(scratch.slice(4, 4), 0xC);
        let mut probe = scratch.pattern(0xC5);
        probe.push((stored.col(0), true));
        p.push(Instr::Compare(probe));
        p.push(Instr::ReduceCount);
        p
    }

    #[test]
    fn overlay_matches_controller_bit_for_bit() {
        for (m, rpm) in [(1usize, 300usize), (3, 100)] {
            let mut array = loaded_array(m, rpm);
            // dirty the scratch columns so equality cannot depend on a
            // pristine all-zero scratch state
            for r in 0..array.total_rows() {
                array.load_row_bits(r, 8, 8, (r % 7) as u64);
            }
            let prog = scratch_program();
            // reference: the mutating controller path on a fresh window
            let mut ctl = Controller::new(array.clone());
            ctl.begin_stats();
            let want = ctl.execute_collect(&prog);
            let want_stats = ctl.stats();
            let want_rows: Vec<u64> = (0..array.total_rows())
                .map(|r| ctl.array.fetch_row_bits(r, 8, 8))
                .collect();
            // overlay path over the shared borrow
            let mut cur = ReadCursor::new(&array);
            let got = cur.execute_overlay(&prog).unwrap();
            let stats = cur.stats_microcoded();
            assert_eq!(got, want, "{m}x{rpm}: collected outputs");
            assert_eq!(stats.cycles, want_stats.cycles, "{m}x{rpm}: cycles");
            assert_eq!(stats.ledger, want_stats.ledger, "{m}x{rpm}: ledger");
            assert_eq!(stats.instructions, want_stats.instructions);
            assert_eq!(stats.passes, want_stats.passes, "{m}x{rpm}: passes");
            for (r, want_bits) in want_rows.iter().enumerate() {
                assert_eq!(
                    cur.fetch_row_bits(r, 8, 8),
                    *want_bits,
                    "{m}x{rpm}: overlay readout of row {r}"
                );
            }
        }
    }

    #[test]
    fn overlay_leaves_array_wear_and_planes_untouched() {
        let mut array = loaded_array(2, 64);
        array.enable_wear_tracking();
        let cycles0 = array.cycles;
        let ledger0 = array.ledger();
        let planes0: Vec<u64> = (0..array.total_rows())
            .map(|r| array.fetch_row_bits(r, 0, 16))
            .collect();
        let wear0: Vec<_> = array
            .modules()
            .iter()
            .map(|m| m.wear_counters().unwrap().to_vec())
            .collect();
        let mut cur = ReadCursor::new(&array);
        cur.execute_overlay(&scratch_program()).unwrap();
        assert_eq!(array.cycles, cycles0);
        assert_eq!(array.ledger(), ledger0);
        for (r, p0) in planes0.iter().enumerate() {
            assert_eq!(array.fetch_row_bits(r, 0, 16), *p0, "stored row {r} mutated");
        }
        for (m, w0) in array.modules().iter().zip(&wear0) {
            assert_eq!(
                m.wear_counters().unwrap(),
                &w0[..],
                "overlay writes must not advance wear counters"
            );
        }
    }

    #[test]
    fn concurrent_overlay_cursors_agree() {
        let array = loaded_array(2, 128);
        let prog = scratch_program();
        let runs: Vec<(Vec<u64>, Vec<u64>)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        let mut cur = ReadCursor::new(&array);
                        let counts = cur.execute_overlay(&prog).unwrap();
                        let rows = (0..array.total_rows())
                            .map(|r| cur.fetch_row_bits(r, 8, 8))
                            .collect();
                        (counts, rows)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &runs[1..] {
            assert_eq!(r, &runs[0]);
        }
    }

    #[test]
    fn overlay_refuses_serializing_instructions() {
        let array = loaded_array(1, 32);
        for bad in [
            Instr::Read { base: 0, width: 8 },
            Instr::IfMatch,
            Instr::FirstMatch,
            Instr::ReduceField { col: 0 },
            Instr::ShiftTagsUp(1),
        ] {
            let mut p = Program::new();
            p.push(bad);
            let mut cur = ReadCursor::new(&array);
            assert!(cur.execute_overlay(&p).is_err());
        }
    }

    #[test]
    fn mutating_instructions_are_refused() {
        let array = loaded_array(1, 32);
        let mut p = Program::new();
        p.compare_field(Field::new(0, 8), 3);
        p.write_field(Field::new(8, 4), 0xA);
        let mut cur = ReadCursor::new(&array);
        assert!(cur.execute_collect(&p).is_err());
    }

    #[test]
    fn program_cache_hits_misses_and_invalidates() {
        let cache = ProgramCache::new();
        let shape = ArrayShape {
            rows: 64,
            rows_per_module: 64,
            width: 16,
        };
        let synth_count = std::sync::atomic::AtomicU64::new(0);
        let synth = || {
            synth_count.fetch_add(1, Ordering::Relaxed);
            QueryPlan {
                programs: vec![probe_program()],
                extra_cycles: 3,
            }
        };
        let a = cache.get_or_insert(shape, "k1", synth);
        let b = cache.get_or_insert(shape, "k1", synth);
        assert!(Arc::ptr_eq(&a, &b), "repeat key must return the cached Arc");
        assert_eq!(synth_count.load(Ordering::Relaxed), 1, "synthesized once");
        assert_eq!(cache.stats(), (1, 1));
        // a different params-class or shape is a distinct entry
        cache.get_or_insert(shape, "k2", synth);
        let other = ArrayShape {
            rows: 128,
            ..shape
        };
        cache.get_or_insert(other, "k1", synth);
        assert_eq!(cache.stats(), (1, 3));
        assert_eq!(cache.len(), 3);
        // invalidation clears plans but keeps cumulative counters, so the
        // forced re-synthesis is observable as a miss delta
        cache.invalidate();
        assert!(cache.is_empty());
        cache.get_or_insert(shape, "k1", synth);
        assert_eq!(cache.stats(), (1, 4));
        assert_eq!(synth_count.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn program_cache_synthesizes_once_under_contention() {
        let cache = ProgramCache::new();
        let shape = ArrayShape {
            rows: 32,
            rows_per_module: 32,
            width: 8,
        };
        let synth_count = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    cache.get_or_insert(shape, "shared", || {
                        synth_count.fetch_add(1, Ordering::Relaxed);
                        QueryPlan::default()
                    });
                });
            }
        });
        assert_eq!(
            synth_count.load(Ordering::Relaxed),
            1,
            "the lock is held across synthesis: racing readers must not re-synthesize"
        );
        let (hits, misses) = cache.stats();
        assert_eq!((hits, misses), (7, 1));
    }

    #[test]
    fn concurrent_cursors_agree_with_each_other() {
        let array = loaded_array(2, 128);
        let prog = probe_program();
        let runs: Vec<Vec<u64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        let mut cur = ReadCursor::new(&array);
                        cur.execute_collect(&prog).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &runs[1..] {
            assert_eq!(r, &runs[0]);
        }
    }
}
