//! Read-only query execution for shared-read concurrency (DESIGN.md
//! §Serving): a [`ReadCursor`] executes the read-only microprograms of a
//! write-free kernel query over a *shared* `&PrinsArray` borrow, so many
//! cursors — one per concurrent reader — can run over the same resident
//! rows at once.
//!
//! The cursor privately owns everything a query would otherwise mutate:
//! per-module tag registers, a local cycle counter, and a local energy
//! ledger. Tag computation reuses the exact word-blocked match function
//! behind `RcamModule::compare`, and every cycle/ledger charge mirrors
//! the mutating path counter-for-counter, so collected outputs and
//! windowed [`ExecStats`] are bit-identical to a [`Controller`] running
//! the same programs on a fresh stats window — while the primary array's
//! cycles, ledger, tags, and wear counters stay untouched.
//!
//! [`Controller`]: crate::controller::Controller

use super::ExecStats;
use crate::analysis::{ArrayShape, QueryPlan};
use crate::error::{bail, Result};
use crate::isa::{Instr, Program};
use crate::rcam::device::{CYCLES_COMPARE, CYCLES_REDUCE_ISSUE};
use crate::rcam::module::compare_tags_into;
use crate::rcam::{BitVec, EnergyLedger, Pattern, PrinsArray};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Compiled-program cache (DESIGN.md §Batching & program cache): memoizes
/// [`QueryPlan`] synthesis keyed by the shard array's [`ArrayShape`] plus
/// a kernel-chosen canonical *params-class* string, so repeat resident
/// queries skip microprogram synthesis entirely and re-execute the cached
/// plan. One cache lives inside each `Resident<K>` (the kernel identity
/// is therefore implicit in the owner), shared by the exclusive
/// `query_shards` path and the concurrent shared-read `read_shards` path
/// alike — the map is behind a [`Mutex`] and plans are handed out as
/// [`Arc`]s, so any number of concurrent readers can execute one cached
/// plan at once.
///
/// Invalidation is the owner's job (the rules live with the server):
/// LOAD/DROP recreate or destroy the owning `Resident` and its cache with
/// it; arming the fault layer (`FAULTS`) and storage remap both call
/// [`ProgramCache::invalidate`], forcing re-synthesis against the new
/// array state. Hit/miss counters are cumulative across invalidations so
/// a forced re-synthesis is observable as a miss delta.
#[derive(Debug, Default)]
pub struct ProgramCache {
    plans: Mutex<HashMap<(ArrayShape, String), Arc<QueryPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ProgramCache {
    /// An empty cache with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// The cached plan for `(shape, key)`, synthesizing (and counting a
    /// miss) via `synth` on first use. The map lock is held across
    /// synthesis so concurrent shards racing for the same key synthesize
    /// once — the losers block briefly and then count hits.
    pub fn get_or_insert(
        &self,
        shape: ArrayShape,
        key: &str,
        synth: impl FnOnce() -> QueryPlan,
    ) -> Arc<QueryPlan> {
        let mut plans = self.plans.lock().expect("program cache poisoned");
        if let Some(plan) = plans.get(&(shape, key.to_string())) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(synth());
        plans.insert((shape, key.to_string()), Arc::clone(&plan));
        plan
    }

    /// Drop every cached plan (counters survive, so forced re-synthesis
    /// shows up as a miss delta). Called on FAULTS arming and storage
    /// remap; LOAD/DROP destroy the whole cache with its owner.
    pub fn invalidate(&self) {
        self.plans.lock().expect("program cache poisoned").clear();
    }

    /// Cumulative `(hits, misses)` since the cache was created.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Number of distinct `(shape, params-class)` plans currently held.
    pub fn len(&self) -> usize {
        self.plans.lock().expect("program cache poisoned").len()
    }

    /// True when no plan is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One concurrent reader's execution context over a borrowed array. See
/// the module doc for the bit-equality contract with [`Controller`].
///
/// Only the two read-only instructions a write-free query plan may
/// contain (`prins verify` rule C01) are executable: `Compare` and
/// `ReduceCount`. Anything else is refused with an error — a mutating
/// instruction here would have to touch the array every other reader is
/// using.
///
/// [`Controller`]: crate::controller::Controller
pub struct ReadCursor<'a> {
    array: &'a PrinsArray,
    /// Cursor-private tag registers, one per module.
    tags: Vec<BitVec>,
    cycles: u64,
    ledger: EnergyLedger,
}

impl<'a> ReadCursor<'a> {
    /// A cursor over `array` with cleared private tags and a zeroed
    /// stats window.
    pub fn new(array: &'a PrinsArray) -> Self {
        ReadCursor {
            array,
            tags: array
                .modules()
                .iter()
                .map(|m| BitVec::zeros(m.rows()))
                .collect(),
            cycles: 0,
            ledger: EnergyLedger::default(),
        }
    }

    /// Compare: identical tags and charges to `PrinsArray::compare`,
    /// landed in the cursor instead of the array.
    pub fn compare(&mut self, pattern: &Pattern) {
        for (m, tags) in self.array.modules().iter().zip(&mut self.tags) {
            compare_tags_into(m.storage(), pattern, tags);
            self.ledger.n_compare += 1;
            self.ledger.compare_bit_events += (m.width() * m.rows()) as u128;
        }
        self.cycles += CYCLES_COMPARE;
    }

    /// Reduction-tree count over the cursor's private tags: identical
    /// result and charges to `PrinsArray::count_tags`.
    pub fn reduce_count(&mut self) -> u64 {
        let mut n = 0u64;
        for (m, tags) in self.array.modules().iter().zip(&self.tags) {
            n += tags.count_ones();
            self.ledger.n_reduce += 1;
            self.ledger.reduce_bit_events +=
                (m.rows() as u128) * (m.tree_levels() as u128);
        }
        self.cycles += CYCLES_REDUCE_ISSUE;
        n
    }

    /// Execute a read-only program (`Compare`/`ReduceCount` only),
    /// collecting reduction results in program order — the shared-read
    /// twin of `Controller::execute_collect`.
    pub fn execute_collect(&mut self, prog: &Program) -> Result<Vec<u64>> {
        let mut out = Vec::new();
        for instr in &prog.instrs {
            match instr {
                Instr::Compare(p) => self.compare(p),
                Instr::ReduceCount => out.push(self.reduce_count()),
                other => bail!(
                    "shared-read cursor refuses non-read-only instruction {other:?}"
                ),
            }
        }
        Ok(out)
    }

    /// Charge cycles outside any program (pipelined reduction-tree
    /// drains — a query plan's `extra_cycles`).
    pub fn add_cycles(&mut self, n: u64) {
        self.cycles += n;
    }

    /// The cursor's windowed stats. `passes` is pinned to 0 exactly as
    /// the write-free kernels pin it on their query paths (no
    /// compare+write microcode passes).
    pub fn stats(&self) -> ExecStats {
        ExecStats {
            cycles: self.cycles,
            instructions: self.ledger.n_compare
                + self.ledger.n_write
                + self.ledger.n_read
                + self.ledger.n_reduce
                + self.ledger.n_tag_op,
            passes: 0,
            ledger: self.ledger.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::isa::Field;
    use crate::rcam::PrinsArray;

    fn loaded_array(n_modules: usize, rows_per_module: usize) -> PrinsArray {
        let mut a = PrinsArray::new(n_modules, rows_per_module, 16);
        for r in 0..a.total_rows() {
            a.load_row_bits(r, 0, 8, (r % 23) as u64);
        }
        a
    }

    fn probe_program() -> Program {
        let f = Field::new(0, 8);
        let mut p = Program::new();
        for v in [5u64, 13, 22, 1] {
            p.compare_field(f, v);
            p.push(Instr::ReduceCount);
        }
        p
    }

    #[test]
    fn cursor_matches_controller_bit_for_bit() {
        for (m, rpm) in [(1usize, 300usize), (3, 100)] {
            let array = loaded_array(m, rpm);
            let prog = probe_program();
            // reference: the mutating controller path on a fresh window
            let mut ctl = Controller::new(array.clone());
            ctl.begin_stats();
            let want = ctl.execute_collect(&prog);
            ctl.array.charge_reduction_latency();
            let want_stats = ctl.stats();
            // shared-read path
            let mut cur = ReadCursor::new(&array);
            let got = cur.execute_collect(&prog).unwrap();
            cur.add_cycles(array.reduction_latency_cycles());
            let stats = cur.stats();
            assert_eq!(got, want, "{m}x{rpm}: collected outputs");
            assert_eq!(stats.cycles, want_stats.cycles, "{m}x{rpm}: cycles");
            assert_eq!(stats.ledger, want_stats.ledger, "{m}x{rpm}: ledger");
            assert_eq!(stats.instructions, want_stats.instructions);
            assert_eq!(stats.passes, 0, "write-free query pins passes to 0");
        }
    }

    #[test]
    fn cursor_leaves_the_array_untouched() {
        let array = loaded_array(2, 64);
        let cycles0 = array.cycles;
        let ledger0 = array.ledger();
        let tags0: Vec<_> = array.modules().iter().map(|m| m.tags().clone()).collect();
        let mut cur = ReadCursor::new(&array);
        cur.execute_collect(&probe_program()).unwrap();
        assert_eq!(array.cycles, cycles0);
        assert_eq!(array.ledger(), ledger0);
        for (m, t0) in array.modules().iter().zip(&tags0) {
            assert_eq!(m.tags(), t0, "array tags mutated by a read cursor");
        }
    }

    #[test]
    fn mutating_instructions_are_refused() {
        let array = loaded_array(1, 32);
        let mut p = Program::new();
        p.compare_field(Field::new(0, 8), 3);
        p.write_field(Field::new(8, 4), 0xA);
        let mut cur = ReadCursor::new(&array);
        assert!(cur.execute_collect(&p).is_err());
    }

    #[test]
    fn program_cache_hits_misses_and_invalidates() {
        let cache = ProgramCache::new();
        let shape = ArrayShape {
            rows: 64,
            rows_per_module: 64,
            width: 16,
        };
        let synth_count = std::sync::atomic::AtomicU64::new(0);
        let synth = || {
            synth_count.fetch_add(1, Ordering::Relaxed);
            QueryPlan {
                programs: vec![probe_program()],
                extra_cycles: 3,
            }
        };
        let a = cache.get_or_insert(shape, "k1", synth);
        let b = cache.get_or_insert(shape, "k1", synth);
        assert!(Arc::ptr_eq(&a, &b), "repeat key must return the cached Arc");
        assert_eq!(synth_count.load(Ordering::Relaxed), 1, "synthesized once");
        assert_eq!(cache.stats(), (1, 1));
        // a different params-class or shape is a distinct entry
        cache.get_or_insert(shape, "k2", synth);
        let other = ArrayShape {
            rows: 128,
            ..shape
        };
        cache.get_or_insert(other, "k1", synth);
        assert_eq!(cache.stats(), (1, 3));
        assert_eq!(cache.len(), 3);
        // invalidation clears plans but keeps cumulative counters, so the
        // forced re-synthesis is observable as a miss delta
        cache.invalidate();
        assert!(cache.is_empty());
        cache.get_or_insert(shape, "k1", synth);
        assert_eq!(cache.stats(), (1, 4));
        assert_eq!(synth_count.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn program_cache_synthesizes_once_under_contention() {
        let cache = ProgramCache::new();
        let shape = ArrayShape {
            rows: 32,
            rows_per_module: 32,
            width: 8,
        };
        let synth_count = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    cache.get_or_insert(shape, "shared", || {
                        synth_count.fetch_add(1, Ordering::Relaxed);
                        QueryPlan::default()
                    });
                });
            }
        });
        assert_eq!(
            synth_count.load(Ordering::Relaxed),
            1,
            "the lock is held across synthesis: racing readers must not re-synthesize"
        );
        let (hits, misses) = cache.stats();
        assert_eq!((hits, misses), (7, 1));
    }

    #[test]
    fn concurrent_cursors_agree_with_each_other() {
        let array = loaded_array(2, 128);
        let prog = probe_program();
        let runs: Vec<Vec<u64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        let mut cur = ReadCursor::new(&array);
                        cur.execute_collect(&prog).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &runs[1..] {
            assert_eq!(r, &runs[0]);
        }
    }
}
