//! Bit-serial subtraction and negation microcode.
//!
//! Same truth-table discipline as `add` (full subtractor with a borrow
//! column); `sub_inplace` computes `acc -= b` modulo 2^width, which also
//! yields the borrow-out in the borrow column before the final ripple —
//! callers that need the comparison outcome should use `micro::cmp`
//! instead.

use super::add::BitSrc;
use super::table::TruthTable;
use crate::isa::{Field, Instr, Pat, Program};

/// One in-place single-bit subtract: `acc_col -= src (+ borrow)`.
fn sub_bit_inplace(
    prog: &mut Program,
    acc_col: u16,
    src: BitSrc,
    brw_col: u16,
    cond: &Pat,
    skip_stationary: bool,
) {
    let mut ccols: Vec<u16> = cond.iter().map(|&(c, _)| c).collect();
    ccols.push(brw_col);
    ccols.push(acc_col);
    let condvals: Vec<bool> = cond.iter().map(|&(_, v)| v).collect();
    let ncond = condvals.len();
    let f = move |i: &[bool], bv: bool| {
        if i[..ncond] != condvals[..] {
            return vec![i[ncond], i[ncond + 1]];
        }
        let (brw, a) = (i[ncond] as i8, i[ncond + 1] as i8);
        let d = a - bv as i8 - brw;
        vec![d < 0, (d & 1) == 1]
    };
    match src {
        BitSrc::Col(b_col) => {
            debug_assert!(b_col != acc_col && b_col != brw_col);
            ccols.push(b_col);
            let t = TruthTable::from_fn(ccols, vec![brw_col, acc_col], move |i| {
                f(i, i[i.len() - 1])
            });
            t.emit(prog, skip_stationary);
        }
        BitSrc::Const(bv) => {
            let t = TruthTable::from_fn(ccols, vec![brw_col, acc_col], move |i| f(i, bv));
            t.emit(prog, skip_stationary);
        }
    }
}

/// `acc -= b` in place, LSB first, borrow through `brw_col` (cleared
/// first). Result is modulo 2^acc.width (two's-complement wraparound).
pub fn sub_inplace(prog: &mut Program, acc: Field, b: Field, brw_col: u16) {
    sub_inplace_cond(prog, acc, b, brw_col, &vec![]);
}

/// [`sub_inplace`] gated on a conjunction of condition bits; condition
/// columns that alias `b`'s bits are folded in as constants.
pub fn sub_inplace_cond(prog: &mut Program, acc: Field, b: Field, brw_col: u16, cond: &Pat) {
    assert!(!acc.overlaps(&b), "in-place sub operands overlap");
    prog.push(Instr::ClearColumns { base: brw_col, width: 1 });
    for j in 0..acc.width {
        let s = if j < b.width {
            let col = b.col(j);
            match cond.iter().find(|&&(c, _)| c == col) {
                Some(&(_, v)) => BitSrc::Const(v),
                None => BitSrc::Col(col),
            }
        } else {
            BitSrc::Const(false)
        };
        sub_bit_inplace(prog, acc.col(j), s, brw_col, cond, true);
    }
}

/// `f -= k` in place (constant subtrahend).
pub fn sub_const(prog: &mut Program, f: Field, k: u64, brw_col: u16) {
    prog.push(Instr::ClearColumns { base: brw_col, width: 1 });
    for j in 0..f.width {
        sub_bit_inplace(
            prog,
            f.col(j),
            BitSrc::Const((k >> j) & 1 == 1),
            brw_col,
            &vec![],
            true,
        );
    }
}

/// Two's-complement negate in place: `f = -f` (subtract-from-zero).
///
/// An in-place bit inversion is inherently a *cyclic* write hazard
/// ((brw=1,f=0)→f=1 lands on (1,1) while (1,1)→f=0 lands on (1,0)), so no
/// safe single-table order exists — `TruthTable::safe_order` rejects it.
/// The classic fix is a staging column: pass set A computes the result
/// bit into `tmp_col` (writes never touch compared columns except the
/// borrow, which is acyclic), pass set B copies `tmp_col` back.
pub fn neg_inplace(prog: &mut Program, f: Field, brw_col: u16, tmp_col: u16) {
    neg_inplace_cond(prog, f, brw_col, tmp_col, &vec![]);
}

/// Conditional negate: rows where every `cond` bit matches get f := -f.
pub fn neg_inplace_cond(prog: &mut Program, f: Field, brw_col: u16, tmp_col: u16, cond: &Pat) {
    assert!(tmp_col < f.base || tmp_col >= f.end());
    assert!(brw_col != tmp_col);
    let condvals: Vec<bool> = cond.iter().map(|&(_, v)| v).collect();
    let ncond = condvals.len();
    prog.push(Instr::ClearColumns { base: brw_col, width: 1 });
    for j in 0..f.width {
        let mut ccols: Vec<u16> = cond.iter().map(|&(c, _)| c).collect();
        ccols.push(brw_col);
        ccols.push(f.col(j));
        // A: (brw, f_j) -> (brw', tmp = 0 - f_j - brw); condition-unmet
        // rows are simply not in the table.
        let mut t = TruthTable::from_fn(ccols, vec![brw_col, tmp_col], move |i| {
            let d = 0i8 - i[ncond + 1] as i8 - i[ncond] as i8;
            vec![d < 0, (d & 1) == 1]
        });
        t.retain(|e| e.input[..ncond] == condvals[..]);
        t.emit(prog, true);
        // B: f_j := tmp (2 passes; tmp is not compared again this bit)
        let mut bcols: Vec<u16> = cond.iter().map(|&(c, _)| c).collect();
        bcols.push(tmp_col);
        let mut t = TruthTable::from_fn(bcols, vec![f.col(j)], |i| vec![*i.last().unwrap()]);
        t.retain(|e| e.input[..ncond] == condvals[..]);
        t.emit(prog, false);
    }
}

/// Absolute value of a two's-complement field: where the sign bit is set,
/// negate. The sign bit itself is part of the negation, so the condition
/// is staged into `flag_col` first (1 = was negative), then a conditional
/// negate keyed on the flag runs over the whole field.
pub fn abs_inplace(prog: &mut Program, f: Field, brw_col: u16, tmp_col: u16, flag_col: u16) {
    let sign = f.col(f.width - 1);
    assert!(flag_col != tmp_col && flag_col != brw_col);
    assert!(flag_col < f.base || flag_col >= f.end());
    // flag := sign (2 passes)
    let t = TruthTable::from_fn(vec![sign], vec![flag_col], |i| vec![i[0]]);
    t.emit(prog, false);
    neg_inplace_cond(prog, f, brw_col, tmp_col, &vec![(flag_col, true)]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::rcam::PrinsArray;

    fn ctl(rows: usize, width: usize) -> Controller {
        Controller::new(PrinsArray::single(rows, width))
    }

    #[test]
    fn sub_inplace_wraps() {
        let (acc, b) = (Field::new(0, 8), Field::new(8, 8));
        let mut prog = Program::new();
        sub_inplace(&mut prog, acc, b, 20);
        let mut c = ctl(64, 24);
        let cases: Vec<(u64, u64)> =
            (0..64).map(|r| ((r * 37 + 5) % 256, (r * 91 + 13) % 256)).collect();
        for (r, &(av, bv)) in cases.iter().enumerate() {
            c.array.load_row_bits(r, 0, 8, av);
            c.array.load_row_bits(r, 8, 8, bv);
        }
        c.execute(&prog);
        for (r, &(av, bv)) in cases.iter().enumerate() {
            assert_eq!(
                c.array.fetch_row_bits(r, 0, 8),
                av.wrapping_sub(bv) & 0xFF,
                "row {r}: {av}-{bv}"
            );
        }
    }

    #[test]
    fn sub_const_works() {
        let f = Field::new(0, 10);
        let mut prog = Program::new();
        sub_const(&mut prog, f, 300, 16);
        let mut c = ctl(4, 20);
        for (r, v) in [0u64, 299, 300, 1023].iter().enumerate() {
            c.array.load_row_bits(r, 0, 10, *v);
        }
        c.execute(&prog);
        for (r, v) in [0u64, 299, 300, 1023].iter().enumerate() {
            assert_eq!(c.array.fetch_row_bits(r, 0, 10), v.wrapping_sub(300) & 0x3FF);
        }
    }

    #[test]
    fn neg_is_twos_complement() {
        let f = Field::new(2, 8);
        let mut prog = Program::new();
        neg_inplace(&mut prog, f, 12, 13);
        let mut c = ctl(4, 16);
        for (r, v) in [0u64, 1, 0x80, 0xFF].iter().enumerate() {
            c.array.load_row_bits(r, 2, 8, *v);
        }
        c.execute(&prog);
        for (r, v) in [0u64, 1, 0x80, 0xFF].iter().enumerate() {
            assert_eq!(c.array.fetch_row_bits(r, 2, 8), (v.wrapping_neg()) & 0xFF);
        }
    }

    #[test]
    fn abs_fixes_negatives_only() {
        let f = Field::new(0, 8);
        let mut prog = Program::new();
        abs_inplace(&mut prog, f, 10, 11, 12);
        let mut c = ctl(6, 16);
        let cases = [0i64, 5, 127, -1, -128, -77];
        for (r, v) in cases.iter().enumerate() {
            c.array.load_row_bits(r, 0, 8, (*v as u64) & 0xFF);
        }
        c.execute(&prog);
        for (r, v) in cases.iter().enumerate() {
            let e = (v.unsigned_abs() as u64) & 0xFF; // |-128| wraps to 0x80
            assert_eq!(c.array.fetch_row_bits(r, 0, 8), e, "row {r}: |{v}|");
        }
    }

    #[test]
    fn add_then_sub_roundtrips() {
        let (acc, b) = (Field::new(0, 12), Field::new(12, 12));
        let mut prog = Program::new();
        super::super::add::add_inplace(&mut prog, acc, b, 30);
        sub_inplace(&mut prog, acc, b, 30);
        let mut c = ctl(32, 32);
        for r in 0..32 {
            c.array.load_row_bits(r, 0, 12, (r * 123) as u64 & 0xFFF);
            c.array.load_row_bits(r, 12, 12, (r * 777) as u64 & 0xFFF);
        }
        c.execute(&prog);
        for r in 0..32 {
            assert_eq!(c.array.fetch_row_bits(r, 0, 12), (r * 123) as u64 & 0xFFF);
        }
    }
}
