//! Comparison microcode: content-addressed equality (the CAM's native
//! single-pass superpower) plus bit-serial magnitude comparison.

use super::table::TruthTable;
use crate::isa::{Field, Instr, Program};

/// Tag all rows whose field equals `value` — one compare instruction.
/// This is the primitive behind histogram binning (Algorithm 3) and the
/// SpMV broadcast (Algorithm 4).
pub fn mark_eq(prog: &mut Program, f: Field, value: u64) {
    prog.compare_field(f, value);
}

/// Set `flag_col` in every row whose unsigned field is strictly less than
/// the constant `k`. O(width) passes: one pass per set bit of `k`
/// (standard CAM prefix trick: f < k iff for some bit i with k_i = 1,
/// f agrees with k above i and f_i = 0).
pub fn flag_lt_const(prog: &mut Program, f: Field, k: u64, flag_col: u16) {
    prog.push(Instr::ClearColumns { base: flag_col, width: 1 });
    for i in (0..f.width).rev() {
        if (k >> i) & 1 == 0 {
            continue;
        }
        let mut cpat = vec![(f.col(i), false)];
        for j in (i + 1)..f.width {
            cpat.push((f.col(j), (k >> j) & 1 == 1));
        }
        prog.pass(cpat, vec![(flag_col, true)]);
    }
}

/// Set `flag_col` where the field is strictly greater than `k` (dual trick).
pub fn flag_gt_const(prog: &mut Program, f: Field, k: u64, flag_col: u16) {
    prog.push(Instr::ClearColumns { base: flag_col, width: 1 });
    for i in (0..f.width).rev() {
        if (k >> i) & 1 == 1 {
            continue;
        }
        let mut cpat = vec![(f.col(i), true)];
        for j in (i + 1)..f.width {
            cpat.push((f.col(j), (k >> j) & 1 == 1));
        }
        prog.pass(cpat, vec![(flag_col, true)]);
    }
}

/// Lexicographic field-vs-field comparison over explicit column lists
/// (MSB first): after execution, `lt_col` = (a < b), `eq_col` = (a == b),
/// per row. 2 passes per bit plus initialization. Used by float alignment
/// (compare exponents, then mantissas) — pass concatenated col lists.
pub fn field_cmp_cols(
    prog: &mut Program,
    a_cols_msb: &[u16],
    b_cols_msb: &[u16],
    lt_col: u16,
    eq_col: u16,
) {
    assert_eq!(a_cols_msb.len(), b_cols_msb.len());
    // init: lt = 0 everywhere, eq = 1 everywhere
    prog.push(Instr::ClearColumns { base: lt_col, width: 1 });
    prog.push(Instr::SetTagsAll);
    prog.push(Instr::Write(vec![(eq_col, true)]));
    for (&ac, &bc) in a_cols_msb.iter().zip(b_cols_msb) {
        // while still equal, the first differing bit decides
        let mut t = TruthTable::from_fn(
            vec![eq_col, ac, bc],
            vec![eq_col, lt_col],
            |i| {
                if !i[0] {
                    return vec![false, false]; // never reached (retained out)
                }
                match (i[1], i[2]) {
                    (false, true) => vec![false, true], // a<b decided
                    (true, false) => vec![false, false], // a>b decided
                    _ => vec![true, false],              // still equal
                }
            },
        );
        t.retain(|e| e.input[0]); // only eq==1 rows participate
        t.emit(prog, true);
    }
}

/// Convenience: compare two equal-width fields.
pub fn field_cmp(prog: &mut Program, a: Field, b: Field, lt_col: u16, eq_col: u16) {
    assert_eq!(a.width, b.width);
    let ac: Vec<u16> = a.cols_msb_first().collect();
    let bc: Vec<u16> = b.cols_msb_first().collect();
    field_cmp_cols(prog, &ac, &bc, lt_col, eq_col);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::rcam::PrinsArray;

    fn ctl(rows: usize, width: usize) -> Controller {
        Controller::new(PrinsArray::single(rows, width))
    }

    #[test]
    fn mark_eq_is_single_compare() {
        let f = Field::new(0, 8);
        let mut p = Program::new();
        mark_eq(&mut p, f, 0x5A);
        assert_eq!(p.len(), 1);
        let mut c = ctl(16, 8);
        c.array.load_row_bits(3, 0, 8, 0x5A);
        c.array.load_row_bits(9, 0, 8, 0x5A);
        c.array.load_row_bits(5, 0, 8, 0x5B);
        c.execute(&p);
        assert_eq!(
            c.array.tags_snapshot().iter_ones().collect::<Vec<_>>(),
            vec![3, 9]
        );
    }

    #[test]
    fn flag_lt_gt_const_exhaustive() {
        // all 64 values vs several constants, exhaustively
        for k in [0u64, 1, 17, 31, 32, 63] {
            let f = Field::new(0, 6);
            let mut plt = Program::new();
            flag_lt_const(&mut plt, f, k, 8);
            let mut pgt = Program::new();
            flag_gt_const(&mut pgt, f, k, 9);
            let mut c = ctl(64, 10);
            for v in 0..64u64 {
                c.array.load_row_bits(v as usize, 0, 6, v);
            }
            c.execute(&plt);
            c.execute(&pgt);
            for v in 0..64u64 {
                assert_eq!(
                    c.array.fetch_row_bits(v as usize, 8, 1) == 1,
                    v < k,
                    "lt: v={v} k={k}"
                );
                assert_eq!(
                    c.array.fetch_row_bits(v as usize, 9, 1) == 1,
                    v > k,
                    "gt: v={v} k={k}"
                );
            }
        }
    }

    #[test]
    fn field_cmp_exhaustive_4bit() {
        let (a, b) = (Field::new(0, 4), Field::new(4, 4));
        let mut p = Program::new();
        field_cmp(&mut p, a, b, 10, 11);
        let mut c = ctl(256, 12);
        for av in 0..16u64 {
            for bv in 0..16u64 {
                let r = (av * 16 + bv) as usize;
                c.array.load_row_bits(r, 0, 4, av);
                c.array.load_row_bits(r, 4, 4, bv);
            }
        }
        c.execute(&p);
        for av in 0..16u64 {
            for bv in 0..16u64 {
                let r = (av * 16 + bv) as usize;
                assert_eq!(c.array.fetch_row_bits(r, 10, 1) == 1, av < bv, "{av} vs {bv}");
                assert_eq!(c.array.fetch_row_bits(r, 11, 1) == 1, av == bv, "{av} vs {bv}");
            }
        }
    }

    #[test]
    fn field_cmp_cols_concatenated_exponent_mantissa() {
        // lexicographic (hi, lo) comparison across two separate fields
        let (ahi, alo) = (Field::new(0, 2), Field::new(2, 2));
        let (bhi, blo) = (Field::new(4, 2), Field::new(6, 2));
        let mut p = Program::new();
        let ac: Vec<u16> = ahi.cols_msb_first().chain(alo.cols_msb_first()).collect();
        let bc: Vec<u16> = bhi.cols_msb_first().chain(blo.cols_msb_first()).collect();
        field_cmp_cols(&mut p, &ac, &bc, 12, 13);
        let mut c = ctl(256, 14);
        for av in 0..16u64 {
            for bv in 0..16u64 {
                let r = (av * 16 + bv) as usize;
                c.array.load_row_bits(r, 0, 2, av >> 2);
                c.array.load_row_bits(r, 2, 2, av & 3);
                c.array.load_row_bits(r, 4, 2, bv >> 2);
                c.array.load_row_bits(r, 6, 2, bv & 3);
            }
        }
        c.execute(&p);
        for av in 0..16u64 {
            for bv in 0..16u64 {
                let r = (av * 16 + bv) as usize;
                assert_eq!(c.array.fetch_row_bits(r, 12, 1) == 1, av < bv);
            }
        }
    }
}
