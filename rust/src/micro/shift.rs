//! Field copy and shift microcode.
//!
//! Bit-column copies are 2 passes each (compare src=1 → write dst=1;
//! compare src=0 → write dst=0). In-place shifts order the per-bit copies
//! so that no column is read after it has been overwritten. Variable
//! (per-row) shifts are barrel-style: one conditional constant shift per
//! bit of the per-row shift-amount field — the associative analogue of a
//! barrel shifter, used by float alignment/normalization.

use super::table::TruthTable;
use crate::isa::{Field, Instr, Pat, Program};

/// dst_col := src_col in all rows satisfying `cond` (2 passes; 1 pass when
/// the source bit's value is implied by the condition itself).
pub fn copy_col_cond(prog: &mut Program, src: u16, dst: u16, cond: &Pat) {
    assert_ne!(src, dst);
    if let Some(&(_, v)) = cond.iter().find(|&&(c, _)| c == src) {
        // source bit value is fixed by the condition: constant write
        prog.push(Instr::Compare(cond.clone()));
        prog.push(Instr::Write(vec![(dst, v)]));
        return;
    }
    let condvals: Vec<bool> = cond.iter().map(|&(_, v)| v).collect();
    let ncond = condvals.len();
    let mut ccols: Vec<u16> = cond.iter().map(|&(c, _)| c).collect();
    ccols.push(src);
    let mut t = TruthTable::from_fn(ccols, vec![dst], |i| vec![*i.last().unwrap()]);
    t.retain(|e| e.input[..ncond] == condvals[..]);
    t.emit(prog, false);
}

/// dst := src for equal-width, non-overlapping fields.
pub fn copy_field(prog: &mut Program, src: Field, dst: Field) {
    copy_field_cond(prog, src, dst, &vec![]);
}

/// dst := src under a row condition.
pub fn copy_field_cond(prog: &mut Program, src: Field, dst: Field, cond: &Pat) {
    assert_eq!(src.width, dst.width);
    assert!(!src.overlaps(&dst), "copy_field fields overlap; use shift_*");
    for j in 0..src.width {
        copy_col_cond(prog, src.col(j), dst.col(j), cond);
    }
}

/// Write a constant into a field of rows satisfying `cond` (compare once,
/// write once — the CAM-native broadcast).
pub fn set_field_cond(prog: &mut Program, f: Field, value: u64, cond: &Pat) {
    prog.push(Instr::Compare(cond.clone()));
    prog.push(Instr::Write(f.pattern(value)));
}

/// In-place logical shift left by `k` (toward the MSB): f := f << k,
/// zero-filling the low bits. Copies MSB-down so sources are read before
/// being overwritten.
pub fn shift_left_inplace(prog: &mut Program, f: Field, k: u16, cond: &Pat) {
    if k == 0 {
        return;
    }
    if k >= f.width {
        clear_field_cond(prog, f, cond);
        return;
    }
    for j in (k..f.width).rev() {
        copy_col_cond(prog, f.col(j - k), f.col(j), cond);
    }
    for j in 0..k {
        set_col_cond(prog, f.col(j), false, cond);
    }
}

/// In-place logical shift right by `k` (toward the LSB), zero-filling.
pub fn shift_right_inplace(prog: &mut Program, f: Field, k: u16, cond: &Pat) {
    if k == 0 {
        return;
    }
    if k >= f.width {
        clear_field_cond(prog, f, cond);
        return;
    }
    for j in 0..(f.width - k) {
        copy_col_cond(prog, f.col(j + k), f.col(j), cond);
    }
    for j in (f.width - k)..f.width {
        set_col_cond(prog, f.col(j), false, cond);
    }
}

fn set_col_cond(prog: &mut Program, col: u16, v: bool, cond: &Pat) {
    prog.push(Instr::Compare(cond.clone()));
    prog.push(Instr::Write(vec![(col, v)]));
}

fn clear_field_cond(prog: &mut Program, f: Field, cond: &Pat) {
    if cond.is_empty() {
        prog.clear_field(f);
    } else {
        prog.push(Instr::Compare(cond.clone()));
        prog.push(Instr::Write(f.pattern(0)));
    }
}

/// Per-row variable right shift: f := f >> amount, where `amount` is a
/// field of the same row. Barrel decomposition: for each bit b of the
/// amount, conditionally shift by 2^b. Shift amounts ≥ f.width clear the
/// field (handled naturally by the barrel stages).
pub fn var_shift_right(prog: &mut Program, f: Field, amount: Field, cond: &Pat) {
    assert!(!f.overlaps(&amount));
    for b in 0..amount.width {
        let mut c = cond.clone();
        c.push((amount.col(b), true));
        let k = 1u32 << b;
        shift_right_inplace(prog, f, (k.min(f.width as u32)) as u16, &c);
    }
}

/// Per-row variable left shift: f := f << amount.
pub fn var_shift_left(prog: &mut Program, f: Field, amount: Field, cond: &Pat) {
    assert!(!f.overlaps(&amount));
    for b in 0..amount.width {
        let mut c = cond.clone();
        c.push((amount.col(b), true));
        let k = 1u32 << b;
        shift_left_inplace(prog, f, (k.min(f.width as u32)) as u16, &c);
    }
}

/// Leading-zero count of `f` into `lzc` (binary value, per row).
/// One compare+write per possible position: pattern "all bits above p are
/// zero and bit p is one" → lzc = width-1-p. All-zero fields get
/// lzc = width.
pub fn leading_zero_count(prog: &mut Program, f: Field, lzc: Field) {
    assert!((1u64 << lzc.width) > f.width as u64, "lzc field too narrow");
    assert!(!f.overlaps(&lzc));
    for p in (0..f.width).rev() {
        let mut cpat: Pat = vec![(f.col(p), true)];
        for j in (p + 1)..f.width {
            cpat.push((f.col(j), false));
        }
        let count = (f.width - 1 - p) as u64;
        prog.pass(cpat, lzc.pattern(count));
    }
    // all-zero case
    let cpat: Pat = f.cols().map(|c| (c, false)).collect();
    prog.pass(cpat, lzc.pattern(f.width as u64));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::rcam::PrinsArray;

    fn ctl(rows: usize, width: usize) -> Controller {
        Controller::new(PrinsArray::single(rows, width))
    }

    #[test]
    fn copy_field_copies_everything() {
        let (s, d) = (Field::new(0, 8), Field::new(8, 8));
        let mut p = Program::new();
        copy_field(&mut p, s, d);
        let mut c = ctl(16, 16);
        for r in 0..16 {
            c.array.load_row_bits(r, 0, 8, (r * 17) as u64 & 0xFF);
            c.array.load_row_bits(r, 8, 8, 0xAA); // stale garbage
        }
        c.execute(&p);
        for r in 0..16 {
            assert_eq!(c.array.fetch_row_bits(r, 8, 8), (r * 17) as u64 & 0xFF);
        }
    }

    #[test]
    fn constant_shifts_inplace() {
        let f = Field::new(2, 8);
        for (k, left) in [(1u16, true), (3, true), (8, true), (1, false), (5, false), (9, false)] {
            let mut p = Program::new();
            if left {
                shift_left_inplace(&mut p, f, k, &vec![]);
            } else {
                shift_right_inplace(&mut p, f, k, &vec![]);
            }
            let mut c = ctl(8, 12);
            let vals = [0u64, 1, 0x80, 0xC3, 0xFF, 0x55, 0x0F, 0xF0];
            for (r, v) in vals.iter().enumerate() {
                c.array.load_row_bits(r, 2, 8, *v);
            }
            c.execute(&p);
            for (r, v) in vals.iter().enumerate() {
                let e = if left {
                    (v << k.min(63)) & 0xFF
                } else {
                    v >> k.min(63)
                };
                assert_eq!(c.array.fetch_row_bits(r, 2, 8), e, "k={k} left={left} v={v:#x}");
            }
        }
    }

    #[test]
    fn var_shift_right_per_row() {
        let (f, amt) = (Field::new(0, 8), Field::new(8, 4));
        let mut p = Program::new();
        var_shift_right(&mut p, f, amt, &vec![]);
        let mut c = ctl(16, 12);
        for r in 0..16 {
            c.array.load_row_bits(r, 0, 8, 0xB7);
            c.array.load_row_bits(r, 8, 4, r as u64);
        }
        c.execute(&p);
        for r in 0..16u64 {
            let e = if r >= 8 { 0 } else { 0xB7u64 >> r };
            assert_eq!(c.array.fetch_row_bits(r as usize, 0, 8), e, "shift {r}");
        }
    }

    #[test]
    fn var_shift_left_per_row() {
        let (f, amt) = (Field::new(0, 8), Field::new(8, 3));
        let mut p = Program::new();
        var_shift_left(&mut p, f, amt, &vec![]);
        let mut c = ctl(8, 12);
        for r in 0..8 {
            c.array.load_row_bits(r, 0, 8, 0x93);
            c.array.load_row_bits(r, 8, 3, r as u64);
        }
        c.execute(&p);
        for r in 0..8u64 {
            assert_eq!(
                c.array.fetch_row_bits(r as usize, 0, 8),
                (0x93u64 << r) & 0xFF
            );
        }
    }

    #[test]
    fn lzc_all_positions() {
        let (f, z) = (Field::new(0, 8), Field::new(8, 4));
        let mut p = Program::new();
        leading_zero_count(&mut p, f, z);
        let mut c = ctl(10, 12);
        let vals = [0u64, 1, 2, 0x80, 0x40, 0xFF, 0x10, 0x08, 0x03, 0x81];
        for (r, v) in vals.iter().enumerate() {
            c.array.load_row_bits(r, 0, 8, *v);
        }
        c.execute(&p);
        for (r, v) in vals.iter().enumerate() {
            let e = if *v == 0 { 8 } else { (v.leading_zeros() - 56) as u64 };
            assert_eq!(c.array.fetch_row_bits(r, 8, 4), e, "v={v:#x}");
        }
    }

    #[test]
    fn conditional_copy_respects_condition() {
        let (s, d) = (Field::new(0, 4), Field::new(4, 4));
        let mut p = Program::new();
        copy_field_cond(&mut p, s, d, &vec![(10, true)]);
        let mut c = ctl(4, 12);
        for r in 0..4 {
            c.array.load_row_bits(r, 0, 4, 0x9);
            c.array.load_row_bits(r, 10, 1, (r % 2) as u64);
        }
        c.execute(&p);
        for r in 0..4 {
            let e = if r % 2 == 1 { 0x9 } else { 0x0 };
            assert_eq!(c.array.fetch_row_bits(r, 4, 4), e);
        }
    }
}
