//! Field reduction via the tag-counter/reduction tree (paper §3.1: "a
//! reduction (adding) tree, enabling logarithmic summation of tag bits...
//! useful whenever a vector needs to be reduced to a scalar").
//!
//! A multi-bit field is summed bit-serially: for each bit-plane i of the
//! field, the tree counts the tagged rows whose bit i is set; the
//! controller accumulates Σ count_i · 2^i in its data buffer — the
//! "baseline processing, such as normalization of the reduction tree
//! results" the paper assigns to the controller (§3.3).

use crate::isa::{Field, Instr, Program};

/// Emit the reduction of `f` over the currently tagged rows: one
/// `ReduceField` per bit-plane. The caller combines the buffer values
/// with [`combine_field_sum`].
pub fn emit_field_sum(prog: &mut Program, f: Field) {
    for c in f.cols() {
        prog.push(Instr::ReduceField { col: c });
    }
}

/// Combine the per-plane counts produced by [`emit_field_sum`] into the
/// field sum (counts\[i\] = number of tagged rows with bit i set).
pub fn combine_field_sum(counts: &[u64]) -> u128 {
    counts
        .iter()
        .enumerate()
        .map(|(i, &c)| (c as u128) << i)
        .sum()
}

/// Two's-complement signed combination: the MSB plane carries weight
/// -2^(w-1).
pub fn combine_field_sum_signed(counts: &[u64]) -> i128 {
    let w = counts.len();
    assert!(w >= 1);
    let mut s: i128 = 0;
    for (i, &c) in counts.iter().enumerate() {
        let weight = if i == w - 1 {
            -((1i128) << i)
        } else {
            1i128 << i
        };
        s += weight * c as i128;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::isa::Field;
    use crate::rcam::PrinsArray;

    #[test]
    fn field_sum_over_tagged_rows() {
        let f = Field::new(0, 8);
        let mut c = Controller::new(PrinsArray::single(64, 10));
        let mut expect = 0u128;
        for r in 0..64 {
            let v = (r * 23 + 7) as u64 & 0xFF;
            c.array.load_row_bits(r, 0, 8, v);
            let sel = r % 3 == 0;
            c.array.load_row_bits(r, 9, 1, sel as u64);
            if sel {
                expect += v as u128;
            }
        }
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(9, true)]));
        emit_field_sum(&mut p, f);
        let counts = c.execute_collect(&p);
        assert_eq!(combine_field_sum(&counts), expect);
    }

    #[test]
    fn signed_combination() {
        let f = Field::new(0, 8);
        let mut c = Controller::new(PrinsArray::single(8, 8));
        let vals: [i64; 5] = [-100, -1, 0, 77, 127];
        for (r, v) in vals.iter().enumerate() {
            c.array.load_row_bits(r, 0, 8, (*v as u64) & 0xFF);
        }
        let mut p = Program::new();
        p.push(Instr::SetTagsAll);
        emit_field_sum(&mut p, f);
        let counts = c.execute_collect(&p);
        // rows 5..8 are zero-filled and contribute 0
        assert_eq!(
            combine_field_sum_signed(&counts),
            vals.iter().map(|&v| v as i128).sum::<i128>()
        );
    }

    #[test]
    fn empty_tags_sum_to_zero() {
        let f = Field::new(0, 4);
        let mut c = Controller::new(PrinsArray::single(16, 8));
        let mut p = Program::new();
        p.compare_field(Field::new(4, 4), 0xF); // matches nothing
        emit_field_sum(&mut p, f);
        let counts = c.execute_collect(&p);
        assert_eq!(combine_field_sum(&counts), 0);
    }
}
