//! Bit-serial, word-parallel addition microcode (paper §4, Fig. 6).
//!
//! Two families:
//!  * [`vec_add`] — the paper-faithful form: S = A + B into a separate sum
//!    field, eight compare+write passes per bit ("Overall, eight steps of
//!    one compare and one write operation are performed to complete a
//!    single-bit addition", §4).
//!  * [`add_inplace`]/[`add_const`] — the optimized in-place form used by
//!    the higher-level generators (multiply, float): only truth-table
//!    entries that change state are emitted (4 passes per bit, 2 per pure
//!    carry-ripple bit). The ablation bench `ablation_microcode`
//!    quantifies the difference.
//!
//! All tables go through [`TruthTable::safe_order`], so the carry-hazard
//! ordering is machine-checked rather than hand-proved.

use super::table::TruthTable;
use crate::isa::{Field, Instr, Pat, Program};

/// Where an addend bit comes from: a bit-column of the row, or a constant
/// folded into the truth table.
#[derive(Clone, Copy, Debug)]
pub enum BitSrc {
    /// The bit lives in this bit-column of the row.
    Col(u16),
    /// A constant bit folded into the truth table.
    Const(bool),
}

/// Emit one in-place single-bit add: `acc_col += src (+ carry)`, under an
/// optional conjunction of condition bits prepended to every compare.
fn add_bit_inplace(
    prog: &mut Program,
    acc_col: u16,
    src: BitSrc,
    c_col: u16,
    cond: &Pat,
    skip_stationary: bool,
) {
    let mut ccols: Vec<u16> = cond.iter().map(|&(c, _)| c).collect();
    ccols.push(c_col);
    ccols.push(acc_col);
    let condvals: Vec<bool> = cond.iter().map(|&(_, v)| v).collect();
    let ncond = condvals.len();
    match src {
        BitSrc::Col(b_col) => {
            debug_assert!(b_col != acc_col && b_col != c_col);
            ccols.push(b_col);
            let t = TruthTable::from_fn(ccols, vec![c_col, acc_col], |i| {
                if i[..ncond] != condvals[..] {
                    // condition not met: no state change
                    return vec![i[ncond], i[ncond + 1]];
                }
                let sum =
                    i[ncond] as u8 + i[ncond + 1] as u8 + i[ncond + 2] as u8;
                vec![sum >= 2, sum % 2 == 1]
            });
            t.emit(prog, skip_stationary);
        }
        BitSrc::Const(bv) => {
            let t = TruthTable::from_fn(ccols, vec![c_col, acc_col], |i| {
                if i[..ncond] != condvals[..] {
                    return vec![i[ncond], i[ncond + 1]];
                }
                let sum = i[ncond] as u8 + i[ncond + 1] as u8 + bv as u8;
                vec![sum >= 2, sum % 2 == 1]
            });
            t.emit(prog, skip_stationary);
        }
    }
}

/// In-place add: `acc += src`, LSB first, carry through `c_col`.
///
/// `src(j)` supplies the addend bit for position j; positions past
/// `src_bits` ripple the carry only. The carry column is cleared first and
/// the result is `acc (mod 2^acc.width)` (the final carry is dropped, as
/// in any fixed-width register).
pub fn add_inplace_src(
    prog: &mut Program,
    acc: Field,
    src: impl Fn(u16) -> BitSrc,
    src_bits: u16,
    c_col: u16,
    cond: &Pat,
    skip_stationary: bool,
) {
    assert!(src_bits <= acc.width);
    prog.push(Instr::ClearColumns { base: c_col, width: 1 });
    for j in 0..acc.width {
        let s = if j < src_bits { src(j) } else { BitSrc::Const(false) };
        add_bit_inplace(prog, acc.col(j), s, c_col, cond, skip_stationary);
    }
}

/// `acc += b` in place (optimized form).
pub fn add_inplace(prog: &mut Program, acc: Field, b: Field, c_col: u16) {
    add_inplace_cond(prog, acc, b, c_col, &vec![]);
}

/// `acc += b` in place, only in rows where every `cond` bit matches.
pub fn add_inplace_cond(prog: &mut Program, acc: Field, b: Field, c_col: u16, cond: &Pat) {
    assert!(!acc.overlaps(&b), "in-place add operands overlap");
    add_inplace_src(
        prog,
        acc,
        |j| {
            let col = b.col(j);
            // If the addend bit is also a condition bit, its value is known.
            match cond.iter().find(|&&(c, _)| c == col) {
                Some(&(_, v)) => BitSrc::Const(v),
                None => BitSrc::Col(col),
            }
        },
        b.width.min(acc.width),
        c_col,
        cond,
        true,
    );
}

/// `f += k` in place (constant addend folded into the tables).
pub fn add_const(prog: &mut Program, f: Field, k: u64, c_col: u16) {
    add_inplace_src(
        prog,
        f,
        |j| BitSrc::Const((k >> j) & 1 == 1),
        f.width,
        c_col,
        &vec![],
        true,
    );
}

/// Paper-faithful vector add: `s = a + b`, eight passes per bit.
///
/// `a`, `b`, `s` must be mutually disjoint and of equal width; if
/// `s.width == a.width + 1` the final carry becomes the top sum bit.
/// The carry column is cleared first.
pub fn vec_add(prog: &mut Program, a: Field, b: Field, s: Field, c_col: u16) {
    assert_eq!(a.width, b.width);
    assert!(s.width == a.width || s.width == a.width + 1);
    assert!(!a.overlaps(&s) && !b.overlaps(&s) && !a.overlaps(&b));
    prog.push(Instr::ClearColumns { base: c_col, width: 1 });
    for j in 0..a.width {
        let t = TruthTable::from_fn(
            vec![c_col, a.col(j), b.col(j)],
            vec![c_col, s.col(j)],
            |i| {
                let sum = i[0] as u8 + i[1] as u8 + i[2] as u8;
                vec![sum >= 2, sum % 2 == 1]
            },
        );
        t.emit(prog, false); // fidelity mode: all 8 entries
    }
    if s.width == a.width + 1 {
        // copy the final carry into the top sum bit (2 passes)
        let t = TruthTable::from_fn(vec![c_col], vec![s.col(a.width)], |i| vec![i[0]]);
        t.emit(prog, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::rcam::PrinsArray;

    fn ctl(rows: usize, width: usize) -> Controller {
        Controller::new(PrinsArray::single(rows, width))
    }

    fn splitmix(seed: &mut u64) -> u64 {
        *seed = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = *seed;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[test]
    fn vec_add_is_parallel_add() {
        let (a, b, s) = (Field::new(0, 8), Field::new(8, 8), Field::new(16, 9));
        let mut prog = Program::new();
        vec_add(&mut prog, a, b, s, 30);
        assert_eq!(prog.n_passes(), 8 * 8 + 2); // paper count + carry-out copy
        let mut c = ctl(64, 32);
        let mut seed = 1u64;
        let mut expect = Vec::new();
        for r in 0..64 {
            let av = splitmix(&mut seed) & 0xFF;
            let bv = splitmix(&mut seed) & 0xFF;
            c.array.load_row_bits(r, 0, 8, av);
            c.array.load_row_bits(r, 8, 8, bv);
            expect.push(av + bv);
        }
        c.execute(&prog);
        for (r, e) in expect.iter().enumerate() {
            assert_eq!(c.array.fetch_row_bits(r, 16, 9), *e, "row {r}");
        }
    }

    #[test]
    fn add_inplace_wraps_mod_width() {
        let (acc, b) = (Field::new(0, 8), Field::new(8, 8));
        let mut prog = Program::new();
        add_inplace(&mut prog, acc, b, 20);
        let mut c = ctl(32, 24);
        let mut seed = 7u64;
        let mut expect = Vec::new();
        for r in 0..32 {
            let av = splitmix(&mut seed) & 0xFF;
            let bv = splitmix(&mut seed) & 0xFF;
            c.array.load_row_bits(r, 0, 8, av);
            c.array.load_row_bits(r, 8, 8, bv);
            expect.push((av + bv) & 0xFF);
        }
        c.execute(&prog);
        for (r, e) in expect.iter().enumerate() {
            assert_eq!(c.array.fetch_row_bits(r, 0, 8), *e, "row {r}");
        }
    }

    #[test]
    fn add_inplace_is_cheaper_than_paper_form() {
        let (a, b, s) = (Field::new(0, 16), Field::new(16, 16), Field::new(32, 16));
        let mut p8 = Program::new();
        vec_add(&mut p8, a, b, s, 60);
        let mut p4 = Program::new();
        add_inplace(&mut p4, a, b, 60);
        assert_eq!(p4.n_passes(), 4 * 16);
        assert!(p4.cycle_estimate() < p8.cycle_estimate());
    }

    #[test]
    fn add_inplace_wider_acc_ripples_carry() {
        let (acc, b) = (Field::new(0, 12), Field::new(16, 8));
        let mut prog = Program::new();
        add_inplace(&mut prog, acc, b, 30);
        let mut c = ctl(16, 32);
        c.array.load_row_bits(0, 0, 12, 0xFF0); // acc
        c.array.load_row_bits(0, 16, 8, 0x20); // b
        c.execute(&prog);
        assert_eq!(c.array.fetch_row_bits(0, 0, 12), 0x010); // 0xFF0+0x20 mod 2^12
    }

    #[test]
    fn add_const_works() {
        let f = Field::new(4, 10);
        let mut prog = Program::new();
        add_const(&mut prog, f, 0x17F, 20);
        let mut c = ctl(16, 24);
        let vals = [0u64, 1, 0x280, 0x3FF];
        for (r, v) in vals.iter().enumerate() {
            c.array.load_row_bits(r, 4, 10, *v);
        }
        c.execute(&prog);
        for (r, v) in vals.iter().enumerate() {
            assert_eq!(c.array.fetch_row_bits(r, 4, 10), (v + 0x17F) & 0x3FF);
        }
    }

    #[test]
    fn conditional_add_only_hits_matching_rows() {
        let (acc, b) = (Field::new(0, 8), Field::new(8, 8));
        let flag = 19u16;
        let mut prog = Program::new();
        add_inplace_cond(&mut prog, acc, b, 22, &vec![(flag, true)]);
        let mut c = ctl(8, 24);
        for r in 0..8 {
            c.array.load_row_bits(r, 0, 8, 10 * r as u64);
            c.array.load_row_bits(r, 8, 8, 5);
            c.array.load_row_bits(r, flag as usize, 1, (r % 2) as u64);
        }
        c.execute(&prog);
        for r in 0..8 {
            let e = if r % 2 == 1 { 10 * r as u64 + 5 } else { 10 * r as u64 };
            assert_eq!(c.array.fetch_row_bits(r, 0, 8), e, "row {r}");
        }
    }

    #[test]
    fn conditional_add_with_cond_inside_addend() {
        // cond bit IS one of the addend bits (the multiply/square case):
        // acc += b only where b bit1 == 1.
        let (acc, b) = (Field::new(0, 4), Field::new(8, 4));
        let mut prog = Program::new();
        add_inplace_cond(&mut prog, acc, b, 20, &vec![(b.col(1), true)]);
        let mut c = ctl(4, 24);
        for (r, bv) in [0b0000u64, 0b0010, 0b0111, 0b1101].iter().enumerate() {
            c.array.load_row_bits(r, 0, 4, 3);
            c.array.load_row_bits(r, 8, 4, *bv);
        }
        c.execute(&prog);
        for (r, bv) in [0b0000u64, 0b0010, 0b0111, 0b1101].iter().enumerate() {
            let e = if (bv >> 1) & 1 == 1 { (3 + bv) & 0xF } else { 3 };
            assert_eq!(c.array.fetch_row_bits(r, 0, 4), e, "row {r}");
        }
    }
}
