//! Bit-serial multiplication microcode: shift-and-conditional-add
//! (paper §4: "Fixed point multiplication and division in PRINS require
//! O(m²) cycles").
//!
//! `p += a << i` is executed for every multiplier bit `b_i`, as an
//! in-place conditional add with the condition `b_i == 1` folded into the
//! compare patterns — rows whose multiplier bit is 0 simply never match,
//! so the add is skipped *per row*, which is the associative analogue of
//! a multiplexer.

use super::add::add_inplace_cond;
use crate::isa::{Field, Instr, Program};

/// `p = a * b` (unsigned). `p` must be disjoint from `a` and `b` and at
/// least `a.width + b.width` wide; it is cleared first.
pub fn mul(prog: &mut Program, a: Field, b: Field, p: Field, c_col: u16) {
    assert!(p.width >= a.width + b.width, "product field too narrow");
    assert!(!p.overlaps(&a) && !p.overlaps(&b));
    prog.push(Instr::ClearColumns { base: p.base, width: p.width });
    for i in 0..b.width {
        // p[i..] += a, where b_i == 1
        let acc = p.slice(i, p.width - i);
        add_inplace_cond(prog, acc, a, c_col, &vec![(b.col(i), true)]);
    }
}

/// `p = a * a` (unsigned square). Works even though the condition bit is
/// one of the addend bits: `add_inplace_cond` folds coinciding columns to
/// constants.
pub fn square(prog: &mut Program, a: Field, p: Field, c_col: u16) {
    assert!(p.width >= 2 * a.width, "square field too narrow");
    assert!(!p.overlaps(&a));
    prog.push(Instr::ClearColumns { base: p.base, width: p.width });
    for i in 0..a.width {
        let acc = p.slice(i, p.width - i);
        add_inplace_cond(prog, acc, a, c_col, &vec![(a.col(i), true)]);
    }
}

/// Multiply-accumulate: `p += a * b` without clearing `p` (used by dot
/// product; p must be wide enough to absorb the accumulation).
pub fn mac(prog: &mut Program, a: Field, b: Field, p: Field, c_col: u16) {
    assert!(p.width >= a.width + b.width);
    assert!(!p.overlaps(&a) && !p.overlaps(&b));
    for i in 0..b.width {
        let acc = p.slice(i, p.width - i);
        add_inplace_cond(prog, acc, a, c_col, &vec![(b.col(i), true)]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::rcam::PrinsArray;

    fn ctl(rows: usize, width: usize) -> Controller {
        Controller::new(PrinsArray::single(rows, width))
    }

    fn splitmix(seed: &mut u64) -> u64 {
        *seed = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = *seed;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[test]
    fn mul_8x8() {
        let (a, b, p) = (Field::new(0, 8), Field::new(8, 8), Field::new(16, 16));
        let mut prog = Program::new();
        mul(&mut prog, a, b, p, 40);
        let mut c = ctl(64, 48);
        let mut seed = 3u64;
        let mut cases = Vec::new();
        for r in 0..64 {
            let av = splitmix(&mut seed) & 0xFF;
            let bv = splitmix(&mut seed) & 0xFF;
            c.array.load_row_bits(r, 0, 8, av);
            c.array.load_row_bits(r, 8, 8, bv);
            cases.push((av, bv));
        }
        c.execute(&prog);
        for (r, (av, bv)) in cases.iter().enumerate() {
            assert_eq!(c.array.fetch_row_bits(r, 16, 16), av * bv, "row {r}");
        }
    }

    #[test]
    fn mul_edge_values() {
        let (a, b, p) = (Field::new(0, 8), Field::new(8, 8), Field::new(16, 16));
        let mut prog = Program::new();
        mul(&mut prog, a, b, p, 40);
        let mut c = ctl(8, 48);
        let cases = [(0u64, 0u64), (0, 255), (255, 0), (255, 255), (1, 171), (128, 2), (3, 85), (16, 16)];
        for (r, (av, bv)) in cases.iter().enumerate() {
            c.array.load_row_bits(r, 0, 8, *av);
            c.array.load_row_bits(r, 8, 8, *bv);
        }
        c.execute(&prog);
        for (r, (av, bv)) in cases.iter().enumerate() {
            assert_eq!(c.array.fetch_row_bits(r, 16, 16), av * bv);
        }
    }

    #[test]
    fn square_matches_mul_by_self() {
        let (a, p) = (Field::new(0, 8), Field::new(8, 16));
        let mut prog = Program::new();
        square(&mut prog, a, p, 30);
        let mut c = ctl(32, 32);
        for r in 0..32 {
            c.array.load_row_bits(r, 0, 8, (r * 8 + 3) as u64 & 0xFF);
        }
        c.execute(&prog);
        for r in 0..32 {
            let v = (r * 8 + 3) as u64 & 0xFF;
            assert_eq!(c.array.fetch_row_bits(r, 8, 16), v * v, "row {r}");
        }
    }

    #[test]
    fn mac_accumulates() {
        let (a, b, p) = (Field::new(0, 4), Field::new(4, 4), Field::new(8, 12));
        let mut prog = Program::new();
        prog.push(Instr::ClearColumns { base: 8, width: 12 });
        mac(&mut prog, a, b, p, 24);
        mac(&mut prog, a, b, p, 24); // p = 2ab
        let mut c = ctl(16, 32);
        for r in 0..16 {
            c.array.load_row_bits(r, 0, 4, r as u64);
            c.array.load_row_bits(r, 4, 4, 15 - r as u64);
        }
        c.execute(&prog);
        for r in 0..16 {
            let e = 2 * (r as u64) * (15 - r as u64);
            assert_eq!(c.array.fetch_row_bits(r, 8, 12), e, "row {r}");
        }
    }

    #[test]
    fn mul_cost_is_quadratic() {
        let mk = |m: u16| {
            let (a, b, p) = (Field::new(0, m), Field::new(m, m), Field::new(2 * m, 2 * m));
            let mut prog = Program::new();
            mul(&mut prog, a, b, p, 200);
            prog.n_passes() as f64
        };
        let (p8, p16) = (mk(8), mk(16));
        let ratio = p16 / p8;
        assert!(ratio > 3.0 && ratio < 5.0, "O(m^2) scaling, got ratio {ratio}");
    }
}
