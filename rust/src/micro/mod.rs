//! Microcode generators: compile arithmetic to associative compare+write
//! passes (paper §4: word-parallel, bit-serial arithmetic with
//! truth-table execution).
//!
//! Every generator routes through [`table::TruthTable::safe_order`], which
//! machine-checks the classic associative-processing write-ordering
//! hazard instead of relying on hand-proved pass orders.

pub mod add;
pub mod cmp;
pub mod float;
pub mod mul;
pub mod reduce;
pub mod shift;
pub mod sub;
pub mod table;

pub use add::{add_const, add_inplace, add_inplace_cond, vec_add, BitSrc};
pub use cmp::{field_cmp, field_cmp_cols, flag_gt_const, flag_lt_const, mark_eq};
pub use float::{fp_add, fp_mul, fp_sub, FloatField, FpScratch, FP_MUL_SCRATCH_BITS, FP_SCRATCH_BITS, UNPACKED_BITS};
pub use mul::{mac, mul, square};
pub use reduce::{combine_field_sum, combine_field_sum_signed, emit_field_sum};
pub use shift::{
    copy_field, copy_field_cond, leading_zero_count, set_field_cond,
    shift_left_inplace, shift_right_inplace, var_shift_left, var_shift_right,
};
pub use sub::{abs_inplace, neg_inplace, neg_inplace_cond, sub_const, sub_inplace, sub_inplace_cond};
pub use table::TruthTable;
