//! Truth-table → microcode compilation with hazard-safe entry ordering.
//!
//! Associative arithmetic (paper §4) executes a Boolean function as a
//! series of compare+write passes, one per truth-table entry. Because a
//! pass's write may change bit-columns that later passes *compare*, a row
//! can be transformed onto another entry's input pattern and be processed
//! twice — the classic associative-processing ordering hazard (Foster,
//! *Content Addressable Parallel Processors*, 1976). This module solves
//! the ordering generically: build the "lands-on" graph (entry X writes →
//! pattern of entry Y ⇒ Y must execute before X) and topologically sort.
//!
//! All arithmetic generators (add/sub/mul/float) are built on this, so a
//! single correctness argument covers every operation.

use crate::isa::{Pat, Program};
use std::collections::HashMap;

/// One truth-table entry: input bits over `compare_cols`, output bits over
/// `write_cols`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    /// Input bit per compare column.
    pub input: Vec<bool>,
    /// Output bit per write column.
    pub output: Vec<bool>,
}

/// A truth table over explicit column lists.
#[derive(Clone, Debug)]
pub struct TruthTable {
    /// Columns the compare pattern covers, in `Entry::input` order.
    pub compare_cols: Vec<u16>,
    /// Columns the write pattern covers, in `Entry::output` order.
    pub write_cols: Vec<u16>,
    /// The table rows (one compare+write pass each when emitted).
    pub entries: Vec<Entry>,
}

impl TruthTable {
    /// An empty table over the given column lists (duplicates within a
    /// list are design errors and panic).
    pub fn new(compare_cols: Vec<u16>, write_cols: Vec<u16>) -> Self {
        // A column may appear in both lists (e.g. an in-place carry), but
        // duplicates within a list are design errors.
        let mut cc = compare_cols.clone();
        cc.sort_unstable();
        cc.dedup();
        assert_eq!(cc.len(), compare_cols.len(), "duplicate compare column");
        let mut wc = write_cols.clone();
        wc.sort_unstable();
        wc.dedup();
        assert_eq!(wc.len(), write_cols.len(), "duplicate write column");
        TruthTable {
            compare_cols,
            write_cols,
            entries: Vec::new(),
        }
    }

    /// Append one entry (input/output lengths must match the columns).
    pub fn entry(&mut self, input: Vec<bool>, output: Vec<bool>) -> &mut Self {
        assert_eq!(input.len(), self.compare_cols.len());
        assert_eq!(output.len(), self.write_cols.len());
        self.entries.push(Entry { input, output });
        self
    }

    /// Build a table from a boolean function over all 2^k input patterns.
    pub fn from_fn(
        compare_cols: Vec<u16>,
        write_cols: Vec<u16>,
        f: impl Fn(&[bool]) -> Vec<bool>,
    ) -> Self {
        let k = compare_cols.len();
        assert!(k <= 16);
        let mut t = TruthTable::new(compare_cols, write_cols);
        for bits in 0..(1u32 << k) {
            let input: Vec<bool> = (0..k).map(|i| (bits >> i) & 1 == 1).collect();
            let output = f(&input);
            t.entry(input.clone(), output);
        }
        t
    }

    /// Drop entries not matching a predicate (used to restrict a table to
    /// condition-met rows; unmatched rows then never match any pass).
    pub fn retain(&mut self, f: impl Fn(&Entry) -> bool) {
        self.entries.retain(|e| f(e));
    }

    /// The input pattern a row matching `e` exhibits *after* e's write:
    /// compared columns that are also written take the written value.
    fn landing(&self, e: &Entry) -> Vec<bool> {
        let mut out = e.input.clone();
        for (wi, &wcol) in self.write_cols.iter().enumerate() {
            if let Some(ci) = self.compare_cols.iter().position(|&c| c == wcol) {
                out[ci] = e.output[wi];
            }
        }
        out
    }

    /// True if `e`'s write leaves every compared column unchanged.
    fn is_stationary(&self, e: &Entry) -> bool {
        self.landing(e) == e.input
    }

    /// Hazard-safe execution order (indices into `entries`).
    ///
    /// Constraint: if X's write lands a row on Y's input pattern (X ≠ Y),
    /// then Y must execute before X. Panics if the constraints are cyclic
    /// (no safe serial order exists — a table design error).
    pub fn safe_order(&self) -> Vec<usize> {
        let n = self.entries.len();
        let index: HashMap<&[bool], usize> = self
            .entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.input.as_slice(), i))
            .collect();
        // edges[y] -> list of x that must come after y
        let mut after: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (x, e) in self.entries.iter().enumerate() {
            let land = self.landing(e);
            if land != e.input {
                if let Some(&y) = index.get(land.as_slice()) {
                    after[y].push(x);
                    indeg[x] += 1;
                }
            }
        }
        // Kahn, preferring original order for determinism.
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        ready.sort_unstable();
        let mut order = Vec::with_capacity(n);
        while let Some(y) = ready.pop() {
            order.push(y);
            for &x in &after[y] {
                indeg[x] -= 1;
                if indeg[x] == 0 {
                    ready.push(x);
                }
            }
            ready.sort_unstable();
        }
        assert_eq!(
            order.len(),
            n,
            "truth table has cyclic write hazards; no safe pass order exists"
        );
        order
    }

    /// Emit the table as compare+write passes in hazard-safe order.
    ///
    /// `skip_stationary`: omit entries whose write cannot change any row
    /// state (write values equal the matched input on every overlapping
    /// column AND the non-compared written columns are... — note: a write
    /// to a column that is NOT compared always counts as a state change,
    /// because the row's current value there is unknown). When false, all
    /// entries are emitted (the paper's "eight steps" fidelity mode).
    pub fn emit(&self, prog: &mut Program, skip_stationary: bool) {
        for &i in &self.safe_order().iter().collect::<Vec<_>>() {
            let e = &self.entries[*i];
            if skip_stationary && self.entry_is_noop(e) {
                continue;
            }
            let cpat: Pat = self
                .compare_cols
                .iter()
                .zip(&e.input)
                .map(|(&c, &b)| (c, b))
                .collect();
            let wpat: Pat = self
                .write_cols
                .iter()
                .zip(&e.output)
                .map(|(&c, &b)| (c, b))
                .collect();
            prog.pass(cpat, wpat);
        }
    }

    /// An entry is a provable no-op iff every written column is also
    /// compared and the written value equals the compared value.
    fn entry_is_noop(&self, e: &Entry) -> bool {
        self.write_cols.iter().enumerate().all(|(wi, &wcol)| {
            match self.compare_cols.iter().position(|&c| c == wcol) {
                Some(ci) => e.input[ci] == e.output[wi],
                None => false, // unknown prior state: the write matters
            }
        })
    }

    /// Emitted pass count (for cycle budgeting).
    pub fn pass_count(&self, skip_stationary: bool) -> usize {
        if skip_stationary {
            self.entries
                .iter()
                .filter(|e| !self.entry_is_noop(e))
                .count()
        } else {
            self.entries.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::rcam::PrinsArray;

    /// The separate-output full adder of paper Fig. 6: inputs (c,a,b) over
    /// cols (2,0,1), outputs (c,s) over cols (2,3).
    fn full_adder() -> TruthTable {
        TruthTable::from_fn(vec![2, 0, 1], vec![2, 3], |i| {
            let (c, a, b) = (i[0] as u8, i[1] as u8, i[2] as u8);
            let sum = c + a + b;
            vec![sum >= 2, sum % 2 == 1]
        })
    }

    #[test]
    fn safe_order_respects_carry_hazards() {
        let t = full_adder();
        let order = t.safe_order();
        let pos = |c: bool, a: bool, b: bool| {
            order
                .iter()
                .position(|&i| t.entries[i].input == vec![c, a, b])
                .unwrap()
        };
        // (0,1,1) writes c=1 -> lands on (1,1,1): (1,1,1) must be earlier
        assert!(pos(true, true, true) < pos(false, true, true));
        // (1,0,0) writes c=0 -> lands on (0,0,0)
        assert!(pos(false, false, false) < pos(true, false, false));
    }

    #[test]
    fn full_adder_emits_8_passes_and_adds_correctly() {
        let t = full_adder();
        let mut prog = Program::new();
        // single-bit add over all rows: c,s initially 0
        t.emit(&mut prog, false);
        assert_eq!(prog.n_passes(), 8);

        let mut ctl = Controller::new(PrinsArray::single(64, 4));
        // rows encode (a, b) in cols 0,1; all four combos present
        for (r, (a, b)) in [(0, (0, 0)), (1, (0, 1)), (2, (1, 0)), (3, (1, 1))] {
            ctl.array.load_row_bits(r, 0, 1, a);
            ctl.array.load_row_bits(r, 1, 1, b);
        }
        ctl.execute(&prog);
        for (r, (a, b)) in [(0, (0u64, 0u64)), (1, (0, 1)), (2, (1, 0)), (3, (1, 1))] {
            let s = ctl.array.fetch_row_bits(r, 3, 1);
            let c = ctl.array.fetch_row_bits(r, 2, 1);
            assert_eq!(s, (a + b) % 2, "row {r}");
            assert_eq!(c, (a + b) / 2, "row {r}");
        }
    }

    #[test]
    fn skip_stationary_prunes_noops() {
        // In-place half adder: (c,a) -> (c', a') with a' = a xor c, c' = a and c
        let t = TruthTable::from_fn(vec![0, 1], vec![0, 1], |i| {
            let (c, a) = (i[0], i[1]);
            vec![c && a, c != a]
        });
        // (0,0)->(0,0) and (0,1)->(0,1) are stationary
        assert_eq!(t.pass_count(false), 4);
        assert_eq!(t.pass_count(true), 2);
        let mut p = Program::new();
        t.emit(&mut p, true);
        assert_eq!(p.n_passes(), 2);
    }

    #[test]
    fn write_to_uncompared_col_is_never_noop() {
        let t = TruthTable::from_fn(vec![0], vec![5], |i| vec![i[0]]);
        assert_eq!(t.pass_count(true), 2);
    }

    #[test]
    #[should_panic(expected = "cyclic")]
    fn cyclic_hazard_panics() {
        // swap table: (0)->(1), (1)->(0) over the same column: cyclic
        let mut t = TruthTable::new(vec![0], vec![0]);
        t.entry(vec![false], vec![true]);
        t.entry(vec![true], vec![false]);
        t.safe_order();
    }

    #[test]
    fn stationary_entries_unconstrained() {
        let t = TruthTable::from_fn(vec![0, 1], vec![2], |i| vec![i[0] ^ i[1]]);
        assert_eq!(t.safe_order().len(), 4);
    }
}
