//! IEEE-754 single-precision microcode (associative fp32 add / multiply).
//!
//! PRINS stores floats *unpacked* (1 sign + 8 exponent + 24 mantissa with
//! the hidden bit materialized = 33 bits/value): the storage manager
//! unpacks on load and repacks on readout, so the microcode never pays
//! for hidden-bit reconstruction per operation.
//!
//! Deviations from IEEE-754, documented in DESIGN.md (substitution
//! ledger): round-toward-zero (truncation, no guard/round/sticky bits),
//! flush-to-zero subnormals, exponent saturation instead of inf/NaN.
//! Property tests bound the error at ≤ 4 ulp per operation.
//!
//! Cycle counts are measured from the emitted microcode; the paper cites
//! 4,400 cycles for fp32 multiply on the associative processor of [79] —
//! `EXPERIMENTS.md §Microcode` compares our measured counts.

use super::add::{add_inplace_cond, add_inplace_src, BitSrc};
use super::cmp::field_cmp_cols;
use super::mul::mul;
use super::shift::{
    copy_col_cond, copy_field_cond, leading_zero_count, var_shift_left, var_shift_right,
};
use super::sub::{neg_inplace, sub_const, sub_inplace_cond};
use super::table::TruthTable;
use crate::isa::{Field, Instr, Pat, Program};

/// An unpacked fp32 value in the row: sign column, 8-bit exponent field,
/// 24-bit mantissa field (hidden bit explicit at bit 23).
#[derive(Clone, Copy, Debug)]
pub struct FloatField {
    /// Sign bit-column.
    pub sign: u16,
    /// 8-bit biased exponent field.
    pub exp: Field,
    /// 24-bit mantissa field (hidden bit explicit at bit 23).
    pub man: Field,
}

/// Bits of one unpacked fp32 value: sign + exponent + mantissa.
pub const UNPACKED_BITS: u16 = 1 + 8 + 24;

impl FloatField {
    /// Lay out sign/exp/man contiguously at `base`.
    pub fn at(base: u16) -> Self {
        FloatField {
            sign: base,
            exp: Field::new(base + 1, 8),
            man: Field::new(base + 9, 24),
        }
    }

    /// MSB-first magnitude columns (exp then man) for lexicographic compare.
    fn mag_cols_msb(&self) -> Vec<u16> {
        self.exp
            .cols_msb_first()
            .chain(self.man.cols_msb_first())
            .collect()
    }
}

/// Unpack an f32 into (sign, biased exp, 24-bit mantissa). FTZ: subnormals
/// and zeros map to (sign, 0, 0).
pub fn unpack_f32(v: f32) -> (bool, u8, u32) {
    let bits = v.to_bits();
    let sign = bits >> 31 == 1;
    let exp = ((bits >> 23) & 0xFF) as u8;
    let frac = bits & 0x7F_FFFF;
    if exp == 0 {
        (sign, 0, 0) // FTZ
    } else {
        (sign, exp, frac | 0x80_0000)
    }
}

/// Repack (sign, exp, man24) to f32 (inverse of `unpack_f32`).
pub fn pack_f32(sign: bool, exp: u8, man: u32) -> f32 {
    if exp == 0 || man & 0x80_0000 == 0 {
        return if sign { -0.0 } else { 0.0 };
    }
    let bits = ((sign as u32) << 31) | ((exp as u32) << 23) | (man & 0x7F_FFFF);
    f32::from_bits(bits)
}

/// Unpacked value as a 33-bit row integer (LSB first: sign, exp, man) —
/// the storage format.
pub fn unpacked_bits(v: f32) -> u64 {
    let (s, e, m) = unpack_f32(v);
    (s as u64) | ((e as u64) << 1) | ((m as u64) << 9)
}

/// Decode a 33-bit row integer (see [`unpacked_bits`]) back to f32.
pub fn bits_to_f32(bits: u64) -> f32 {
    pack_f32(
        bits & 1 == 1,
        ((bits >> 1) & 0xFF) as u8,
        ((bits >> 9) & 0xFF_FFFF) as u32,
    )
}

/// Scratch area required by `fp_add`: flags + working fields, 63 bits.
#[derive(Clone, Copy, Debug)]
pub struct FpScratch {
    /// First column of the scratch area.
    pub base: u16,
}

/// Width of the [`FpScratch`] area in bit-columns.
pub const FP_SCRATCH_BITS: u16 = 63;

impl FpScratch {
    /// Scratch area starting at column `base`.
    pub fn at(base: u16) -> Self {
        FpScratch { base }
    }
    fn carry(&self) -> u16 {
        self.base
    }
    fn lt(&self) -> u16 {
        self.base + 1
    }
    fn eq(&self) -> u16 {
        self.base + 2
    }
    fn seq(&self) -> u16 {
        self.base + 3
    }
    fn bsign(&self) -> u16 {
        self.base + 4
    }
    fn ssign(&self) -> u16 {
        self.base + 5
    }
    /// 25-bit working mantissa (bit 24 = carry-out).
    fn bman(&self) -> Field {
        Field::new(self.base + 6, 25)
    }
    fn sman(&self) -> Field {
        Field::new(self.base + 31, 24)
    }
    fn bexp(&self) -> Field {
        Field::new(self.base + 55, 8)
    }
}

/// fp32 multiply scratch width: 10-bit exponent accumulator + 48-bit
/// product + carry column.
pub const FP_MUL_SCRATCH_BITS: u16 = 10 + 48 + 1;

/// z := x * y (all three unpacked FloatFields; z disjoint from x and y).
///
/// `wide_base` is a scratch area of at least [`FP_MUL_SCRATCH_BITS`] bits.
pub fn fp_mul(prog: &mut Program, x: FloatField, y: FloatField, z: FloatField, wide_base: u16) {
    let eexp = Field::new(wide_base, 10);
    let pman = Field::new(wide_base + 10, 48);
    let carry = wide_base + 58;

    // 1. result sign = xor of signs (squaring: x ≡ y → sign is always 0)
    if x.sign == y.sign {
        prog.push(Instr::Compare(vec![]));
        prog.push(Instr::Write(vec![(z.sign, false)]));
    } else {
        let t =
            TruthTable::from_fn(vec![x.sign, y.sign], vec![z.sign], |i| vec![i[0] ^ i[1]]);
        t.emit(prog, true);
    }

    // 2. eexp = x.exp + y.exp - 127 (10-bit two's complement)
    prog.clear_field(eexp);
    copy_field_cond(prog, x.exp, eexp.slice(0, 8), &vec![]);
    add_inplace_cond(prog, eexp, y.exp, carry, &vec![]);
    sub_const(prog, eexp, 127, carry);

    // 3. pman = x.man * y.man (48-bit product; value in [1,4) × 2^46)
    mul(prog, x.man, y.man, pman, carry);

    // 4. normalize: top bit at 47 → take bits [47..24], exp += 1;
    //    else top at 46 → take bits [46..23]. (Truncation rounding.)
    let top = pman.col(47);
    add_inplace_src(
        prog,
        eexp,
        |_| BitSrc::Const(true),
        1,
        carry,
        &vec![(top, true)],
        true,
    );
    copy_field_cond(prog, pman.slice(24, 24), z.man, &vec![(top, true)]);
    copy_field_cond(prog, pman.slice(23, 24), z.man, &vec![(top, false)]);

    // 5. z.exp := eexp[0..8]
    copy_field_cond(prog, eexp.slice(0, 8), z.exp, &vec![]);

    // 6. clamps, in overwrite order
    //    zero operand (canonical zero: exp == 0) → zero result
    for op in [x, y] {
        let cpat: Pat = op.exp.cols().map(|c| (c, false)).collect();
        prog.push(Instr::Compare(cpat));
        let mut w: Pat = z.exp.pattern(0);
        w.extend(z.man.pattern(0));
        w.push((z.sign, false));
        prog.push(Instr::Write(w));
    }
    //    exponent underflow (eexp negative: bit 9 set) → zero
    prog.push(Instr::Compare(vec![(eexp.col(9), true)]));
    let mut w: Pat = z.exp.pattern(0);
    w.extend(z.man.pattern(0));
    prog.push(Instr::Write(w));
    //    overflow (eexp ≥ 256: bit 8 set, or eexp == 255) → saturate
    prog.push(Instr::Compare(vec![(eexp.col(9), false), (eexp.col(8), true)]));
    let mut w: Pat = z.exp.pattern(254);
    w.extend(z.man.pattern(0xFF_FFFF));
    prog.push(Instr::Write(w));
    let mut cpat: Pat = (0..8).map(|b| (eexp.col(b), true)).collect();
    cpat.push((eexp.col(8), false));
    cpat.push((eexp.col(9), false));
    prog.push(Instr::Compare(cpat));
    let mut w: Pat = z.exp.pattern(254);
    w.extend(z.man.pattern(0xFF_FFFF));
    prog.push(Instr::Write(w));
}

/// z := x + y (unpacked fp32; z disjoint from x and y).
///
/// `s` is an [`FP_SCRATCH_BITS`]-bit scratch area; `wexp` an additional
/// 8-bit working field (alignment distance, then reused for the lzc).
pub fn fp_add(
    prog: &mut Program,
    x: FloatField,
    y: FloatField,
    z: FloatField,
    s: FpScratch,
    wexp: Field,
) {
    assert!(wexp.width >= 8);
    let carry = s.carry();
    let (lt, eq, seq) = (s.lt(), s.eq(), s.seq());
    let (bman, sman, bexp) = (s.bman(), s.sman(), s.bexp());
    let wexp = wexp.slice(0, 8);

    // 1. lt := |x| < |y| (lexicographic over exp:man — valid because
    //    mantissas are normalized or zero)
    field_cmp_cols(prog, &x.mag_cols_msb(), &y.mag_cols_msb(), lt, eq);

    // 2. big := max-magnitude operand, small := the other
    prog.clear_field(bman);
    copy_field_cond(prog, x.man, bman.slice(0, 24), &vec![(lt, false)]);
    copy_field_cond(prog, y.man, bman.slice(0, 24), &vec![(lt, true)]);
    copy_field_cond(prog, x.exp, bexp, &vec![(lt, false)]);
    copy_field_cond(prog, y.exp, bexp, &vec![(lt, true)]);
    copy_field_cond(prog, x.man, sman, &vec![(lt, true)]);
    copy_field_cond(prog, y.man, sman, &vec![(lt, false)]);
    copy_col_cond(prog, x.sign, s.bsign(), &vec![(lt, false)]);
    copy_col_cond(prog, y.sign, s.bsign(), &vec![(lt, true)]);
    copy_col_cond(prog, x.sign, s.ssign(), &vec![(lt, true)]);
    copy_col_cond(prog, y.sign, s.ssign(), &vec![(lt, false)]);

    // 3. wexp := bexp - small.exp (alignment distance)
    copy_field_cond(prog, x.exp, wexp, &vec![(lt, true)]);
    copy_field_cond(prog, y.exp, wexp, &vec![(lt, false)]);
    // wexp = bexp - wexp: subtract then negate (negation staged via `eq`,
    // which is dead after step 1).
    sub_inplace_cond(prog, wexp, bexp, carry, &vec![]);
    neg_inplace(prog, wexp, carry, eq);

    // 4. align: sman >>= wexp (per-row barrel shift; distances ≥ 24 clear)
    var_shift_right(prog, sman, wexp, &vec![]);

    // 5. seq := (bsign == ssign)
    let t = TruthTable::from_fn(vec![s.bsign(), s.ssign()], vec![seq], |i| {
        vec![i[0] == i[1]]
    });
    t.emit(prog, true);

    // 6. same sign: bman += sman; different sign: bman -= sman
    //    (big ≥ small in magnitude, so the subtract cannot borrow out)
    add_inplace_cond(prog, bman, sman, carry, &vec![(seq, true)]);
    sub_inplace_cond(prog, bman, sman, carry, &vec![(seq, false)]);

    // 7. carry-out (same-sign only): exp += 1 BEFORE the right shift — the
    //    shift's final step clears the condition bit itself.
    let cout = bman.col(24);
    add_inplace_src(
        prog,
        bexp,
        |_| BitSrc::Const(true),
        1,
        carry,
        &vec![(cout, true)],
        true,
    );
    super::shift::shift_right_inplace(prog, bman, 1, &vec![(cout, true)]);

    // 8. cancellation (different-sign only): renormalize by the leading-
    //    zero count. wexp is reused (alignment distance is dead).
    prog.clear_field(wexp);
    leading_zero_count(prog, bman.slice(0, 24), wexp.slice(0, 5));
    var_shift_left(prog, bman.slice(0, 24), wexp.slice(0, 5), &vec![(seq, false)]);
    sub_inplace_cond(prog, bexp, wexp.slice(0, 5), carry, &vec![(seq, false)]);

    // 9. write out
    copy_col_cond(prog, s.bsign(), z.sign, &vec![]);
    copy_field_cond(prog, bexp, z.exp, &vec![]);
    copy_field_cond(prog, bman.slice(0, 24), z.man, &vec![]);

    // 10. zero clamp: mantissa cancelled to zero → canonical zero
    let cpat: Pat = z.man.cols().map(|c| (c, false)).collect();
    prog.push(Instr::Compare(cpat));
    let mut w: Pat = z.exp.pattern(0);
    w.push((z.sign, false));
    prog.push(Instr::Write(w));
}

/// z := x - y = x + (-y): copy y with flipped sign, then fp_add.
/// `ycopy` must be a spare unpacked field.
pub fn fp_sub(
    prog: &mut Program,
    x: FloatField,
    y: FloatField,
    z: FloatField,
    ycopy: FloatField,
    s: FpScratch,
    wexp: Field,
) {
    copy_field_cond(prog, y.exp, ycopy.exp, &vec![]);
    copy_field_cond(prog, y.man, ycopy.man, &vec![]);
    let t = TruthTable::from_fn(vec![y.sign], vec![ycopy.sign], |i| vec![!i[0]]);
    t.emit(prog, false);
    fp_add(prog, x, ycopy, z, s, wexp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Controller;
    use crate::rcam::PrinsArray;

    fn splitmix(seed: &mut u64) -> u64 {
        *seed = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = *seed;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn rand_f32(seed: &mut u64) -> f32 {
        let m = (splitmix(seed) % 2_000_000) as f32 / 1000.0 - 1000.0;
        if m == 0.0 {
            1.0
        } else {
            m
        }
    }

    fn ulp_diff(a: f32, b: f32) -> u64 {
        if a == b || (a == 0.0 && b == 0.0) {
            return 0;
        }
        let key = |v: f32| {
            let b = v.to_bits();
            if b >> 31 == 1 {
                -((b & 0x7FFF_FFFF) as i64)
            } else {
                (b & 0x7FFF_FFFF) as i64
            }
        };
        (key(a) - key(b)).unsigned_abs()
    }

    #[test]
    fn unpack_pack_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 3.14159, -2.5e10, 7.0e-20, 1.5e38] {
            assert_eq!(bits_to_f32(unpacked_bits(v)), v);
        }
        assert_eq!(bits_to_f32(unpacked_bits(1.0e-40)), 0.0); // FTZ
    }

    #[test]
    fn fp_mul_random_within_4ulp() {
        let x = FloatField::at(0);
        let y = FloatField::at(33);
        let z = FloatField::at(66);
        let mut prog = Program::new();
        fp_mul(&mut prog, x, y, z, 100);
        let mut c = Controller::new(PrinsArray::single(64, 168));
        let mut seed = 42;
        let mut cases = Vec::new();
        for r in 0..64 {
            let (a, b) = (rand_f32(&mut seed), rand_f32(&mut seed));
            c.array.load_row_bits(r, 0, 33, unpacked_bits(a));
            c.array.load_row_bits(r, 33, 33, unpacked_bits(b));
            cases.push((a, b));
        }
        c.execute(&prog);
        for (r, (a, b)) in cases.iter().enumerate() {
            let got = bits_to_f32(c.array.fetch_row_bits(r, 66, 33));
            let exact = a * b;
            assert!(
                ulp_diff(got, exact) <= 4,
                "row {r}: {a} * {b} = {exact}, got {got}"
            );
        }
    }

    #[test]
    fn fp_mul_zero_and_identity() {
        let x = FloatField::at(0);
        let y = FloatField::at(33);
        let z = FloatField::at(66);
        let mut prog = Program::new();
        fp_mul(&mut prog, x, y, z, 100);
        let mut c = Controller::new(PrinsArray::single(8, 168));
        let cases = [
            (0.0f32, 5.0f32),
            (5.0, 0.0),
            (0.0, 0.0),
            (1.0, 7.25),
            (-1.0, 7.25),
            (2.0, -3.5),
            (0.5, 0.5),
            (-4.0, -0.25),
        ];
        for (r, (a, b)) in cases.iter().enumerate() {
            c.array.load_row_bits(r, 0, 33, unpacked_bits(*a));
            c.array.load_row_bits(r, 33, 33, unpacked_bits(*b));
        }
        c.execute(&prog);
        for (r, (a, b)) in cases.iter().enumerate() {
            let got = bits_to_f32(c.array.fetch_row_bits(r, 66, 33));
            assert_eq!(got, a * b, "row {r}: {a} * {b}");
        }
    }

    #[test]
    fn fp_add_random_within_4ulp() {
        let x = FloatField::at(0);
        let y = FloatField::at(33);
        let z = FloatField::at(66);
        let s = FpScratch::at(100);
        let wexp = Field::new(100 + FP_SCRATCH_BITS, 8);
        let mut prog = Program::new();
        fp_add(&mut prog, x, y, z, s, wexp);
        let mut c = Controller::new(PrinsArray::single(64, 200));
        let mut seed = 7;
        let mut cases = Vec::new();
        for r in 0..64 {
            let (a, b) = (rand_f32(&mut seed), rand_f32(&mut seed));
            c.array.load_row_bits(r, 0, 33, unpacked_bits(a));
            c.array.load_row_bits(r, 33, 33, unpacked_bits(b));
            cases.push((a, b));
        }
        c.execute(&prog);
        for (r, (a, b)) in cases.iter().enumerate() {
            let got = bits_to_f32(c.array.fetch_row_bits(r, 66, 33));
            let exact = a + b;
            assert!(
                ulp_diff(got, exact) <= 4,
                "row {r}: {a} + {b} = {exact}, got {got}"
            );
        }
    }

    #[test]
    fn fp_add_special_cases() {
        let x = FloatField::at(0);
        let y = FloatField::at(33);
        let z = FloatField::at(66);
        let s = FpScratch::at(100);
        let wexp = Field::new(100 + FP_SCRATCH_BITS, 8);
        let mut prog = Program::new();
        fp_add(&mut prog, x, y, z, s, wexp);
        let mut c = Controller::new(PrinsArray::single(10, 200));
        let cases = [
            (1.0f32, -1.0f32), // exact cancellation → canonical zero
            (0.0, 3.5),
            (3.5, 0.0),
            (0.0, 0.0),
            (1.0, 1.0),
            (1.5, -0.75),
            (1.0e10, 1.0), // small operand fully truncated away
            (-2.0, -2.0),
            (255.0, 1.0), // carry into the next exponent
            (1.0, -0.9999999),
        ];
        for (r, (a, b)) in cases.iter().enumerate() {
            c.array.load_row_bits(r, 0, 33, unpacked_bits(*a));
            c.array.load_row_bits(r, 33, 33, unpacked_bits(*b));
        }
        c.execute(&prog);
        for (r, (a, b)) in cases.iter().enumerate() {
            let got = bits_to_f32(c.array.fetch_row_bits(r, 66, 33));
            let exact = a + b;
            assert!(
                ulp_diff(got, exact) <= 4,
                "row {r}: {a} + {b} = {exact}, got {got}"
            );
        }
    }

    #[test]
    fn fp_sub_is_add_of_negation() {
        let x = FloatField::at(0);
        let y = FloatField::at(33);
        let z = FloatField::at(66);
        let yc = FloatField::at(99);
        let s = FpScratch::at(132);
        let wexp = Field::new(132 + FP_SCRATCH_BITS, 8);
        let mut prog = Program::new();
        fp_sub(&mut prog, x, y, z, yc, s, wexp);
        let mut c = Controller::new(PrinsArray::single(16, 220));
        let mut seed = 99;
        let mut cases = Vec::new();
        for r in 0..16 {
            let (a, b) = (rand_f32(&mut seed), rand_f32(&mut seed));
            c.array.load_row_bits(r, 0, 33, unpacked_bits(a));
            c.array.load_row_bits(r, 33, 33, unpacked_bits(b));
            cases.push((a, b));
        }
        c.execute(&prog);
        for (r, (a, b)) in cases.iter().enumerate() {
            let got = bits_to_f32(c.array.fetch_row_bits(r, 66, 33));
            assert!(ulp_diff(got, a - b) <= 4, "row {r}: {a} - {b}, got {got}");
        }
    }

    #[test]
    fn microcode_cost_in_expected_band() {
        // Regression guard around the measured pass/cycle counts that
        // EXPERIMENTS.md compares with the paper's 4,400-cycle figure.
        let x = FloatField::at(0);
        let y = FloatField::at(33);
        let z = FloatField::at(66);
        let mut pm = Program::new();
        fp_mul(&mut pm, x, y, z, 100);
        let s = FpScratch::at(100);
        let wexp = Field::new(164, 8);
        let mut pa = Program::new();
        fp_add(&mut pa, x, y, z, s, wexp);
        assert!(
            pm.cycle_estimate() > 1_000 && pm.cycle_estimate() < 20_000,
            "fp_mul cycles = {}",
            pm.cycle_estimate()
        );
        assert!(
            pa.cycle_estimate() > 500 && pa.cycle_estimate() < 10_000,
            "fp_add cycles = {}",
            pa.cycle_estimate()
        );
    }
}
