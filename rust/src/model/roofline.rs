//! Roofline baseline model (paper §6, Eq. 3):
//!
//!   Attainable Perf = min(Peak Perf, AI × Peak Storage BW)
//!
//! The paper evaluates PRINS against "a computer architecture with a
//! bandwidth-limited external storage": a 10 GB/s storage appliance [35]
//! and a 24 GB/s NVDIMM store [34], with a KNL-class compute roof
//! (Fig. 15). The baselines here are *analytical by construction*, exactly
//! as in the paper; `runtime::golden` additionally executes the same
//! kernels for numeric cross-validation.

/// A bandwidth-limited external storage tier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StorageTier {
    /// Tier name, as printed in figures.
    pub name: &'static str,
    /// Peak storage bandwidth [GB/s].
    pub bandwidth_gb_s: f64,
}

/// Paper's 10 GB/s high-end storage appliance [35].
pub const STORAGE_APPLIANCE: StorageTier = StorageTier {
    name: "storage appliance (10 GB/s)",
    bandwidth_gb_s: 10.0,
};

/// Paper's 24 GB/s NVDIMM storage [34].
pub const NVDIMM: StorageTier = StorageTier {
    name: "NVDIMM (24 GB/s)",
    bandwidth_gb_s: 24.0,
};

/// Compute roof of the reference architecture. Paper Fig. 15 uses Knights
/// Landing: ~3 TFLOP/s single-precision-ish DP roof; the exact roof never
/// binds for the paper's low-AI workloads, but it caps the model.
#[derive(Clone, Copy, Debug)]
pub struct ComputeRoof {
    /// Roof name, as printed in figures.
    pub name: &'static str,
    /// Peak compute [GFLOP/s].
    pub peak_gflops: f64,
}

/// The paper's Knights-Landing-class compute roof (Fig. 15).
pub const KNL_ROOF: ComputeRoof = ComputeRoof {
    name: "Xeon Phi KNL (≈3 TFLOP/s)",
    peak_gflops: 3_000.0,
};

/// Workload arithmetic intensities used in §6 (FLOP or OP per byte).
pub mod ai {
    /// Euclidean distance: 3 FLOP per 4-byte attribute fetch.
    pub const EUCLIDEAN: f64 = 3.0 / 4.0;
    /// Dot product: 2 FLOP per 4-byte attribute fetch.
    pub const DOT_PRODUCT: f64 = 2.0 / 4.0;
    /// Histogram: 2 OP (shift + increment) per 4-byte sample.
    pub const HISTOGRAM: f64 = 2.0 / 4.0;
    /// SpMV: 1/6 FLOP per byte [65].
    pub const SPMV: f64 = 1.0 / 6.0;
    /// BFS: 1 OP per 4 bytes.
    pub const BFS: f64 = 1.0 / 4.0;
}

/// Eq. 3: attainable GFLOPS (or GOPS) of the reference architecture.
pub fn attainable_gflops(roof: &ComputeRoof, tier: &StorageTier, ai: f64) -> f64 {
    (ai * tier.bandwidth_gb_s).min(roof.peak_gflops)
}

/// Attainable GTEPS for BFS: the paper states 2.5 GTEPS @ 10 GB/s and
/// ~6 GTEPS @ 24 GB/s, i.e. bandwidth / 4 bytes per traversed edge.
pub fn attainable_gteps(tier: &StorageTier) -> f64 {
    tier.bandwidth_gb_s / 4.0
}

/// One point of the Fig. 15 roofline chart: attainable performance at a
/// given arithmetic intensity.
pub fn roofline_point(roof: &ComputeRoof, bandwidth_gb_s: f64, ai: f64) -> f64 {
    (ai * bandwidth_gb_s).min(roof.peak_gflops)
}

/// PRINS "internal bandwidth" roof (Fig. 15): an entire bit column moves
/// to the tag register in one cycle — `rows` bits per 2 ns.
pub fn prins_internal_bandwidth_gb_s(rows: u64, freq_hz: f64) -> f64 {
    (rows as f64 / 8.0) * freq_hz / 1e9
}

/// PRINS peak theoretical GFLOPS (Fig. 15): one fp32 MAC over the entire
/// dataset in parallel, at the measured fp32 MAC microcode latency.
pub fn prins_peak_gflops(rows: u64, mac_cycles: u64, freq_hz: f64) -> f64 {
    let t = mac_cycles as f64 / freq_hz;
    (2.0 * rows as f64 / t) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_attainable_numbers() {
        // §6: "attainable performance of Euclidean distance calculation is
        // 7.5 GFLOPS for a storage appliance and 18 GFLOPS for NVDIMM"
        assert!((attainable_gflops(&KNL_ROOF, &STORAGE_APPLIANCE, ai::EUCLIDEAN) - 7.5).abs() < 1e-9);
        assert!((attainable_gflops(&KNL_ROOF, &NVDIMM, ai::EUCLIDEAN) - 18.0).abs() < 1e-9);
        // dot product: 5 GFLOPS / 12 GFLOPS
        assert!((attainable_gflops(&KNL_ROOF, &STORAGE_APPLIANCE, ai::DOT_PRODUCT) - 5.0).abs() < 1e-9);
        assert!((attainable_gflops(&KNL_ROOF, &NVDIMM, ai::DOT_PRODUCT) - 12.0).abs() < 1e-9);
        // BFS: 2.5 GTEPS / 6 GTEPS
        assert!((attainable_gteps(&STORAGE_APPLIANCE) - 2.5).abs() < 1e-9);
        assert!((attainable_gteps(&NVDIMM) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn roofline_is_monotone_and_capped() {
        let mut last = 0.0;
        for ai_exp in -6..10 {
            let ai = 2f64.powi(ai_exp);
            let p = roofline_point(&KNL_ROOF, 10.0, ai);
            assert!(p >= last);
            assert!(p <= KNL_ROOF.peak_gflops);
            last = p;
        }
        assert_eq!(roofline_point(&KNL_ROOF, 10.0, 1e9), KNL_ROOF.peak_gflops);
    }

    #[test]
    fn prins_internal_bw_dwarfs_external() {
        // 4 TB PRINS (paper Fig. 15): 1T 32-bit elements = 1e12 rows
        let bw = prins_internal_bandwidth_gb_s(1_000_000_000_000, 500e6);
        assert!(bw > 1e7, "PRINS internal BW {bw} GB/s"); // vs 10 GB/s external
    }

    #[test]
    fn prins_peak_scales_linearly_with_rows() {
        let a = prins_peak_gflops(1_000_000, 10_000, 500e6);
        let b = prins_peak_gflops(100_000_000, 10_000, 500e6);
        assert!((b / a - 100.0).abs() < 1e-9);
    }
}
