//! Analytical models: the roofline baseline of Eq. 3, the power/efficiency
//! model behind the paper's GFLOPS/W numbers, and the TPU feasibility
//! estimate for the L1 kernel.

pub mod figures;
pub mod power;
pub mod roofline;
pub mod tpu;

pub use power::{efficiency, extrapolate_rows, Efficiency};
pub use roofline::{
    attainable_gflops, attainable_gteps, prins_internal_bandwidth_gb_s,
    prins_peak_gflops, roofline_point, ComputeRoof, StorageTier, KNL_ROOF,
    NVDIMM, STORAGE_APPLIANCE,
};
