//! Paper-figure harnesses: one function per table/figure of §6, returning
//! a [`Table`] with exactly the rows/series the paper reports. The benches
//! (`rust/benches/fig*.rs`) and `examples/end_to_end.rs` both call these,
//! so the printed artifacts and EXPERIMENTS.md agree.
//!
//! Methodology (DESIGN.md §1): associative kernel cycle counts are
//! measured on reduced row counts (they are row-count-independent — the
//! property the paper is built on) and throughput is extrapolated to the
//! paper's 1M/10M/100M element scales; energy events scale linearly in
//! rows (`model::power::extrapolate_rows`).

use crate::algorithms::{
    dot::DotKernel, euclidean::EuclideanKernel, histogram::HistogramKernel,
    paper_model_teps, spmv::ReduceEngine, spmv::SpmvKernel, BfsKernel,
};
use crate::controller::Controller;
use crate::metrics::table::{fmt_ratio, Table};
use crate::model::power::{efficiency, extrapolate_rows, flops};
use crate::model::roofline::{
    self, attainable_gflops, attainable_gteps, KNL_ROOF, NVDIMM, STORAGE_APPLIANCE,
};
use crate::rcam::{ExecBackend, PrinsArray};
use crate::storage::StorageManager;
use crate::workloads::{
    synth_hist_samples, synth_samples, synth_uniform, Rng, PAPER_GRAPHS, PAPER_MATRICES,
};

/// Dense-kernel simulation size (cycles are N-independent; this only needs
/// to be big enough to be a real parallel workload).
pub const SIM_ROWS: usize = 1024;
/// Paper Fig. 12 dataset sizes.
pub const PAPER_SIZES: [u64; 3] = [1_000_000, 10_000_000, 100_000_000];
/// DP uses 16-dimensional vectors (paper §6); we use the same for ED.
pub const DIMS: usize = 16;

/// One dense kernel's measured simulation point (extrapolation input).
pub struct DenseKernelRun {
    /// Kernel label (ED / DP / Hist).
    pub name: &'static str,
    /// Measured device cycles at simulation scale.
    pub sim_cycles: u64,
    /// Measured runtime \[s\] at simulation scale.
    pub runtime_s: f64,
    /// FLOP (or OP) per data element (row) at paper scale.
    pub flops_per_row: f64,
    /// energy at SIM_ROWS (J), extrapolated linearly per row.
    pub sim_stats: crate::controller::ExecStats,
    /// Rows of the simulated run.
    pub sim_rows: u64,
}

/// Run the three dense kernels (ED / DP / Hist) at simulation scale.
pub fn run_dense_kernels(dims: usize, sim_rows: usize) -> Vec<DenseKernelRun> {
    run_dense_kernels_on(dims, sim_rows, ExecBackend::Serial)
}

/// [`run_dense_kernels`] with an explicit simulator execution backend
/// (device cycles/energy are backend-independent; the backend only sets
/// how fast the simulation itself runs).
pub fn run_dense_kernels_on(
    dims: usize,
    sim_rows: usize,
    backend: ExecBackend,
) -> Vec<DenseKernelRun> {
    let mut out = Vec::new();
    let freq = crate::rcam::DeviceModel::default().freq_hz;
    // --- Euclidean distance (1 center per paper AI accounting) ---
    {
        let x = synth_samples(sim_rows, dims, 4, 1);
        let centers = synth_uniform(dims, 2);
        let layout = crate::algorithms::euclidean::EuclideanLayout::new(dims);
        let mut array =
            PrinsArray::single(sim_rows, layout.width as usize).with_backend(backend);
        let mut sm = StorageManager::new(sim_rows);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, sim_rows, dims);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &sm, &centers, 1);
        out.push(DenseKernelRun {
            name: "ED",
            sim_cycles: res.stats.cycles,
            runtime_s: res.stats.cycles as f64 / freq,
            flops_per_row: 3.0 * dims as f64,
            sim_stats: res.stats,
            sim_rows: sim_rows as u64,
        });
    }
    // --- Dot product ---
    {
        let x = synth_samples(sim_rows, dims, 4, 3);
        let h = synth_uniform(dims, 4);
        let layout = crate::algorithms::dot::DotLayout::new(dims);
        let mut array =
            PrinsArray::single(sim_rows, layout.width as usize).with_backend(backend);
        let mut sm = StorageManager::new(sim_rows);
        let kern = DotKernel::load(&mut sm, &mut array, &x, sim_rows, dims);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &sm, &h);
        out.push(DenseKernelRun {
            name: "DP",
            sim_cycles: res.stats.cycles,
            runtime_s: res.stats.cycles as f64 / freq,
            flops_per_row: 2.0 * dims as f64,
            sim_stats: res.stats,
            sim_rows: sim_rows as u64,
        });
    }
    // --- Histogram ---
    {
        let xs = synth_hist_samples(sim_rows, 5);
        // deployment row width (paper §5.1): 256-bit rows — affects match-line energy
        let mut array = PrinsArray::single(sim_rows, 256).with_backend(backend);
        let mut sm = StorageManager::new(sim_rows);
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        out.push(DenseKernelRun {
            name: "Hist",
            sim_cycles: res.stats.cycles,
            runtime_s: res.stats.cycles as f64 / freq,
            flops_per_row: 2.0,
            sim_stats: res.stats,
            sim_rows: sim_rows as u64,
        });
    }
    out
}

/// Figure 12: ED/DP/Hist performance normalized to the bandwidth-limited
/// reference (10 GB/s appliance, 24 GB/s NVDIMM), for 1M/10M/100M
/// elements, plus the §6 power-efficiency numbers.
pub fn fig12(dims: usize, sim_rows: usize) -> Table {
    fig12_on(dims, sim_rows, ExecBackend::Serial)
}

/// [`fig12`] with an explicit simulator execution backend.
pub fn fig12_on(dims: usize, sim_rows: usize, backend: ExecBackend) -> Table {
    let dev = crate::rcam::DeviceModel::default();
    let runs = run_dense_kernels_on(dims, sim_rows, backend);
    let mut t = Table::new(
        "Fig. 12 — dense kernels, normalized to bandwidth-limited reference",
        &[
            "kernel", "N", "PRINS GFLOPS", "vs 10GB/s", "vs 24GB/s", "GFLOPS/W",
        ],
    );
    for r in &runs {
        let (ai, base10, base24) = match r.name {
            "ED" => (
                roofline::ai::EUCLIDEAN,
                attainable_gflops(&KNL_ROOF, &STORAGE_APPLIANCE, roofline::ai::EUCLIDEAN),
                attainable_gflops(&KNL_ROOF, &NVDIMM, roofline::ai::EUCLIDEAN),
            ),
            "DP" => (
                roofline::ai::DOT_PRODUCT,
                attainable_gflops(&KNL_ROOF, &STORAGE_APPLIANCE, roofline::ai::DOT_PRODUCT),
                attainable_gflops(&KNL_ROOF, &NVDIMM, roofline::ai::DOT_PRODUCT),
            ),
            _ => (
                roofline::ai::HISTOGRAM,
                attainable_gflops(&KNL_ROOF, &STORAGE_APPLIANCE, roofline::ai::HISTOGRAM),
                attainable_gflops(&KNL_ROOF, &NVDIMM, roofline::ai::HISTOGRAM),
            ),
        };
        let _ = ai;
        for &n in &PAPER_SIZES {
            let total_flops = r.flops_per_row * n as f64;
            let gflops = total_flops / r.runtime_s / 1e9;
            let stats = extrapolate_rows(&r.sim_stats, n as f64 / r.sim_rows as f64);
            let eff = efficiency(&stats, &dev, total_flops);
            t.row(vec![
                r.name.into(),
                format!("{}M", n / 1_000_000),
                format!("{gflops:.1}"),
                fmt_ratio(gflops / base10),
                fmt_ratio(gflops / base24),
                format!("{:.2}", eff.gflops_per_w),
            ]);
        }
    }
    t
}

/// Figure 13: SpMV normalized performance + power efficiency over the 18
/// paper matrices (density-matched synthetics, simulated scaled-down and
/// extrapolated; see module docs).
pub fn fig13(sim_n_target: usize) -> Table {
    fig13_on(sim_n_target, ExecBackend::Serial)
}

/// [`fig13`] with an explicit simulator execution backend.
pub fn fig13_on(sim_n_target: usize, backend: ExecBackend) -> Table {
    let dev = crate::rcam::DeviceModel::default();
    let freq = dev.freq_hz;
    let mut t = Table::new(
        "Fig. 13 — SpMV normalized performance & power efficiency (by density)",
        &[
            "matrix", "n", "nnz", "density", "PRINS GFLOPS", "vs 10GB/s",
            "vs 24GB/s", "GFLOPS/W",
        ],
    );
    let base10 = attainable_gflops(&KNL_ROOF, &STORAGE_APPLIANCE, roofline::ai::SPMV);
    let base24 = attainable_gflops(&KNL_ROOF, &NVDIMM, roofline::ai::SPMV);
    for (mi, m) in PAPER_MATRICES.iter().enumerate() {
        let scale = (m.n / sim_n_target).max(1);
        let a = m.synthesize(scale, 100 + mi as u64);
        let mut rng = Rng::seed_from(200 + mi as u64);
        let x: Vec<f32> = (0..a.n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let mut array = PrinsArray::single(a.nnz(), 256).with_backend(backend);
        let mut sm = StorageManager::new(a.nnz());
        let kern = SpmvKernel::load(&mut sm, &mut array, &a);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &x, ReduceEngine::ChainTree);
        // extrapolate to the full matrix: broadcast scales with n, the
        // parallel multiply and the (density-preserving) chain reduction
        // do not.
        let bcast_full = res.broadcast_cycles as f64 * (m.n as f64 / a.n as f64);
        let cycles_full = bcast_full + (res.multiply_cycles + res.reduce_cycles) as f64;
        let runtime = cycles_full / freq;
        let total_flops = flops::spmv(m.nnz as u64);
        let gflops = total_flops / runtime / 1e9;
        let stats = extrapolate_rows(&res.stats, m.nnz as f64 / a.nnz() as f64);
        let mut stats = stats;
        stats.cycles = cycles_full as u64;
        let eff = efficiency(&stats, &dev, total_flops);
        t.row(vec![
            m.name.into(),
            format!("{}", m.n),
            format!("{}", m.nnz),
            format!("{:.1}", m.density()),
            format!("{gflops:.2}"),
            fmt_ratio(gflops / base10),
            fmt_ratio(gflops / base24),
            format!("{:.2}", eff.gflops_per_w),
        ]);
    }
    t
}

/// Figure 14: BFS normalized performance over the Table 3 graphs.
/// Reports BOTH the literal Algorithm 5 measurement and the paper's
/// vertex-serial analytical model (see EXPERIMENTS.md for the gap
/// discussion).
pub fn fig14(sim_vertices: usize) -> Table {
    fig14_on(sim_vertices, ExecBackend::Serial)
}

/// [`fig14`] with an explicit simulator execution backend.
pub fn fig14_on(sim_vertices: usize, backend: ExecBackend) -> Table {
    let dev = crate::rcam::DeviceModel::default();
    let freq = dev.freq_hz;
    let mut t = Table::new(
        "Fig. 14 — BFS normalized performance (by avg out-degree)",
        &[
            "graph", "avgD", "cyc/edge-iter", "literal GTEPS", "lit vs 2.5GTEPS",
            "model GTEPS", "model vs 2.5GTEPS", "model vs 6GTEPS",
        ],
    );
    /// paper-model controller cycles per serially examined vertex
    const MODEL_CPV: f64 = 3.0;
    for (gi, pg) in PAPER_GRAPHS.iter().enumerate() {
        let g = pg.synthesize(sim_vertices, 300 + gi as u64);
        let mut array = PrinsArray::single(g.edges(), 128).with_backend(backend);
        let mut sm = StorageManager::new(g.edges());
        let kern = BfsKernel::load(&mut sm, &mut array, &g);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, 0);
        let cpi = res.stats.cycles as f64 / res.iterations.max(1) as f64;
        let literal_teps = freq / cpi; // one edge per iteration
        let model_teps = paper_model_teps(pg.avg_d, freq, MODEL_CPV);
        t.row(vec![
            pg.name.into(),
            format!("{:.0}", pg.avg_d),
            format!("{cpi:.1}"),
            format!("{:.3}", literal_teps / 1e9),
            fmt_ratio(literal_teps / (attainable_gteps(&STORAGE_APPLIANCE) * 1e9)),
            format!("{:.1}", model_teps / 1e9),
            fmt_ratio(model_teps / (attainable_gteps(&STORAGE_APPLIANCE) * 1e9)),
            fmt_ratio(model_teps / (attainable_gteps(&NVDIMM) * 1e9)),
        ]);
    }
    t
}

/// Figure 15: roofline chart — KNL behind a 10 GB/s appliance vs 4 TB
/// PRINS (1T 32-bit rows), sampled over arithmetic intensities.
pub fn fig15() -> Table {
    let dev = crate::rcam::DeviceModel::default();
    let mut t = Table::new(
        "Fig. 15 — roofline: KNL + external storage vs 4 TB PRINS",
        &[
            "AI (FLOP/B)", "KNL+10GB/s GFLOPS", "KNL+DRAM GFLOPS", "PRINS GFLOPS",
        ],
    );
    let prins_rows: u64 = 1_000_000_000_000; // 1T elements = 4 TB of data
    // fp32 MAC latency from the measured microcode (mul + add)
    let mac_cycles = {
        use crate::isa::{Field, Program};
        use crate::micro::float::{FloatField, FpScratch, FP_SCRATCH_BITS};
        let x = FloatField::at(0);
        let y = FloatField::at(33);
        let z = FloatField::at(66);
        let mut p = Program::new();
        crate::micro::float::fp_mul(&mut p, x, y, z, 100);
        let s = FpScratch::at(100);
        let w = Field::new(100 + FP_SCRATCH_BITS, 8);
        let zz = FloatField::at(170);
        crate::micro::float::fp_add(&mut p, z, x, zz, s, w);
        p.cycle_estimate()
    };
    let prins_peak = roofline::prins_peak_gflops(prins_rows, mac_cycles, dev.freq_hz);
    let knl_dram_bw = 400.0; // KNL MCDRAM-ish GB/s [20]
    for ai_exp in [-4i32, -3, -2, -1, 0, 1, 2, 3, 4, 6, 8, 10] {
        let ai = 2f64.powi(ai_exp);
        t.row(vec![
            format!("2^{ai_exp}"),
            format!("{:.2}", roofline::roofline_point(&KNL_ROOF, 10.0, ai)),
            format!("{:.1}", roofline::roofline_point(&KNL_ROOF, knl_dram_bw, ai)),
            format!(
                "{:.0}",
                // PRINS never leaves the arrays: bounded by its own compute
                // roof at every AI (internal BW >> any workload demand)
                prins_peak
            ),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_shape_criteria() {
        // (i) normalized speedup grows linearly in N and crosses 1e3 at
        // 100M for every dense kernel
        let t = fig12(4, 128); // small dims/rows for test speed
        assert_eq!(t.rows.len(), 9);
        for chunk in t.rows.chunks(3) {
            let v: Vec<f64> = chunk.iter().map(|r| r[3].parse().unwrap()).collect();
            assert!(v[1] / v[0] > 9.0 && v[1] / v[0] < 11.0, "linear in N: {v:?}");
            assert!(v[2] > 100.0, "orders of magnitude at 100M: {v:?}");
        }
    }

    #[test]
    fn fig12_backend_invariant() {
        // device cycles/energy are simulator-backend-independent, so the
        // reported tables must match cell for cell
        let s = fig12_on(4, 128, ExecBackend::Serial);
        let t = fig12_on(4, 128, ExecBackend::Threaded(3));
        assert_eq!(s.rows, t.rows);
    }

    #[test]
    fn fig14_shape_criteria() {
        let t = fig14(1 << 9);
        assert_eq!(t.rows.len(), 6);
        // model speedups ordered by avg degree, max ≈ 7x, min ≈ 1-2x
        let model: Vec<f64> = t.rows.iter().map(|r| r[6].parse().unwrap()).collect();
        for w in model.windows(2) {
            assert!(w[0] <= w[1] + 1e-9);
        }
        assert!(model[5] > 5.0 && model[5] < 9.0, "hollywood ≈ 7x: {model:?}");
        assert!(model[0] < 3.0, "indochina small: {model:?}");
    }

    #[test]
    fn fig15_prins_dwarfs_external() {
        let t = fig15();
        let knl: f64 = t.rows[0][1].parse().unwrap();
        let prins: f64 = t.rows[0][3].parse().unwrap();
        assert!(prins / knl > 1e4);
    }
}
