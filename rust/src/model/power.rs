//! Power/efficiency model (paper §6: in-house power simulator; reported
//! efficiencies 2.9 / 2.7 / 2.4 GFLOPS/W for ED / DP / histogram and
//! 3–4 GFLOPS/W for SpMV).
//!
//! Efficiency = FLOP-equivalents / energy. FLOPs are counted exactly as
//! the paper's AI definitions count them (3 per attribute for ED, 2 for
//! DP, 2 OP per histogram sample, 2 per SpMV nonzero), energy comes from
//! the simulator's event ledger × device constants.

use crate::controller::ExecStats;
use crate::rcam::DeviceModel;

/// Throughput + power efficiency of one kernel execution.
#[derive(Clone, Debug)]
pub struct Efficiency {
    /// FLOP-equivalents of the workload (paper §6 conventions).
    pub flops: f64,
    /// Kernel runtime \[s\].
    pub runtime_s: f64,
    /// Total energy \[J\].
    pub energy_j: f64,
    /// Throughput [GFLOP/s].
    pub gflops: f64,
    /// Energy efficiency [GFLOPS/W].
    pub gflops_per_w: f64,
    /// Average power \[W\].
    pub avg_power_w: f64,
}

/// Compute throughput + power efficiency for a kernel execution.
pub fn efficiency(stats: &ExecStats, dev: &DeviceModel, flops: f64) -> Efficiency {
    let runtime_s = stats.runtime_s(dev);
    let energy_j = stats.energy_j(dev);
    let gflops = if runtime_s > 0.0 { flops / runtime_s / 1e9 } else { 0.0 };
    let gflops_per_w = if energy_j > 0.0 { flops / energy_j / 1e9 } else { 0.0 };
    Efficiency {
        flops,
        runtime_s,
        energy_j,
        gflops,
        gflops_per_w,
        avg_power_w: stats.avg_power_w(dev),
    }
}

/// FLOP-equivalent counts per workload (paper §6 conventions).
pub mod flops {
    /// ED: 3 FLOP per attribute per sample per center.
    pub fn euclidean(n_samples: u64, dims: u64, centers: u64) -> f64 {
        3.0 * n_samples as f64 * dims as f64 * centers as f64
    }

    /// DP: 2 FLOP per attribute per vector.
    pub fn dot_product(n_vectors: u64, dims: u64) -> f64 {
        2.0 * n_vectors as f64 * dims as f64
    }

    /// Histogram: 2 OP per sample.
    pub fn histogram(n_samples: u64) -> f64 {
        2.0 * n_samples as f64
    }

    /// SpMV: 2 FLOP per nonzero (multiply + add).
    pub fn spmv(nnz: u64) -> f64 {
        2.0 * nnz as f64
    }
}

/// Extrapolate an execution to a larger dataset: associative kernel cycle
/// count is independent of row count (the paper's central property), so
/// runtime is unchanged while FLOPs and per-row energy events scale by
/// `row_factor`. (Controller/static energy scales with runtime, i.e. not
/// at all.)
pub fn extrapolate_rows(stats: &ExecStats, row_factor: f64) -> ExecStats {
    let mut s = stats.clone();
    let scale = |v: u128| -> u128 { (v as f64 * row_factor) as u128 };
    s.ledger.compare_bit_events = scale(s.ledger.compare_bit_events);
    s.ledger.write_bit_events = scale(s.ledger.write_bit_events);
    s.ledger.reduce_bit_events = scale(s.ledger.reduce_bit_events);
    s.ledger.chain_bit_events = scale(s.ledger.chain_bit_events);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcam::EnergyLedger;

    fn stats(cycles: u64, cmp_bits: u128, wr_bits: u128) -> ExecStats {
        ExecStats {
            cycles,
            instructions: 0,
            passes: 0,
            ledger: EnergyLedger {
                compare_bit_events: cmp_bits,
                write_bit_events: wr_bits,
                ..Default::default()
            },
        }
    }

    #[test]
    fn efficiency_math() {
        let dev = DeviceModel::default();
        // 1e9 FLOP in 500M cycles (1 s) with 1e15 compare-bit events (1 J)
        let s = stats(500_000_000, 1_000_000_000_000_000, 0);
        let e = efficiency(&s, &dev, 1e9);
        assert!((e.runtime_s - 1.0).abs() < 1e-12);
        assert!((e.gflops - 1.0).abs() < 1e-9);
        // energy = 1 J dynamic + 0.5 J controller → 2/3 GFLOPS/W
        assert!((e.gflops_per_w - 1.0 / 1.5).abs() < 1e-6);
    }

    #[test]
    fn extrapolation_preserves_runtime_scales_energy() {
        let s = stats(1000, 1_000_000, 10_000);
        let big = extrapolate_rows(&s, 100.0);
        assert_eq!(big.cycles, s.cycles);
        assert_eq!(big.ledger.compare_bit_events, 100_000_000);
        assert_eq!(big.ledger.write_bit_events, 1_000_000);
    }

    #[test]
    fn flop_conventions() {
        assert_eq!(flops::euclidean(1000, 16, 1), 48_000.0);
        assert_eq!(flops::dot_product(1000, 16), 32_000.0);
        assert_eq!(flops::histogram(1000), 2_000.0);
        assert_eq!(flops::spmv(1000), 2_000.0);
    }
}
