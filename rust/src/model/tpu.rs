//! TPU feasibility model for the L1 Pallas kernel (DESIGN.md
//! §Hardware-Adaptation).
//!
//! interpret=True gives CPU-numpy timings only, so real-TPU performance is
//! *estimated structurally*: VMEM footprint of the bit-plane working set
//! per BlockSpec step, and the VPU lane-op count per associative pass.
//! These numbers justify the chosen BLOCK_WORDS and are reported by
//! `prins report --tpu`.

/// TPU v4-ish envelope used for the estimate.
#[derive(Clone, Copy, Debug)]
pub struct TpuEnvelope {
    /// VMEM capacity per core \[bytes\].
    pub vmem_bytes: usize,
    /// VPU lanes (8×128 lanes × 4 sublanes).
    pub vpu_lanes: usize,
    /// u32 ops per cycle across all lanes.
    pub vpu_ops_per_cycle: usize,
    /// Core clock \[Hz\].
    pub freq_hz: f64,
    /// HBM bandwidth [GB/s].
    pub hbm_gb_s: f64,
}

/// TPU v4-class envelope constants.
pub const TPU_V4: TpuEnvelope = TpuEnvelope {
    vmem_bytes: 16 << 20,
    vpu_lanes: 1024,
    vpu_ops_per_cycle: 4096,
    freq_hz: 940e6,
    hbm_gb_s: 1200.0,
};

/// Structural performance estimate of the rcam_step Pallas kernel.
#[derive(Clone, Debug)]
pub struct KernelEstimate {
    /// Bytes of bit-plane state resident per grid step.
    pub vmem_per_block: usize,
    /// Fits in VMEM with double buffering?
    pub fits_vmem: bool,
    /// u32 lane-ops per associative pass per block (compare + write).
    pub ops_per_pass: usize,
    /// Estimated passes/second for a whole array of `nw_words` u32 words.
    pub passes_per_s: f64,
    /// HBM-bound passes/second (each pass streams the planes once if the
    /// program does not fit VMEM-resident).
    pub hbm_passes_per_s: f64,
}

/// Estimate the rcam_step kernel on a TPU envelope.
///
/// `w` bit columns, `nw_words` u32 words (rows/32), `block_words` per grid
/// step.
pub fn estimate_rcam_step(
    env: &TpuEnvelope,
    w: usize,
    nw_words: usize,
    block_words: usize,
) -> KernelEstimate {
    let vmem_per_block = w * block_words * 4 * 2; // in + out planes
    let fits_vmem = vmem_per_block * 2 <= env.vmem_bytes; // double-buffered
    // compare: W select+mask+AND ops per word; write: W blend ops per word
    let ops_per_word = 3 * w + 2 * w;
    let ops_per_pass = ops_per_word * block_words;
    let total_ops = ops_per_word * nw_words;
    let compute_passes = env.freq_hz * env.vpu_ops_per_cycle as f64 / total_ops as f64;
    let bytes_per_pass = (w * nw_words * 4 * 2) as f64;
    let hbm_passes = env.hbm_gb_s * 1e9 / bytes_per_pass;
    KernelEstimate {
        vmem_per_block,
        fits_vmem,
        ops_per_pass,
        passes_per_s: compute_passes.min(hbm_passes),
        hbm_passes_per_s: hbm_passes,
    }
}

/// The "keep data in VMEM across the whole microprogram" speedup factor:
/// a P-pass scan-composed program reads HBM once instead of P times.
pub fn vmem_residency_speedup(p_passes: usize) -> f64 {
    p_passes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_block_fits_vmem() {
        // aot.py: W=256, BLOCK_WORDS=256 → 512 KB per block, double-buffered
        let e = estimate_rcam_step(&TPU_V4, 256, 2048, 256);
        assert!(e.fits_vmem, "vmem/block = {}", e.vmem_per_block);
        assert!(e.passes_per_s > 1e3);
    }

    #[test]
    fn oversized_block_flagged() {
        let e = estimate_rcam_step(&TPU_V4, 256, 1 << 20, 1 << 20);
        assert!(!e.fits_vmem);
    }

    #[test]
    fn hbm_bound_at_scale() {
        // at very large arrays the kernel is HBM-streaming-bound
        let e = estimate_rcam_step(&TPU_V4, 256, 1 << 24, 256);
        assert!((e.passes_per_s - e.hbm_passes_per_s).abs() / e.hbm_passes_per_s < 1e-9);
    }

    #[test]
    fn scan_residency_wins_scale_with_p() {
        assert_eq!(vmem_residency_speedup(128), 128.0);
    }
}
