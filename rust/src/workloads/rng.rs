//! Deterministic PRNG for workload synthesis (SplitMix64 seeding a
//! xoshiro256**) — the vendored crate set has no `rand`, and determinism
//! across runs matters for the benches anyway.

/// xoshiro256** PRNG state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator deterministically (SplitMix64 expansion).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Next uniform u32 (high bits of [`Rng::next_u64`]).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return hi;
            }
        }
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Approximately normal via sum of 4 uniforms (Irwin–Hall), scaled to
    /// mean 0, std 1 — plenty for workload synthesis.
    pub fn normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.f32()).sum::<f32>() - 2.0;
        s * (12.0f32 / 4.0).sqrt()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from(4);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::seed_from(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::seed_from(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
