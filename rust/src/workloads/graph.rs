//! Graph workloads for the BFS evaluation (paper Table 3 / Fig. 14).
//!
//! The paper's graphs (indochina-2004 … hollywood-09) are multi-GB web and
//! social graphs; we synthesize degree-matched stand-ins (substitution
//! ledger, DESIGN.md): PRINS BFS cost depends on V, E and the out-degree
//! distribution, so the generators preserve V:E ratio and degree skew at a
//! configurable scale.

use super::rng::Rng;

/// Adjacency-list digraph.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Vertex count.
    pub n: usize,
    /// Out-neighbour lists, one per vertex.
    pub adj: Vec<Vec<u32>>,
}

impl Graph {
    /// Directed edge count.
    pub fn edges(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum()
    }

    /// Average out-degree.
    pub fn avg_degree(&self) -> f64 {
        self.edges() as f64 / self.n as f64
    }

    /// Maximum out-degree.
    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|a| a.len()).max().unwrap_or(0)
    }

    /// Reference BFS (CPU baseline): distances from `src`, u32::MAX =
    /// unreachable. Also returns total traversed edges.
    pub fn bfs(&self, src: usize) -> (Vec<u32>, u64) {
        let mut dist = vec![u32::MAX; self.n];
        let mut queue = std::collections::VecDeque::new();
        dist[src] = 0;
        queue.push_back(src as u32);
        let mut traversed = 0u64;
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u as usize] {
                traversed += 1;
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = dist[u as usize] + 1;
                    queue.push_back(v);
                }
            }
        }
        (dist, traversed)
    }

    /// Edge list (u, v).
    pub fn edge_list(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.edges());
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs {
                out.push((u as u32, v));
            }
        }
        out
    }

    /// Structural invariants (proptest target).
    pub fn validate(&self) {
        assert_eq!(self.adj.len(), self.n);
        for nbrs in &self.adj {
            for &v in nbrs {
                assert!((v as usize) < self.n);
            }
        }
    }
}

/// Power-law out-degree digraph, guaranteed weakly connected via a
/// backbone ring (so BFS from vertex 0 reaches everything).
pub fn synth_power_law(n: usize, avg_degree: f64, skew: f64, seed: u64) -> Graph {
    let mut rng = Rng::seed_from(seed);
    let mut adj = vec![Vec::new(); n];
    // backbone ring: connectivity
    for u in 0..n {
        adj[u].push(((u + 1) % n) as u32);
    }
    let extra_total = ((avg_degree - 1.0).max(0.0) * n as f64) as usize;
    // Zipf-ish targets: preferential attachment to low-indexed vertices
    for _ in 0..extra_total {
        // skew both endpoints: hubs have high out-degree (drives the
        // paper's avg-D-limited BFS behaviour) and high in-degree
        let tu = rng.f32() as f64;
        let u = (((n as f64) * tu.powf(skew)) as usize) % n;
        let tv = rng.f32() as f64;
        let v = (((n as f64) * tv.powf(skew)) as usize) % n;
        adj[u].push(v as u32);
    }
    Graph { n, adj }
}

/// Kronecker-style (RMAT) digraph used for the kron_g500 stand-in.
pub fn synth_rmat(scale: u32, avg_degree: f64, seed: u64) -> Graph {
    let n = 1usize << scale;
    let m = (n as f64 * avg_degree) as usize;
    let mut rng = Rng::seed_from(seed);
    let (a, b, c) = (0.57f32, 0.19f32, 0.19f32);
    let mut adj = vec![Vec::new(); n];
    for u in 0..n {
        adj[u].push(((u + 1) % n) as u32); // connectivity backbone
    }
    for _ in 0..m.saturating_sub(n) {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f32();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        adj[u].push(v as u32);
    }
    Graph { n, adj }
}

/// One graph of the paper's Table 3: name + original stats.
#[derive(Clone, Copy, Debug)]
pub struct PaperGraph {
    /// Graph name as the paper lists it.
    pub name: &'static str,
    /// Vertices, in millions.
    pub v_millions: f64,
    /// Edges, in millions.
    pub e_millions: f64,
    /// Average out-degree.
    pub avg_d: f64,
    /// Maximum out-degree.
    pub max_d: u64,
    /// Whether the original is a Kronecker (RMAT) graph.
    pub kron: bool,
}

/// Paper Table 3, ordered by increasing average out-degree like Fig. 14.
pub const PAPER_GRAPHS: [PaperGraph; 6] = [
    PaperGraph { name: "indochina-2004", v_millions: 5.3, e_millions: 79.0, avg_d: 15.0, max_d: 19_409, kron: false },
    PaperGraph { name: "arabic-2005", v_millions: 23.0, e_millions: 640.0, avg_d: 28.0, max_d: 575_618, kron: false },
    PaperGraph { name: "it-2004", v_millions: 41.0, e_millions: 1151.0, avg_d: 28.0, max_d: 1_326_745, kron: false },
    PaperGraph { name: "sk-2005", v_millions: 50.6, e_millions: 1949.0, avg_d: 38.0, max_d: 8_563_808, kron: false },
    PaperGraph { name: "kron_g500-logn21", v_millions: 2.1, e_millions: 182.0, avg_d: 87.0, max_d: 213_905, kron: true },
    PaperGraph { name: "hollywood-09", v_millions: 1.1, e_millions: 114.0, avg_d: 100.0, max_d: 11_468, kron: false },
];

impl PaperGraph {
    /// Degree-matched synthetic stand-in with `n` vertices.
    pub fn synthesize(&self, n: usize, seed: u64) -> Graph {
        if self.kron {
            let scale = (n as f64).log2().ceil() as u32;
            synth_rmat(scale, self.avg_d, seed)
        } else {
            // skew chosen so max-degree/avg-degree roughly tracks the original
            let skew = 2.5;
            synth_power_law(n, self.avg_d, skew, seed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_matches_degree_and_connectivity() {
        let g = synth_power_law(2000, 12.0, 2.5, 1);
        g.validate();
        assert!((g.avg_degree() - 12.0).abs() < 1.5, "avg {}", g.avg_degree());
        let (dist, traversed) = g.bfs(0);
        assert!(dist.iter().all(|&d| d != u32::MAX), "connected");
        assert_eq!(traversed as usize, g.edges());
        // skew: max degree far above average
        assert!(g.max_degree() as f64 > 3.0 * g.avg_degree());
    }

    #[test]
    fn rmat_generates_reachable_graph() {
        let g = synth_rmat(10, 8.0, 2);
        g.validate();
        assert_eq!(g.n, 1024);
        let (dist, _) = g.bfs(0);
        assert!(dist.iter().all(|&d| d != u32::MAX));
    }

    #[test]
    fn paper_table3_shape() {
        for w in PAPER_GRAPHS.windows(2) {
            assert!(w[0].avg_d <= w[1].avg_d, "ordered by avg out-degree");
        }
        let h = PAPER_GRAPHS[5];
        assert_eq!(h.name, "hollywood-09");
        assert!((h.e_millions / h.v_millions - h.avg_d).abs() / h.avg_d < 0.1);
    }

    #[test]
    fn synthesized_standins_track_avg_degree() {
        for pg in PAPER_GRAPHS {
            let g = pg.synthesize(1 << 11, 3);
            g.validate();
            let ratio = g.avg_degree() / pg.avg_d;
            assert!(
                (0.6..1.6).contains(&ratio),
                "{}: avg {} vs paper {}",
                pg.name,
                g.avg_degree(),
                pg.avg_d
            );
        }
    }

    #[test]
    fn bfs_reference_on_known_graph() {
        // path graph 0->1->2->3
        let g = Graph {
            n: 4,
            adj: vec![vec![1], vec![2], vec![3], vec![]],
        };
        let (dist, traversed) = g.bfs(0);
        assert_eq!(dist, vec![0, 1, 2, 3]);
        assert_eq!(traversed, 3);
    }
}
