//! Dense vector/sample workloads for the Euclidean-distance, dot-product
//! and histogram evaluations (paper Fig. 12: synthetic vectors of 1M, 10M
//! and 100M elements; DP uses 16-dimensional vectors; histogram uses
//! 32-bit integers binned on the top byte).

use super::rng::Rng;

/// N×D f32 samples, row-major, values in a clustered gaussian mix so the
/// k-means example has actual structure.
pub fn synth_samples(n: usize, d: usize, n_clusters: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from(seed);
    let centers: Vec<f32> = (0..n_clusters * d)
        .map(|_| rng.f32_range(-10.0, 10.0))
        .collect();
    let mut out = Vec::with_capacity(n * d);
    for i in 0..n {
        let c = i % n_clusters;
        for j in 0..d {
            out.push(centers[c * d + j] + rng.normal());
        }
    }
    out
}

/// Uniform f32 vectors in [-1, 1).
pub fn synth_uniform(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from(seed);
    (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
}

/// 32-bit histogram samples: a gaussian-ish bump over the bin space plus a
/// uniform floor, so the histogram has structure to check.
pub fn synth_hist_samples(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|_| {
            if rng.f32() < 0.3 {
                // bump around bin 128
                let bin = (128.0 + 8.0 * rng.normal()).clamp(0.0, 255.0) as u32;
                (bin << 24) | (rng.next_u32() & 0x00FF_FFFF)
            } else {
                rng.next_u32()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_have_cluster_structure() {
        let d = 4;
        let x = synth_samples(100, d, 2, 1);
        assert_eq!(x.len(), 400);
        // same-cluster rows are closer than cross-cluster rows on average
        let dist = |a: usize, b: usize| -> f32 {
            (0..d).map(|j| (x[a * d + j] - x[b * d + j]).powi(2)).sum()
        };
        let same = dist(0, 2) + dist(1, 3);
        let cross = dist(0, 1) + dist(2, 3);
        assert!(same < cross, "same {same} cross {cross}");
    }

    #[test]
    fn hist_samples_have_bump() {
        let xs = synth_hist_samples(100_000, 2);
        let mut hist = [0u32; 256];
        for x in xs {
            hist[(x >> 24) as usize] += 1;
        }
        let bump: u32 = hist[118..138].iter().sum();
        let floor: u32 = hist[0..20].iter().sum();
        assert!(bump > 3 * floor, "bump {bump} floor {floor}");
    }

    #[test]
    fn uniform_in_range() {
        assert!(synth_uniform(1000, 3).iter().all(|v| (-1.0..1.0).contains(v)));
    }
}
