//! Sparse matrix workloads for the SpMV evaluation (paper Fig. 13).
//!
//! The paper used 18 square matrices from the UFL (SuiteSparse) collection
//! with 1.2M–29M nonzeros, reporting results "in the order of increasing
//! matrix density nnz/n". The collection is not bundled here (substitution
//! ledger, DESIGN.md): we synthesize CSR matrices matched to each paper
//! matrix's (n, nnz) — the two parameters that fully determine PRINS SpMV
//! cost (broadcast is O(n), multiply is O(1) in rows, reduction depends on
//! the row-length distribution, which we synthesize as a banded+random mix
//! typical of the originals).

use super::rng::Rng;

/// CSR square sparse matrix (f32 values).
#[derive(Clone, Debug)]
pub struct Csr {
    /// Matrix dimension (square: n × n).
    pub n: usize,
    /// Row pointers: row r's nonzeros are `indptr[r]..indptr[r+1]`.
    pub indptr: Vec<usize>,
    /// Column index of each nonzero.
    pub indices: Vec<u32>,
    /// Value of each nonzero.
    pub values: Vec<f32>,
}

impl Csr {
    /// Nonzero count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Density nnz/n (the paper's Fig. 13 ordering key).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / self.n as f64
    }

    /// Longest row's nonzero count (the chain-scan depth driver).
    pub fn max_row_nnz(&self) -> usize {
        self.indptr.windows(2).map(|w| w[1] - w[0]).max().unwrap_or(0)
    }

    /// Per-row nonzero counts — the partition weights for row-balanced
    /// sharding (`ShardPlan::weighted`).
    pub fn row_nnz(&self) -> Vec<usize> {
        self.indptr.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// The same matrix with every row outside `rows` emptied — shard s's
    /// sub-matrix under row-range partitioning. Dimensions, row ids and
    /// column ids are unchanged, so the sub-matrix's SpMV output rows
    /// inside `rows` are bit-identical to the full matrix's.
    pub fn mask_rows(&self, rows: std::ops::Range<usize>) -> Csr {
        let mut indptr = Vec::with_capacity(self.n + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0usize);
        for r in 0..self.n {
            if rows.contains(&r) {
                let (a, b) = (self.indptr[r], self.indptr[r + 1]);
                indices.extend_from_slice(&self.indices[a..b]);
                values.extend_from_slice(&self.values[a..b]);
            }
            indptr.push(indices.len());
        }
        Csr {
            n: self.n,
            indptr,
            indices,
            values,
        }
    }

    /// y = A·x (scalar reference implementation — the CPU baseline).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        for r in 0..self.n {
            let mut acc = 0f32;
            for k in self.indptr[r]..self.indptr[r + 1] {
                acc += self.values[k] * x[self.indices[k] as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// COO triplets (row, col, value) in CSR order.
    pub fn triplets(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.n).flat_map(move |r| {
            (self.indptr[r]..self.indptr[r + 1])
                .map(move |k| (r as u32, self.indices[k], self.values[k]))
        })
    }

    /// Structural invariants (proptest target).
    pub fn validate(&self) {
        assert_eq!(self.indptr.len(), self.n + 1);
        assert_eq!(self.indptr[0], 0);
        assert_eq!(*self.indptr.last().unwrap(), self.values.len());
        assert_eq!(self.indices.len(), self.values.len());
        for w in self.indptr.windows(2) {
            assert!(w[0] <= w[1]);
        }
        for &c in &self.indices {
            assert!((c as usize) < self.n);
        }
    }
}

/// Synthesize an n×n matrix with ~nnz nonzeros: a tri-diagonal band
/// (locality, like FEM/circuit matrices) plus uniformly random fill, with
/// a skewed row-length tail. Deterministic in `seed`.
pub fn synth_csr(n: usize, nnz_target: usize, seed: u64) -> Csr {
    let mut rng = Rng::seed_from(seed);
    let avg = (nnz_target as f64 / n as f64).max(1.0);
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0usize);
    for r in 0..n {
        // row length: avg +/- 50%, with a heavy row every 1024 rows
        let mut len = ((avg * (0.5 + rng.f32() as f64)) as usize).max(1);
        if r % 1024 == 0 {
            len = (len * 4).min(n);
        }
        let mut cols: Vec<u32> = Vec::with_capacity(len);
        // band part
        for d in 0..len.min(3) {
            let c = (r + d).min(n - 1) as u32;
            cols.push(c);
        }
        // random fill
        while cols.len() < len {
            cols.push(rng.below(n as u64) as u32);
        }
        cols.sort_unstable();
        cols.dedup();
        for c in cols {
            indices.push(c);
            values.push(rng.f32_range(-1.0, 1.0));
        }
        indptr.push(indices.len());
    }
    Csr {
        n,
        indptr,
        indices,
        values,
    }
}

/// One matrix of the paper's Fig. 13 set: name + original (n, nnz).
#[derive(Clone, Copy, Debug)]
pub struct PaperMatrix {
    /// Matrix name as the SuiteSparse collection lists it.
    pub name: &'static str,
    /// Dimension of the original matrix.
    pub n: usize,
    /// Nonzero count of the original matrix.
    pub nnz: usize,
}

impl PaperMatrix {
    /// Density nnz/n of the original matrix.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / self.n as f64
    }

    /// The synthetic stand-in, optionally scaled down by `scale` (both n
    /// and nnz divided, preserving density) for tractable simulation.
    pub fn synthesize(&self, scale: usize, seed: u64) -> Csr {
        let n = (self.n / scale).max(16);
        let nnz = (self.nnz / scale).max(n);
        synth_csr(n, nnz, seed)
    }
}

/// 18 SuiteSparse/UFL square matrices spanning the paper's range
/// (1.2M–29M nonzeros), ordered by increasing density nnz/n like Fig. 13.
/// (n, nnz) taken from the public SuiteSparse collection metadata.
pub const PAPER_MATRICES: [PaperMatrix; 18] = [
    PaperMatrix { name: "wiki-Talk", n: 2_394_385, nnz: 5_021_410 },      // d≈2.1
    PaperMatrix { name: "roadNet-CA", n: 1_971_281, nnz: 5_533_214 },     // d≈2.8
    PaperMatrix { name: "webbase-1M", n: 1_000_005, nnz: 3_105_536 },     // d≈3.1
    PaperMatrix { name: "cit-Patents", n: 3_774_768, nnz: 16_518_948 },   // d≈4.4
    PaperMatrix { name: "G3_circuit", n: 1_585_478, nnz: 7_660_826 },     // d≈4.8
    PaperMatrix { name: "memchip", n: 2_707_524, nnz: 13_343_948 },       // d≈4.9
    PaperMatrix { name: "ecology1", n: 1_000_000, nnz: 4_996_000 },       // d≈5.0
    PaperMatrix { name: "kkt_power", n: 2_063_494, nnz: 12_771_361 },     // d≈6.2
    PaperMatrix { name: "atmosmodd", n: 1_270_432, nnz: 8_814_880 },      // d≈6.9
    PaperMatrix { name: "thermal2", n: 1_228_045, nnz: 8_580_313 },       // d≈7.0
    PaperMatrix { name: "parabolic_fem", n: 525_825, nnz: 3_674_625 },    // d≈7.0
    PaperMatrix { name: "offshore", n: 259_789, nnz: 4_242_673 },         // d≈16.3
    PaperMatrix { name: "cage13", n: 445_315, nnz: 7_479_343 },           // d≈16.8
    PaperMatrix { name: "af_shell9", n: 504_855, nnz: 17_588_875 },       // d≈34.8
    PaperMatrix { name: "msdoor", n: 415_863, nnz: 19_173_163 },          // d≈46.1
    PaperMatrix { name: "pwtk", n: 217_918, nnz: 11_524_432 },            // d≈52.9
    PaperMatrix { name: "F1", n: 343_791, nnz: 26_837_113 },              // d≈78.1
    PaperMatrix { name: "nd24k", n: 72_000, nnz: 28_715_634 },            // d≈398.8
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_hits_target_roughly() {
        let m = synth_csr(1000, 10_000, 1);
        m.validate();
        assert_eq!(m.n, 1000);
        let ratio = m.nnz() as f64 / 10_000.0;
        assert!((0.5..1.5).contains(&ratio), "nnz {}", m.nnz());
    }

    #[test]
    fn synth_is_deterministic() {
        let a = synth_csr(500, 3000, 42);
        let b = synth_csr(500, 3000, 42);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.values, b.values);
    }

    #[test]
    fn spmv_reference_correct_on_identityish() {
        // diag(2) matrix: y = 2x
        let n = 64;
        let csr = Csr {
            n,
            indptr: (0..=n).collect(),
            indices: (0..n as u32).collect(),
            values: vec![2.0; n],
        };
        csr.validate();
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let y = csr.spmv(&x);
        for i in 0..n {
            assert_eq!(y[i], 2.0 * i as f32);
        }
    }

    #[test]
    fn paper_set_ordered_by_density_and_in_range() {
        for w in PAPER_MATRICES.windows(2) {
            assert!(
                w[0].density() <= w[1].density() + 1e-9,
                "{} vs {}",
                w[0].name,
                w[1].name
            );
        }
        for m in PAPER_MATRICES {
            assert!(
                (1_200_000..=29_000_000).contains(&m.nnz),
                "{}: nnz {}",
                m.name,
                m.nnz
            );
        }
        // density span covers the >100x-speedup regime (dense end ~400)
        assert!(PAPER_MATRICES.last().unwrap().density() > 300.0);
        assert!(PAPER_MATRICES[0].density() < 5.0);
    }

    #[test]
    fn mask_rows_keeps_shape_and_slices_rows() {
        let a = synth_csr(64, 300, 3);
        let sub = a.mask_rows(16..40);
        sub.validate();
        assert_eq!(sub.n, a.n);
        let weights = a.row_nnz();
        assert_eq!(weights.len(), a.n);
        assert_eq!(sub.nnz(), weights[16..40].iter().sum::<usize>());
        let x: Vec<f32> = (0..a.n).map(|i| (i as f32 * 0.37).sin()).collect();
        let (yf, ys) = (a.spmv(&x), sub.spmv(&x));
        for r in 0..a.n {
            if (16..40).contains(&r) {
                assert_eq!(yf[r].to_bits(), ys[r].to_bits(), "row {r}");
            } else {
                assert_eq!(ys[r], 0.0, "row {r} must be empty");
            }
        }
    }

    #[test]
    fn scaled_synthesis_preserves_density() {
        let m = PAPER_MATRICES[17]; // nd24k
        let csr = m.synthesize(100, 7);
        csr.validate();
        let d = csr.density();
        assert!(
            (m.density() * 0.5..m.density() * 1.5).contains(&d),
            "density {d} vs {}",
            m.density()
        );
    }
}
