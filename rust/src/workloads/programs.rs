//! Seeded random microprogram generator — fuzz input for the ISA's
//! structural invariants (`Program::spans` partitioning, cycle
//! accounting) and for the static analyzer's clean-program path.
//!
//! Generated programs are *well-formed by construction*: every pattern
//! binds distinct in-bounds columns, every `Read`/`ClearColumns` range
//! stays inside the array width, and tag shifts never exceed the
//! caller's `max_shift` hop bound. Callers that keep `max_shift` at or
//! below the array's rows-per-module therefore get programs the
//! analyzer (`crate::analysis`) must accept with zero diagnostics —
//! which is exactly what the property tests assert.

use crate::isa::{Instr, Program};
use crate::workloads::Rng;

/// A random pattern of `1..=max_cols` distinct columns below `width`,
/// each bound to a random bit. Distinctness comes from a partial
/// Fisher–Yates draw over the column index space.
fn random_pattern(rng: &mut Rng, width: u16, max_cols: usize) -> Vec<(u16, bool)> {
    let max_cols = max_cols.min(width as usize).max(1);
    let n_cols = 1 + rng.below(max_cols as u64) as usize;
    let mut cols: Vec<u16> = (0..width).collect();
    for i in 0..n_cols {
        let j = i + rng.below((cols.len() - i) as u64) as usize;
        cols.swap(i, j);
    }
    cols[..n_cols]
        .iter()
        .map(|&c| (c, rng.next_u64() & 1 == 1))
        .collect()
}

/// A random in-bounds `(base, width)` column range with `width >= 1`
/// and `base + width <= array_width`; read ranges are additionally
/// capped at 64 columns so a `Read` result fits the data buffer's u64.
fn random_range(rng: &mut Rng, array_width: u16, cap: u16) -> (u16, u16) {
    let base = rng.below(array_width as u64) as u16;
    let room = (array_width - base).min(cap);
    let width = 1 + rng.below(room as u64) as u16;
    (base, width)
}

/// Generate a seeded random `len`-instruction program over an array of
/// `width` columns, drawing every [`Instr`] variant: data-parallel
/// compare/write/set-tags/clear-columns ops interleaved with
/// serializing reads, match queries, reductions and tag shifts (hops in
/// `1..=max_shift`), so `Program::spans` sees many alternation
/// boundaries.
///
/// Requires `width >= 1` and `max_shift >= 1`.
pub fn random_program(rng: &mut Rng, width: u16, max_shift: u32, len: usize) -> Program {
    assert!(width >= 1 && max_shift >= 1);
    let mut p = Program::new();
    for _ in 0..len {
        match rng.below(11) {
            0 => p.push(Instr::Compare(random_pattern(rng, width, 8))),
            1 => p.push(Instr::Write(random_pattern(rng, width, 8))),
            2 => {
                let (base, w) = random_range(rng, width, 64);
                p.push(Instr::Read { base, width: w });
            }
            3 => p.push(Instr::IfMatch),
            4 => p.push(Instr::FirstMatch),
            5 => p.push(Instr::ReduceCount),
            6 => p.push(Instr::ReduceField {
                col: rng.below(width as u64) as u16,
            }),
            7 => p.push(Instr::SetTagsAll),
            8 => p.push(Instr::ShiftTagsUp(1 + rng.below(max_shift as u64) as u32)),
            9 => p.push(Instr::ShiftTagsDown(1 + rng.below(max_shift as u64) as u32)),
            _ => {
                let (base, w) = random_range(rng, width, width);
                p.push(Instr::ClearColumns { base, width: w });
            }
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_per_seed() {
        let a = random_program(&mut Rng::seed_from(9), 32, 4, 40);
        let b = random_program(&mut Rng::seed_from(9), 32, 4, 40);
        let c = random_program(&mut Rng::seed_from(10), 32, 4, 40);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 40);
    }

    #[test]
    fn generated_patterns_are_distinct_and_in_bounds() {
        let mut rng = Rng::seed_from(3);
        for _ in 0..200 {
            let pat = random_pattern(&mut rng, 16, 8);
            assert!(!pat.is_empty() && pat.len() <= 8);
            for (i, &(c, _)) in pat.iter().enumerate() {
                assert!(c < 16);
                assert!(!pat[..i].iter().any(|&(c2, _)| c2 == c));
            }
        }
    }

    #[test]
    fn generated_ranges_stay_inside_the_array() {
        let mut rng = Rng::seed_from(4);
        for _ in 0..200 {
            let (base, w) = random_range(&mut rng, 24, 64);
            assert!(w >= 1 && (base as usize + w as usize) <= 24);
            assert!(w <= 64);
        }
    }
}
