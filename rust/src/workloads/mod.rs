//! Synthetic workload generators standing in for the paper's datasets
//! (substitution ledger in DESIGN.md §1).

pub mod graph;
pub mod programs;
pub mod rng;
pub mod sparse;
pub mod vectors;

pub use graph::{synth_power_law, synth_rmat, Graph, PaperGraph, PAPER_GRAPHS};
pub use programs::random_program;
pub use rng::Rng;
pub use sparse::{synth_csr, Csr, PaperMatrix, PAPER_MATRICES};
pub use vectors::{synth_hist_samples, synth_samples, synth_uniform};
