fn main() -> prins::error::Result<()> {
    prins::cli::main()
}
