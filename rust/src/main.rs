fn main() -> anyhow::Result<()> {
    prins::cli::main()
}
