//! The PRINS associative instruction set (paper §5.2) and the program
//! container the controller executes.
//!
//! The paper's five architectural instructions are `compare`, `write`,
//! `read`, `if_match`, `first_match`. The remaining variants are
//! controller macros (paper §3.3: the controller "issues instructions,
//! sets the key and mask registers, handles control sequences"): reduction
//! tree issues, tag-chain shifts, and bulk column clears.

use super::fields::Field;
use crate::rcam::device::{
    CYCLES_COMPARE, CYCLES_READ, CYCLES_REDUCE_ISSUE, CYCLES_TAG_OP, CYCLES_WRITE,
};

/// Sparse key/mask pattern: (bit-column, key bit); unlisted columns are
/// masked out.
pub type Pat = Vec<(u16, bool)>;

/// One associative instruction: the paper's five architectural
/// operations plus controller macros (see the module doc).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Instr {
    /// compare (y1==x1, ...): tag rows matching the pattern.
    Compare(Pat),
    /// write (y1=x1, ...): write pattern into all tagged rows.
    Write(Pat),
    /// read (y): read a field of the first tagged row into the key register
    /// (result lands in the controller data buffer).
    Read { base: u16, width: u16 },
    /// Signal whether any row is tagged (result in data buffer: 0/1).
    IfMatch,
    /// Keep only the first (top-most) tag.
    FirstMatch,
    /// Reduction tree over tags: count of tagged rows → data buffer.
    ReduceCount,
    /// Reduction over tags AND bit-column `col` → data buffer (weighted
    /// popcount used by bit-serial field sums).
    ReduceField { col: u16 },
    /// Tag every row.
    SetTagsAll,
    /// Daisy-chain tag shift, towards higher rows.
    ShiftTagsUp(u32),
    /// Daisy-chain tag shift, towards lower rows.
    ShiftTagsDown(u32),
    /// Untagged parallel clear of a column range (controller bulk macro).
    ClearColumns { base: u16, width: u16 },
}

impl Instr {
    /// Documented cycle cost (DESIGN.md §4).
    pub fn cycles(&self) -> u64 {
        match self {
            Instr::Compare(_) => CYCLES_COMPARE,
            Instr::Write(_) => CYCLES_WRITE,
            Instr::Read { .. } => CYCLES_READ,
            Instr::IfMatch | Instr::FirstMatch | Instr::SetTagsAll => CYCLES_TAG_OP,
            Instr::ReduceCount | Instr::ReduceField { .. } => CYCLES_REDUCE_ISSUE,
            Instr::ShiftTagsUp(h) | Instr::ShiftTagsDown(h) => {
                (*h as u64) * CYCLES_TAG_OP
            }
            Instr::ClearColumns { .. } => CYCLES_WRITE,
        }
    }

    /// Data-parallel instructions execute independently on every row
    /// stripe — no global result, no cross-row communication — so the
    /// threaded backend runs them striped without a barrier. Everything
    /// else (reads, match queries, reductions, tag-chain shifts)
    /// serializes the array (DESIGN.md §5, barrier rules).
    pub fn is_data_parallel(&self) -> bool {
        matches!(
            self,
            Instr::Compare(_)
                | Instr::Write(_)
                | Instr::SetTagsAll
                | Instr::ClearColumns { .. }
        )
    }
}

/// A maximal run of instructions with uniform parallelism class
/// (see [`Program::spans`]).
#[derive(Clone, Copy, Debug)]
pub struct Span<'a> {
    /// The instructions of this span, in program order.
    pub instrs: &'a [Instr],
    /// Whether every instruction in the span is data-parallel.
    pub data_parallel: bool,
}

/// Iterator over a program's execution spans: alternating maximal
/// data-parallel and serializing runs, in program order. The threaded
/// controller dispatches each data-parallel span to the worker pool as
/// one unit — each worker runs the whole span over its stripe before the
/// next barrier (DESIGN.md §5).
pub struct Spans<'a> {
    rest: &'a [Instr],
}

impl<'a> Iterator for Spans<'a> {
    type Item = Span<'a>;

    fn next(&mut self) -> Option<Span<'a>> {
        let first = self.rest.first()?;
        let dp = first.is_data_parallel();
        let n = self
            .rest
            .iter()
            .take_while(|i| i.is_data_parallel() == dp)
            .count();
        let (instrs, rest) = self.rest.split_at(n);
        self.rest = rest;
        Some(Span {
            instrs,
            data_parallel: dp,
        })
    }
}

/// A straight-line associative program (the paper's "associative
/// primitives that are downloaded into and executed by the PRINS
/// controller", §5.3).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Program {
    /// The instruction sequence, executed front to back.
    pub instrs: Vec<Instr>,
}

impl Program {
    /// An empty program.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one instruction.
    pub fn push(&mut self, i: Instr) {
        self.instrs.push(i);
    }

    /// Append a compare+write microcode pass.
    pub fn pass(&mut self, cpat: Pat, wpat: Pat) {
        self.instrs.push(Instr::Compare(cpat));
        self.instrs.push(Instr::Write(wpat));
    }

    /// Compare a full field against a constant.
    pub fn compare_field(&mut self, f: Field, value: u64) {
        self.instrs.push(Instr::Compare(f.pattern(value)));
    }

    /// Write a constant into a full field of all tagged rows.
    pub fn write_field(&mut self, f: Field, value: u64) {
        self.instrs.push(Instr::Write(f.pattern(value)));
    }

    /// Append an untagged parallel clear of a whole field.
    pub fn clear_field(&mut self, f: Field) {
        self.instrs.push(Instr::ClearColumns {
            base: f.base,
            width: f.width,
        });
    }

    /// Number of instructions.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// Whether the program has no instructions.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Static cycle cost of the whole program.
    pub fn cycle_estimate(&self) -> u64 {
        self.instrs.iter().map(|i| i.cycles()).sum()
    }

    /// Number of compare+write passes (microcode cost metric).
    pub fn n_passes(&self) -> usize {
        self.instrs
            .iter()
            .filter(|i| matches!(i, Instr::Compare(_)))
            .count()
    }

    /// Append every instruction of `other`.
    pub fn extend(&mut self, other: Program) {
        self.instrs.extend(other.instrs);
    }

    /// Split the program into maximal data-parallel / serializing spans
    /// (program-span batching; DESIGN.md §5).
    pub fn spans(&self) -> Spans<'_> {
        Spans {
            rest: &self.instrs,
        }
    }

    /// Highest bit-column referenced (for width validation).
    ///
    /// Zero-width `Read`/`ClearColumns` ranges reference no columns and
    /// contribute nothing; a range whose end `base + width - 1` exceeds
    /// `u16::MAX` saturates there (the range is out of bounds for every
    /// real array width, and saturation keeps it reported as such
    /// instead of wrapping around to a small column). The per-column
    /// diagnosis lives in `crate::analysis` (rule W01); this is the
    /// summary the quick width checks use.
    pub fn max_column(&self) -> Option<u16> {
        self.instrs
            .iter()
            .flat_map(|i| match i {
                Instr::Compare(p) | Instr::Write(p) => {
                    p.iter().map(|&(c, _)| c).max()
                }
                Instr::Read { base, width } | Instr::ClearColumns { base, width } => width
                    .checked_sub(1)
                    .map(|w| base.saturating_add(w)),
                Instr::ReduceField { col } => Some(*col),
                _ => None,
            })
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_costs_match_design_doc() {
        assert_eq!(Instr::Compare(vec![]).cycles(), 1);
        assert_eq!(Instr::Write(vec![]).cycles(), 2);
        assert_eq!(Instr::Read { base: 0, width: 8 }.cycles(), 1);
        assert_eq!(Instr::ShiftTagsUp(5).cycles(), 5);
    }

    #[test]
    fn program_accounting() {
        let mut p = Program::new();
        let f = Field::new(0, 8);
        p.compare_field(f, 0xAA);
        p.write_field(f, 0x55);
        p.push(Instr::ReduceCount);
        assert_eq!(p.len(), 3);
        assert_eq!(p.n_passes(), 1);
        assert_eq!(p.cycle_estimate(), 1 + 2 + 1);
        assert_eq!(p.max_column(), Some(7));
    }

    #[test]
    fn max_column_ignores_zero_width_ranges() {
        // width == 0 used to evaluate `base + width - 1`, panicking in
        // debug builds and wrapping to u16::MAX in release
        let mut p = Program::new();
        p.push(Instr::Read { base: 5, width: 0 });
        p.push(Instr::ClearColumns { base: 9, width: 0 });
        assert_eq!(p.max_column(), None);
        // a real reference alongside the empty ranges still wins
        p.push(Instr::ReduceField { col: 3 });
        assert_eq!(p.max_column(), Some(3));
    }

    #[test]
    fn max_column_saturates_instead_of_overflowing_u16() {
        // base + width - 1 > u16::MAX used to wrap around to a small
        // column; it must stay pinned at the top instead
        let mut p = Program::new();
        p.push(Instr::Read {
            base: u16::MAX,
            width: 4,
        });
        assert_eq!(p.max_column(), Some(u16::MAX));
        let mut q = Program::new();
        q.push(Instr::ClearColumns {
            base: u16::MAX - 1,
            width: u16::MAX,
        });
        assert_eq!(q.max_column(), Some(u16::MAX));
    }

    #[test]
    fn pass_appends_compare_then_write() {
        let mut p = Program::new();
        p.pass(vec![(0, true)], vec![(1, false)]);
        assert!(matches!(p.instrs[0], Instr::Compare(_)));
        assert!(matches!(p.instrs[1], Instr::Write(_)));
    }

    #[test]
    fn parallelism_classification() {
        assert!(Instr::Compare(vec![]).is_data_parallel());
        assert!(Instr::Write(vec![]).is_data_parallel());
        assert!(Instr::SetTagsAll.is_data_parallel());
        assert!(Instr::ClearColumns { base: 0, width: 4 }.is_data_parallel());
        assert!(!Instr::Read { base: 0, width: 4 }.is_data_parallel());
        assert!(!Instr::IfMatch.is_data_parallel());
        assert!(!Instr::FirstMatch.is_data_parallel());
        assert!(!Instr::ReduceCount.is_data_parallel());
        assert!(!Instr::ReduceField { col: 0 }.is_data_parallel());
        assert!(!Instr::ShiftTagsUp(1).is_data_parallel());
        assert!(!Instr::ShiftTagsDown(1).is_data_parallel());
    }

    #[test]
    fn spans_split_on_serializing_instrs() {
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(0, true)]));
        p.push(Instr::Write(vec![(1, true)]));
        p.push(Instr::ReduceCount);
        p.push(Instr::ShiftTagsUp(2));
        p.push(Instr::SetTagsAll);
        p.push(Instr::ClearColumns { base: 0, width: 2 });
        p.push(Instr::Compare(vec![(2, false)]));
        let spans: Vec<_> = p.spans().collect();
        assert_eq!(spans.len(), 3);
        assert!(spans[0].data_parallel);
        assert_eq!(spans[0].instrs.len(), 2);
        assert!(!spans[1].data_parallel);
        assert_eq!(spans[1].instrs.len(), 2);
        assert!(spans[2].data_parallel);
        assert_eq!(spans[2].instrs.len(), 3);
        // spans cover the whole program in order
        let total: usize = spans.iter().map(|s| s.instrs.len()).sum();
        assert_eq!(total, p.len());
        assert!(Program::new().spans().next().is_none());
    }
}
