//! The PRINS associative instruction set (paper §5.2) and the program
//! container the controller executes.
//!
//! The paper's five architectural instructions are `compare`, `write`,
//! `read`, `if_match`, `first_match`. The remaining variants are
//! controller macros (paper §3.3: the controller "issues instructions,
//! sets the key and mask registers, handles control sequences"): reduction
//! tree issues, tag-chain shifts, and bulk column clears.

use super::fields::Field;
use crate::rcam::device::{
    CYCLES_COMPARE, CYCLES_READ, CYCLES_REDUCE_ISSUE, CYCLES_TAG_OP, CYCLES_WRITE,
};

/// Sparse key/mask pattern: (bit-column, key bit); unlisted columns are
/// masked out.
pub type Pat = Vec<(u16, bool)>;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Instr {
    /// compare (y1==x1, ...): tag rows matching the pattern.
    Compare(Pat),
    /// write (y1=x1, ...): write pattern into all tagged rows.
    Write(Pat),
    /// read (y): read a field of the first tagged row into the key register
    /// (result lands in the controller data buffer).
    Read { base: u16, width: u16 },
    /// Signal whether any row is tagged (result in data buffer: 0/1).
    IfMatch,
    /// Keep only the first (top-most) tag.
    FirstMatch,
    /// Reduction tree over tags: count of tagged rows → data buffer.
    ReduceCount,
    /// Reduction over tags AND bit-column `col` → data buffer (weighted
    /// popcount used by bit-serial field sums).
    ReduceField { col: u16 },
    /// Tag every row.
    SetTagsAll,
    /// Daisy-chain tag shift, towards higher rows.
    ShiftTagsUp(u32),
    /// Daisy-chain tag shift, towards lower rows.
    ShiftTagsDown(u32),
    /// Untagged parallel clear of a column range (controller bulk macro).
    ClearColumns { base: u16, width: u16 },
}

impl Instr {
    /// Documented cycle cost (DESIGN.md §4).
    pub fn cycles(&self) -> u64 {
        match self {
            Instr::Compare(_) => CYCLES_COMPARE,
            Instr::Write(_) => CYCLES_WRITE,
            Instr::Read { .. } => CYCLES_READ,
            Instr::IfMatch | Instr::FirstMatch | Instr::SetTagsAll => CYCLES_TAG_OP,
            Instr::ReduceCount | Instr::ReduceField { .. } => CYCLES_REDUCE_ISSUE,
            Instr::ShiftTagsUp(h) | Instr::ShiftTagsDown(h) => {
                (*h as u64) * CYCLES_TAG_OP
            }
            Instr::ClearColumns { .. } => CYCLES_WRITE,
        }
    }
}

/// A straight-line associative program (the paper's "associative
/// primitives that are downloaded into and executed by the PRINS
/// controller", §5.3).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Program {
    pub instrs: Vec<Instr>,
}

impl Program {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, i: Instr) {
        self.instrs.push(i);
    }

    /// Append a compare+write microcode pass.
    pub fn pass(&mut self, cpat: Pat, wpat: Pat) {
        self.instrs.push(Instr::Compare(cpat));
        self.instrs.push(Instr::Write(wpat));
    }

    /// Compare a full field against a constant.
    pub fn compare_field(&mut self, f: Field, value: u64) {
        self.instrs.push(Instr::Compare(f.pattern(value)));
    }

    /// Write a constant into a full field of all tagged rows.
    pub fn write_field(&mut self, f: Field, value: u64) {
        self.instrs.push(Instr::Write(f.pattern(value)));
    }

    pub fn clear_field(&mut self, f: Field) {
        self.instrs.push(Instr::ClearColumns {
            base: f.base,
            width: f.width,
        });
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Static cycle cost of the whole program.
    pub fn cycle_estimate(&self) -> u64 {
        self.instrs.iter().map(|i| i.cycles()).sum()
    }

    /// Number of compare+write passes (microcode cost metric).
    pub fn n_passes(&self) -> usize {
        self.instrs
            .iter()
            .filter(|i| matches!(i, Instr::Compare(_)))
            .count()
    }

    pub fn extend(&mut self, other: Program) {
        self.instrs.extend(other.instrs);
    }

    /// Highest bit-column referenced (for width validation).
    pub fn max_column(&self) -> Option<u16> {
        self.instrs
            .iter()
            .flat_map(|i| match i {
                Instr::Compare(p) | Instr::Write(p) => {
                    p.iter().map(|&(c, _)| c).max()
                }
                Instr::Read { base, width } => Some(base + width - 1),
                Instr::ReduceField { col } => Some(*col),
                Instr::ClearColumns { base, width } => Some(base + width - 1),
                _ => None,
            })
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_costs_match_design_doc() {
        assert_eq!(Instr::Compare(vec![]).cycles(), 1);
        assert_eq!(Instr::Write(vec![]).cycles(), 2);
        assert_eq!(Instr::Read { base: 0, width: 8 }.cycles(), 1);
        assert_eq!(Instr::ShiftTagsUp(5).cycles(), 5);
    }

    #[test]
    fn program_accounting() {
        let mut p = Program::new();
        let f = Field::new(0, 8);
        p.compare_field(f, 0xAA);
        p.write_field(f, 0x55);
        p.push(Instr::ReduceCount);
        assert_eq!(p.len(), 3);
        assert_eq!(p.n_passes(), 1);
        assert_eq!(p.cycle_estimate(), 1 + 2 + 1);
        assert_eq!(p.max_column(), Some(7));
    }

    #[test]
    fn pass_appends_compare_then_write() {
        let mut p = Program::new();
        p.pass(vec![(0, true)], vec![(1, false)]);
        assert!(matches!(p.instrs[0], Instr::Compare(_)));
        assert!(matches!(p.instrs[1], Instr::Write(_)));
    }
}
