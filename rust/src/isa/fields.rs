//! Row layout: named bit-fields inside the W-bit RCAM row (paper §5.1 —
//! "data element normally occupies only a part of the row, while the rest
//! of it is used for temporary storage").

use crate::error::{err, Result};
use std::collections::BTreeMap;

/// A contiguous bit-field inside the row: columns [base, base+width).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Field {
    /// First (lowest) bit-column of the field.
    pub base: u16,
    /// Field width in bit-columns.
    pub width: u16,
}

impl Field {
    /// Field covering columns `[base, base + width)`.
    pub fn new(base: u16, width: u16) -> Self {
        Field { base, width }
    }

    /// Column index of bit `i` (LSB first).
    #[inline]
    pub fn col(&self, i: u16) -> u16 {
        debug_assert!(i < self.width);
        self.base + i
    }

    /// Columns LSB→MSB.
    pub fn cols(&self) -> impl Iterator<Item = u16> + '_ {
        (0..self.width).map(|i| self.base + i)
    }

    /// Columns MSB→LSB (for lexicographic compares).
    pub fn cols_msb_first(&self) -> impl Iterator<Item = u16> + '_ {
        (0..self.width).rev().map(|i| self.base + i)
    }

    /// Full-field key/write pattern for `value` (LSB first).
    pub fn pattern(&self, value: u64) -> Vec<(u16, bool)> {
        assert!(self.width <= 64);
        (0..self.width)
            .map(|i| (self.base + i, (value >> i) & 1 == 1))
            .collect()
    }

    /// Sub-field covering bits [lo, lo+width) of this field.
    pub fn slice(&self, lo: u16, width: u16) -> Field {
        assert!(lo + width <= self.width);
        Field {
            base: self.base + lo,
            width,
        }
    }

    /// Whether the two fields share any bit-column.
    pub fn overlaps(&self, other: &Field) -> bool {
        self.base < other.base + other.width && other.base < self.base + self.width
    }

    /// One past the last column: `base + width`.
    pub fn end(&self) -> u16 {
        self.base + self.width
    }

    /// Maximum value storable (width < 64).
    pub fn max_value(&self) -> u64 {
        if self.width >= 64 {
            u64::MAX
        } else {
            (1u64 << self.width) - 1
        }
    }
}

/// First-fit allocator of named fields over the row width.
#[derive(Clone, Debug)]
pub struct RowLayout {
    width: u16,
    fields: BTreeMap<String, Field>,
}

impl RowLayout {
    /// An empty layout over a `width`-bit row.
    pub fn new(width: u16) -> Self {
        RowLayout {
            width,
            fields: BTreeMap::new(),
        }
    }

    /// The row width this layout allocates within.
    pub fn width(&self) -> u16 {
        self.width
    }

    /// Allocate `width` columns under `name`. First-fit over the free gaps.
    pub fn alloc(&mut self, name: &str, width: u16) -> Field {
        assert!(
            !self.fields.contains_key(name),
            "field {name:?} already allocated"
        );
        let mut used: Vec<(u16, u16)> = self
            .fields
            .values()
            .map(|f| (f.base, f.end()))
            .collect();
        used.sort_unstable();
        let mut cursor = 0u16;
        for (s, e) in used {
            if cursor + width <= s {
                break;
            }
            cursor = cursor.max(e);
        }
        assert!(
            cursor + width <= self.width,
            "row overflow: cannot fit {width} bits for {name:?} in {}-bit row",
            self.width
        );
        let f = Field::new(cursor, width);
        self.fields.insert(name.to_string(), f);
        f
    }

    /// Allocate at a fixed base (paper-specified layouts, e.g. BFS Table 2).
    pub fn alloc_at(&mut self, name: &str, base: u16, width: u16) -> Field {
        let f = Field::new(base, width);
        assert!(f.end() <= self.width, "field {name:?} exceeds row width");
        for (other, g) in &self.fields {
            assert!(
                !f.overlaps(g),
                "field {name:?} overlaps {other:?}"
            );
        }
        assert!(!self.fields.contains_key(name));
        self.fields.insert(name.to_string(), f);
        f
    }

    /// Release a named field's columns for reuse (no-op if absent).
    pub fn free(&mut self, name: &str) {
        self.fields.remove(name);
    }

    /// Look up a named field. Unknown names are a recoverable error (a
    /// kernel asking for a field a dataset does not carry must not take
    /// the whole device down).
    pub fn get(&self, name: &str) -> Result<Field> {
        self.fields
            .get(name)
            .copied()
            .ok_or_else(|| err!("unknown field {name:?}"))
    }

    /// Iterate the allocated field names (sorted).
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.fields.keys().map(|s| s.as_str())
    }

    /// Bits still unallocated.
    pub fn free_bits(&self) -> u16 {
        self.width - self.fields.values().map(|f| f.width).sum::<u16>()
    }

    /// Check the no-overlap invariant (proptest target).
    pub fn assert_disjoint(&self) {
        let fields: Vec<_> = self.fields.iter().collect();
        for (i, (na, fa)) in fields.iter().enumerate() {
            for (nb, fb) in fields.iter().skip(i + 1) {
                assert!(!fa.overlaps(fb), "{na} overlaps {nb}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_is_lsb_first() {
        let f = Field::new(4, 8);
        let p = f.pattern(0b1010_0001);
        assert_eq!(p[0], (4, true));
        assert_eq!(p[1], (5, false));
        assert_eq!(p[7], (11, true));
    }

    #[test]
    fn alloc_first_fit_and_free() {
        let mut l = RowLayout::new(64);
        let a = l.alloc("a", 16);
        let b = l.alloc("b", 16);
        assert_eq!((a.base, b.base), (0, 16));
        l.free("a");
        let c = l.alloc("c", 8);
        assert_eq!(c.base, 0); // reuses the gap
        let d = l.alloc("d", 16);
        assert_eq!(d.base, 32); // 8..16 gap too small
        l.assert_disjoint();
        assert_eq!(l.free_bits(), 64 - 8 - 16 - 16);
    }

    #[test]
    #[should_panic(expected = "row overflow")]
    fn alloc_overflow_panics() {
        let mut l = RowLayout::new(16);
        l.alloc("a", 12);
        l.alloc("b", 8);
    }

    #[test]
    #[should_panic(expected = "overlaps")]
    fn alloc_at_overlap_panics() {
        let mut l = RowLayout::new(64);
        l.alloc_at("a", 0, 10);
        l.alloc_at("b", 5, 10);
    }

    #[test]
    fn get_unknown_field_is_error_not_panic() {
        let mut l = RowLayout::new(64);
        let a = l.alloc("a", 16);
        assert_eq!(l.get("a").unwrap(), a);
        let e = l.get("nope").unwrap_err();
        assert!(e.to_string().contains("unknown field"), "{e}");
    }

    #[test]
    fn slice_and_overlap() {
        let f = Field::new(8, 16);
        let s = f.slice(4, 4);
        assert_eq!((s.base, s.width), (12, 4));
        assert!(f.overlaps(&s));
        assert!(!f.overlaps(&Field::new(24, 4)));
    }
}
