//! Textual assembly for PRINS programs.
//!
//! The paper (§5.3) states "PRINS code is manually encoded at assembly
//! language level"; this module provides that assembly. One instruction
//! per line; `#` starts a comment. Patterns are `c<col>=<0|1>` terms:
//!
//! ```text
//! # histogram inner loop body
//! compare c24=1 c25=0 c26=1
//! write   c30=1
//! read    base=8 width=16
//! ifmatch
//! firstmatch
//! reduce
//! reducefield col=12
//! settagsall
//! shiftup 3
//! shiftdown 1
//! clearcols base=40 width=8
//! ```

use super::program::{Instr, Pat, Program};
use crate::error::{bail, err, Context, Result};
use std::fmt::Write as _;

/// Render a key/mask pattern as space-separated `c<col>=<0|1>` terms.
pub fn format_pattern(p: &Pat) -> String {
    p.iter()
        .map(|&(c, b)| format!("c{}={}", c, if b { 1 } else { 0 }))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Render one instruction as its assembly line.
pub fn format_instr(i: &Instr) -> String {
    match i {
        Instr::Compare(p) => format!("compare {}", format_pattern(p)).trim_end().into(),
        Instr::Write(p) => format!("write {}", format_pattern(p)).trim_end().into(),
        Instr::Read { base, width } => format!("read base={base} width={width}"),
        Instr::IfMatch => "ifmatch".into(),
        Instr::FirstMatch => "firstmatch".into(),
        Instr::ReduceCount => "reduce".into(),
        Instr::ReduceField { col } => format!("reducefield col={col}"),
        Instr::SetTagsAll => "settagsall".into(),
        Instr::ShiftTagsUp(h) => format!("shiftup {h}"),
        Instr::ShiftTagsDown(h) => format!("shiftdown {h}"),
        Instr::ClearColumns { base, width } => {
            format!("clearcols base={base} width={width}")
        }
    }
}

/// Render a whole program, one instruction per line.
pub fn format_program(p: &Program) -> String {
    let mut s = String::new();
    for i in &p.instrs {
        let _ = writeln!(s, "{}", format_instr(i));
    }
    s
}

fn parse_pattern(terms: &[&str]) -> Result<Pat> {
    terms
        .iter()
        .map(|t| {
            let t = t.strip_prefix('c').ok_or_else(|| err!("bad term {t:?}"))?;
            let (col, bit) = t
                .split_once('=')
                .ok_or_else(|| err!("bad term c{t:?}"))?;
            let col: u16 = col.parse().context("column")?;
            let bit = match bit {
                "0" => false,
                "1" => true,
                _ => bail!("bit must be 0/1, got {bit:?}"),
            };
            Ok((col, bit))
        })
        .collect()
}

fn kv(term: &str, key: &str) -> Result<u16> {
    let (k, v) = term
        .split_once('=')
        .ok_or_else(|| err!("expected {key}=<n>, got {term:?}"))?;
    if k != key {
        bail!("expected key {key:?}, got {k:?}");
    }
    Ok(v.parse()?)
}

/// Parse one assembly line (no comments/blank handling — see
/// [`parse_program`] for whole-file parsing).
pub fn parse_instr(line: &str) -> Result<Instr> {
    let mut parts = line.split_whitespace();
    let op = parts.next().ok_or_else(|| err!("empty instruction"))?;
    let rest: Vec<&str> = parts.collect();
    Ok(match op {
        "compare" => Instr::Compare(parse_pattern(&rest)?),
        "write" => Instr::Write(parse_pattern(&rest)?),
        "read" => {
            if rest.len() != 2 {
                bail!("read needs base= and width=");
            }
            Instr::Read {
                base: kv(rest[0], "base")?,
                width: kv(rest[1], "width")?,
            }
        }
        "ifmatch" => Instr::IfMatch,
        "firstmatch" => Instr::FirstMatch,
        "reduce" => Instr::ReduceCount,
        "reducefield" => Instr::ReduceField {
            col: kv(rest.first().ok_or_else(|| err!("reducefield col="))?, "col")?,
        },
        "settagsall" => Instr::SetTagsAll,
        "shiftup" => Instr::ShiftTagsUp(
            rest.first().ok_or_else(|| err!("shiftup <n>"))?.parse()?,
        ),
        "shiftdown" => Instr::ShiftTagsDown(
            rest.first().ok_or_else(|| err!("shiftdown <n>"))?.parse()?,
        ),
        "clearcols" => {
            if rest.len() != 2 {
                bail!("clearcols needs base= and width=");
            }
            Instr::ClearColumns {
                base: kv(rest[0], "base")?,
                width: kv(rest[1], "width")?,
            }
        }
        _ => bail!("unknown instruction {op:?}"),
    })
}

/// Parse an assembly listing (`#` comments and blank lines ignored);
/// parse errors carry 1-based line numbers in their context.
pub fn parse_program(text: &str) -> Result<Program> {
    let mut prog = Program::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let instr =
            parse_instr(line).with_context(|| format!("line {}: {raw:?}", ln + 1))?;
        prog.push(instr);
    }
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Program {
        let mut p = Program::new();
        p.pass(vec![(3, true), (7, false)], vec![(9, true)]);
        p.push(Instr::Read { base: 8, width: 16 });
        p.push(Instr::IfMatch);
        p.push(Instr::FirstMatch);
        p.push(Instr::ReduceCount);
        p.push(Instr::ReduceField { col: 12 });
        p.push(Instr::SetTagsAll);
        p.push(Instr::ShiftTagsUp(3));
        p.push(Instr::ShiftTagsDown(1));
        p.push(Instr::ClearColumns { base: 40, width: 8 });
        p
    }

    #[test]
    fn roundtrip() {
        let p = sample();
        let text = format_program(&p);
        let q = parse_program(&text).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let p = parse_program("# a comment\n\n  compare c1=1 # tail\n").unwrap();
        assert_eq!(p.instrs, vec![Instr::Compare(vec![(1, true)])]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_program("compare c1=1\nbogus op\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"));
    }

    #[test]
    fn empty_compare_is_valid() {
        // compare with empty mask = tag all rows
        let p = parse_program("compare\n").unwrap();
        assert_eq!(p.instrs, vec![Instr::Compare(vec![])]);
    }
}
