//! The PRINS associative ISA (paper §5.2): instruction set, program
//! container, row-layout (field) management, and the textual assembly the
//! paper says PRINS is programmed in.

pub mod asm;
pub mod fields;
pub mod program;

pub use fields::{Field, RowLayout};
pub use program::{Instr, Pat, Program, Span, Spans};
