//! In-tree error handling — the crate's `anyhow` stand-in, keeping the
//! `[dependencies]` section of Cargo.toml honestly empty.
//!
//! Provides the same ergonomics the rest of the crate needs:
//!   * [`Error`] — a message-carrying error; context is folded into the
//!     message front-to-back, so `{e}` and `{e:#}` both print the full
//!     chain (`"outer: inner"`).
//!   * [`Result`] — alias with `Error` as the default error type.
//!   * [`err!`] / [`bail!`] / [`ensure!`] — the `anyhow!`-family macros.
//!   * [`Context`] — `.context(..)` / `.with_context(..)` on any
//!     `Result` whose error displays, and on `Option`.
//!
//! `From` impls cover the std error types the crate propagates with `?`
//! (io, integer/float parsing, UTF-8).

use std::fmt;

/// A human-readable error. Context wrapping prepends to the message, so
/// the Display output is the whole chain, outermost context first.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    /// An error from a plain message (the `anyhow::Error::msg` twin).
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }

    /// Prepend a context layer: `"{ctx}: {self}"`.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Crate-wide result alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

macro_rules! from_display {
    ($($ty:ty),* $(,)?) => {$(
        impl From<$ty> for Error {
            fn from(e: $ty) -> Self {
                Error::msg(e.to_string())
            }
        }
    )*};
}

from_display!(
    std::io::Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::num::TryFromIntError,
    std::str::Utf8Error,
    std::string::FromUtf8Error,
    std::fmt::Error,
);

/// Construct an [`Error`] from a format string (the `anyhow!` equivalent).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

// Re-export the crate-root macros so call sites can import everything
// from one place: `use crate::error::{bail, err, Context, Result};`
pub use crate::{bail, ensure, err};

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the failure with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the failure with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_the_message() {
        let e = err!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        assert_eq!(format!("{e:#}"), "bad value 42");
    }

    #[test]
    fn context_prepends_outermost_first() {
        let e = err!("root cause").context("while parsing").context("loading config");
        assert_eq!(e.to_string(), "loading config: while parsing: root cause");
    }

    #[test]
    fn context_trait_on_results_and_options() {
        let r: Result<u16, std::num::ParseIntError> = "x".parse::<u16>();
        let e = r.context("port").unwrap_err();
        assert!(e.to_string().starts_with("port:"), "{e}");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing key {:?}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing key \"k\"");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(v: u32) -> Result<u32> {
            ensure!(v < 10, "v too big: {v}");
            if v == 3 {
                bail!("three is right out");
            }
            Ok(v)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "v too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
    }

    #[test]
    fn question_mark_conversions() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").is_err());
        fn read_missing() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read_missing().is_err());
    }
}
