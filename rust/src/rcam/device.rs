//! Device model: the memristor/periphery constants that turn operation
//! counts into time and energy (paper §3.1, §6.1).
//!
//! All latency/energy in this reproduction derives from event counts
//! accumulated by the simulator multiplied by these constants — the same
//! methodology as the paper's timing + power simulators (they obtained the
//! per-event constants from SPICE/TEAM [45]; we take them from the numbers
//! the paper states).

/// Per-operation cycle costs (paper §3.1: write is two-phase).
pub const CYCLES_COMPARE: u64 = 1;
/// Write cycle cost (two-phase: set then reset, paper §3.1).
pub const CYCLES_WRITE: u64 = 2;
/// Read cycle cost (first tagged row → key register).
pub const CYCLES_READ: u64 = 1;
/// Tag-logic cycle cost: first_match / if_match / tag moves.
pub const CYCLES_TAG_OP: u64 = 1;
/// Reduction-tree issue cost. The tree itself is pipelined; its log-depth
/// drain latency is charged once per dependent use (see `Controller`).
pub const CYCLES_REDUCE_ISSUE: u64 = 1;

/// Memristor/periphery constants that convert event counts into time and
/// energy (paper §3.1, §6.1).
#[derive(Clone, Debug)]
pub struct DeviceModel {
    /// Operating frequency \[Hz\]. Paper: 500 MHz in 28 nm.
    pub freq_hz: f64,
    /// Compare energy per bit per row \[J\]. Paper: "less than 1 fJ per bit".
    pub e_compare_bit: f64,
    /// Write energy per bit per (tagged) row \[J\]. Paper: "100 fJ per bit range".
    pub e_write_bit: f64,
    /// Reduction-tree energy per tag bit per tree level \[J\] (our estimate;
    /// the paper folds this into its in-house power simulator).
    pub e_reduce_bit: f64,
    /// Static/controller power \[W\] charged for the whole runtime.
    pub p_controller: f64,
    /// Program/erase endurance per cell. Paper: ~1e12 today, 1e14–1e15 predicted.
    pub endurance: f64,
    /// Technology label (reporting only).
    pub technology: &'static str,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            freq_hz: 500e6,
            e_compare_bit: 1e-15,
            e_write_bit: 100e-15,
            e_reduce_bit: 0.1e-15,
            p_controller: 0.5,
            endurance: 1e12,
            technology: "28nm RRAM (TEAM-calibrated constants from paper §3.1/§6)",
        }
    }
}

impl DeviceModel {
    /// Predicted-endurance variant (paper §3.1: 1e14–1e15).
    pub fn future_endurance() -> Self {
        DeviceModel {
            endurance: 1e14,
            ..Default::default()
        }
    }

    /// One clock period \[s\].
    #[inline]
    pub fn cycle_time_s(&self) -> f64 {
        1.0 / self.freq_hz
    }

    /// Convert a cycle count to wall-clock seconds.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 * self.cycle_time_s()
    }
}

/// Event ledger: everything the power/timing models need, accumulated on
/// the simulation fast path as plain counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EnergyLedger {
    /// Σ over compares of (unmasked bit-columns × rows compared).
    pub compare_bit_events: u128,
    /// Σ over writes of (written bit-columns × tagged rows).
    pub write_bit_events: u128,
    /// Σ over reductions of (tag bits × tree levels).
    pub reduce_bit_events: u128,
    /// Σ bits moved over the daisy-chain interconnect.
    pub chain_bit_events: u128,
    /// Compare-operation count (reporting and ablation).
    pub n_compare: u64,
    /// Write-operation count.
    pub n_write: u64,
    /// Read-operation count.
    pub n_read: u64,
    /// Reduction-issue count.
    pub n_reduce: u64,
    /// Tag-logic operation count.
    pub n_tag_op: u64,
}

impl EnergyLedger {
    /// Accumulate another ledger's events into this one.
    pub fn add(&mut self, other: &EnergyLedger) {
        self.compare_bit_events += other.compare_bit_events;
        self.write_bit_events += other.write_bit_events;
        self.reduce_bit_events += other.reduce_bit_events;
        self.chain_bit_events += other.chain_bit_events;
        self.n_compare += other.n_compare;
        self.n_write += other.n_write;
        self.n_read += other.n_read;
        self.n_reduce += other.n_reduce;
        self.n_tag_op += other.n_tag_op;
    }

    /// Events accumulated since `base` (`self − base`, counter-wise).
    /// `base` must be an earlier snapshot of the same monotonically
    /// growing ledger — the stats-window subtraction used by the
    /// controller's kernel windows and the kernels' load-phase windows.
    pub fn minus(&self, base: &EnergyLedger) -> EnergyLedger {
        EnergyLedger {
            compare_bit_events: self.compare_bit_events - base.compare_bit_events,
            write_bit_events: self.write_bit_events - base.write_bit_events,
            reduce_bit_events: self.reduce_bit_events - base.reduce_bit_events,
            chain_bit_events: self.chain_bit_events - base.chain_bit_events,
            n_compare: self.n_compare - base.n_compare,
            n_write: self.n_write - base.n_write,
            n_read: self.n_read - base.n_read,
            n_reduce: self.n_reduce - base.n_reduce,
            n_tag_op: self.n_tag_op - base.n_tag_op,
        }
    }

    /// Dynamic energy \[J\] under a device model.
    pub fn dynamic_energy_j(&self, dev: &DeviceModel) -> f64 {
        self.compare_bit_events as f64 * dev.e_compare_bit
            + self.write_bit_events as f64 * dev.e_write_bit
            + self.reduce_bit_events as f64 * dev.e_reduce_bit
            + self.chain_bit_events as f64 * dev.e_reduce_bit
    }

    /// Total energy \[J\] including controller/static power over `cycles`.
    pub fn total_energy_j(&self, dev: &DeviceModel, cycles: u64) -> f64 {
        self.dynamic_energy_j(dev) + dev.p_controller * dev.cycles_to_seconds(cycles)
    }

    /// Average power \[W\] over `cycles`.
    pub fn avg_power_w(&self, dev: &DeviceModel, cycles: u64) -> f64 {
        let t = dev.cycles_to_seconds(cycles);
        if t == 0.0 {
            0.0
        } else {
            self.total_energy_j(dev, cycles) / t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_constants() {
        let d = DeviceModel::default();
        assert_eq!(d.freq_hz, 500e6);
        assert!(d.e_compare_bit <= 1e-15);
        assert!((d.e_write_bit - 100e-15).abs() < 1e-18);
        assert_eq!(d.endurance, 1e12);
    }

    #[test]
    fn minus_inverts_add() {
        let mut a = EnergyLedger::default();
        a.compare_bit_events = 10;
        a.n_compare = 2;
        a.n_write = 1;
        let mut b = a.clone();
        b.add(&a);
        b.write_bit_events += 7;
        b.n_tag_op += 3;
        let d = b.minus(&a);
        assert_eq!(d.compare_bit_events, 10);
        assert_eq!(d.n_compare, 2);
        assert_eq!(d.write_bit_events, 7);
        assert_eq!(d.n_tag_op, 3);
    }

    #[test]
    fn energy_accumulates_linearly() {
        let d = DeviceModel::default();
        let mut l = EnergyLedger::default();
        l.compare_bit_events = 1_000;
        l.write_bit_events = 10;
        let e1 = l.dynamic_energy_j(&d);
        let mut l2 = l.clone();
        l2.add(&l);
        assert!((l2.dynamic_energy_j(&d) - 2.0 * e1).abs() < 1e-24);
        // 1000 compare-bit at 1fJ + 10 write-bit at 100fJ = 2 pJ
        assert!((e1 - 2e-12).abs() < 1e-18);
    }

    #[test]
    fn controller_power_dominates_idle() {
        let d = DeviceModel::default();
        let l = EnergyLedger::default();
        let e = l.total_energy_j(&d, 500_000_000); // 1 s
        assert!((e - d.p_controller).abs() < 1e-9);
        assert!((l.avg_power_w(&d, 500_000_000) - d.p_controller).abs() < 1e-9);
    }
}
