//! Parallel execution engine: row-striped multi-threaded backend for the
//! PRINS array (DESIGN.md §5).
//!
//! The modeled hardware executes every associative instruction on all rows
//! of all modules simultaneously; the simulator mirrors that data
//! parallelism by sharding the array into per-worker *row stripes* —
//! contiguous 64-row-word ranges spanning one or more modules — and
//! running whole *spans* of data-parallel instructions on all stripes
//! concurrently. Three rules keep the threaded backend bit-identical to
//! the serial path (DESIGN.md §5, "barrier rules"):
//!
//!   1. Only data-parallel instructions (compare / write / set-tags /
//!      column clears) run striped; anything with a global result or
//!      cross-row communication (read, if/first-match, reductions, tag
//!      shifts) is a barrier and executes serially between spans.
//!   2. Every kernel is word-local: a stripe only ever reads and writes
//!      its own tag/plane words, so stripes never race and instruction
//!      order within a span only matters per word.
//!   3. Energy accounting is split: data-independent events (instruction
//!      counts, compare match-line precharge) are charged analytically
//!      per module at the span barrier; data-dependent events (write
//!      bits, wear) are accumulated per stripe and summed — integer
//!      sums, so the merged ledger is exactly the serial ledger.
//!
//! Everything here is std-only (`[dependencies]` stays empty): the worker
//! pool is persistent `std::thread` workers fed lifetime-erased jobs
//! through channels, with a latch providing the scoped-join guarantee
//! that `std::thread::scope` would (the dispatcher blocks until every
//! worker finishes, so borrowed stripe state outlives all uses).

use super::bitvec::WORD_BITS;
use super::device::{CYCLES_COMPARE, CYCLES_TAG_OP, CYCLES_WRITE};
use super::module::Pattern;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// How `PrinsArray` executes data-parallel instruction spans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecBackend {
    /// Single-threaded per-module sweep (the reference path; stats from
    /// the threaded backend are asserted bit-identical to this one).
    Serial,
    /// Row-striped execution on `n` workers (the dispatching thread is
    /// worker 0, so `Threaded(n)` spawns `n - 1` pool threads).
    Threaded(usize),
}

impl ExecBackend {
    /// `Threaded(available_parallelism())` — the CLI/bench default.
    pub fn threaded_default() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        ExecBackend::from_workers(n)
    }

    /// `n <= 1` selects the serial reference path.
    pub fn from_workers(n: usize) -> Self {
        if n <= 1 {
            ExecBackend::Serial
        } else {
            ExecBackend::Threaded(n)
        }
    }

    /// Total worker count (the dispatcher included; 1 for serial).
    #[inline]
    pub fn workers(&self) -> usize {
        match self {
            ExecBackend::Serial => 1,
            ExecBackend::Threaded(n) => (*n).max(1),
        }
    }

    /// Whether data-parallel spans run striped over a worker pool.
    #[inline]
    pub fn is_threaded(&self) -> bool {
        matches!(self, ExecBackend::Threaded(_))
    }
}

// ---------------------------------------------------------------------------
// Data-parallel operations (one span = a slice of these)
// ---------------------------------------------------------------------------

/// A data-parallel instruction, borrowing its patterns from the caller.
/// `Pass` is the fused compare + tagged-write kernel: one traversal,
/// identical results and ledger to `Compare` followed by `Write`.
#[derive(Clone, Copy)]
pub enum StripeOp<'a> {
    /// Tag rows matching the pattern.
    Compare(&'a Pattern),
    /// Write the pattern into all tagged rows.
    Write(&'a Pattern),
    /// Fused compare + tagged write (one traversal).
    Pass(&'a Pattern, &'a Pattern),
    /// Tag every row.
    SetTagsAll,
    /// Untagged parallel clear of a column range.
    ClearColumns {
        /// First column to clear.
        base: u16,
        /// Number of columns to clear.
        width: u16,
    },
}

/// Cycle charge of one op — must agree with `Instr::cycles()` and with
/// what the serial path charges (DESIGN.md §4).
pub(crate) fn op_cycles(op: &StripeOp) -> u64 {
    match op {
        StripeOp::Compare(_) => CYCLES_COMPARE,
        StripeOp::Write(_) => CYCLES_WRITE,
        StripeOp::Pass(_, _) => CYCLES_COMPARE + CYCLES_WRITE,
        StripeOp::SetTagsAll => CYCLES_TAG_OP,
        StripeOp::ClearColumns { .. } => CYCLES_WRITE,
    }
}

// ---------------------------------------------------------------------------
// Stripe planning
// ---------------------------------------------------------------------------

/// Raw pointers into one module's state, harvested from disjoint `&mut`
/// borrows immediately before a dispatch (see `RcamModule::raw_parts`).
pub(crate) struct ModuleParts {
    pub tags: *mut u64,
    /// One base pointer per bit-column plane's word buffer.
    pub planes: Vec<*mut u64>,
    /// Per-row wear counters, if tracking is enabled.
    pub wear: Option<*mut u32>,
    pub rows: usize,
    /// Tag/plane words per module (`words_for(rows)`).
    pub words: usize,
}

/// One contiguous word range of one module, owned by exactly one stripe.
///
/// Safety: segments are constructed by [`plan_stripes`] as a disjoint
/// partition of all (module, word) pairs, and each segment is executed by
/// exactly one worker while the dispatcher blocks — so the `*mut`
/// accesses never alias across threads. `Send`/`Sync` are asserted on
/// that basis.
pub(crate) struct Segment {
    pub module: usize,
    nwords: usize,
    /// Canonical mask of this segment's LAST word (all-ones unless it is
    /// the module's tail word and `rows % 64 != 0`).
    tail_mask: u64,
    tags: *mut u64,
    planes: Vec<*mut u64>,
    /// Null when wear tracking is disabled. Offset so index `w*64 + b`
    /// is the wear slot of this segment's word `w`, bit `b`.
    wear: *mut u32,
}

unsafe impl Send for Segment {}
unsafe impl Sync for Segment {}

impl Segment {
    #[inline]
    fn mask(&self, w: usize) -> u64 {
        if w + 1 == self.nwords {
            self.tail_mask
        } else {
            u64::MAX
        }
    }

    /// Apply the write pattern to plane word `w` under tag mask `t`.
    #[inline]
    fn write_word(&self, w: usize, t: u64, pat: &Pattern) {
        for &(col, bit) in pat {
            let p = unsafe { &mut *self.planes[col as usize].add(w) };
            if bit {
                *p |= t;
            } else {
                *p &= !t;
            }
        }
    }

    /// Bump wear counters for the set bits of tag word `w` (word-skipped:
    /// callers only invoke this for non-zero `t`).
    #[inline]
    fn wear_word(&self, w: usize, t: u64) {
        if self.wear.is_null() {
            return;
        }
        let mut m = t;
        while m != 0 {
            let b = m.trailing_zeros() as usize;
            unsafe { *self.wear.add(w * WORD_BITS + b) += 1 };
            m &= m - 1;
        }
    }
}

/// Partition the global word space (modules × words-per-module) into at
/// most `max_stripes` contiguous, balanced stripes. A stripe that crosses
/// a module boundary gets one segment per module touched. Stripe
/// boundaries are word-aligned, so splits that do not divide module rows
/// evenly are fine — the tail word keeps its canonical mask.
pub(crate) fn plan_stripes(parts: &[ModuleParts], max_stripes: usize) -> Vec<Vec<Segment>> {
    let wpm = parts[0].words;
    let total = wpm * parts.len();
    let n = max_stripes.clamp(1, total);
    let mut stripes = Vec::with_capacity(n);
    for s in 0..n {
        let g0 = s * total / n;
        let g1 = (s + 1) * total / n;
        let mut segs = Vec::new();
        let mut g = g0;
        while g < g1 {
            let m = g / wpm;
            let w0 = g % wpm;
            let take = (g1 - g).min(wpm - w0);
            let part = &parts[m];
            let tail = part.rows % WORD_BITS;
            let tail_mask = if w0 + take == part.words && tail != 0 {
                (1u64 << tail) - 1
            } else {
                u64::MAX
            };
            segs.push(Segment {
                module: m,
                nwords: take,
                tail_mask,
                tags: unsafe { part.tags.add(w0) },
                planes: part
                    .planes
                    .iter()
                    .map(|&p| unsafe { p.add(w0) })
                    .collect(),
                wear: part
                    .wear
                    .map(|p| unsafe { p.add(w0 * WORD_BITS) })
                    .unwrap_or(std::ptr::null_mut()),
            });
            g += take;
        }
        stripes.push(segs);
    }
    stripes
}

// ---------------------------------------------------------------------------
// Stripe kernels
// ---------------------------------------------------------------------------

/// Execute a whole span of ops over one segment. Returns the segment's
/// data-dependent write-bit events (Σ pattern-columns × tagged rows at
/// each write); everything data-independent is charged by the caller.
///
/// All kernels are word-blocked: the tag word stays in a register across
/// every pattern column of an op (DESIGN.md §Perf), and tagged writes /
/// wear updates skip all-zero tag words.
pub(crate) fn run_ops_on_segment(seg: &Segment, ops: &[StripeOp]) -> u128 {
    let tags = unsafe { std::slice::from_raw_parts_mut(seg.tags, seg.nwords) };
    let mut write_events: u128 = 0;
    for op in ops {
        match *op {
            StripeOp::Compare(pat) => {
                for (w, tw) in tags.iter_mut().enumerate() {
                    let mut t = seg.mask(w);
                    for &(col, bit) in pat {
                        let p = unsafe { *seg.planes[col as usize].add(w) };
                        t &= if bit { p } else { !p };
                    }
                    *tw = t;
                }
            }
            StripeOp::Write(pat) => {
                let mut tagged: u64 = 0;
                for (w, &t) in tags.iter().enumerate() {
                    if t == 0 {
                        continue;
                    }
                    tagged += t.count_ones() as u64;
                    seg.write_word(w, t, pat);
                    seg.wear_word(w, t);
                }
                write_events += pat.len() as u128 * tagged as u128;
            }
            StripeOp::Pass(cpat, wpat) => {
                let mut tagged: u64 = 0;
                for (w, tw) in tags.iter_mut().enumerate() {
                    let mut t = seg.mask(w);
                    for &(col, bit) in cpat {
                        let p = unsafe { *seg.planes[col as usize].add(w) };
                        t &= if bit { p } else { !p };
                    }
                    *tw = t;
                    if t != 0 {
                        tagged += t.count_ones() as u64;
                        seg.write_word(w, t, wpat);
                        seg.wear_word(w, t);
                    }
                }
                write_events += wpat.len() as u128 * tagged as u128;
            }
            StripeOp::SetTagsAll => {
                for (w, tw) in tags.iter_mut().enumerate() {
                    *tw = seg.mask(w);
                }
            }
            StripeOp::ClearColumns { base, width } => {
                for col in base..base + width {
                    let p = seg.planes[col as usize];
                    for w in 0..seg.nwords {
                        unsafe { *p.add(w) = 0 };
                    }
                }
            }
        }
    }
    write_events
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Completion latch: the dispatcher blocks until every sent job is done;
/// a worker panic is recorded and re-raised on the dispatcher.
struct Latch {
    state: Mutex<(usize, bool)>, // (remaining, poisoned)
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            state: Mutex::new((n, false)),
            cv: Condvar::new(),
        }
    }

    fn done(&self, poisoned: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        st.1 |= poisoned;
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Returns true if any job panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.1
    }
}

/// One unit of dispatched work: a lifetime-erased pointer to the
/// dispatcher's stripe closure plus the stripe index to run.
///
/// Safety: the pointer targets a `dyn Fn(usize) + Sync` living on the
/// dispatcher's stack; [`WorkerPool::run`] does not return until the
/// latch releases, so the pointee strictly outlives every use. `Send` is
/// asserted on that basis.
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    latch: Arc<Latch>,
    stripe: usize,
}

unsafe impl Send for Job {}

fn worker_loop(rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let f = unsafe { &*job.task };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(job.stripe)));
        job.latch.done(r.is_err());
    }
}

/// A persistent pool of `threads` workers fed through per-worker
/// channels. The dispatching thread participates as worker 0, so an
/// `ExecBackend::Threaded(n)` array owns a pool of `n - 1` threads and a
/// dispatch costs one channel send + one latch wait — no thread spawns on
/// the hot path.
pub struct WorkerPool {
    threads: usize,
    txs: Mutex<Vec<Sender<Job>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Spawn a dedicated pool of `threads` workers (prefer
    /// [`WorkerPool::shared`] — pools are process-lifetime objects).
    pub fn new(threads: usize) -> Self {
        let mut txs = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, rx) = channel::<Job>();
            txs.push(tx);
            handles.push(std::thread::spawn(move || worker_loop(rx)));
        }
        WorkerPool {
            threads,
            txs: Mutex::new(txs),
            handles: Mutex::new(handles),
        }
    }

    /// Number of pool threads (the dispatching thread is extra).
    #[inline]
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Process-wide shared pool for a given thread count. Arrays with the
    /// same worker setting reuse one pool instead of spawning threads per
    /// array — the TCP server builds a device per request and the figure
    /// harnesses build an array per matrix/graph, so per-array pools
    /// would put thread spawn/join on the serving hot path. Shared pools
    /// live for the process lifetime (workers idle on channel recv).
    pub fn shared(threads: usize) -> Arc<WorkerPool> {
        static POOLS: OnceLock<Mutex<HashMap<usize, Arc<WorkerPool>>>> = OnceLock::new();
        let mut map = POOLS
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap();
        map.entry(threads)
            .or_insert_with(|| Arc::new(WorkerPool::new(threads)))
            .clone()
    }

    /// Run `f(stripe)` for every stripe in `0..stripes`, blocking until
    /// all complete. Stripe 0 runs inline on the calling thread; stripes
    /// 1.. are dispatched to pool workers (`stripes <= threads + 1`).
    pub fn run(&self, stripes: usize, f: &(dyn Fn(usize) + Sync)) {
        if stripes == 0 {
            return;
        }
        let remote = stripes - 1;
        assert!(
            remote <= self.threads,
            "stripe plan exceeds pool size ({stripes} stripes, {} threads)",
            self.threads
        );
        let latch = Arc::new(Latch::new(remote));
        // Safety: erase the borrow's lifetime for transport; the latch
        // wait below guarantees the pointee outlives every worker use.
        let task: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        {
            let txs = self.txs.lock().unwrap();
            for i in 0..remote {
                txs[i]
                    .send(Job {
                        task,
                        latch: latch.clone(),
                        stripe: i + 1,
                    })
                    .expect("worker pool thread died");
            }
        }
        // Run the inline stripe with panics deferred: the dispatcher must
        // not unwind past the borrowed task state while workers still
        // reference it, so wait for the latch before re-raising.
        let inline = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        let remote_poisoned = latch.wait();
        if let Err(e) = inline {
            std::panic::resume_unwind(e);
        }
        if remote_poisoned {
            panic!("parallel execution worker panicked");
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // dropping the senders ends every worker's recv loop
        self.txs.lock().unwrap().clear();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn backend_from_workers() {
        assert_eq!(ExecBackend::from_workers(0), ExecBackend::Serial);
        assert_eq!(ExecBackend::from_workers(1), ExecBackend::Serial);
        assert_eq!(ExecBackend::from_workers(4), ExecBackend::Threaded(4));
        assert_eq!(ExecBackend::Threaded(4).workers(), 4);
        assert_eq!(ExecBackend::Serial.workers(), 1);
        assert!(ExecBackend::threaded_default().workers() >= 1);
    }

    #[test]
    fn pool_runs_every_stripe_exactly_once() {
        let pool = WorkerPool::new(3);
        for stripes in 1..=4 {
            let hits: Vec<AtomicUsize> = (0..stripes).map(|_| AtomicUsize::new(0)).collect();
            pool.run(stripes, &|s| {
                hits[s].fetch_add(1, Ordering::SeqCst);
            });
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "stripe {s}/{stripes}");
            }
        }
    }

    #[test]
    fn pool_reusable_across_dispatches() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run(3, &|s| {
                total.fetch_add(s + 1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 600);
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn pool_propagates_worker_panic() {
        let pool = WorkerPool::new(1);
        pool.run(2, &|s| {
            if s == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn stripe_plan_partitions_all_words() {
        use crate::rcam::module::RcamModule;
        for (nmods, rows, stripes) in
            [(1usize, 130usize, 3usize), (3, 100, 4), (2, 64, 8), (1, 1, 5), (4, 65, 2)]
        {
            let mut mods: Vec<RcamModule> =
                (0..nmods).map(|_| RcamModule::new(rows, 4)).collect();
            let parts: Vec<ModuleParts> =
                mods.iter_mut().map(|m| m.raw_parts()).collect();
            let wpm = parts[0].words;
            let plan = plan_stripes(&parts, stripes);
            assert!(plan.len() <= stripes.max(1));
            // every (module, word) covered exactly once, in order
            let mut covered = vec![0usize; nmods * wpm];
            for stripe in &plan {
                for seg in stripe {
                    let base = unsafe { seg.tags.offset_from(parts[seg.module].tags) };
                    let w0 = base as usize;
                    for w in w0..w0 + seg.nwords {
                        covered[seg.module * wpm + w] += 1;
                    }
                    // tail mask only on the module's final word
                    let tail = rows % WORD_BITS;
                    if tail != 0 && w0 + seg.nwords == wpm {
                        assert_eq!(seg.tail_mask, (1u64 << tail) - 1);
                    } else {
                        assert_eq!(seg.tail_mask, u64::MAX);
                    }
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "{nmods}x{rows}/{stripes}: {covered:?}");
        }
    }
}
