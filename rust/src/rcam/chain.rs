//! The PRINS array: multiple daisy-chained RCAM modules (paper Fig. 4),
//! presented to the controller as a single associative address space.
//!
//! Every associative instruction is broadcast to all modules; they execute
//! it simultaneously in hardware, so the array charges each instruction's
//! cycle cost ONCE while energy events accrue in every module. The daisy
//! chain links the last PU of module k to the first PU of module k+1, so
//! tag shifts ripple across module boundaries; per-module reduction-tree
//! outputs are cascaded/accumulated in the controller's data buffer.

use super::bitvec::BitVec;
use super::device::{
    DeviceModel, EnergyLedger, CYCLES_COMPARE, CYCLES_READ, CYCLES_REDUCE_ISSUE,
    CYCLES_TAG_OP, CYCLES_WRITE,
};
use super::module::{Pattern, RcamModule};

#[derive(Clone, Debug)]
pub struct PrinsArray {
    modules: Vec<RcamModule>,
    rows_per_module: usize,
    width: usize,
    pub device: DeviceModel,
    /// Total elapsed cycles across all executed instructions.
    pub cycles: u64,
}

impl PrinsArray {
    pub fn new(n_modules: usize, rows_per_module: usize, width: usize) -> Self {
        Self::with_device(n_modules, rows_per_module, width, DeviceModel::default())
    }

    pub fn with_device(
        n_modules: usize,
        rows_per_module: usize,
        width: usize,
        device: DeviceModel,
    ) -> Self {
        assert!(n_modules > 0 && rows_per_module > 0 && width > 0);
        PrinsArray {
            modules: (0..n_modules)
                .map(|_| RcamModule::new(rows_per_module, width))
                .collect(),
            rows_per_module,
            width,
            device,
            cycles: 0,
        }
    }

    pub fn single(rows: usize, width: usize) -> Self {
        Self::new(1, rows, width)
    }

    /// Enable per-row wear counters on every module (costs O(tagged rows)
    /// per write in simulation; off by default).
    pub fn enable_wear_tracking(&mut self) {
        let (r, w) = (self.rows_per_module, self.width);
        for m in &mut self.modules {
            *m = RcamModule::with_wear_tracking(r, w);
        }
    }

    #[inline]
    pub fn total_rows(&self) -> usize {
        self.rows_per_module * self.modules.len()
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn n_modules(&self) -> usize {
        self.modules.len()
    }

    #[inline]
    pub fn modules(&self) -> &[RcamModule] {
        &self.modules
    }

    #[inline]
    fn split(&self, row: usize) -> (usize, usize) {
        (row / self.rows_per_module, row % self.rows_per_module)
    }

    /// Merged energy ledger over all modules.
    pub fn ledger(&self) -> EnergyLedger {
        let mut total = EnergyLedger::default();
        for m in &self.modules {
            total.add(&m.ledger);
        }
        total
    }

    // ----- broadcast associative instructions ---------------------------

    pub fn compare(&mut self, pattern: &Pattern) {
        for m in &mut self.modules {
            m.compare(pattern);
        }
        self.cycles += CYCLES_COMPARE;
    }

    pub fn write(&mut self, pattern: &Pattern) {
        for m in &mut self.modules {
            m.write(pattern);
        }
        self.cycles += CYCLES_WRITE;
    }

    /// compare immediately followed by tagged write — the microcode pass.
    pub fn pass(&mut self, cpat: &Pattern, wpat: &Pattern) {
        self.compare(cpat);
        self.write(wpat);
    }

    pub fn if_match(&mut self) -> bool {
        let mut any = false;
        for m in &mut self.modules {
            any |= m.if_match();
        }
        self.cycles += CYCLES_TAG_OP;
        any
    }

    /// Global first_match: the daisy-chained first-match circuits keep the
    /// first tag of the first module that has one and clear all others.
    /// Returns the global row index.
    pub fn first_match(&mut self) -> Option<usize> {
        let mut found: Option<usize> = None;
        for (i, m) in self.modules.iter_mut().enumerate() {
            if found.is_none() {
                if let Some(r) = m.first_match() {
                    found = Some(i * self.rows_per_module + r);
                }
            } else {
                m.tags_mut().fill(false);
            }
        }
        self.cycles += CYCLES_TAG_OP;
        found
    }

    /// Read a field from the first tagged row anywhere in the chain.
    pub fn read_first(&mut self, base: u16, width: u16) -> Option<u64> {
        self.cycles += CYCLES_READ;
        for m in &mut self.modules {
            if let Some(v) = m.read_first(base, width) {
                return Some(v);
            }
        }
        None
    }

    /// Total tagged rows (per-module reduction trees + controller add).
    pub fn count_tags(&mut self) -> u64 {
        let mut n = 0;
        for m in &mut self.modules {
            n += m.count_tags();
        }
        self.cycles += CYCLES_REDUCE_ISSUE;
        n
    }

    /// Weighted popcount: tagged rows whose bit-column `col` is set.
    pub fn count_tags_and_col(&mut self, col: u16) -> u64 {
        let mut n = 0;
        for m in &mut self.modules {
            n += m.count_tags_and_col(col);
        }
        self.cycles += CYCLES_REDUCE_ISSUE;
        n
    }

    /// Reduction-tree drain latency (charged once per dependent readout).
    pub fn reduction_latency_cycles(&self) -> u64 {
        let per_module = self.rows_per_module.max(2).next_power_of_two().ilog2() as u64;
        // cascaded module outputs accumulate down the chain
        per_module + self.modules.len() as u64 - 1
    }

    pub fn charge_reduction_latency(&mut self) {
        self.cycles += self.reduction_latency_cycles();
    }

    pub fn set_tags_all(&mut self) {
        for m in &mut self.modules {
            m.set_tags_all();
        }
        self.cycles += CYCLES_TAG_OP;
    }

    /// Shift the global tag vector towards higher rows by `hops` (daisy
    /// chain, 1 hop per cycle, carries ripple across module boundaries).
    pub fn shift_tags_up(&mut self, hops: usize) {
        for _ in 0..hops {
            let mut carry = false;
            for m in &mut self.modules {
                let last = m.tags().get(m.rows() - 1);
                let t = m.tags_mut();
                t.shift_up(1);
                t.set(0, carry);
                carry = last;
            }
        }
        self.cycles += (hops as u64) * CYCLES_TAG_OP;
        let bits = (self.total_rows() as u128) * (hops as u128);
        if let Some(m0) = self.modules.first_mut() {
            m0.ledger.chain_bit_events += bits;
        }
    }

    /// Shift the global tag vector towards lower rows by `hops`.
    pub fn shift_tags_down(&mut self, hops: usize) {
        for _ in 0..hops {
            let mut carry = false;
            for m in self.modules.iter_mut().rev() {
                let first = m.tags().get(0);
                let t = m.tags_mut();
                t.shift_down(1);
                let top = t.len() - 1;
                t.set(top, carry);
                carry = first;
            }
        }
        self.cycles += (hops as u64) * CYCLES_TAG_OP;
        let bits = (self.total_rows() as u128) * (hops as u128);
        if let Some(m0) = self.modules.first_mut() {
            m0.ledger.chain_bit_events += bits;
        }
    }

    /// Daisy-chain field move (paper §3.1: "A daisy-chain like bitwise
    /// interconnect allows PUs to intercommunicate, all PUs in parallel"):
    /// copy planes [src..src+width) into [dst..dst+width), shifted `hops`
    /// rows towards LOWER indices (row r receives row r+hops; the top
    /// `hops` rows receive zeros). All bit-columns move in parallel
    /// (bitwise chain), one hop per cycle → cost = `hops` cycles.
    /// Source planes are preserved (dst must not overlap src).
    pub fn shift_columns_to(&mut self, src: u16, dst: u16, width: u16, hops: usize) {
        assert!(
            dst + width <= src || src + width <= dst,
            "shift_columns_to: src/dst overlap"
        );
        let total = self.total_rows();
        let rpm = self.rows_per_module;
        let word_aligned = rpm % 64 == 0;
        for i in 0..width {
            // gather the global source plane (word-level when modules are
            // 64-row aligned — the §Perf fast path; bit-level fallback)
            let mut global = if word_aligned {
                let mut words = Vec::with_capacity(total.div_ceil(64));
                for m in self.modules.iter() {
                    words.extend_from_slice(m.storage().plane((src + i) as usize).words());
                }
                BitVec::from_words(words, total)
            } else {
                let mut g = BitVec::zeros(total);
                for (mi, m) in self.modules.iter().enumerate() {
                    let p = m.storage().plane((src + i) as usize);
                    for r in p.iter_ones() {
                        g.set(mi * rpm + r, true);
                    }
                }
                g
            };
            global.shift_down(hops);
            // scatter into the destination plane
            if word_aligned {
                let wpm = rpm / 64;
                for (mi, m) in self.modules.iter_mut().enumerate() {
                    let words = global.words()[mi * wpm..(mi + 1) * wpm].to_vec();
                    m.replace_plane(dst + i, BitVec::from_words(words, rpm));
                }
            } else {
                for (mi, m) in self.modules.iter_mut().enumerate() {
                    let mut local = BitVec::zeros(rpm);
                    for r in 0..rpm {
                        if global.get(mi * rpm + r) {
                            local.set(r, true);
                        }
                    }
                    m.replace_plane(dst + i, local);
                }
            }
        }
        self.cycles += hops as u64;
        let bits = (total as u128) * (width as u128) * (hops as u128);
        if let Some(m0) = self.modules.first_mut() {
            m0.ledger.chain_bit_events += bits;
        }
    }

    /// Snapshot of the global tag vector (test/debug aid, not an ISA op).
    pub fn tags_snapshot(&self) -> BitVec {
        let mut out = BitVec::zeros(self.total_rows());
        for (i, m) in self.modules.iter().enumerate() {
            for r in m.tags().iter_ones() {
                out.set(i * self.rows_per_module + r, true);
            }
        }
        out
    }

    /// Clear a column range across the whole array.
    pub fn clear_columns(&mut self, base: u16, width: u16) {
        for m in &mut self.modules {
            m.clear_columns(base, width);
        }
        self.cycles += CYCLES_WRITE;
    }

    // ----- storage-management access path --------------------------------

    pub fn load_row_bits(&mut self, row: usize, base: usize, width: usize, value: u64) {
        let (mi, r) = self.split(row);
        self.modules[mi].load_row_bits(r, base, width, value);
    }

    pub fn fetch_row_bits(&self, row: usize, base: usize, width: usize) -> u64 {
        let (mi, r) = self.split(row);
        self.modules[mi].fetch_row_bits(r, base, width)
    }

    /// Elapsed wall-clock time of everything executed so far.
    pub fn elapsed_seconds(&self) -> f64 {
        self.device.cycles_to_seconds(self.cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 4-module chain must behave exactly like one flat module.
    #[test]
    fn chain_equivalent_to_single_module() {
        let rows = 256;
        let mut chain = PrinsArray::new(4, rows / 4, 16);
        let mut flat = PrinsArray::single(rows, 16);
        // identical contents
        for r in 0..rows {
            let v = ((r as u64).wrapping_mul(2654435761)) & 0xFFFF;
            chain.load_row_bits(r, 0, 16, v);
            flat.load_row_bits(r, 0, 16, v);
        }
        let pat: Vec<(u16, bool)> = vec![(3, true), (7, false)];
        chain.compare(&pat);
        flat.compare(&pat);
        assert_eq!(
            chain.tags_snapshot().iter_ones().collect::<Vec<_>>(),
            flat.tags_snapshot().iter_ones().collect::<Vec<_>>()
        );
        assert_eq!(chain.count_tags(), flat.count_tags());
        chain.write(&[(15, true)]);
        flat.write(&[(15, true)]);
        for r in 0..rows {
            assert_eq!(chain.fetch_row_bits(r, 0, 16), flat.fetch_row_bits(r, 0, 16));
        }
        // cycle cost identical regardless of module count (SIMD broadcast)
        assert_eq!(chain.cycles, flat.cycles);
    }

    #[test]
    fn global_first_match_spans_modules() {
        let mut a = PrinsArray::new(3, 10, 8);
        a.load_row_bits(25, 0, 1, 1); // module 2
        a.load_row_bits(13, 0, 1, 1); // module 1
        a.compare(&[(0, true)]);
        assert_eq!(a.first_match(), Some(13));
        let snap = a.tags_snapshot();
        assert_eq!(snap.iter_ones().collect::<Vec<_>>(), vec![13]);
    }

    #[test]
    fn read_first_finds_any_module() {
        let mut a = PrinsArray::new(2, 8, 16);
        a.load_row_bits(11, 0, 1, 1);
        a.load_row_bits(11, 4, 8, 0x5A);
        a.compare(&[(0, true)]);
        assert_eq!(a.read_first(4, 8), Some(0x5A));
    }

    #[test]
    fn tag_shift_crosses_module_boundary() {
        let mut a = PrinsArray::new(2, 4, 4);
        a.load_row_bits(3, 0, 1, 1); // last row of module 0
        a.compare(&[(0, true)]);
        a.shift_tags_up(1);
        assert_eq!(a.tags_snapshot().iter_ones().collect::<Vec<_>>(), vec![4]);
        a.shift_tags_down(2);
        assert_eq!(a.tags_snapshot().iter_ones().collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn cycles_follow_documented_costs() {
        let mut a = PrinsArray::single(64, 8);
        let c0 = a.cycles;
        a.compare(&[(0, true)]);
        assert_eq!(a.cycles - c0, 1);
        a.write(&[(1, true)]);
        assert_eq!(a.cycles - c0, 3); // write is two-phase
        a.count_tags();
        assert_eq!(a.cycles - c0, 4);
        a.if_match();
        assert_eq!(a.cycles - c0, 5);
    }

    #[test]
    fn reduction_latency_scales_with_chain() {
        let a1 = PrinsArray::single(1 << 20, 8);
        assert_eq!(a1.reduction_latency_cycles(), 20);
        let a4 = PrinsArray::new(4, 1 << 18, 8);
        assert_eq!(a4.reduction_latency_cycles(), 18 + 3);
    }
}
#[cfg(test)]
mod shift_tests {
    use super::*;

    #[test]
    fn shift_columns_to_moves_fields_down() {
        let mut a = PrinsArray::new(2, 8, 16); // 16 rows across 2 modules
        for r in 0..16 {
            a.load_row_bits(r, 0, 4, (r % 16) as u64);
        }
        let c0 = a.cycles;
        a.shift_columns_to(0, 8, 4, 3);
        assert_eq!(a.cycles - c0, 3, "hops cycles");
        for r in 0..16 {
            let expect = if r + 3 < 16 { ((r + 3) % 16) as u64 } else { 0 };
            assert_eq!(a.fetch_row_bits(r, 8, 4), expect, "row {r}");
            // source preserved
            assert_eq!(a.fetch_row_bits(r, 0, 4), (r % 16) as u64);
        }
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn shift_columns_overlap_rejected() {
        let mut a = PrinsArray::single(8, 8);
        a.shift_columns_to(0, 2, 4, 1);
    }
}
