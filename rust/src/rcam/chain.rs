//! The PRINS array: multiple daisy-chained RCAM modules (paper Fig. 4),
//! presented to the controller as a single associative address space.
//!
//! Every associative instruction is broadcast to all modules; they execute
//! it simultaneously in hardware, so the array charges each instruction's
//! cycle cost ONCE while energy events accrue in every module. The daisy
//! chain links the last PU of module k to the first PU of module k+1, so
//! tag shifts ripple across module boundaries; per-module reduction-tree
//! outputs are cascaded/accumulated in the controller's data buffer.

use super::bitvec::{BitVec, WORD_BITS};
use super::device::{
    DeviceModel, EnergyLedger, CYCLES_COMPARE, CYCLES_READ, CYCLES_REDUCE_ISSUE,
    CYCLES_TAG_OP, CYCLES_WRITE,
};
use super::exec::{self, ExecBackend, StripeOp, WorkerPool};
use super::module::{Pattern, RcamModule};
use crate::isa::Instr;
use crate::reliability::{AmbientKind, FaultModel, FaultState, FaultStats};
use std::ops::Range;
use std::sync::{Arc, Mutex};

/// The full PRINS array: daisy-chained RCAM modules presented to the
/// controller as one associative address space (paper Fig. 4).
#[derive(Clone, Debug)]
pub struct PrinsArray {
    modules: Vec<RcamModule>,
    rows_per_module: usize,
    width: usize,
    /// Timing/energy constants for this array.
    pub device: DeviceModel,
    /// Total elapsed cycles across all executed instructions.
    pub cycles: u64,
    /// How data-parallel instruction spans execute (DESIGN.md §5).
    backend: ExecBackend,
    /// Handle to the process-shared persistent worker pool for this
    /// backend's worker count (None for serial).
    pool: Option<Arc<WorkerPool>>,
    /// Reliability layer: installed fault model + runtime state
    /// (None = ideal device, the zero-cost default).
    fault: Option<Box<FaultState>>,
}

impl PrinsArray {
    /// A chain of `n_modules` modules of `rows_per_module` × `width`
    /// cells with the default device model.
    pub fn new(n_modules: usize, rows_per_module: usize, width: usize) -> Self {
        Self::with_device(n_modules, rows_per_module, width, DeviceModel::default())
    }

    /// [`PrinsArray::new`] with an explicit device model.
    pub fn with_device(
        n_modules: usize,
        rows_per_module: usize,
        width: usize,
        device: DeviceModel,
    ) -> Self {
        assert!(n_modules > 0 && rows_per_module > 0 && width > 0);
        PrinsArray {
            modules: (0..n_modules)
                .map(|_| RcamModule::new(rows_per_module, width))
                .collect(),
            rows_per_module,
            width,
            device,
            cycles: 0,
            backend: ExecBackend::Serial,
            pool: None,
            fault: None,
        }
    }

    /// A one-module array (the common kernel-test geometry).
    pub fn single(rows: usize, width: usize) -> Self {
        Self::new(1, rows, width)
    }

    // ----- execution backend ---------------------------------------------

    /// Builder: select the execution backend (`Serial` keeps the exact
    /// pre-refactor single-threaded path; `Threaded(n)` stripes the array
    /// over `n` workers with bit-identical results and stats).
    pub fn with_backend(mut self, backend: ExecBackend) -> Self {
        self.set_backend(backend);
        self
    }

    /// Builder: shorthand for `with_backend(ExecBackend::from_workers(n))`
    /// — `n <= 1` is the serial path.
    pub fn with_workers(self, n: usize) -> Self {
        self.with_backend(ExecBackend::from_workers(n))
    }

    /// In-place variant of [`PrinsArray::with_backend`]; attaches the
    /// process-shared worker pool for the backend's worker count.
    pub fn set_backend(&mut self, backend: ExecBackend) {
        self.backend = backend;
        // attach the process-shared pool for this worker count so arrays
        // (and clones) never spawn threads per array or per dispatch
        let want = backend.workers().saturating_sub(1);
        match &self.pool {
            Some(p) if p.threads() == want => {}
            _ if want == 0 => self.pool = None,
            _ => self.pool = Some(WorkerPool::shared(want)),
        }
    }

    /// The configured execution backend.
    #[inline]
    pub fn backend(&self) -> ExecBackend {
        self.backend
    }

    /// Whether data-parallel spans run on the worker pool. Forced off
    /// while faults are enabled: corruption draws must happen in one
    /// deterministic serial order, and the serial path charges identical
    /// cycles/ledgers, so backend invariance holds by construction
    /// (asserted by `tests/reliability.rs`).
    #[inline]
    pub fn is_threaded(&self) -> bool {
        self.backend.is_threaded() && self.fault.is_none()
    }

    fn ensure_pool(&mut self) -> Arc<WorkerPool> {
        let want = self.backend.workers().saturating_sub(1);
        match &self.pool {
            Some(p) if p.threads() == want => p.clone(),
            _ => {
                let p = WorkerPool::shared(want);
                self.pool = Some(p.clone());
                p
            }
        }
    }

    /// Enable per-row wear counters on every module (costs O(tagged rows)
    /// per write in simulation; off by default).
    pub fn enable_wear_tracking(&mut self) {
        let (r, w) = (self.rows_per_module, self.width);
        for m in &mut self.modules {
            *m = RcamModule::with_wear_tracking(r, w);
        }
    }

    /// Rows across the whole chain.
    #[inline]
    pub fn total_rows(&self) -> usize {
        self.rows_per_module * self.modules.len()
    }

    /// Row width in bit-columns.
    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    /// Module count in the chain.
    #[inline]
    pub fn n_modules(&self) -> usize {
        self.modules.len()
    }

    /// The chained modules, in daisy-chain order.
    #[inline]
    pub fn modules(&self) -> &[RcamModule] {
        &self.modules
    }

    #[inline]
    fn split(&self, row: usize) -> (usize, usize) {
        (row / self.rows_per_module, row % self.rows_per_module)
    }

    /// Merged energy ledger over all modules.
    pub fn ledger(&self) -> EnergyLedger {
        let mut total = EnergyLedger::default();
        for m in &self.modules {
            total.add(&m.ledger);
        }
        total
    }

    // ----- broadcast associative instructions ---------------------------

    /// Debug-build twin of the static analyzer's W01/W02 rules
    /// (`crate::analysis`): assert every pattern column is in bounds and
    /// no column is bound twice. The `cfg!` guard keeps release builds
    /// zero-cost (no loop, no branch); debug CI catches violations in
    /// programs the analyzer never saw (hand-stepped instructions,
    /// generated microcode).
    fn debug_check_pattern(&self, pattern: &Pattern) {
        if cfg!(debug_assertions) {
            for (i, &(c, _)) in pattern.iter().enumerate() {
                debug_assert!(
                    (c as usize) < self.width,
                    "pattern column {c} out of bounds (width {}) — analyzer rule W01",
                    self.width
                );
                debug_assert!(
                    !pattern[..i].iter().any(|&(c2, _)| c2 == c),
                    "pattern binds column {c} more than once — analyzer rule W02"
                );
            }
        }
    }

    /// Broadcast compare: tag matching rows in every module (1 cycle).
    /// With faults enabled, every stored cell of the pattern columns is
    /// observed through the seeded read-noise path (stuck-at overrides,
    /// wear-coupled BER flips) — cycle and ledger charges stay identical
    /// to the ideal compare.
    pub fn compare(&mut self, pattern: &Pattern) {
        self.debug_check_pattern(pattern);
        if let Some(mut fault) = self.fault.take() {
            fault.begin_op();
            let rpm = self.rows_per_module;
            for (mi, m) in self.modules.iter_mut().enumerate() {
                let base = mi * rpm;
                m.compare_noisy(pattern, &mut |row, col, stored, wear| {
                    fault.observe(base + row, col, stored, wear)
                });
            }
            self.cycles += CYCLES_COMPARE;
            self.fault = Some(fault);
        } else if self.is_threaded() {
            self.execute_ops(&[StripeOp::Compare(pattern)]);
        } else {
            for m in &mut self.modules {
                m.compare(pattern);
            }
            self.cycles += CYCLES_COMPARE;
        }
    }

    /// Broadcast write: pattern into every tagged row (2 cycles). With
    /// faults enabled, each written bit lands inverted with the
    /// wear-coupled write BER (identical charges to the ideal write).
    pub fn write(&mut self, pattern: &Pattern) {
        self.debug_check_pattern(pattern);
        if let Some(mut fault) = self.fault.take() {
            fault.begin_op();
            let rpm = self.rows_per_module;
            for (mi, m) in self.modules.iter_mut().enumerate() {
                let base = mi * rpm;
                m.write_noisy(pattern, &mut |row, col, wear| {
                    fault.flip_written(base + row, col, wear)
                });
            }
            self.cycles += CYCLES_WRITE;
            self.fault = Some(fault);
        } else if self.is_threaded() {
            self.execute_ops(&[StripeOp::Write(pattern)]);
        } else {
            for m in &mut self.modules {
                m.write(pattern);
            }
            self.cycles += CYCLES_WRITE;
        }
    }

    /// compare immediately followed by tagged write — the microcode pass,
    /// executed by the fused one-traversal kernel. Results and stats are
    /// exactly `compare(cpat); write(wpat)`.
    pub fn pass(&mut self, cpat: &Pattern, wpat: &Pattern) {
        self.debug_check_pattern(cpat);
        self.debug_check_pattern(wpat);
        if self.fault.is_some() {
            // decompose: the fused pass charges exactly compare + write,
            // and the fault layer needs the per-row observation order
            self.compare(cpat);
            self.write(wpat);
            return;
        }
        if self.is_threaded() {
            self.execute_ops(&[StripeOp::Pass(cpat, wpat)]);
        } else {
            for m in &mut self.modules {
                m.pass(cpat, wpat);
            }
            self.cycles += CYCLES_COMPARE + CYCLES_WRITE;
        }
    }

    /// Execute one data-parallel span (consecutive Compare / Write /
    /// SetTagsAll / ClearColumns instructions, DESIGN.md §5): each worker
    /// runs the WHOLE span over its row stripe before the next barrier,
    /// so a span costs one pool dispatch regardless of its length.
    /// Adjacent Compare+Write pairs are fused into the one-traversal pass
    /// kernel. Callers (the controller) must not put serializing
    /// instructions in a span.
    pub fn execute_span(&mut self, instrs: &[Instr]) {
        // threaded spans bypass compare()/write(), so the debug W01/W02
        // twin re-checks here
        if cfg!(debug_assertions) {
            for instr in instrs {
                if let Instr::Compare(p) | Instr::Write(p) = instr {
                    self.debug_check_pattern(p);
                }
            }
        }
        let mut ops: Vec<StripeOp> = Vec::with_capacity(instrs.len());
        let mut i = 0;
        while i < instrs.len() {
            match (&instrs[i], instrs.get(i + 1)) {
                (Instr::Compare(c), Some(Instr::Write(w))) => {
                    ops.push(StripeOp::Pass(c, w));
                    i += 2;
                }
                (Instr::Compare(c), _) => {
                    ops.push(StripeOp::Compare(c));
                    i += 1;
                }
                (Instr::Write(p), _) => {
                    ops.push(StripeOp::Write(p));
                    i += 1;
                }
                (Instr::SetTagsAll, _) => {
                    ops.push(StripeOp::SetTagsAll);
                    i += 1;
                }
                (Instr::ClearColumns { base, width }, _) => {
                    ops.push(StripeOp::ClearColumns {
                        base: *base,
                        width: *width,
                    });
                    i += 1;
                }
                (other, _) => panic!("execute_span: serializing instruction {other:?}"),
            }
        }
        self.execute_ops(&ops);
    }

    /// Run a span of data-parallel ops on the configured backend; the
    /// single entry point that charges cycles and merges ledgers for the
    /// striped path.
    fn execute_ops(&mut self, ops: &[StripeOp]) {
        if ops.is_empty() {
            return;
        }
        if !self.is_threaded() {
            for op in ops {
                match *op {
                    StripeOp::Compare(p) => self.compare(p),
                    StripeOp::Write(p) => self.write(p),
                    StripeOp::Pass(c, w) => self.pass(c, w),
                    StripeOp::SetTagsAll => self.set_tags_all(),
                    StripeOp::ClearColumns { base, width } => self.clear_columns(base, width),
                }
            }
            return;
        }
        let pool = self.ensure_pool();
        // Harvest disjoint raw views (one `iter_mut` pass — see
        // `RcamModule::raw_parts`), stripe them, and dispatch. The pool
        // blocks until every worker finishes, so no safe reference to
        // module state exists while the stripes run.
        let parts: Vec<exec::ModuleParts> =
            self.modules.iter_mut().map(|m| m.raw_parts()).collect();
        let stripes = exec::plan_stripes(&parts, self.backend.workers());
        // data-dependent (module, write_bit_events) pairs, merged below —
        // u128 sums, so merge order cannot change the total
        let collected: Mutex<Vec<(usize, u128)>> = Mutex::new(Vec::new());
        {
            let task = |sid: usize| {
                let mut local: Vec<(usize, u128)> = Vec::new();
                for seg in &stripes[sid] {
                    let ev = exec::run_ops_on_segment(seg, ops);
                    if ev != 0 {
                        local.push((seg.module, ev));
                    }
                }
                if !local.is_empty() {
                    collected.lock().unwrap().extend(local);
                }
            };
            pool.run(stripes.len(), &task);
        }
        drop(stripes);
        drop(parts);
        let mut write_events = vec![0u128; self.modules.len()];
        for (m, ev) in collected.into_inner().unwrap() {
            write_events[m] += ev;
        }
        // data-independent charges, per module, exactly as the serial
        // per-module sweep would have accrued them
        let width = self.width as u128;
        for (mi, m) in self.modules.iter_mut().enumerate() {
            let rows = m.rows() as u128;
            let l = &mut m.ledger;
            l.write_bit_events += write_events[mi];
            for op in ops {
                match op {
                    StripeOp::Compare(_) => {
                        l.n_compare += 1;
                        l.compare_bit_events += width * rows;
                    }
                    StripeOp::Write(_) => l.n_write += 1,
                    StripeOp::Pass(_, _) => {
                        l.n_compare += 1;
                        l.compare_bit_events += width * rows;
                        l.n_write += 1;
                    }
                    StripeOp::SetTagsAll => l.n_tag_op += 1,
                    StripeOp::ClearColumns { width: cw, .. } => {
                        l.n_write += 1;
                        l.write_bit_events += (*cw as u128) * rows;
                    }
                }
            }
        }
        self.cycles += ops.iter().map(exec::op_cycles).sum::<u64>();
    }

    /// Whether any row in the chain is tagged (1 cycle).
    pub fn if_match(&mut self) -> bool {
        let mut any = false;
        for m in &mut self.modules {
            any |= m.if_match();
        }
        self.cycles += CYCLES_TAG_OP;
        any
    }

    /// Global first_match: the daisy-chained first-match circuits keep the
    /// first tag of the first module that has one and clear all others.
    /// Returns the global row index.
    pub fn first_match(&mut self) -> Option<usize> {
        let mut found: Option<usize> = None;
        for (i, m) in self.modules.iter_mut().enumerate() {
            if found.is_none() {
                if let Some(r) = m.first_match() {
                    found = Some(i * self.rows_per_module + r);
                }
            } else {
                m.tags_mut().fill(false);
            }
        }
        self.cycles += CYCLES_TAG_OP;
        found
    }

    /// Read a field from the first tagged row anywhere in the chain.
    /// With faults enabled, each returned bit is observed through the
    /// read-noise path (same `CYCLES_READ` + one read op charged on the
    /// module that answered).
    pub fn read_first(&mut self, base: u16, width: u16) -> Option<u64> {
        self.cycles += CYCLES_READ;
        if let Some(mut fault) = self.fault.take() {
            fault.begin_op();
            let rpm = self.rows_per_module;
            let mut out = None;
            for (mi, m) in self.modules.iter_mut().enumerate() {
                let Some(r) = m.tags().first_one() else { continue };
                m.ledger.n_read += 1;
                let raw = m.fetch_row_bits(r, base as usize, width as usize);
                let wear = m.wear_counters().map_or(0, |w| w[r]);
                let mut v = raw;
                for i in 0..width as usize {
                    let stored = (raw >> i) & 1 == 1;
                    if fault.observe(mi * rpm + r, base + i as u16, stored, wear) != stored {
                        v ^= 1 << i;
                    }
                }
                out = Some(v);
                break;
            }
            self.fault = Some(fault);
            return out;
        }
        for m in &mut self.modules {
            if let Some(v) = m.read_first(base, width) {
                return Some(v);
            }
        }
        None
    }

    /// Total tagged rows (per-module reduction trees + controller add).
    pub fn count_tags(&mut self) -> u64 {
        let mut n = 0;
        for m in &mut self.modules {
            n += m.count_tags();
        }
        self.cycles += CYCLES_REDUCE_ISSUE;
        n
    }

    /// Weighted popcount: tagged rows whose bit-column `col` is set.
    pub fn count_tags_and_col(&mut self, col: u16) -> u64 {
        let mut n = 0;
        for m in &mut self.modules {
            n += m.count_tags_and_col(col);
        }
        self.cycles += CYCLES_REDUCE_ISSUE;
        n
    }

    /// Reduction-tree drain latency (charged once per dependent readout).
    pub fn reduction_latency_cycles(&self) -> u64 {
        let per_module = self.rows_per_module.max(2).next_power_of_two().ilog2() as u64;
        // cascaded module outputs accumulate down the chain
        per_module + self.modules.len() as u64 - 1
    }

    /// Charge one pipelined reduction-tree drain to the cycle counter.
    pub fn charge_reduction_latency(&mut self) {
        self.cycles += self.reduction_latency_cycles();
    }

    /// Tag every row in the chain (1 cycle).
    pub fn set_tags_all(&mut self) {
        if self.is_threaded() {
            self.execute_ops(&[StripeOp::SetTagsAll]);
        } else {
            for m in &mut self.modules {
                m.set_tags_all();
            }
            self.cycles += CYCLES_TAG_OP;
        }
    }

    /// Shift the global tag vector towards higher rows by `hops` (daisy
    /// chain, 1 hop per cycle, carries ripple across module boundaries).
    ///
    /// Simulated as ONE word-level shift per module with a `hops`-bit
    /// carry window from its predecessor (not `hops` 1-bit sweeps): the
    /// simulation cost is O(rows/64) words independent of `hops`, while
    /// the modeled cost stays `hops` cycles and `rows × hops` chain-bit
    /// events.
    pub fn shift_tags_up(&mut self, hops: usize) {
        let rpm = self.rows_per_module;
        if hops > 0 {
            if self.modules.len() == 1 {
                self.modules[0].tags_mut().shift_up(hops);
            } else if hops <= rpm {
                // carry window: the top `hops` rows of module k-1
                // (pre-shift) land in rows [0, hops) of module k
                let carries: Vec<BitVec> = self
                    .modules
                    .iter()
                    .map(|m| {
                        let mut c = m.tags().clone();
                        c.shift_down(rpm - hops);
                        c
                    })
                    .collect();
                for (k, m) in self.modules.iter_mut().enumerate() {
                    let t = m.tags_mut();
                    t.shift_up(hops);
                    if k > 0 {
                        t.or_assign(&carries[k - 1]);
                    }
                }
            } else {
                // hops spans multiple modules: shift the gathered global
                // vector once and scatter it back
                let mut g = self.tags_snapshot();
                g.shift_up(hops);
                self.scatter_tags(&g);
            }
        }
        self.cycles += (hops as u64) * CYCLES_TAG_OP;
        let bits = (self.total_rows() as u128) * (hops as u128);
        if let Some(m0) = self.modules.first_mut() {
            m0.ledger.chain_bit_events += bits;
        }
    }

    /// Shift the global tag vector towards lower rows by `hops` (same
    /// word-level carry-window scheme as [`Self::shift_tags_up`]).
    pub fn shift_tags_down(&mut self, hops: usize) {
        let rpm = self.rows_per_module;
        if hops > 0 {
            if self.modules.len() == 1 {
                self.modules[0].tags_mut().shift_down(hops);
            } else if hops <= rpm {
                // carry window: rows [0, hops) of module k+1 (pre-shift)
                // land in the top `hops` rows of module k
                let carries: Vec<BitVec> = self
                    .modules
                    .iter()
                    .map(|m| {
                        let mut c = m.tags().clone();
                        c.shift_up(rpm - hops);
                        c
                    })
                    .collect();
                let last = self.modules.len() - 1;
                for (k, m) in self.modules.iter_mut().enumerate() {
                    let t = m.tags_mut();
                    t.shift_down(hops);
                    if k < last {
                        t.or_assign(&carries[k + 1]);
                    }
                }
            } else {
                let mut g = self.tags_snapshot();
                g.shift_down(hops);
                self.scatter_tags(&g);
            }
        }
        self.cycles += (hops as u64) * CYCLES_TAG_OP;
        let bits = (self.total_rows() as u128) * (hops as u128);
        if let Some(m0) = self.modules.first_mut() {
            m0.ledger.chain_bit_events += bits;
        }
    }

    /// Overwrite every module's tag vector from a global snapshot
    /// (word-sliced fast path when module rows are 64-aligned).
    fn scatter_tags(&mut self, g: &BitVec) {
        let rpm = self.rows_per_module;
        if rpm % WORD_BITS == 0 {
            let wpm = rpm / WORD_BITS;
            for (mi, m) in self.modules.iter_mut().enumerate() {
                let words = g.words()[mi * wpm..(mi + 1) * wpm].to_vec();
                *m.tags_mut() = BitVec::from_words(words, rpm);
            }
        } else {
            for (mi, m) in self.modules.iter_mut().enumerate() {
                let t = m.tags_mut();
                t.fill(false);
                for r in 0..rpm {
                    if g.get(mi * rpm + r) {
                        t.set(r, true);
                    }
                }
            }
        }
    }

    /// Daisy-chain field move (paper §3.1: "A daisy-chain like bitwise
    /// interconnect allows PUs to intercommunicate, all PUs in parallel"):
    /// copy planes [src..src+width) into [dst..dst+width), shifted `hops`
    /// rows towards LOWER indices (row r receives row r+hops; the top
    /// `hops` rows receive zeros). All bit-columns move in parallel
    /// (bitwise chain), one hop per cycle → cost = `hops` cycles.
    /// Source planes are preserved (dst must not overlap src).
    pub fn shift_columns_to(&mut self, src: u16, dst: u16, width: u16, hops: usize) {
        assert!(
            dst + width <= src || src + width <= dst,
            "shift_columns_to: src/dst overlap"
        );
        let total = self.total_rows();
        let rpm = self.rows_per_module;
        let word_aligned = rpm % 64 == 0;
        for i in 0..width {
            // gather the global source plane (word-level when modules are
            // 64-row aligned — the §Perf fast path; bit-level fallback)
            let mut global = if word_aligned {
                let mut words = Vec::with_capacity(total.div_ceil(64));
                for m in self.modules.iter() {
                    words.extend_from_slice(m.storage().plane((src + i) as usize).words());
                }
                BitVec::from_words(words, total)
            } else {
                let mut g = BitVec::zeros(total);
                for (mi, m) in self.modules.iter().enumerate() {
                    let p = m.storage().plane((src + i) as usize);
                    for r in p.iter_ones() {
                        g.set(mi * rpm + r, true);
                    }
                }
                g
            };
            global.shift_down(hops);
            // scatter into the destination plane
            if word_aligned {
                let wpm = rpm / 64;
                for (mi, m) in self.modules.iter_mut().enumerate() {
                    let words = global.words()[mi * wpm..(mi + 1) * wpm].to_vec();
                    m.replace_plane(dst + i, BitVec::from_words(words, rpm));
                }
            } else {
                for (mi, m) in self.modules.iter_mut().enumerate() {
                    let mut local = BitVec::zeros(rpm);
                    for r in 0..rpm {
                        if global.get(mi * rpm + r) {
                            local.set(r, true);
                        }
                    }
                    m.replace_plane(dst + i, local);
                }
            }
        }
        self.cycles += hops as u64;
        let bits = (total as u128) * (width as u128) * (hops as u128);
        if let Some(m0) = self.modules.first_mut() {
            m0.ledger.chain_bit_events += bits;
        }
    }

    /// Snapshot of the global tag vector (test/debug aid, not an ISA op).
    pub fn tags_snapshot(&self) -> BitVec {
        let mut out = BitVec::zeros(self.total_rows());
        for (i, m) in self.modules.iter().enumerate() {
            for r in m.tags().iter_ones() {
                out.set(i * self.rows_per_module + r, true);
            }
        }
        out
    }

    /// Clear a column range across the whole array.
    pub fn clear_columns(&mut self, base: u16, width: u16) {
        if self.is_threaded() {
            self.execute_ops(&[StripeOp::ClearColumns { base, width }]);
        } else {
            for m in &mut self.modules {
                m.clear_columns(base, width);
            }
            self.cycles += CYCLES_WRITE;
        }
    }

    // ----- storage-management access path --------------------------------

    /// Storage-manager load: write `width` bits of `value` into a global
    /// row (routed to the owning module; not an associative operation).
    pub fn load_row_bits(&mut self, row: usize, base: usize, width: usize, value: u64) {
        let (mi, r) = self.split(row);
        self.modules[mi].load_row_bits(r, base, width, value);
    }

    /// Storage-path load with device-model charging: the same data
    /// movement as [`Self::load_row_bits`], but billed like the two-phase
    /// RRAM row write it models — `CYCLES_WRITE` cycles on the array
    /// clock and one write op on the owning module's ledger (the `width`
    /// write-bit events are billed by the module's direct-write path
    /// itself, shared with the uncharged form). This is the explicit
    /// **load phase** of a load-once / query-many kernel (DESIGN.md
    /// §Resident datasets): `XKernel::load` pays it once per stored
    /// field, and queries never do. Charges are identical on every
    /// execution backend (the storage path is not striped). The
    /// cycle-free [`Self::load_row_bits`] stays for test scaffolding and
    /// readout-side setup.
    pub fn load_row_bits_charged(&mut self, row: usize, base: usize, width: usize, value: u64) {
        let (mi, r) = self.split(row);
        let m = &mut self.modules[mi];
        // bills `width` write-bit events + wear on the module
        m.load_row_bits(r, base, width, value);
        m.ledger.n_write += 1;
        self.cycles += CYCLES_WRITE;
    }

    /// Storage-manager readout: fetch `width` bits of a global row.
    ///
    /// Deliberately ideal even with faults enabled: this is the
    /// host-side result-readout path, modeled as ECC-protected DRAM-side
    /// access (the scrubber's device-facing reads go through
    /// [`Self::fetch_row_bits_faulty`] instead).
    pub fn fetch_row_bits(&self, row: usize, base: usize, width: usize) -> u64 {
        let (mi, r) = self.split(row);
        self.modules[mi].fetch_row_bits(r, base, width)
    }

    // ----- fault injection (reliability layer) ---------------------------

    /// Install a fault model on this array (DESIGN.md §Reliability).
    /// The configuration is validated by analyzer rule F01 first (all
    /// BERs in `[0, 1)`, stuck cells inside the array geometry) and
    /// invalid models are refused. While faults are enabled the array
    /// forces the serial execution path (see [`Self::is_threaded`]), so
    /// corruption draws are reproducible bit-for-bit on every backend.
    pub fn enable_faults(&mut self, model: FaultModel) -> crate::error::Result<()> {
        let shape = crate::analysis::ArrayShape::of(self);
        let diags = crate::analysis::rules::fault_config(&model, &shape);
        if !diags.is_empty() {
            crate::error::bail!(
                "fault model rejected by analyzer rule F01 ({} diagnostic(s)); first: {}",
                diags.len(),
                diags[0]
            );
        }
        self.fault = Some(Box::new(FaultState::new(
            model,
            self.total_rows(),
            self.width,
        )));
        Ok(())
    }

    /// Remove the fault layer; the array is ideal again.
    pub fn disable_faults(&mut self) {
        self.fault = None;
    }

    /// Whether a fault model is installed.
    #[inline]
    pub fn has_faults(&self) -> bool {
        self.fault.is_some()
    }

    /// The installed fault model, if any.
    pub fn fault_model(&self) -> Option<&FaultModel> {
        self.fault.as_deref().map(FaultState::model)
    }

    /// Snapshot of the fault-event counters, if faults are enabled.
    pub fn fault_stats(&self) -> Option<FaultStats> {
        self.fault.as_deref().map(FaultState::stats)
    }

    /// Charge idle cycles to the array clock (query-retry backoff; no
    /// energy events — the controller is waiting, not issuing).
    pub fn add_idle_cycles(&mut self, n: u64) {
        self.cycles += n;
    }

    /// Charged faulty storage-path read: like [`Self::fetch_row_bits`]
    /// but billed as one read op + `CYCLES_READ`, with every bit
    /// observed through the fault layer (stuck cells override, read-BER
    /// flips apply). This is the scrubber's device-facing read — scrub
    /// checks must pay device cycles and see device noise. Still charged
    /// (but noise-free) when faults are disabled.
    pub fn fetch_row_bits_faulty(&mut self, row: usize, base: usize, width: usize) -> u64 {
        let (mi, r) = self.split(row);
        let m = &mut self.modules[mi];
        let raw = m.fetch_row_bits(r, base, width);
        let wear = m.wear_counters().map_or(0, |w| w[r]);
        m.ledger.n_read += 1;
        self.cycles += CYCLES_READ;
        let mut out = raw;
        if let Some(mut fault) = self.fault.take() {
            fault.begin_op();
            for i in 0..width {
                let stored = (raw >> i) & 1 == 1;
                if fault.observe(row, (base + i) as u16, stored, wear) != stored {
                    out ^= 1 << i;
                }
            }
            self.fault = Some(fault);
        }
        out
    }

    fn apply_ambient(&mut self, cols: Range<u16>, kind: AmbientKind) -> u64 {
        let Some(mut fault) = self.fault.take() else {
            return 0;
        };
        let mut flips = 0u64;
        if fault.ambient_enabled(kind) {
            fault.begin_op();
            let rpm = self.rows_per_module;
            for (mi, m) in self.modules.iter_mut().enumerate() {
                for r in 0..m.rows() {
                    for col in cols.clone() {
                        if fault.ambient(kind, mi * rpm + r, col) {
                            m.flip_stored_bit(r, col);
                            flips += 1;
                        }
                    }
                }
            }
        }
        self.fault = Some(fault);
        flips
    }

    /// Ambient retention decay over `cols` (every row): storage bits
    /// flip at the model's `retention_ber`. Uncharged — decay happens
    /// while the device sits idle, not as an issued operation. No-op
    /// without faults. Returns the number of flips applied.
    pub fn apply_retention(&mut self, cols: Range<u16>) -> u64 {
        self.apply_ambient(cols, AmbientKind::Retention)
    }

    /// Post-load write disturb over `cols` (every row): storage bits
    /// flip at the model's `write_ber`. Applied once after a dataset
    /// load — after the scrubber's golden capture — so resident data
    /// carries persistent corruption for the scrubber to find. No-op
    /// without faults. Returns the number of flips applied.
    pub fn apply_disturb(&mut self, cols: Range<u16>) -> u64 {
        self.apply_ambient(cols, AmbientKind::Disturb)
    }

    /// Elapsed wall-clock time of everything executed so far.
    pub fn elapsed_seconds(&self) -> f64 {
        self.device.cycles_to_seconds(self.cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 4-module chain must behave exactly like one flat module.
    #[test]
    fn chain_equivalent_to_single_module() {
        let rows = 256;
        let mut chain = PrinsArray::new(4, rows / 4, 16);
        let mut flat = PrinsArray::single(rows, 16);
        // identical contents
        for r in 0..rows {
            let v = ((r as u64).wrapping_mul(2654435761)) & 0xFFFF;
            chain.load_row_bits(r, 0, 16, v);
            flat.load_row_bits(r, 0, 16, v);
        }
        let pat: Vec<(u16, bool)> = vec![(3, true), (7, false)];
        chain.compare(&pat);
        flat.compare(&pat);
        assert_eq!(
            chain.tags_snapshot().iter_ones().collect::<Vec<_>>(),
            flat.tags_snapshot().iter_ones().collect::<Vec<_>>()
        );
        assert_eq!(chain.count_tags(), flat.count_tags());
        chain.write(&[(15, true)]);
        flat.write(&[(15, true)]);
        for r in 0..rows {
            assert_eq!(chain.fetch_row_bits(r, 0, 16), flat.fetch_row_bits(r, 0, 16));
        }
        // cycle cost identical regardless of module count (SIMD broadcast)
        assert_eq!(chain.cycles, flat.cycles);
    }

    #[test]
    fn global_first_match_spans_modules() {
        let mut a = PrinsArray::new(3, 10, 8);
        a.load_row_bits(25, 0, 1, 1); // module 2
        a.load_row_bits(13, 0, 1, 1); // module 1
        a.compare(&[(0, true)]);
        assert_eq!(a.first_match(), Some(13));
        let snap = a.tags_snapshot();
        assert_eq!(snap.iter_ones().collect::<Vec<_>>(), vec![13]);
    }

    #[test]
    fn read_first_finds_any_module() {
        let mut a = PrinsArray::new(2, 8, 16);
        a.load_row_bits(11, 0, 1, 1);
        a.load_row_bits(11, 4, 8, 0x5A);
        a.compare(&[(0, true)]);
        assert_eq!(a.read_first(4, 8), Some(0x5A));
    }

    #[test]
    fn tag_shift_crosses_module_boundary() {
        let mut a = PrinsArray::new(2, 4, 4);
        a.load_row_bits(3, 0, 1, 1); // last row of module 0
        a.compare(&[(0, true)]);
        a.shift_tags_up(1);
        assert_eq!(a.tags_snapshot().iter_ones().collect::<Vec<_>>(), vec![4]);
        a.shift_tags_down(2);
        assert_eq!(a.tags_snapshot().iter_ones().collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn cycles_follow_documented_costs() {
        let mut a = PrinsArray::single(64, 8);
        let c0 = a.cycles;
        a.compare(&[(0, true)]);
        assert_eq!(a.cycles - c0, 1);
        a.write(&[(1, true)]);
        assert_eq!(a.cycles - c0, 3); // write is two-phase
        a.count_tags();
        assert_eq!(a.cycles - c0, 4);
        a.if_match();
        assert_eq!(a.cycles - c0, 5);
    }

    #[test]
    fn charged_load_bills_cycles_and_ledger() {
        let mut a = PrinsArray::new(2, 8, 16);
        let c0 = a.cycles;
        let l0 = a.ledger();
        a.load_row_bits_charged(11, 0, 12, 0xABC); // module 1
        assert_eq!(a.cycles - c0, 2, "one two-phase row write");
        let d = a.ledger().minus(&l0);
        assert_eq!(d.n_write, 1);
        assert_eq!(d.write_bit_events, 12);
        assert_eq!(a.fetch_row_bits(11, 0, 12), 0xABC);
        // the uncharged path stays cycle-free (it has always billed the
        // raw write-bit events, but no cycles and no write op)
        let c1 = a.cycles;
        let w1 = a.ledger().n_write;
        a.load_row_bits(3, 0, 12, 0x123);
        assert_eq!(a.cycles, c1);
        assert_eq!(a.ledger().n_write, w1);
    }

    #[test]
    fn reduction_latency_scales_with_chain() {
        let a1 = PrinsArray::single(1 << 20, 8);
        assert_eq!(a1.reduction_latency_cycles(), 20);
        let a4 = PrinsArray::new(4, 1 << 18, 8);
        assert_eq!(a4.reduction_latency_cycles(), 18 + 3);
    }
}
#[cfg(test)]
mod shift_tests {
    use super::*;

    #[test]
    fn shift_columns_to_moves_fields_down() {
        let mut a = PrinsArray::new(2, 8, 16); // 16 rows across 2 modules
        for r in 0..16 {
            a.load_row_bits(r, 0, 4, (r % 16) as u64);
        }
        let c0 = a.cycles;
        a.shift_columns_to(0, 8, 4, 3);
        assert_eq!(a.cycles - c0, 3, "hops cycles");
        for r in 0..16 {
            let expect = if r + 3 < 16 { ((r + 3) % 16) as u64 } else { 0 };
            assert_eq!(a.fetch_row_bits(r, 8, 4), expect, "row {r}");
            // source preserved
            assert_eq!(a.fetch_row_bits(r, 0, 4), (r % 16) as u64);
        }
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn shift_columns_overlap_rejected() {
        let mut a = PrinsArray::single(8, 8);
        a.shift_columns_to(0, 2, 4, 1);
    }

    #[test]
    fn multi_hop_shift_crosses_multiple_modules() {
        // hops > rows_per_module exercises the gathered-global fallback
        let mut a = PrinsArray::new(3, 4, 4);
        a.load_row_bits(1, 0, 1, 1);
        a.compare(&[(0, true)]);
        let c0 = a.cycles;
        a.shift_tags_up(9);
        assert_eq!(a.tags_snapshot().iter_ones().collect::<Vec<_>>(), vec![10]);
        a.shift_tags_down(7);
        assert_eq!(a.tags_snapshot().iter_ones().collect::<Vec<_>>(), vec![3]);
        assert_eq!(a.cycles - c0, 16, "hops keep their per-hop cycle charge");
    }
}

#[cfg(test)]
mod exec_tests {
    use super::*;
    use crate::rcam::ExecBackend;

    fn filled(backend: ExecBackend, modules: usize, rpm: usize) -> PrinsArray {
        let mut a = PrinsArray::new(modules, rpm, 16).with_backend(backend);
        a.enable_wear_tracking();
        for r in 0..a.total_rows() {
            a.load_row_bits(r, 0, 16, (r as u64).wrapping_mul(0x9E37_79B9) & 0xFFFF);
        }
        a
    }

    fn drive(a: &mut PrinsArray) {
        a.compare(&[(1, true), (4, false)]);
        a.write(&[(9, true), (10, false)]);
        a.pass(&[(2, false)], &[(11, true)]);
        a.set_tags_all();
        a.clear_columns(12, 2);
        a.compare(&[(0, true)]);
        a.shift_tags_up(3);
        a.shift_tags_down(5);
        a.write(&[(13, true)]);
    }

    /// The threaded backend must be bit-identical to the serial path:
    /// storage, tags, wear, cycle counts, and the full energy ledger —
    /// including stripe splits that do not divide module words evenly.
    #[test]
    fn threaded_matches_serial_bit_identical() {
        for (modules, rpm) in [(1usize, 130usize), (3, 33), (2, 64), (4, 100)] {
            let mut s = filled(ExecBackend::Serial, modules, rpm);
            drive(&mut s);
            for n in [2usize, 3, 8] {
                let mut t = filled(ExecBackend::Threaded(n), modules, rpm);
                drive(&mut t);
                let label = format!("{modules}x{rpm} workers={n}");
                assert_eq!(t.cycles, s.cycles, "{label}: cycles");
                assert_eq!(t.ledger(), s.ledger(), "{label}: ledger");
                assert_eq!(t.tags_snapshot(), s.tags_snapshot(), "{label}: tags");
                for r in 0..s.total_rows() {
                    assert_eq!(
                        t.fetch_row_bits(r, 0, 16),
                        s.fetch_row_bits(r, 0, 16),
                        "{label}: row {r}"
                    );
                }
                for (ms, mt) in s.modules().iter().zip(t.modules()) {
                    assert_eq!(ms.wear_counters(), mt.wear_counters(), "{label}: wear");
                }
            }
        }
    }

    #[test]
    fn fused_pass_equals_compare_then_write() {
        let mut a = PrinsArray::single(100, 16);
        let mut b = PrinsArray::single(100, 16);
        for r in 0..100 {
            let v = (r as u64 * 37) & 0xFFFF;
            a.load_row_bits(r, 0, 16, v);
            b.load_row_bits(r, 0, 16, v);
        }
        a.pass(&[(0, true), (3, false)], &[(8, true), (9, false)]);
        b.compare(&[(0, true), (3, false)]);
        b.write(&[(8, true), (9, false)]);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.ledger(), b.ledger());
        assert_eq!(a.tags_snapshot(), b.tags_snapshot());
        for r in 0..100 {
            assert_eq!(a.fetch_row_bits(r, 0, 16), b.fetch_row_bits(r, 0, 16));
        }
    }

    #[test]
    fn zero_ber_fault_layer_is_bit_identical_to_ideal() {
        use crate::reliability::FaultModel;
        let build = |faults: bool| {
            let mut a = PrinsArray::new(2, 33, 16);
            a.enable_wear_tracking();
            for r in 0..66 {
                a.load_row_bits(r, 0, 16, (r as u64).wrapping_mul(0x9E37) & 0xFFFF);
            }
            if faults {
                a.enable_faults(FaultModel::uniform(0.0, 42)).unwrap();
            }
            a
        };
        let mut ideal = build(false);
        let mut noisy = build(true);
        for a in [&mut ideal, &mut noisy] {
            a.compare(&[(1, true), (4, false)]);
            a.write(&[(9, true)]);
            a.pass(&[(2, false)], &[(11, true)]);
            a.compare(&[(0, true)]);
        }
        assert_eq!(ideal.read_first(0, 8), noisy.read_first(0, 8));
        assert_eq!(ideal.cycles, noisy.cycles, "cycles");
        assert_eq!(ideal.ledger(), noisy.ledger(), "ledger");
        assert_eq!(ideal.tags_snapshot(), noisy.tags_snapshot(), "tags");
        for r in 0..66 {
            assert_eq!(
                ideal.fetch_row_bits(r, 0, 16),
                noisy.fetch_row_bits(r, 0, 16),
                "row {r}"
            );
        }
        assert_eq!(noisy.fault_stats().unwrap().injected(), 0);
    }

    #[test]
    fn faulty_runs_reproduce_under_one_seed_and_force_serial() {
        use crate::reliability::FaultModel;
        let run = |backend| {
            let mut a = PrinsArray::new(2, 50, 16).with_backend(backend);
            for r in 0..100 {
                a.load_row_bits(r, 0, 16, (r as u64).wrapping_mul(37) & 0xFFFF);
            }
            a.enable_faults(FaultModel::uniform(0.02, 7)).unwrap();
            assert!(!a.is_threaded(), "faults must force the serial path");
            a.compare(&[(0, true), (3, false)]);
            a.write(&[(8, true), (9, true)]);
            a.pass(&[(1, true)], &[(10, false)]);
            let rows: Vec<u64> = (0..100).map(|r| a.fetch_row_bits(r, 0, 16)).collect();
            (rows, a.tags_snapshot(), a.cycles, a.ledger(), a.fault_stats().unwrap())
        };
        let serial = run(ExecBackend::Serial);
        let serial2 = run(ExecBackend::Serial);
        let threaded = run(ExecBackend::Threaded(4));
        assert_eq!(serial, serial2, "same seed → identical corruption");
        assert_eq!(serial, threaded, "backend-invariant corruption");
        assert!(serial.4.injected() > 0, "2% BER must inject something");
    }

    #[test]
    fn invalid_fault_models_are_rejected() {
        use crate::reliability::{FaultModel, StuckCell};
        let mut a = PrinsArray::single(16, 8);
        assert!(a.enable_faults(FaultModel::uniform(1.0, 0)).is_err());
        assert!(a.enable_faults(FaultModel::uniform(-0.1, 0)).is_err());
        let oob = FaultModel::uniform(0.0, 0).with_stuck(vec![StuckCell {
            row: 16,
            col: 0,
            value: true,
        }]);
        assert!(a.enable_faults(oob).is_err());
        assert!(!a.has_faults(), "rejected models are not installed");
        assert!(a.enable_faults(FaultModel::uniform(0.1, 0)).is_ok());
        assert!(a.has_faults());
        a.disable_faults();
        assert!(!a.has_faults());
    }

    #[test]
    fn stuck_cell_overrides_reads_until_disabled() {
        use crate::reliability::{FaultModel, StuckCell};
        let mut a = PrinsArray::single(8, 8);
        a.load_row_bits(2, 0, 8, 0b0000_0001); // row 2: col0=1, col3=0
        let model = FaultModel::uniform(0.0, 1).with_stuck(vec![StuckCell {
            row: 2,
            col: 3,
            value: true,
        }]);
        a.enable_faults(model).unwrap();
        a.compare(&[(0, true)]); // tags row 2
        assert_eq!(a.read_first(0, 8), Some(0b0000_1001), "col 3 reads stuck-at-1");
        assert_eq!(a.fetch_row_bits(2, 0, 8), 0b0000_0001, "storage untouched");
        a.disable_faults();
        a.compare(&[(0, true)]);
        assert_eq!(a.read_first(0, 8), Some(0b0000_0001));
    }

    #[test]
    fn disturb_and_retention_flip_storage_deterministically() {
        use crate::reliability::FaultModel;
        let mut a = PrinsArray::new(2, 32, 8);
        a.enable_faults(FaultModel::uniform(0.05, 3)).unwrap();
        let c0 = a.cycles;
        let d = a.apply_disturb(0..8);
        let r = a.apply_retention(0..8);
        assert!(d > 0 && r > 0, "5% over 512 cells must flip ({d}, {r})");
        assert_eq!(a.cycles, c0, "ambient passes are uncharged");
        let s = a.fault_stats().unwrap();
        assert_eq!(s.disturb_flips, d);
        assert_eq!(s.retention_flips, r);
        // started all-zero, so set bits = flips minus any cell both
        // passes flipped (disturb 0→1 then retention 1→0)
        let set: u64 = (0..64).map(|row| a.fetch_row_bits(row, 0, 8).count_ones() as u64).sum();
        assert!(set > 0 && set <= d + r, "set {set} vs flips {d}+{r}");
    }

    #[test]
    fn execute_span_fuses_and_matches_stepwise() {
        use crate::isa::Instr;
        let mk = |backend| {
            let mut a = PrinsArray::new(2, 70, 8).with_backend(backend);
            for r in 0..140 {
                a.load_row_bits(r, 0, 8, (r as u64) & 0xFF);
            }
            a
        };
        let span = vec![
            Instr::Compare(vec![(0, true)]),
            Instr::Write(vec![(6, true)]),
            Instr::SetTagsAll,
            Instr::ClearColumns { base: 7, width: 1 },
            Instr::Compare(vec![(1, false), (6, true)]),
        ];
        let mut t = mk(ExecBackend::Threaded(3));
        t.execute_span(&span);
        let mut s = mk(ExecBackend::Serial);
        s.compare(&[(0, true)]);
        s.write(&[(6, true)]);
        s.set_tags_all();
        s.clear_columns(7, 1);
        s.compare(&[(1, false), (6, true)]);
        assert_eq!(t.cycles, s.cycles);
        assert_eq!(t.ledger(), s.ledger());
        assert_eq!(t.tags_snapshot(), s.tags_snapshot());
        for r in 0..140 {
            assert_eq!(t.fetch_row_bits(r, 0, 8), s.fetch_row_bits(r, 0, 8));
        }
    }
}
