//! Packed bit vectors — the tag register and the unit of bit-plane storage.
//!
//! The RCAM simulator is *bit-sliced*: an N-row × W-column crossbar is W
//! planes of ⌈N/64⌉ u64 words (bit r of plane j = row r, column j). All
//! associative operations then become word-wide boolean ops, which is the
//! simulator's hot path (see DESIGN.md §Perf).

/// A packed vector of `nbits` bits backed by u64 words.
///
/// Bits past `nbits` in the last word are kept zero ("canonical form") by
/// every mutating method; `debug_assert_canonical` checks the invariant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    nbits: usize,
}

/// Bits per storage word.
pub const WORD_BITS: usize = 64;

/// Words needed to hold `nbits` bits.
#[inline]
pub fn words_for(nbits: usize) -> usize {
    nbits.div_ceil(WORD_BITS)
}

impl BitVec {
    /// An all-zero vector of `nbits` bits.
    pub fn zeros(nbits: usize) -> Self {
        BitVec {
            words: vec![0; words_for(nbits)],
            nbits,
        }
    }

    /// An all-one vector of `nbits` bits (canonical tail).
    pub fn ones(nbits: usize) -> Self {
        let mut v = BitVec {
            words: vec![u64::MAX; words_for(nbits)],
            nbits,
        };
        v.trim();
        v
    }

    /// Construct from a little-endian word slice (word 0 holds rows 0..64).
    pub fn from_words(words: Vec<u64>, nbits: usize) -> Self {
        assert_eq!(words.len(), words_for(nbits));
        let mut v = BitVec { words, nbits };
        v.trim();
        v
    }

    /// Bit length.
    #[inline]
    pub fn len(&self) -> usize {
        self.nbits
    }

    /// Whether the vector has zero bits.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nbits == 0
    }

    /// Backing words (little-endian: word 0 holds bits 0..64).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable backing words; callers must keep the tail canonical.
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Zero the dead bits past `nbits` in the last word.
    #[inline]
    pub fn trim(&mut self) {
        let tail = self.nbits % WORD_BITS;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.nbits);
        (self.words[i / WORD_BITS] >> (i % WORD_BITS)) & 1 == 1
    }

    /// Set bit `i` to `v`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.nbits);
        let w = &mut self.words[i / WORD_BITS];
        let m = 1u64 << (i % WORD_BITS);
        if v {
            *w |= m;
        } else {
            *w &= !m;
        }
    }

    /// Set every bit to `v` (canonical tail preserved).
    pub fn fill(&mut self, v: bool) {
        let word = if v { u64::MAX } else { 0 };
        self.words.iter_mut().for_each(|w| *w = word);
        self.trim();
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// True iff at least one bit is set (the `if_match` primitive).
    pub fn any(&self) -> bool {
        self.words.iter().any(|&w| w != 0)
    }

    /// Index of the first (lowest) set bit, if any.
    pub fn first_one(&self) -> Option<usize> {
        for (i, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some(i * WORD_BITS + w.trailing_zeros() as usize);
            }
        }
        None
    }

    /// Keep only the first set bit, clearing the rest (the `first_match`
    /// tag-logic primitive, paper Fig. 3(b)). Returns its index, if any.
    pub fn keep_first_one(&mut self) -> Option<usize> {
        let idx = self.first_one()?;
        self.words.iter_mut().for_each(|w| *w = 0);
        self.words[idx / WORD_BITS] = 1u64 << (idx % WORD_BITS);
        Some(idx)
    }

    /// self &= other
    pub fn and_assign(&mut self, other: &BitVec) {
        debug_assert_eq!(self.nbits, other.nbits);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// self &= !other
    pub fn and_not_assign(&mut self, other: &BitVec) {
        debug_assert_eq!(self.nbits, other.nbits);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// self |= other
    pub fn or_assign(&mut self, other: &BitVec) {
        debug_assert_eq!(self.nbits, other.nbits);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Shift the whole vector towards higher indices (row r -> row r+1) by
    /// `by` positions, dropping bits at the top and inserting zeros at the
    /// bottom. This models the daisy-chain inter-PU interconnect (paper
    /// §3.1): each PU sees its predecessor's bit after one hop.
    pub fn shift_up(&mut self, by: usize) {
        if by == 0 {
            return;
        }
        if by >= self.nbits {
            self.fill(false);
            return;
        }
        let word_shift = by / WORD_BITS;
        let bit_shift = by % WORD_BITS;
        let n = self.words.len();
        for i in (0..n).rev() {
            let lo = if i >= word_shift {
                self.words[i - word_shift]
            } else {
                0
            };
            let hi = if bit_shift > 0 && i > word_shift {
                self.words[i - word_shift - 1] >> (WORD_BITS - bit_shift)
            } else {
                0
            };
            self.words[i] = if bit_shift == 0 { lo } else { (lo << bit_shift) | hi };
        }
        self.trim();
    }

    /// Shift towards lower indices (row r -> row r-1).
    pub fn shift_down(&mut self, by: usize) {
        if by == 0 {
            return;
        }
        if by >= self.nbits {
            self.fill(false);
            return;
        }
        let word_shift = by / WORD_BITS;
        let bit_shift = by % WORD_BITS;
        let n = self.words.len();
        for i in 0..n {
            let lo = if i + word_shift < n {
                self.words[i + word_shift]
            } else {
                0
            };
            let hi = if bit_shift > 0 && i + word_shift + 1 < n {
                self.words[i + word_shift + 1] << (WORD_BITS - bit_shift)
            } else {
                0
            };
            self.words[i] = if bit_shift == 0 { lo } else { (lo >> bit_shift) | hi };
        }
        self.trim();
    }

    /// Iterate over indices of set bits.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(i, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(i * WORD_BITS + b)
                }
            })
        })
    }

    /// Debug check of the canonical-tail invariant (dead bits zero).
    #[cfg(debug_assertions)]
    pub fn debug_assert_canonical(&self) {
        let tail = self.nbits % WORD_BITS;
        if tail != 0 {
            debug_assert_eq!(self.words.last().unwrap() & !((1u64 << tail) - 1), 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        for i in [0, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!v.get(i));
            v.set(i, true);
            assert!(v.get(i));
        }
        assert_eq!(v.count_ones(), 8);
        v.set(64, false);
        assert_eq!(v.count_ones(), 7);
    }

    #[test]
    fn ones_is_canonical() {
        let v = BitVec::ones(70);
        assert_eq!(v.count_ones(), 70);
        assert_eq!(v.words()[1] >> 6, 0);
    }

    #[test]
    fn first_and_keep_first() {
        let mut v = BitVec::zeros(200);
        v.set(77, true);
        v.set(150, true);
        assert_eq!(v.first_one(), Some(77));
        assert_eq!(v.keep_first_one(), Some(77));
        assert_eq!(v.count_ones(), 1);
        assert!(v.get(77));
        assert!(!v.get(150));
        let mut empty = BitVec::zeros(10);
        assert_eq!(empty.keep_first_one(), None);
    }

    #[test]
    fn boolean_ops() {
        let mut a = BitVec::zeros(100);
        let mut b = BitVec::zeros(100);
        a.set(3, true);
        a.set(70, true);
        b.set(70, true);
        b.set(99, true);
        let mut c = a.clone();
        c.and_assign(&b);
        assert_eq!(c.iter_ones().collect::<Vec<_>>(), vec![70]);
        let mut d = a.clone();
        d.or_assign(&b);
        assert_eq!(d.iter_ones().collect::<Vec<_>>(), vec![3, 70, 99]);
        let mut e = a.clone();
        e.and_not_assign(&b);
        assert_eq!(e.iter_ones().collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn shifts_move_rows() {
        let mut v = BitVec::zeros(192);
        v.set(0, true);
        v.set(100, true);
        v.shift_up(3);
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), vec![3, 103]);
        v.shift_down(4);
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), vec![99]);
        v.shift_up(1000);
        assert!(!v.any());
    }

    #[test]
    fn shift_up_drops_top_bits() {
        let mut v = BitVec::zeros(64);
        v.set(63, true);
        v.set(1, true);
        v.shift_up(1);
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn iter_ones_matches_gets() {
        let mut v = BitVec::zeros(300);
        let idxs = [0usize, 5, 64, 65, 128, 191, 192, 299];
        for &i in &idxs {
            v.set(i, true);
        }
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), idxs.to_vec());
    }
}
