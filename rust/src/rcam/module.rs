//! One RCAM module (paper Fig. 2): resistive crossbar + peripheral
//! circuitry — key/mask comparand, tag logic, reduction tree port.
//!
//! The key and mask registers of the hardware are represented here by the
//! *pattern* argument of `compare`/`write`: a sparse list of
//! `(column, bit)` pairs — exactly the unmasked columns and their key
//! bits. Columns absent from the pattern are masked out (Bit/Bit-not lines
//! floating). This is both the natural microcode form and the simulator's
//! fast path: cost is proportional to the number of *unmasked* columns
//! only, mirroring the energy behaviour of the real array (match-line
//! current flows only through connected cells).

use super::bitmatrix::BitMatrix;
use super::bitvec::{BitVec, WORD_BITS};
use super::device::EnergyLedger;
use super::exec::ModuleParts;

/// Sparse key/mask pattern: (bit-column, key bit). Columns not listed are
/// masked out.
pub type Pattern = [(u16, bool)];

/// Compute the match tags of `pattern` over `storage` into `tags`,
/// touching no module state: the pure tag function shared by
/// [`RcamModule::compare`] and the read-only shared-query cursor
/// (`crate::controller::read::ReadCursor`). Single word-blocked pass
/// (DESIGN.md §Perf): each tag word stays in a register across every
/// pattern column, instead of one full fill sweep plus one and/and-not
/// sweep per column.
pub(crate) fn compare_tags_into(storage: &BitMatrix, pattern: &Pattern, tags: &mut BitVec) {
    let nwords = tags.words().len();
    let tail = storage.rows() % WORD_BITS;
    let tail_mask = if tail == 0 { u64::MAX } else { (1u64 << tail) - 1 };
    let planes: Vec<&[u64]> = pattern
        .iter()
        .map(|&(col, _)| storage.plane(col as usize).words())
        .collect();
    let tags = tags.words_mut();
    for w in 0..nwords {
        let mut t = if w + 1 == nwords { tail_mask } else { u64::MAX };
        for (&(_, bit), plane) in pattern.iter().zip(&planes) {
            let p = plane[w];
            t &= if bit { p } else { !p };
        }
        tags[w] = t;
    }
}

/// One RCAM module: bit-sliced crossbar storage, tag register, and the
/// per-module energy-event ledger.
#[derive(Clone, Debug)]
pub struct RcamModule {
    storage: BitMatrix,
    tags: BitVec,
    /// Per-row write counters for endurance/wear-levelling analysis
    /// (None = tracking disabled; it is O(tagged rows) per write).
    wear: Option<Vec<u32>>,
    /// Energy events accrued by this module's operations.
    pub ledger: EnergyLedger,
}

impl RcamModule {
    /// A module of `rows` × `width` cells, tags cleared, no wear tracking.
    pub fn new(rows: usize, width: usize) -> Self {
        RcamModule {
            storage: BitMatrix::new(rows, width),
            tags: BitVec::zeros(rows),
            wear: None,
            ledger: EnergyLedger::default(),
        }
    }

    /// [`RcamModule::new`] with per-row wear counters enabled.
    pub fn with_wear_tracking(rows: usize, width: usize) -> Self {
        let mut m = Self::new(rows, width);
        m.wear = Some(vec![0; rows]);
        m
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.storage.rows()
    }

    /// Row width in bit-columns.
    #[inline]
    pub fn width(&self) -> usize {
        self.storage.width()
    }

    /// The tag register (one bit per row).
    #[inline]
    pub fn tags(&self) -> &BitVec {
        &self.tags
    }

    /// Mutable tag register (array-level tag-chain operations).
    #[inline]
    pub fn tags_mut(&mut self) -> &mut BitVec {
        &mut self.tags
    }

    /// The bit-sliced crossbar storage.
    #[inline]
    pub fn storage(&self) -> &BitMatrix {
        &self.storage
    }

    /// Per-row write counters, if wear tracking is enabled.
    pub fn wear_counters(&self) -> Option<&[u32]> {
        self.wear.as_deref()
    }

    /// Compare: tag every row whose (unmasked) columns equal the key
    /// (paper §3.1). An empty pattern tags every row (floating lines never
    /// discharge the match line).
    ///
    /// Energy: the match line spans the ENTIRE row, so every compare
    /// precharges W cells per row regardless of how many columns are
    /// unmasked — compare energy is rows × width × E_cmp/bit (paper §3.1:
    /// "less than 1 fJ per bit" is per match-line cell).
    pub fn compare(&mut self, pattern: &Pattern) {
        compare_tags_into(&self.storage, pattern, &mut self.tags);
        self.ledger.n_compare += 1;
        self.ledger.compare_bit_events += (self.width() * self.rows()) as u128;
    }

    /// Parallel write of the key into the unmasked columns of every
    /// *tagged* row (two-phase, paper §3.1). Word-blocked: all-zero tag
    /// words are skipped entirely, and wear counters are updated in the
    /// same traversal (word-at-a-time via `trailing_zeros`) so tracking
    /// no longer dominates write cost at low tag density.
    pub fn write(&mut self, pattern: &Pattern) {
        let nwords = self.tags.words().len();
        let mut tagged: u64 = 0;
        for w in 0..nwords {
            let t = self.tags.words()[w];
            if t == 0 {
                continue;
            }
            tagged += t.count_ones() as u64;
            for &(col, bit) in pattern {
                let pw = &mut self.storage.plane_mut(col as usize).words_mut()[w];
                if bit {
                    *pw |= t;
                } else {
                    *pw &= !t;
                }
            }
            if let Some(wear) = &mut self.wear {
                let mut m = t;
                while m != 0 {
                    wear[w * WORD_BITS + m.trailing_zeros() as usize] += 1;
                    m &= m - 1;
                }
            }
        }
        self.ledger.n_write += 1;
        self.ledger.write_bit_events += (pattern.len() as u128) * (tagged as u128);
    }

    /// Per-row compare used by the fault layer (`PrinsArray::compare`
    /// with faults enabled): every pattern cell of every row is observed
    /// through `observe(row, col, stored, wear)` — with **no**
    /// data-dependent early exit, so the draw sequence is
    /// input-independent — and the tag becomes the match result of the
    /// *observed* bits. Ledger charges are identical to
    /// [`RcamModule::compare`].
    pub fn compare_noisy<F>(&mut self, pattern: &Pattern, observe: &mut F)
    where
        F: FnMut(usize, u16, bool, u32) -> bool,
    {
        for row in 0..self.rows() {
            let wear = self.wear.as_ref().map_or(0, |w| w[row]);
            let mut matched = true;
            for &(col, bit) in pattern {
                let stored = self.storage.plane(col as usize).get(row);
                if observe(row, col, stored, wear) != bit {
                    matched = false;
                }
            }
            self.tags.set(row, matched);
        }
        self.ledger.n_compare += 1;
        self.ledger.compare_bit_events += (self.width() * self.rows()) as u128;
    }

    /// Tagged write with post-write corruption: performs the ideal
    /// [`RcamModule::write`] first (identical charges and wear), then
    /// inverts each written cell where `flip(row, col, wear)` says so.
    pub fn write_noisy<F>(&mut self, pattern: &Pattern, flip: &mut F)
    where
        F: FnMut(usize, u16, u32) -> bool,
    {
        self.write(pattern);
        let tagged: Vec<usize> = self.tags.iter_ones().collect();
        for row in tagged {
            let wear = self.wear.as_ref().map_or(0, |w| w[row]);
            for &(col, bit) in pattern {
                if flip(row, col, wear) {
                    self.storage.plane_mut(col as usize).set(row, !bit);
                }
            }
        }
    }

    /// Invert one stored bit in place (ambient retention/disturb faults;
    /// not an ISA operation).
    pub fn flip_stored_bit(&mut self, row: usize, col: u16) {
        let p = self.storage.plane_mut(col as usize);
        let v = p.get(row);
        p.set(row, !v);
    }

    /// Fused compare + tagged write — the microcode pass — in one
    /// traversal. Results and ledger are exactly `compare(cpat)` followed
    /// by `write(wpat)`: per word, the match result is computed from the
    /// pre-write plane values (compare only reads its own word), then the
    /// write applies under that tag word.
    pub fn pass(&mut self, cpat: &Pattern, wpat: &Pattern) {
        let nwords = self.tags.words().len();
        let tail = self.storage.rows() % WORD_BITS;
        let tail_mask = if tail == 0 { u64::MAX } else { (1u64 << tail) - 1 };
        let mut tagged: u64 = 0;
        for w in 0..nwords {
            let mut t = if w + 1 == nwords { tail_mask } else { u64::MAX };
            for &(col, bit) in cpat {
                let p = self.storage.plane(col as usize).words()[w];
                t &= if bit { p } else { !p };
            }
            self.tags.words_mut()[w] = t;
            if t == 0 {
                continue;
            }
            tagged += t.count_ones() as u64;
            for &(col, bit) in wpat {
                let pw = &mut self.storage.plane_mut(col as usize).words_mut()[w];
                if bit {
                    *pw |= t;
                } else {
                    *pw &= !t;
                }
            }
            if let Some(wear) = &mut self.wear {
                let mut m = t;
                while m != 0 {
                    wear[w * WORD_BITS + m.trailing_zeros() as usize] += 1;
                    m &= m - 1;
                }
            }
        }
        self.ledger.n_compare += 1;
        self.ledger.compare_bit_events += (self.width() * self.rows()) as u128;
        self.ledger.n_write += 1;
        self.ledger.write_bit_events += (wpat.len() as u128) * (tagged as u128);
    }

    /// Harvest raw pointers for the striped execution engine. All
    /// pointers come from disjoint `&mut` borrows taken in this call;
    /// the caller must not touch the module through safe references
    /// until its dispatch completes (see `rcam::exec`).
    pub(crate) fn raw_parts(&mut self) -> ModuleParts {
        let rows = self.rows();
        let words = self.tags.words().len();
        ModuleParts {
            tags: self.tags.words_mut().as_mut_ptr(),
            planes: self.storage.plane_word_ptrs(),
            wear: self.wear.as_mut().map(|v| v.as_mut_ptr()),
            rows,
            words,
        }
    }

    /// Read `width` bits at `base` from the first tagged row, if any
    /// (paper §5.2: read moves a masked field of a tagged row to the key
    /// register).
    pub fn read_first(&mut self, base: u16, width: u16) -> Option<u64> {
        let row = self.tags.first_one()?;
        self.ledger.n_read += 1;
        Some(self.storage.row_bits(row, base as usize, width as usize))
    }

    /// `first_match` tag-logic primitive: keep only the first tag.
    pub fn first_match(&mut self) -> Option<usize> {
        self.ledger.n_tag_op += 1;
        self.tags.keep_first_one()
    }

    /// `if_match`: at least one tag set.
    pub fn if_match(&mut self) -> bool {
        self.ledger.n_tag_op += 1;
        self.tags.any()
    }

    /// Reduction tree over tag bits (paper §3.1: logarithmic adder tree).
    pub fn count_tags(&mut self) -> u64 {
        let n = self.tags.count_ones();
        self.ledger.n_reduce += 1;
        self.ledger.reduce_bit_events +=
            (self.rows() as u128) * (self.tree_levels() as u128);
        n
    }

    /// Reduction over (tags AND bit-column) — the weighted popcount used by
    /// bit-serial field reductions (histogram counts, SpMV row sums).
    pub fn count_tags_and_col(&mut self, col: u16) -> u64 {
        let plane = self.storage.plane(col as usize);
        let n: u64 = self
            .tags
            .words()
            .iter()
            .zip(plane.words())
            .map(|(t, p)| (t & p).count_ones() as u64)
            .sum();
        self.ledger.n_reduce += 1;
        self.ledger.reduce_bit_events +=
            (self.rows() as u128) * (self.tree_levels() as u128);
        n
    }

    /// Depth of the reduction tree: ⌈log₂(rows)⌉, in exact integer math
    /// (a lossy float log2 here would mis-charge reduce energy events for
    /// large power-of-two row counts).
    #[inline]
    pub fn tree_levels(&self) -> u32 {
        self.rows().max(2).next_power_of_two().ilog2()
    }

    /// Tag every row (controller macro; hardware: compare with empty mask).
    pub fn set_tags_all(&mut self) {
        self.tags.fill(true);
        self.ledger.n_tag_op += 1;
    }

    // ----- storage-management (non-associative) access path -------------

    /// Direct row write used by the storage manager for dataset load.
    /// Charged as a (row-local) write of `width` bits.
    pub fn load_row_bits(&mut self, row: usize, base: usize, width: usize, value: u64) {
        self.storage.set_row_bits(row, base, width, value);
        self.ledger.write_bit_events += width as u128;
        if let Some(wear) = &mut self.wear {
            wear[row] += 1;
        }
    }

    /// Direct row read used by the storage manager for result readout.
    pub fn fetch_row_bits(&self, row: usize, base: usize, width: usize) -> u64 {
        self.storage.row_bits(row, base, width)
    }

    /// Replace a plane wholesale (used by the array-level daisy-chain
    /// field move; not an ISA operation by itself).
    pub fn replace_plane(&mut self, col: u16, plane: BitVec) {
        *self.storage.plane_mut(col as usize) = plane;
    }

    /// Clear a column range in every row (controller macro: one untagged
    /// parallel write per column pair).
    pub fn clear_columns(&mut self, base: u16, width: u16) {
        let rows = self.rows() as u128;
        self.storage.clear_columns(base as usize, width as usize);
        self.ledger.n_write += 1;
        self.ledger.write_bit_events += width as u128 * rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(m: &mut RcamModule, rows: &[(usize, u64)], base: usize, width: usize) {
        for &(r, v) in rows {
            m.storage.set_row_bits(r, base, width, v);
        }
    }

    #[test]
    fn compare_tags_matching_rows() {
        let mut m = RcamModule::new(100, 16);
        load(&mut m, &[(3, 0b1010), (7, 0b1010), (9, 0b0110)], 0, 4);
        // key = 1010 over columns 0..4
        m.compare(&[(0, false), (1, true), (2, false), (3, true)]);
        assert_eq!(m.tags().iter_ones().collect::<Vec<_>>(), vec![3, 7]);
    }

    #[test]
    fn empty_pattern_tags_all() {
        let mut m = RcamModule::new(70, 8);
        m.compare(&[]);
        assert_eq!(m.tags().count_ones(), 70);
    }

    #[test]
    fn write_hits_only_tagged_rows() {
        let mut m = RcamModule::new(10, 8);
        load(&mut m, &[(2, 1), (5, 1)], 0, 1);
        m.compare(&[(0, true)]);
        m.write(&[(4, true), (5, false)]);
        assert_eq!(m.storage().row_bits(2, 4, 2), 0b01);
        assert_eq!(m.storage().row_bits(5, 4, 2), 0b01);
        assert_eq!(m.storage().row_bits(3, 4, 2), 0);
    }

    #[test]
    fn first_match_and_read() {
        let mut m = RcamModule::new(50, 16);
        load(&mut m, &[(11, 1), (30, 1)], 0, 1);
        load(&mut m, &[(11, 0xAB), (30, 0xCD)], 8, 8);
        m.compare(&[(0, true)]);
        assert!(m.if_match());
        assert_eq!(m.first_match(), Some(11));
        assert_eq!(m.read_first(8, 8), Some(0xAB));
        // untag everything -> read yields None
        m.compare(&[(7, true)]);
        assert!(!m.if_match());
        assert_eq!(m.read_first(8, 8), None);
    }

    #[test]
    fn reduction_counts() {
        let mut m = RcamModule::new(64, 8);
        for r in 0..10 {
            m.storage.set_row_bits(r, 0, 1, 1);
        }
        for r in 0..5 {
            m.storage.set_row_bits(r, 1, 1, 1);
        }
        m.compare(&[(0, true)]);
        assert_eq!(m.count_tags(), 10);
        assert_eq!(m.count_tags_and_col(1), 5);
    }

    #[test]
    fn energy_events_accrue() {
        let mut m = RcamModule::new(100, 8);
        m.compare(&[(0, false), (1, false)]); // tags all 100 rows
        // full match-line precharge: 8 cells x 100 rows
        assert_eq!(m.ledger.compare_bit_events, 800);
        m.write(&[(2, true)]);
        assert_eq!(m.ledger.write_bit_events, 100);
        assert_eq!(m.ledger.n_compare, 1);
        assert_eq!(m.ledger.n_write, 1);
    }

    #[test]
    fn tree_levels_is_exact_integer_ceil_log2() {
        for (rows, want) in [
            (1usize, 1u32),
            (2, 1),
            (3, 2),
            (4, 2),
            (64, 6),
            (65, 7),
            (1 << 20, 20),
            ((1 << 20) + 1, 21),
        ] {
            assert_eq!(RcamModule::new(rows, 4).tree_levels(), want, "rows={rows}");
        }
    }

    #[test]
    fn wear_tracking_counts_tagged_writes() {
        let mut m = RcamModule::with_wear_tracking(10, 8);
        m.storage.set_row_bits(4, 0, 1, 1);
        m.compare(&[(0, true)]);
        m.write(&[(1, true)]);
        m.write(&[(2, true)]);
        let wear = m.wear_counters().unwrap();
        assert_eq!(wear[4], 2);
        assert_eq!(wear[3], 0);
    }
}
