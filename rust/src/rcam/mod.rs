//! RCAM substrate: the resistive content-addressable storage array that is
//! simultaneously the PRINS processor (paper §3).
//!
//! Layering:
//!   [`bitvec`]    — packed bit vectors (tag register, plane storage unit)
//!   [`bitmatrix`] — bit-sliced crossbar storage (W planes × N rows)
//!   [`device`]    — memristor/periphery constants; event → time/energy
//!   [`module`]    — one RCAM module: compare / write / read / tag logic /
//!                   reduction tree (paper Fig. 2 + Fig. 3)
//!   [`chain`]     — daisy-chained modules as one associative address
//!                   space (paper Fig. 4)

//!   [`exec`]      — row-striped parallel execution engine: worker pool,
//!                   stripe planning, fused word-blocked kernels
//!                   (DESIGN.md §5)
//!   [`shard`]     — multi-device sharding substrate: dataset
//!                   partitioning, host-side merge operators, and the
//!                   host-link interconnect cost model
//!                   (DESIGN.md §Sharding)

pub mod bitmatrix;
pub mod bitvec;
pub mod chain;
pub mod device;
pub mod exec;
pub mod module;
pub mod shard;

pub use bitmatrix::BitMatrix;
pub use bitvec::BitVec;
pub use chain::PrinsArray;
pub use device::{DeviceModel, EnergyLedger};
pub use exec::ExecBackend;
pub use module::{Pattern, RcamModule};
pub use shard::{InterconnectModel, ShardPlan};
