//! Sharding substrate for a multi-device PRINS rack: dataset
//! partitioning, host-side merge operators, and the interconnect cost
//! model (DESIGN.md §Sharding).
//!
//! A real PRINS deployment is a *rack* of SSD-resident RCAM devices, not
//! one chip: the paper's scaling argument (compute grows with storage
//! size) only holds if a dataset can be striped over many devices and the
//! per-device results merged by the host. This module holds everything
//! that is independent of any particular workload:
//!
//!   * [`ShardPlan`] — contiguous row-range partitioning, either
//!     equal-rows ([`ShardPlan::rows`]) or weight-balanced
//!     ([`ShardPlan::weighted`], used for nnz-balanced CSR splits);
//!   * merge operators — [`merge_histograms`] (bin-wise add),
//!     [`merge_concat`] (order-preserving row-range concatenation),
//!     [`reduce_partial_sums`] (low-bandwidth one-scalar-per-shard
//!     aggregate merge), [`merge_topk`] (k-way nearest-result merge);
//!   * [`InterconnectModel`] — the host-link bytes/latency cost model
//!     that keeps rack-level cycle/energy figures honest.
//!
//! The rack itself ([`crate::host::rack::PrinsRack`]) composes these with
//! per-workload kernels; the sharded algorithm entry points live next to
//! their single-device twins in [`crate::algorithms`].

use super::device::DeviceModel;
use std::ops::Range;

// ---------------------------------------------------------------------------
// Partitioning
// ---------------------------------------------------------------------------

/// A contiguous, order-preserving partition of `0..n` rows into shard
/// ranges. Ranges never overlap, cover every row exactly once, and are in
/// ascending order — so a shard-merged result that concatenates per-shard
/// outputs in plan order is bit-identical to the single-device row order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// One row range per shard, ascending, disjoint, covering `0..n`.
    /// Ranges may be empty when there are more shards than rows.
    pub ranges: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Equal-rows partition: `n` rows over `shards` shards; the first
    /// `n % shards` shards get one extra row.
    pub fn rows(n: usize, shards: usize) -> ShardPlan {
        let shards = shards.max(1);
        let base = n / shards;
        let rem = n % shards;
        let mut ranges = Vec::with_capacity(shards);
        let mut start = 0usize;
        for s in 0..shards {
            let len = base + usize::from(s < rem);
            ranges.push(start..start + len);
            start += len;
        }
        debug_assert_eq!(start, n);
        ShardPlan { ranges }
    }

    /// Weight-balanced contiguous partition: split `0..weights.len()` so
    /// every shard's weight sum tracks `total / shards` as closely as a
    /// contiguous split allows (prefix-target cuts). Used for row-balanced
    /// CSR partitioning where `weights[r]` is row r's nonzero count.
    pub fn weighted(weights: &[usize], shards: usize) -> ShardPlan {
        let shards = shards.max(1);
        let n = weights.len();
        let total: u128 = weights.iter().map(|&w| w as u128).sum();
        let mut ranges = Vec::with_capacity(shards);
        let mut row = 0usize;
        let mut acc: u128 = 0;
        for s in 0..shards {
            let start = row;
            let end = if s + 1 == shards {
                n
            } else {
                let target = total * (s as u128 + 1) / shards as u128;
                while row < n && acc < target {
                    acc += weights[row] as u128;
                    row += 1;
                }
                row
            };
            row = end;
            ranges.push(start..end);
        }
        ShardPlan { ranges }
    }

    /// Number of shards in the plan (empty shards included).
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// Total rows covered by the plan.
    pub fn total_rows(&self) -> usize {
        self.ranges.iter().map(|r| r.len()).sum()
    }

    /// Check the partition invariants: ascending, disjoint, gap-free
    /// coverage of `0..total_rows()` (property-test target).
    pub fn assert_partition(&self) {
        let mut next = 0usize;
        for r in &self.ranges {
            assert_eq!(r.start, next, "shard ranges must be gap-free");
            assert!(r.end >= r.start);
            next = r.end;
        }
    }
}

// ---------------------------------------------------------------------------
// Merge operators
// ---------------------------------------------------------------------------

/// Bin-wise histogram merge: element-wise sum of equally-sized per-shard
/// histograms. Exact — counting is associative, so the merged histogram
/// is bit-identical to the single-device one.
pub fn merge_histograms(parts: &[Vec<u64>]) -> Vec<u64> {
    let bins = parts.first().map(|p| p.len()).unwrap_or(0);
    let mut out = vec![0u64; bins];
    for p in parts {
        assert_eq!(p.len(), bins, "histogram shards must have equal bin counts");
        for (o, &v) in out.iter_mut().zip(p) {
            *o += v;
        }
    }
    out
}

/// Order-preserving concatenation merge for row-partitioned outputs
/// (ED distances, DP values, SpMV row slices): shard outputs arrive in
/// [`ShardPlan`] order, so plain concatenation reconstructs the global
/// row order bit-exactly. Accepts owned vectors or borrowed slices, so
/// callers merging sub-views need not clone per shard first.
pub fn merge_concat<T: Clone, S: AsRef<[T]>>(parts: &[S]) -> Vec<T> {
    let mut out = Vec::with_capacity(parts.iter().map(|p| p.as_ref().len()).sum());
    for p in parts {
        out.extend_from_slice(p.as_ref());
    }
    out
}

/// Partial-sum reduction: the host reduces one scalar per shard instead
/// of shipping whole result vectors — the low-bandwidth merge for
/// aggregate-only queries (8 bytes/shard on the link instead of the full
/// readback). Summed in shard order, in f64, so the reduced value is
/// deterministic for a given plan. Note the protocol's `checksum=` reply
/// fields deliberately do NOT use this: they are row-order f32 sums over
/// the merged vector, so they stay bit-identical to the single-device
/// path (`prop_sharded_equals_single` asserts that equality).
pub fn reduce_partial_sums(partials: &[f64]) -> f64 {
    partials.iter().sum()
}

/// K-way top-k merge for nearest-result queries (ED): each shard ships
/// its local `k` best `(row, score)` pairs sorted ascending by score; the
/// host merges them into the global `k` best. Scores order by
/// `f32::total_cmp` (a total order, so NaN inputs cannot panic the sort
/// or make the merge shard-count-dependent) with ties breaking toward
/// the lower row index, so the merge is deterministic.
pub fn merge_topk(parts: &[Vec<(usize, f32)>], k: usize) -> Vec<(usize, f32)> {
    let mut all: Vec<(usize, f32)> = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
    for p in parts {
        all.extend_from_slice(p);
    }
    all.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

/// Local top-k helper: the `k` smallest `(global_row, score)` pairs of one
/// shard's scores, ascending, with `row0` the shard's first global row.
/// Same `f32::total_cmp` + row-index ordering as [`merge_topk`].
pub fn local_topk(scores: &[f32], row0: usize, k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<(usize, f32)> = scores
        .iter()
        .enumerate()
        .map(|(i, &s)| (row0 + i, s))
        .collect();
    idx.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    idx.truncate(k);
    idx
}

// ---------------------------------------------------------------------------
// Interconnect cost model
// ---------------------------------------------------------------------------

/// Modeled bytes of one kernel-invocation command message (verb + register
/// parameters), before any data payload (broadcast vectors, centers).
pub const CMD_BYTES: u64 = 64;

/// Maximum shard devices a rack request may ask for — shared by the CLI
/// `--shards` flag and the server's `RACK <n>` verb so the two surfaces
/// cannot drift. Each shard is one OS thread plus a fully simulated
/// device, so unbounded values would exhaust threads instead of failing
/// cleanly.
pub const MAX_SHARDS: usize = 64;

/// Host-link cost model for a sharded rack (DESIGN.md §Sharding).
///
/// Charges every host↔shard message a fixed latency plus a
/// bandwidth-proportional transfer time over ONE shared host link
/// (messages serialize on it — the conservative choice), and a per-byte
/// link energy. What it deliberately ignores is documented in DESIGN.md:
/// dataset load traffic (datasets reside in PRINS by the paper's §5.3
/// model), host-CPU merge time, and compute/transfer overlap.
#[derive(Clone, Debug)]
pub struct InterconnectModel {
    /// Host-link bandwidth [bytes/s]. Default 8 GB/s (PCIe-gen3-x8-class
    /// storage fabric).
    pub bytes_per_s: f64,
    /// Per-message latency \[s\]: submission + completion + protocol
    /// overhead. Default 2 µs (NVMe-class round trip).
    pub latency_s: f64,
    /// Link energy per byte moved [J/byte]. Default 50 pJ/byte
    /// (≈ 6 pJ/bit, SerDes + controller estimate).
    pub e_per_byte: f64,
}

impl Default for InterconnectModel {
    fn default() -> Self {
        InterconnectModel {
            bytes_per_s: 8e9,
            latency_s: 2e-6,
            e_per_byte: 50e-12,
        }
    }
}

impl InterconnectModel {
    /// An idealized free interconnect (zero latency/energy) — the ablation
    /// baseline that isolates pure device-level scaling.
    pub fn free() -> Self {
        InterconnectModel {
            bytes_per_s: f64::INFINITY,
            latency_s: 0.0,
            e_per_byte: 0.0,
        }
    }

    /// Wall-clock seconds to move one `bytes`-sized message.
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bytes_per_s
    }

    /// One message's transfer time expressed in device cycles (rounded
    /// up) so link costs land in the same ledger as kernel cycles.
    pub fn transfer_cycles(&self, bytes: u64, dev: &DeviceModel) -> u64 {
        (self.transfer_s(bytes) * dev.freq_hz).ceil() as u64
    }

    /// Total link cycles for a message sequence on the shared host link
    /// (serialized: the sum of per-message transfer cycles).
    pub fn link_cycles(&self, messages: &[u64], dev: &DeviceModel) -> u64 {
        messages.iter().map(|&b| self.transfer_cycles(b, dev)).sum()
    }

    /// Link energy \[J\] for `bytes` moved (latency phases charge no
    /// energy in this model).
    pub fn energy_j(&self, bytes: u64) -> f64 {
        bytes as f64 * self.e_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_plan_is_balanced_partition() {
        for (n, s) in [(10usize, 3usize), (0, 4), (7, 7), (5, 8), (1 << 16, 6)] {
            let p = ShardPlan::rows(n, s);
            assert_eq!(p.shards(), s);
            assert_eq!(p.total_rows(), n);
            p.assert_partition();
            let lens: Vec<usize> = p.ranges.iter().map(|r| r.len()).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1, "{n}/{s}: {lens:?}");
        }
    }

    #[test]
    fn weighted_plan_balances_weight_not_rows() {
        // one heavy row at the front: the first shard should hold few rows
        let mut w = vec![1usize; 100];
        w[0] = 100;
        let p = ShardPlan::weighted(&w, 2);
        p.assert_partition();
        assert_eq!(p.total_rows(), 100);
        let w0: usize = w[p.ranges[0].clone()].iter().sum();
        let w1: usize = w[p.ranges[1].clone()].iter().sum();
        let total: usize = w.iter().sum();
        assert!(w0.abs_diff(w1) <= total / 2, "{w0} vs {w1}");
        assert!(p.ranges[0].len() < p.ranges[1].len());
    }

    #[test]
    fn weighted_plan_handles_zero_and_tail_weights() {
        // trailing zero-weight rows must still be assigned (to the tail)
        let w = [5usize, 0, 0, 0];
        let p = ShardPlan::weighted(&w, 2);
        p.assert_partition();
        assert_eq!(p.total_rows(), 4);
        let p = ShardPlan::weighted(&[], 3);
        p.assert_partition();
        assert_eq!(p.shards(), 3);
        assert_eq!(p.total_rows(), 0);
    }

    #[test]
    fn histogram_merge_is_binwise_sum() {
        let a = vec![1u64, 2, 3];
        let b = vec![10u64, 0, 5];
        assert_eq!(merge_histograms(&[a, b]), vec![11, 2, 8]);
        assert_eq!(merge_histograms(&[]), Vec::<u64>::new());
    }

    #[test]
    fn concat_preserves_plan_order() {
        let merged = merge_concat(&[vec![1, 2], vec![], vec![3]]);
        assert_eq!(merged, vec![1, 2, 3]);
        // borrowed-slice form: no per-shard clone required
        let (a, b) = ([4i32, 5], [6i32]);
        let merged = merge_concat(&[&a[..], &b[..]]);
        assert_eq!(merged, vec![4, 5, 6]);
    }

    #[test]
    fn partial_sum_reduce_is_shard_ordered_f64() {
        assert_eq!(reduce_partial_sums(&[1.5, 2.25, -0.75]), 3.0);
        assert_eq!(reduce_partial_sums(&[]), 0.0);
    }

    #[test]
    fn topk_merge_matches_global_sort() {
        let a = local_topk(&[5.0, 1.0, 9.0], 0, 2); // rows 0..3
        let b = local_topk(&[0.5, 7.0], 3, 2); // rows 3..5
        assert_eq!(a, vec![(1, 1.0), (0, 5.0)]);
        let m = merge_topk(&[a, b], 3);
        assert_eq!(m, vec![(3, 0.5), (1, 1.0), (0, 5.0)]);
    }

    #[test]
    fn interconnect_costs_are_monotone() {
        let ic = InterconnectModel::default();
        let dev = DeviceModel::default();
        // latency floor: 2 µs at 500 MHz = 1000 cycles
        assert_eq!(ic.transfer_cycles(0, &dev), 1000);
        assert!(ic.transfer_cycles(1 << 20, &dev) > ic.transfer_cycles(1 << 10, &dev));
        assert!(ic.energy_j(1000) > 0.0);
        let free = InterconnectModel::free();
        assert_eq!(free.transfer_cycles(1 << 30, &dev), 0);
        assert_eq!(free.energy_j(1 << 30), 0.0);
    }
}
