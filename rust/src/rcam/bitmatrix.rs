//! Bit-sliced storage for one RCAM crossbar: W bit-column planes over N rows.
//!
//! This is the in-data storage array itself — each `BitVec` plane holds one
//! bit-column of every row (paper Fig. 2(a): one virtual RCAM cell pair per
//! bit). Row-oriented access (`set_row_bits`/`row_bits`) exists for the
//! storage-management path (dataset load/readout); the associative compute
//! path only ever touches whole planes.

use super::bitvec::BitVec;

/// W bit-column planes over N rows — one RCAM crossbar's storage.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    planes: Vec<BitVec>,
    rows: usize,
}

impl BitMatrix {
    /// An all-zero `rows` × `width` crossbar.
    pub fn new(rows: usize, width: usize) -> Self {
        BitMatrix {
            planes: (0..width).map(|_| BitVec::zeros(rows)).collect(),
            rows,
        }
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Width in bit-columns (planes).
    #[inline]
    pub fn width(&self) -> usize {
        self.planes.len()
    }

    /// The plane of bit-column `col`.
    #[inline]
    pub fn plane(&self, col: usize) -> &BitVec {
        &self.planes[col]
    }

    /// Mutable plane of bit-column `col`.
    #[inline]
    pub fn plane_mut(&mut self, col: usize) -> &mut BitVec {
        &mut self.planes[col]
    }

    /// Read one cell.
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> bool {
        self.planes[col].get(row)
    }

    /// Write one cell.
    #[inline]
    pub fn set(&mut self, row: usize, col: usize, v: bool) {
        self.planes[col].set(row, v);
    }

    /// Write `width` bits of `value` (LSB first) into columns
    /// `[base, base+width)` of one row. Storage-path helper.
    pub fn set_row_bits(&mut self, row: usize, base: usize, width: usize, value: u64) {
        debug_assert!(width <= 64 && base + width <= self.width());
        for i in 0..width {
            self.planes[base + i].set(row, (value >> i) & 1 == 1);
        }
    }

    /// Read `width` bits (LSB first) from columns `[base, base+width)` of one row.
    pub fn row_bits(&self, row: usize, base: usize, width: usize) -> u64 {
        debug_assert!(width <= 64 && base + width <= self.width());
        let mut v = 0u64;
        for i in 0..width {
            if self.planes[base + i].get(row) {
                v |= 1 << i;
            }
        }
        v
    }

    /// Zero every bit of a whole column range (controller macro used to
    /// clear temporaries; in hardware this is one untagged parallel write).
    pub fn clear_columns(&mut self, base: usize, width: usize) {
        for c in base..base + width {
            self.planes[c].fill(false);
        }
    }

    /// Base word pointer of every plane, harvested through disjoint
    /// `iter_mut` borrows (one per plane, alive together) so the pointers
    /// are valid simultaneously. Used by the striped execution engine
    /// (`rcam::exec`) to build per-worker segment views.
    pub(crate) fn plane_word_ptrs(&mut self) -> Vec<*mut u64> {
        self.planes
            .iter_mut()
            .map(|p| p.words_mut().as_mut_ptr())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_bits_roundtrip() {
        let mut m = BitMatrix::new(100, 32);
        m.set_row_bits(42, 4, 16, 0xBEEF);
        assert_eq!(m.row_bits(42, 4, 16), 0xBEEF);
        assert_eq!(m.row_bits(42, 0, 4), 0);
        assert_eq!(m.row_bits(42, 20, 12), 0);
        assert_eq!(m.row_bits(41, 4, 16), 0);
    }

    #[test]
    fn planes_are_column_major() {
        let mut m = BitMatrix::new(70, 8);
        m.set(65, 3, true);
        assert!(m.plane(3).get(65));
        assert!(!m.plane(2).get(65));
    }

    #[test]
    fn clear_columns_only_hits_range() {
        let mut m = BitMatrix::new(10, 8);
        m.set_row_bits(1, 0, 8, 0xFF);
        m.clear_columns(2, 3);
        assert_eq!(m.row_bits(1, 0, 8), 0b1110_0011);
    }
}
