//! # PRINS — Resistive CAM Processing in Storage
//!
//! Full-system reproduction of Yavits, Kaplan & Ginosar, *"PRINS:
//! Resistive CAM Processing in Storage"* (2018): an in-data
//! processing-in-storage architecture in which the storage array is a
//! resistive CAM and every row is a bit-serial associative processing
//! unit.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

#![warn(missing_docs)]
// CI runs `cargo clippy --all-targets -- -D warnings` as a hard gate.
// Correctness, suspicious and perf lints stay hard errors; the
// stylistic lints below are opted out tree-wide because the simulator's
// index-arithmetic kernels and microcode emitters trip them by design
// (bit-column loops index several parallel planes at once, microcode
// helpers thread many fields, and tag-pattern literals are built as
// vectors because the ISA owns them).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::useless_vec,
    clippy::ptr_arg,
    clippy::new_without_default,
    clippy::manual_div_ceil
)]

pub mod algorithms;
pub mod analysis;
pub mod cli;
pub mod controller;
pub mod error;
pub mod host;
pub mod isa;
pub mod micro;
pub mod metrics;
pub mod model;
pub mod rcam;
pub mod reliability;
pub mod runtime;
pub mod storage;
pub mod workloads;
