//! # PRINS — Resistive CAM Processing in Storage
//!
//! Full-system reproduction of Yavits, Kaplan & Ginosar, *"PRINS:
//! Resistive CAM Processing in Storage"* (2018): an in-data
//! processing-in-storage architecture in which the storage array is a
//! resistive CAM and every row is a bit-serial associative processing
//! unit.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

#![warn(missing_docs)]

pub mod algorithms;
pub mod cli;
pub mod controller;
pub mod error;
pub mod host;
pub mod isa;
pub mod micro;
pub mod metrics;
pub mod model;
pub mod rcam;
pub mod runtime;
pub mod storage;
pub mod workloads;
