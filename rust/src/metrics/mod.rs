//! Reporting utilities: table rendering for the paper-figure harnesses and
//! a tiny self-timing bench helper (the vendored crate set has no
//! criterion; benches are `harness = false` binaries built on this).

pub mod bench;
pub mod table;

pub use bench::{time_it, BenchTimer};
pub use table::Table;
