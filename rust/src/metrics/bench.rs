//! Minimal self-timing harness for the `harness = false` benches
//! (criterion is not in the vendored crate set): warmup + N timed
//! iterations, reporting min/mean.

use std::time::{Duration, Instant};

pub struct BenchTimer {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchTimer {
    pub fn mean(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or(Duration::ZERO)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} min {:>12?}  mean {:>12?}  ({} samples)",
            self.name,
            self.min(),
            self.mean(),
            self.samples.len()
        )
    }
}

/// Time `f` with `warmup` unmeasured runs and `iters` measured runs.
pub fn time_it<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchTimer {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    BenchTimer {
        name: name.to_string(),
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_collects_samples() {
        let t = time_it("noop", 1, 5, || 1 + 1);
        assert_eq!(t.samples.len(), 5);
        assert!(t.min() <= t.mean());
        assert!(t.report().contains("noop"));
    }
}
