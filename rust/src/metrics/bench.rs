//! Minimal self-timing harness for the `harness = false` benches
//! (criterion is not in the vendored crate set): warmup + N timed
//! iterations, reporting min/mean — plus the shared bench surface:
//! `--workers`/`--rows` argument parsing, the machine-readable
//! `BENCH_*.json` result files that seed the perf trajectory
//! (DESIGN.md §6), and the **registry-driven sweep drivers**
//! ([`rack_registry_points`], [`resident_registry_points`]) that run
//! every kernel in [`crate::algorithms::kernel::registry`] — a newly
//! registered kernel joins the rack-scaling and resident-amortization
//! benches without touching any bench code.

use crate::algorithms::kernel::registry;
use crate::host::rack::PrinsRack;
use crate::rcam::ExecBackend;
use std::time::{Duration, Instant};

/// Collected timing samples of one benchmarked closure.
pub struct BenchTimer {
    /// Label printed in reports.
    pub name: String,
    /// One wall-clock duration per measured iteration.
    pub samples: Vec<Duration>,
}

impl BenchTimer {
    /// Mean of the measured samples.
    pub fn mean(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    /// Fastest measured sample (the number perf work tracks).
    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or(Duration::ZERO)
    }

    /// One-line min/mean report.
    pub fn report(&self) -> String {
        format!(
            "{:<40} min {:>12?}  mean {:>12?}  ({} samples)",
            self.name,
            self.min(),
            self.mean(),
            self.samples.len()
        )
    }
}

/// Time `f` with `warmup` unmeasured runs and `iters` measured runs.
pub fn time_it<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchTimer {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    BenchTimer {
        name: name.to_string(),
        samples,
    }
}

// ---------------------------------------------------------------------------
// Shared bench CLI surface
// ---------------------------------------------------------------------------

/// Value of `--name <v>` among the given args (benches receive argv after
/// `cargo bench --bench x -- ...`).
pub fn arg_value(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

/// `--name <v>` parsed as u64, with a default.
pub fn arg_u64(args: &[String], name: &str, default: u64) -> u64 {
    arg_value(args, name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Execution backend from `--workers N` (default: all cores; `1` selects
/// the serial reference path). Every bench exposes this knob.
pub fn backend_from_args(args: &[String]) -> ExecBackend {
    match arg_value(args, "--workers").and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => ExecBackend::from_workers(n),
        None => ExecBackend::threaded_default(),
    }
}

/// Worker-count sweep from `--workers a,b,c` (for thread-scaling benches;
/// a single value is a one-element sweep).
pub fn workers_sweep_from_args(args: &[String], default: &[usize]) -> Vec<usize> {
    sweep_from_args(args, "--workers", default)
}

/// Shard-count sweep from `--shards a,b,c` (for rack-scaling benches;
/// a single value is a one-element sweep).
pub fn shards_sweep_from_args(args: &[String], default: &[usize]) -> Vec<usize> {
    sweep_from_args(args, "--shards", default)
}

/// Query-count sweep from `--queries a,b,c` (for the resident
/// load-once / query-many bench; a single value is a one-element sweep).
pub fn queries_sweep_from_args(args: &[String], default: &[usize]) -> Vec<usize> {
    sweep_from_args(args, "--queries", default)
}

/// Batch-size sweep from `--batch a,b,c` (for the resident bench's
/// packed-operand points; a single value is a one-element sweep).
pub fn batch_sweep_from_args(args: &[String], default: &[usize]) -> Vec<usize> {
    sweep_from_args(args, "--batch", default)
}

/// BER sweep from `--ber a,b,c` (for the fidelity bench; a single value
/// is a one-element sweep). Values are probabilities, so entries outside
/// `[0, 1)` are dropped like any other parse failure.
pub fn ber_sweep_from_args(args: &[String], default: &[f64]) -> Vec<f64> {
    match arg_value(args, "--ber") {
        Some(list) => {
            let v: Vec<f64> = list
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|b: &f64| b.is_finite() && (0.0..1.0).contains(b))
                .collect();
            if v.is_empty() {
                default.to_vec()
            } else {
                v
            }
        }
        None => default.to_vec(),
    }
}

/// Comma-separated `usize` sweep behind a flag, with a default.
fn sweep_from_args(args: &[String], flag: &str, default: &[usize]) -> Vec<usize> {
    match arg_value(args, flag) {
        Some(list) => {
            let v: Vec<usize> = list
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            if v.is_empty() {
                default.to_vec()
            } else {
                v
            }
        }
        None => default.to_vec(),
    }
}

// ---------------------------------------------------------------------------
// Machine-readable results (BENCH_*.json at the repository root)
// ---------------------------------------------------------------------------

/// One measured point of the perf trajectory.
pub struct BenchRecord {
    /// Microbenchmark name.
    pub bench: String,
    /// Array rows of the measured configuration.
    pub rows: u64,
    /// Simulator worker count (1 = serial).
    pub workers: u64,
    /// Measured throughput (bench-specific op definition).
    pub ops_per_s: f64,
    /// Wall-clock seconds of the fastest sample.
    pub wall_s: f64,
}

/// Hand-rolled JSON (the crate set has no serde): a flat array of
/// `{bench, rows, workers, ops_per_s, wall_s}` objects.
pub fn bench_records_json(records: &[BenchRecord]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"bench\": \"{}\", \"rows\": {}, \"workers\": {}, \
             \"ops_per_s\": {:e}, \"wall_s\": {:e}}}{}\n",
            r.bench,
            r.rows,
            r.workers,
            r.ops_per_s,
            r.wall_s,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    s
}

/// Repository-root path for a bench artifact, independent of the cwd the
/// bench binary was launched from (the crate lives in `rust/`, one level
/// below the repo root).
pub fn repo_root_path(file: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join(file)
}

/// Write `BENCH_<name>.json` at the repository root.
pub fn write_bench_json(name: &str, records: &[BenchRecord]) -> std::io::Result<std::path::PathBuf> {
    let path = repo_root_path(&format!("BENCH_{name}.json"));
    std::fs::write(&path, bench_records_json(records))?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Rack-scaling results (BENCH_rack.json)
// ---------------------------------------------------------------------------

/// One measured point of the rack shard-count sweep (`benches/
/// rack_scaling.rs`): modeled rack cycles/energy under the interconnect
/// cost model plus host wall-clock of the simulation itself.
pub struct RackRecord {
    /// Workload name (`hist`, `dp`, `ed`, `spmv`).
    pub bench: String,
    /// Dataset rows (samples / vectors / matrix dimension).
    pub rows: u64,
    /// Shard-device count of the rack.
    pub shards: u64,
    /// Modeled rack latency: slowest shard + serialized host link.
    pub total_cycles: u64,
    /// Modeled slowest-shard kernel cycles.
    pub max_shard_cycles: u64,
    /// Modeled host-link traffic in bytes.
    pub link_bytes: u64,
    /// Modeled rack energy \[J\] (device + link).
    pub energy_j: f64,
    /// Host wall-clock seconds of the simulated run.
    pub wall_s: f64,
}

/// Hand-rolled JSON for [`RackRecord`]s (the crate set has no serde): a
/// flat array of objects, one per (bench, shards) point.
pub fn rack_records_json(records: &[RackRecord]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"bench\": \"{}\", \"rows\": {}, \"shards\": {}, \
             \"total_cycles\": {}, \"max_shard_cycles\": {}, \
             \"link_bytes\": {}, \"energy_j\": {:e}, \"wall_s\": {:e}}}{}\n",
            r.bench,
            r.rows,
            r.shards,
            r.total_cycles,
            r.max_shard_cycles,
            r.link_bytes,
            r.energy_j,
            r.wall_s,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    s
}

/// Write `BENCH_<name>.json` of rack records at the repository root.
pub fn write_rack_json(name: &str, records: &[RackRecord]) -> std::io::Result<std::path::PathBuf> {
    let path = repo_root_path(&format!("BENCH_{name}.json"));
    std::fs::write(&path, rack_records_json(records))?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Resident-query amortization results (BENCH_resident.json)
// ---------------------------------------------------------------------------

/// One measured point of the load-once / query-many sweep
/// (`benches/resident_queries.rs`): the one-time load cost, the
/// per-query cost, and the amortized per-query figure that collapses
/// toward the query floor as the query count grows (DESIGN.md §Resident
/// datasets).
pub struct ResidentRecord {
    /// Workload name (`hist`, `dp`, `ed`, `spmv`).
    pub bench: String,
    /// Dataset rows (samples / vectors / matrix dimension).
    pub rows: u64,
    /// Shard-device count of the resident rack.
    pub shards: u64,
    /// Queries run against the resident dataset.
    pub queries: u64,
    /// Operands packed into each query's sweep (1 = the single-operand
    /// path; > 1 = the kernel's batched parameter stream).
    pub batch: u64,
    /// Modeled one-time load-phase cycles (device + link).
    pub load_cycles: u64,
    /// Modeled mean cycles per query (constant for a fixed workload).
    pub query_cycles: f64,
    /// `(load_cycles + Σ query cycles) / queries` — the amortized figure.
    pub amortized_cycles: f64,
    /// Mean slowest-shard **device** cycles per packed operand:
    /// `Σ max_shard_cycles / (queries × batch)` — link charges excluded
    /// so batched and unbatched points compare on in-array work alone.
    pub per_op_cycles: f64,
    /// Analytic device-cycle floor per operand if every operand ran as
    /// its own query (`Σ unbatched floors / (queries × batch)`); the CI
    /// gate asserts `per_op_cycles < floor_per_op` at batch ≥ 2.
    pub floor_per_op: f64,
    /// Cumulative compiled-program cache hits across the sweep.
    pub cache_hits: u64,
    /// Cumulative compiled-program cache misses (plan syntheses).
    pub cache_misses: u64,
    /// Modeled total energy \[J\] (load + all queries).
    pub energy_j: f64,
    /// Host wall-clock seconds of the simulated load + queries.
    pub wall_s: f64,
}

/// Hand-rolled JSON for [`ResidentRecord`]s (the crate set has no
/// serde): a flat array of objects, one per (bench, queries, batch)
/// point.
pub fn resident_records_json(records: &[ResidentRecord]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"bench\": \"{}\", \"rows\": {}, \"shards\": {}, \
             \"queries\": {}, \"batch\": {}, \"load_cycles\": {}, \
             \"query_cycles\": {:e}, \"amortized_cycles\": {:e}, \
             \"per_op_cycles\": {:e}, \"floor_per_op\": {:e}, \
             \"cache_hits\": {}, \"cache_misses\": {}, \
             \"energy_j\": {:e}, \"wall_s\": {:e}}}{}\n",
            r.bench,
            r.rows,
            r.shards,
            r.queries,
            r.batch,
            r.load_cycles,
            r.query_cycles,
            r.amortized_cycles,
            r.per_op_cycles,
            r.floor_per_op,
            r.cache_hits,
            r.cache_misses,
            r.energy_j,
            r.wall_s,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    s
}

/// Write `BENCH_<name>.json` of resident records at the repository root.
pub fn write_resident_json(
    name: &str,
    records: &[ResidentRecord],
) -> std::io::Result<std::path::PathBuf> {
    let path = repo_root_path(&format!("BENCH_{name}.json"));
    std::fs::write(&path, resident_records_json(records))?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// BER → accuracy fidelity results (BENCH_fidelity.json)
// ---------------------------------------------------------------------------

/// One measured point of the BER → accuracy sweep
/// (`benches/fidelity.rs`): for a (kernel, BER) pair, the fraction of
/// queries whose results survive the fault layer bit-exactly — once
/// with the raw single-attempt path and once with scrub/retry recovery
/// — plus the recovery overhead charged to the cycle ledger
/// (DESIGN.md §Reliability).
pub struct FidelityRecord {
    /// Workload name (`hist`, `dp`, `ed`, `spmv`, `search`).
    pub bench: String,
    /// Dataset rows (samples / vectors / matrix dimension).
    pub rows: u64,
    /// Queries run per point (each compared against the ideal run).
    pub queries: u64,
    /// Injected per-read bit-error rate (also write/retention BER).
    pub ber: f64,
    /// Fraction of queries bit-exact vs ideal, recovery disabled.
    pub exact_rate: f64,
    /// Fraction of queries bit-exact vs ideal, scrub/retry enabled.
    pub recovered_rate: f64,
    /// Mean relative error of the recovered results vs ideal (capped
    /// at 1.0 per element; 0.0 when bit-exact).
    pub mean_rel_err: f64,
    /// Total fault events injected across the recovered run.
    pub injected: u64,
    /// Resident-row corruptions the scrubber detected.
    pub detected: u64,
    /// Corruptions repaired by golden-copy rewrite.
    pub repaired: u64,
    /// Corruptions surviving all retries (stuck-at cells).
    pub residual: u64,
    /// Query retries triggered by scrub mismatches.
    pub retries: u64,
    /// Recovery cycles (scrub + retries + backoff) beyond the kernel's
    /// own query cycles, summed over the run.
    pub overhead_cycles: u64,
    /// Host wall-clock seconds of the three simulated runs.
    pub wall_s: f64,
}

/// Hand-rolled JSON for [`FidelityRecord`]s (the crate set has no
/// serde): a flat array of objects, one per (bench, ber) point.
pub fn fidelity_records_json(records: &[FidelityRecord]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"bench\": \"{}\", \"rows\": {}, \"queries\": {}, \
             \"ber\": {:e}, \"exact_rate\": {:e}, \"recovered_rate\": {:e}, \
             \"mean_rel_err\": {:e}, \"injected\": {}, \"detected\": {}, \
             \"repaired\": {}, \"residual\": {}, \"retries\": {}, \
             \"overhead_cycles\": {}, \"wall_s\": {:e}}}{}\n",
            r.bench,
            r.rows,
            r.queries,
            r.ber,
            r.exact_rate,
            r.recovered_rate,
            r.mean_rel_err,
            r.injected,
            r.detected,
            r.repaired,
            r.residual,
            r.retries,
            r.overhead_cycles,
            r.wall_s,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    s
}

/// Write `BENCH_<name>.json` of fidelity records at the repository root.
pub fn write_fidelity_json(
    name: &str,
    records: &[FidelityRecord],
) -> std::io::Result<std::path::PathBuf> {
    let path = repo_root_path(&format!("BENCH_{name}.json"));
    std::fs::write(&path, fidelity_records_json(records))?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Serving-throughput results (BENCH_throughput.json)
// ---------------------------------------------------------------------------

/// One measured point of the multiplexed-serving sweep
/// (`benches/throughput.rs`): wall-clock queries/second against a live
/// [`crate::host::server::Server`] for one (clients, pipeline-depth,
/// admission-mode) cell. `mode` is `"shared"` (write-free queries admit
/// as concurrent readers) or `"exclusive"` (every request serialized
/// per connection — the `&mut`-access baseline); the cross-session
/// cells, where every client hammers **one** dataset loaded by a setup
/// connection, report as `"cross_session"` (shared admission plus the
/// cross-connection coalescer) vs `"cross_exclusive"` (the same
/// workload fully serialized through the slot gate).
pub struct ThroughputRecord {
    /// Workload name of the queried resident dataset (`hist`, `search`).
    pub bench: String,
    /// Concurrent client connections driving the server.
    pub clients: u64,
    /// Request lines each client keeps in flight (1 = strict
    /// request/reply lockstep).
    pub pipeline: u64,
    /// Admission mode: `"shared"` or `"exclusive"`.
    pub mode: String,
    /// Total queries answered across all clients.
    pub queries: u64,
    /// Wall-clock queries per second across the whole run.
    pub qps: f64,
    /// Wall-clock seconds of the measured run.
    pub wall_s: f64,
    /// Device cycles per query attributed by the cross-connection
    /// coalescer over this cell (`coal_cycles / coal_members` from the
    /// dataset's `STATS` deltas). `0.0` when the cell coalesced nothing
    /// — exclusive modes, per-client-dataset cells, or bursts the mux
    /// never saw pending together.
    pub coalesced_per_op_cycles: f64,
}

/// Hand-rolled JSON for [`ThroughputRecord`]s (the crate set has no
/// serde): a flat array of objects, one per (bench, clients, pipeline,
/// mode) cell.
pub fn throughput_records_json(records: &[ThroughputRecord]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"bench\": \"{}\", \"clients\": {}, \"pipeline\": {}, \
             \"mode\": \"{}\", \"queries\": {}, \"qps\": {:e}, \
             \"wall_s\": {:e}, \"coalesced_per_op_cycles\": {:e}}}{}\n",
            r.bench,
            r.clients,
            r.pipeline,
            r.mode,
            r.queries,
            r.qps,
            r.wall_s,
            r.coalesced_per_op_cycles,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    s
}

/// Write `BENCH_<name>.json` of throughput records at the repository
/// root.
pub fn write_throughput_json(
    name: &str,
    records: &[ThroughputRecord],
) -> std::io::Result<std::path::PathBuf> {
    let path = repo_root_path(&format!("BENCH_{name}.json"));
    std::fs::write(&path, throughput_records_json(records))?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Registry-driven sweep drivers (rack_scaling / resident_queries benches)
// ---------------------------------------------------------------------------

/// One kernel's measured point of a registry-driven rack sweep: the
/// record for `BENCH_rack.json` plus the canonical bit encoding of the
/// merged result (the bench's `--verify` bit-equality gate compares it
/// across shard counts).
pub struct RackPoint {
    /// Kernel registry name.
    pub name: &'static str,
    /// The JSON record.
    pub record: RackRecord,
    /// Canonical bits of the merged result ([`crate::algorithms::ShardMerge::bits`]).
    pub bits: Vec<u64>,
}

/// Dataset rows a sweep gives `entry`: dense (microcoded) workloads
/// simulate every pass over every row per query, so they cap at
/// `dense_cap`; compare-only workloads (hist, search) take `rows` whole.
fn sweep_rows(dense: bool, rows: usize, dense_cap: usize) -> usize {
    if dense {
        rows.min(dense_cap)
    } else {
        rows
    }
}

/// Run every registered kernel once (load + a single query — the
/// framework one-shot) on `rack` and return one [`RackPoint`] per
/// kernel, printing the per-point summary line. `seed` fixes both the
/// synthesized dataset and the query parameters, so points are
/// comparable across shard counts.
pub fn rack_registry_points(
    rack: &PrinsRack,
    rows: usize,
    dense_cap: usize,
    dims: usize,
    seed: u64,
) -> Vec<RackPoint> {
    let shards = rack.n_shards();
    let mut points = Vec::new();
    for entry in registry() {
        let nrows = sweep_rows(entry.dense, rows, dense_cap);
        let t0 = Instant::now();
        let mut res = (entry.synth_load)(rack, nrows, dims, seed);
        let out = res.query_seeded(0, seed);
        let wall = t0.elapsed().as_secs_f64();
        let rs = &out.rack;
        println!(
            "{:<6} shards={shards:<2} total_cycles={:>9} max_shard={:>9} \
             link_bytes={:>9} energy={:.3e} J  wall={wall:.3}s",
            entry.name, rs.total_cycles, rs.max_shard_cycles, rs.link_bytes, rs.energy_j
        );
        points.push(RackPoint {
            name: entry.name,
            record: RackRecord {
                bench: entry.name.into(),
                rows: nrows as u64,
                shards: shards as u64,
                total_cycles: rs.total_cycles,
                max_shard_cycles: rs.max_shard_cycles,
                link_bytes: rs.link_bytes,
                energy_j: rs.energy_j,
                wall_s: wall,
            },
            bits: out.bits,
        });
    }
    points
}

/// Run the load-once / query-many amortization sweep for every
/// registered kernel at one (query count, batch size) cell: load once,
/// run `q_count` queries with the kernel's seeded fresh-parameters
/// stream — `batch` operands packed into each query's sweep when
/// `batch > 1` — and return one [`ResidentRecord`] per kernel (printing
/// the per-point summary line). Kernels without a batched parameter
/// stream are skipped (with a note) at `batch > 1`, so `batch = 1`
/// covers the whole registry and larger batches cover search/ed.
/// With `verify`, the first and last query of each kernel's sweep is
/// asserted bit-equal to a freshly loaded run with the same parameters
/// (every intermediate query is covered by `tests/resident_datasets.rs`).
pub fn resident_registry_points(
    rack: &PrinsRack,
    rows: usize,
    dense_cap: usize,
    dims: usize,
    q_count: usize,
    batch: usize,
    seed: u64,
    verify: bool,
) -> Vec<ResidentRecord> {
    assert!(q_count > 0, "--queries entries must be positive");
    assert!(batch > 0, "--batch entries must be positive");
    let shards = rack.n_shards() as u64;
    let mut records = Vec::new();
    for entry in registry() {
        let nrows = sweep_rows(entry.dense, rows, dense_cap);
        let t0 = Instant::now();
        let mut res = (entry.synth_load)(rack, nrows, dims, seed);
        // analytic probe, no execution: does this kernel pack operands?
        if batch > 1 && res.query_floor_seeded_batch(0, seed, batch).is_none() {
            println!("{:<6} B={batch:<3} skipped (no batched query form)", entry.name);
            continue;
        }
        let load_cycles = res.load_report().total_cycles;
        let mut energy = res.load_report().energy_j;
        let mut qcycles = Vec::with_capacity(q_count);
        let mut device_cycles = 0u64;
        let mut floor_sum = 0u64;
        for q in 0..q_count {
            let r = if batch > 1 {
                res.query_seeded_batch(q, seed, batch)
                    .expect("batched stream probed above")
            } else {
                res.query_seeded(q, seed)
            };
            qcycles.push(r.rack.total_cycles);
            device_cycles += r.rack.max_shard_cycles;
            // what the same operands would cost as one query each
            floor_sum += if batch > 1 {
                res.query_floor_seeded_batch(q, seed, batch)
                    .expect("batched stream probed above")
            } else {
                res.query_floor_seeded(q, seed)
            };
            energy += r.rack.energy_j;
            if verify && (q == 0 || q == q_count - 1) {
                // fresh load + the same parameter index = the one-shot
                // reference; results must be bit-equal
                let mut fresh = (entry.synth_load)(rack, nrows, dims, seed);
                let f = if batch > 1 {
                    fresh
                        .query_seeded_batch(q, seed, batch)
                        .expect("batched stream probed above")
                } else {
                    fresh.query_seeded(q, seed)
                };
                assert_eq!(
                    r.bits, f.bits,
                    "{} Q={q_count} B={batch} q={q}: resident query diverged from fresh load",
                    entry.name
                );
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let qsum: u64 = qcycles.iter().sum();
        let ops = (q_count * batch) as f64;
        let query_cycles = qsum as f64 / q_count as f64;
        let amortized = (load_cycles + qsum) as f64 / q_count as f64;
        let per_op = device_cycles as f64 / ops;
        let floor_per_op = floor_sum as f64 / ops;
        let (cache_hits, cache_misses) = res.cache_stats();
        println!(
            "{:<6} Q={q_count:<3} B={batch:<2} load={load_cycles:>9} \
             query/Q={query_cycles:>12.1} amortized/Q={amortized:>12.1} \
             per_op={per_op:>9.1} floor={floor_per_op:>9.1} \
             cache={cache_hits}h/{cache_misses}m energy={energy:.3e} J  wall={wall:.3}s",
            entry.name
        );
        records.push(ResidentRecord {
            bench: entry.name.into(),
            rows: nrows as u64,
            shards,
            queries: q_count as u64,
            batch: batch as u64,
            load_cycles,
            query_cycles,
            amortized_cycles: amortized,
            per_op_cycles: per_op,
            floor_per_op,
            cache_hits,
            cache_misses,
            energy_j: energy,
            wall_s: wall,
        });
    }
    records
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_sweep_covers_every_kernel_and_amortizes() {
        let rack = PrinsRack::new(1);
        let recs = resident_registry_points(&rack, 64, 32, 2, 2, 1, 5, true);
        assert_eq!(recs.len(), registry().len());
        for r in &recs {
            assert!(r.load_cycles > 0, "{}: uncharged load", r.bench);
            assert!(r.amortized_cycles > r.query_cycles, "{}", r.bench);
            assert_eq!(r.batch, 1);
            assert!(r.per_op_cycles > 0.0 && r.floor_per_op > 0.0, "{}", r.bench);
        }
        let pts = rack_registry_points(&rack, 64, 32, 2, 5);
        assert_eq!(pts.len(), registry().len());
        for p in &pts {
            assert!(!p.bits.is_empty(), "{}: empty bit encoding", p.name);
            assert!(p.record.total_cycles >= p.record.max_shard_cycles);
        }
    }

    #[test]
    fn batched_sweep_covers_packing_kernels_and_beats_the_unbatched_floor() {
        let rack = PrinsRack::new(1);
        let recs = resident_registry_points(&rack, 64, 32, 2, 2, 4, 5, true);
        // only kernels with a batched parameter stream produce points
        let names: Vec<&str> = recs.iter().map(|r| r.bench.as_str()).collect();
        assert_eq!(names, ["ed", "search"], "batched registry points: {names:?}");
        for r in &recs {
            assert_eq!(r.batch, 4);
            assert!(
                r.per_op_cycles < r.floor_per_op,
                "{}: packed per-operand cost {} must beat the unbatched floor {}",
                r.bench,
                r.per_op_cycles,
                r.floor_per_op
            );
            assert!(
                r.cache_misses > 0,
                "{}: batched plans must flow through the program cache",
                r.bench
            );
        }
        // the same kernels at batch=1 cost strictly more per operand
        let base = resident_registry_points(&rack, 64, 32, 2, 2, 1, 5, false);
        for r in &recs {
            let b1 = base.iter().find(|b| b.bench == r.bench).unwrap();
            assert!(
                r.per_op_cycles < b1.per_op_cycles,
                "{}: per-op at B=4 ({}) must undercut B=1 ({})",
                r.bench,
                r.per_op_cycles,
                b1.per_op_cycles
            );
        }
    }

    #[test]
    fn timer_collects_samples() {
        let t = time_it("noop", 1, 5, || 1 + 1);
        assert_eq!(t.samples.len(), 5);
        assert!(t.min() <= t.mean());
        assert!(t.report().contains("noop"));
    }

    #[test]
    fn arg_parsing() {
        let args: Vec<String> = ["--rows", "4096", "--workers", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(arg_u64(&args, "--rows", 1), 4096);
        assert_eq!(arg_u64(&args, "--missing", 7), 7);
        assert_eq!(backend_from_args(&args), ExecBackend::Threaded(4));
        let one: Vec<String> = ["--workers", "1"].iter().map(|s| s.to_string()).collect();
        assert_eq!(backend_from_args(&one), ExecBackend::Serial);
        let sweep: Vec<String> = ["--workers", "1,2,8"].iter().map(|s| s.to_string()).collect();
        assert_eq!(workers_sweep_from_args(&sweep, &[4]), vec![1, 2, 8]);
        assert_eq!(workers_sweep_from_args(&[], &[1, 4]), vec![1, 4]);
        let sweep: Vec<String> = ["--shards", "1,2,4,8"].iter().map(|s| s.to_string()).collect();
        assert_eq!(shards_sweep_from_args(&sweep, &[1]), vec![1, 2, 4, 8]);
        assert_eq!(shards_sweep_from_args(&[], &[1, 2]), vec![1, 2]);
        let sweep: Vec<String> = ["--queries", "1,8"].iter().map(|s| s.to_string()).collect();
        assert_eq!(queries_sweep_from_args(&sweep, &[1, 4]), vec![1, 8]);
        assert_eq!(queries_sweep_from_args(&[], &[1, 4, 16, 64]), vec![1, 4, 16, 64]);
    }

    #[test]
    fn resident_json_shape() {
        let recs = vec![
            ResidentRecord {
                bench: "hist".into(),
                rows: 4096,
                shards: 1,
                queries: 1,
                batch: 1,
                load_cycles: 16384,
                query_cycles: 524.0,
                amortized_cycles: 16908.0,
                per_op_cycles: 524.0,
                floor_per_op: 524.0,
                cache_hits: 0,
                cache_misses: 1,
                energy_j: 1.0e-6,
                wall_s: 0.01,
            },
            ResidentRecord {
                bench: "search".into(),
                rows: 4096,
                shards: 1,
                queries: 64,
                batch: 4,
                load_cycles: 16384,
                query_cycles: 524.0,
                amortized_cycles: 780.0,
                per_op_cycles: 131.0,
                floor_per_op: 164.0,
                cache_hits: 63,
                cache_misses: 1,
                energy_j: 3.0e-6,
                wall_s: 0.4,
            },
        ];
        let s = resident_records_json(&recs);
        assert!(s.starts_with("[\n") && s.trim_end().ends_with(']'));
        assert_eq!(s.matches("\"queries\"").count(), 2);
        assert_eq!(s.matches("\"batch\"").count(), 2);
        assert_eq!(s.matches("},\n").count(), 1);
        assert!(s.contains("\"load_cycles\": 16384"));
        assert!(s.contains("\"amortized_cycles\""));
        assert!(s.contains("\"batch\": 4"));
        assert!(s.contains("\"per_op_cycles\""));
        assert!(s.contains("\"floor_per_op\""));
        assert!(s.contains("\"cache_hits\": 63"));
        assert!(s.contains("\"cache_misses\": 1"));
    }

    #[test]
    fn rack_json_shape() {
        let recs = vec![
            RackRecord {
                bench: "hist".into(),
                rows: 1 << 14,
                shards: 2,
                total_cycles: 4600,
                max_shard_cycles: 525,
                link_bytes: 4224,
                energy_j: 1.1e-6,
                wall_s: 0.02,
            },
            RackRecord {
                bench: "spmv".into(),
                rows: 256,
                shards: 8,
                total_cycles: 20_000,
                max_shard_cycles: 2_000,
                link_bytes: 1 << 16,
                energy_j: 2.0e-6,
                wall_s: 0.5,
            },
        ];
        let s = rack_records_json(&recs);
        assert!(s.starts_with("[\n") && s.trim_end().ends_with(']'));
        assert_eq!(s.matches("\"shards\"").count(), 2);
        assert_eq!(s.matches("},\n").count(), 1);
        assert!(s.contains("\"total_cycles\": 4600"));
        assert!(s.contains("\"link_bytes\": 4224"));
    }

    #[test]
    fn fidelity_json_shape_and_ber_sweep() {
        let recs = vec![
            FidelityRecord {
                bench: "hist".into(),
                rows: 256,
                queries: 4,
                ber: 0.0,
                exact_rate: 1.0,
                recovered_rate: 1.0,
                mean_rel_err: 0.0,
                injected: 0,
                detected: 0,
                repaired: 0,
                residual: 0,
                retries: 0,
                overhead_cycles: 1024,
                wall_s: 0.01,
            },
            FidelityRecord {
                bench: "hist".into(),
                rows: 256,
                queries: 4,
                ber: 1e-3,
                exact_rate: 0.25,
                recovered_rate: 0.75,
                mean_rel_err: 0.02,
                injected: 37,
                detected: 12,
                repaired: 12,
                residual: 0,
                retries: 3,
                overhead_cycles: 4096,
                wall_s: 0.02,
            },
        ];
        let s = fidelity_records_json(&recs);
        assert!(s.starts_with("[\n") && s.trim_end().ends_with(']'));
        assert_eq!(s.matches("\"ber\"").count(), 2);
        assert_eq!(s.matches("},\n").count(), 1);
        assert!(s.contains("\"recovered_rate\""));
        assert!(s.contains("\"injected\": 37"));

        let args: Vec<String> = ["--ber", "0,0.001,0.01"].iter().map(|s| s.to_string()).collect();
        assert_eq!(ber_sweep_from_args(&args, &[0.5]), vec![0.0, 0.001, 0.01]);
        assert_eq!(ber_sweep_from_args(&[], &[0.0, 0.1]), vec![0.0, 0.1]);
        // out-of-range and garbage entries fall back to the default
        let bad: Vec<String> = ["--ber", "1.5,nan,x"].iter().map(|s| s.to_string()).collect();
        assert_eq!(ber_sweep_from_args(&bad, &[0.25]), vec![0.25]);
    }

    #[test]
    fn throughput_json_shape() {
        let recs = vec![
            ThroughputRecord {
                bench: "hist".into(),
                clients: 1,
                pipeline: 1,
                mode: "exclusive".into(),
                queries: 64,
                qps: 1.2e3,
                wall_s: 0.05,
                coalesced_per_op_cycles: 0.0,
            },
            ThroughputRecord {
                bench: "search".into(),
                clients: 16,
                pipeline: 8,
                mode: "cross_session".into(),
                queries: 1024,
                qps: 9.6e3,
                wall_s: 0.1,
                coalesced_per_op_cycles: 812.5,
            },
        ];
        let s = throughput_records_json(&recs);
        assert!(s.starts_with("[\n") && s.trim_end().ends_with(']'));
        assert_eq!(s.matches("\"clients\"").count(), 2);
        assert_eq!(s.matches("},\n").count(), 1);
        assert!(s.contains("\"mode\": \"cross_session\""));
        assert!(s.contains("\"pipeline\": 8"));
        assert!(s.contains("\"qps\""));
        assert_eq!(s.matches("\"coalesced_per_op_cycles\"").count(), 2);
    }

    #[test]
    fn bench_json_shape() {
        let recs = vec![
            BenchRecord {
                bench: "compare".into(),
                rows: 1 << 20,
                workers: 4,
                ops_per_s: 3.2e9,
                wall_s: 0.001,
            },
            BenchRecord {
                bench: "pass".into(),
                rows: 1 << 20,
                workers: 1,
                ops_per_s: 1.0e9,
                wall_s: 0.004,
            },
        ];
        let s = bench_records_json(&recs);
        assert!(s.starts_with("[\n"));
        assert!(s.trim_end().ends_with(']'));
        assert_eq!(s.matches("\"bench\"").count(), 2);
        // one separator between the two objects, none after the last
        assert_eq!(s.matches("},\n").count(), 1);
        assert!(s.contains("\"rows\": 1048576"));
        assert!(s.contains("\"workers\": 4"));
    }
}
