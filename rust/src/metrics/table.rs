//! Fixed-width text tables — every figure harness prints the paper's
//! rows/series through this, so EXPERIMENTS.md and bench output agree.

/// A titled, fixed-width text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table title, printed as a `== title ==` banner.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows; every row has exactly one cell per header.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (cell count must match the headers).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as right-aligned fixed-width text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// CSV (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a speedup/ratio compactly (paper figures are log-scale).
pub fn fmt_ratio(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 1.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

/// Format engineering quantities.
pub fn fmt_si(x: f64, unit: &str) -> String {
    let (v, p) = if x >= 1e12 {
        (x / 1e12, "T")
    } else if x >= 1e9 {
        (x / 1e9, "G")
    } else if x >= 1e6 {
        (x / 1e6, "M")
    } else if x >= 1e3 {
        (x / 1e3, "k")
    } else if x >= 1.0 || x == 0.0 {
        (x, "")
    } else if x >= 1e-3 {
        (x * 1e3, "m")
    } else if x >= 1e-6 {
        (x * 1e6, "µ")
    } else {
        (x * 1e9, "n")
    };
    format!("{v:.2} {p}{unit}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ratio(1234.5), "1234");  // {:.0} uses round-half-even
        assert_eq!(fmt_ratio(3.25), "3.2");
        assert_eq!(fmt_ratio(0.5), "0.500");
        assert_eq!(fmt_si(2.5e9, "FLOPS"), "2.50 GFLOPS");
        assert_eq!(fmt_si(0.002, "s"), "2.00 ms");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
