//! `prins` command line: drive the PRINS system from a shell.
//!
//!   prins run <ed|dp|hist|spmv|bfs> [--n N] [--dims D] [--seed S]
//!             [--workers W] [--shards S] [--queries Q]
//!   prins validate            # PRINS vs golden XLA kernels (needs artifacts/)
//!   prins serve [--bind ADDR] [--workers W] # TCP storage-appliance front-end
//!                                           # (protocol: docs/PROTOCOL.md)
//!   prins report <fig12|fig13|fig14|fig15|all> [--csv]
//!   prins info                # device model + artifact inventory
//!
//! `--shards S` (2 ≤ S ≤ 64, same bound as the server's `RACK` verb)
//! runs ed/dp/hist/spmv on a [`PrinsRack`] of S shard devices with
//! cost-modeled host-side merging (DESIGN.md §Sharding) instead of one
//! device.
//!
//! `--queries Q` (Q ≥ 2) switches ed/dp/hist/spmv to the load-once /
//! query-many resident path (DESIGN.md §Resident datasets): the dataset
//! is loaded once and Q queries with fresh parameters (new centers, new
//! hyperplane, new bin edges, new x vector) run against the resident
//! rows, printing the amortization table — load cost paid once, query
//! floor per repetition.
//!
//! (Hand-rolled argument parsing; the vendored crate set has no clap.)

use crate::controller::Controller;
use crate::host::rack::{PrinsRack, RackStats};
use crate::model::figures;
use crate::rcam::{DeviceModel, ExecBackend, InterconnectModel, PrinsArray};
use crate::storage::StorageManager;
use crate::workloads::*;
use crate::error::{bail, Result};

fn flag(args: &[String], name: &str, default: u64) -> u64 {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// `--workers N` simulator backend knob: default = all cores,
/// `--workers 1` = the serial reference path. Device-model results are
/// identical either way; this only sets simulation speed.
fn backend_flag(args: &[String]) -> ExecBackend {
    crate::metrics::bench::backend_from_args(args)
}

/// CLI entry point: parse argv and dispatch to the subcommands listed in
/// the module doc (called by `src/main.rs`).
pub fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("run") => run(&args[1..]),
        Some("validate") => validate(),
        Some("serve") => serve(&args[1..]),
        Some("report") => report(&args[1..]),
        Some("info") => info(),
        _ => {
            eprintln!("usage: prins <run|validate|serve|report|info> ...");
            eprintln!(
                "  run <ed|dp|hist|spmv|bfs> [--n N] [--dims D] [--seed S] \
                 [--workers W] [--shards S] [--queries Q]"
            );
            eprintln!("  validate");
            eprintln!("  serve [--bind ADDR] [--workers W]");
            eprintln!("  report <fig12|fig13|fig14|fig15|all> [--csv] [--workers W]");
            eprintln!("  (--workers: simulator threads; default = cores, 1 = serial)");
            eprintln!("  (--shards: run ed/dp/hist/spmv on an S-device rack; default 1)");
            eprintln!(
                "  (--queries: load once, run Q queries against the resident \
                 dataset; default 1)"
            );
            Ok(())
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let n = flag(args, "--n", 1024) as usize;
    let dims = flag(args, "--dims", 8) as usize;
    let seed = flag(args, "--seed", 1);
    let shards = flag(args, "--shards", 1) as usize;
    if !(1..=crate::rcam::shard::MAX_SHARDS).contains(&shards) {
        bail!(
            "--shards out of range (1..={})",
            crate::rcam::shard::MAX_SHARDS
        );
    }
    let queries = flag(args, "--queries", 1) as usize;
    if queries == 0 {
        bail!("--queries must be at least 1");
    }
    let backend = backend_flag(args);
    let dev = DeviceModel::default();
    let rack = || {
        PrinsRack::with_config(
            shards,
            DeviceModel::default(),
            backend,
            InterconnectModel::default(),
        )
    };
    if queries > 1 {
        return run_resident(args, n, dims, seed, queries, &rack(), &dev);
    }
    match args.first().map(|s| s.as_str()) {
        Some("ed") => {
            let x = synth_samples(n, dims, 4, seed);
            let c = synth_uniform(dims, seed + 1);
            if shards > 1 {
                let res = crate::algorithms::euclidean_sharded(&rack(), &x, n, dims, &c, 1, 5);
                print_rack_stats("euclidean distance", &res.rack, &dev);
                println!("nearest      : {:?}", res.nearest[0]);
                return Ok(());
            }
            let layout = crate::algorithms::euclidean::EuclideanLayout::new(dims);
            let mut array =
                PrinsArray::single(n, layout.width as usize).with_backend(backend);
            let mut sm = StorageManager::new(n);
            let kern = crate::algorithms::EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
            let mut ctl = Controller::new(array);
            let res = kern.run(&mut ctl, &sm, &c, 1);
            print_stats("euclidean distance", &res.stats, &dev, 3.0 * (n * dims) as f64);
        }
        Some("dp") => {
            let x = synth_samples(n, dims, 4, seed);
            let h = synth_uniform(dims, seed + 1);
            if shards > 1 {
                let res = crate::algorithms::dot_sharded(&rack(), &x, n, dims, &h);
                print_rack_stats("dot product", &res.rack, &dev);
                println!("checksum     : {:.4}", res.checksum);
                return Ok(());
            }
            let layout = crate::algorithms::dot::DotLayout::new(dims);
            let mut array =
                PrinsArray::single(n, layout.width as usize).with_backend(backend);
            let mut sm = StorageManager::new(n);
            let kern = crate::algorithms::DotKernel::load(&mut sm, &mut array, &x, n, dims);
            let mut ctl = Controller::new(array);
            let res = kern.run(&mut ctl, &sm, &h);
            print_stats("dot product", &res.stats, &dev, 2.0 * (n * dims) as f64);
        }
        Some("hist") => {
            let xs = synth_hist_samples(n, seed);
            if shards > 1 {
                let res = crate::algorithms::histogram_sharded(&rack(), &xs);
                print_rack_stats("histogram (256 bins)", &res.rack, &dev);
                let top = res.hist.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
                println!("top bin      : {top} ({} samples)", res.hist[top]);
                return Ok(());
            }
            let mut array = PrinsArray::single(n, 40).with_backend(backend);
            let mut sm = StorageManager::new(n);
            let kern = crate::algorithms::HistogramKernel::load(&mut sm, &mut array, &xs);
            let mut ctl = Controller::new(array);
            let res = kern.run(&mut ctl);
            print_stats("histogram (256 bins)", &res.stats, &dev, 2.0 * n as f64);
        }
        Some("spmv") => {
            let a = synth_csr(n, n * 8, seed);
            let mut rng = Rng::seed_from(seed + 1);
            let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            if shards > 1 {
                let res = crate::algorithms::spmv_sharded(&rack(), &a, &x);
                print_rack_stats("spmv", &res.rack, &dev);
                println!("checksum     : {:.4}", res.checksum);
                return Ok(());
            }
            let res = crate::algorithms::spmv_single(&a, &x, backend);
            println!(
                "phases: broadcast {} + multiply {} + reduce {} cycles",
                res.broadcast_cycles, res.multiply_cycles, res.reduce_cycles
            );
            print_stats("spmv", &res.stats, &dev, 2.0 * a.nnz() as f64);
        }
        Some("bfs") => {
            if shards > 1 {
                bail!("bfs has no sharded variant yet (the frontier is global state)");
            }
            let g = synth_power_law(n, (dims as f64).max(2.0), 2.5, seed);
            let mut array = PrinsArray::single(g.edges(), 128).with_backend(backend);
            let mut sm = StorageManager::new(g.edges());
            let kern = crate::algorithms::BfsKernel::load(&mut sm, &mut array, &g);
            let mut ctl = Controller::new(array);
            let res = kern.run(&mut ctl, 0);
            println!(
                "levels {} iterations {} reached {}",
                res.levels,
                res.iterations,
                res.dist.iter().filter(|&&d| d != u32::MAX).count()
            );
            print_stats("bfs", &res.stats, &dev, res.iterations as f64);
        }
        other => bail!("unknown kernel {other:?}"),
    }
    Ok(())
}

/// `run --queries Q` (Q ≥ 2): the load-once / query-many resident path.
/// Loads the dataset onto the rack once, runs Q queries with fresh
/// parameters per query (new centers / hyperplane / bin edges / x
/// vector), and prints the amortization table.
fn run_resident(
    args: &[String],
    n: usize,
    dims: usize,
    seed: u64,
    queries: usize,
    rack: &PrinsRack,
    dev: &DeviceModel,
) -> Result<()> {
    use crate::algorithms::{ResidentDot, ResidentEuclidean, ResidentHistogram, ResidentSpmv};
    let mut qcycles = Vec::with_capacity(queries);
    let (name, load, energy_j, summary): (&str, RackStats, f64, String) =
        match args.first().map(|s| s.as_str()) {
            Some("ed") => {
                let x = synth_samples(n, dims, 4, seed);
                let mut res = ResidentEuclidean::load(rack, &x, n, dims);
                let mut energy = res.load_report().energy_j;
                let mut checksum = 0.0f32;
                for q in 0..queries {
                    let c = synth_uniform(dims, seed + 1 + q as u64);
                    let r = res.query(&c, 1, 5);
                    qcycles.push(r.rack.total_cycles);
                    energy += r.rack.energy_j;
                    checksum = r.checksum;
                }
                let load = res.load_report().clone();
                ("euclidean distance", load, energy, format!("checksum(last): {checksum:.4}"))
            }
            Some("dp") => {
                let x = synth_samples(n, dims, 4, seed);
                let mut res = ResidentDot::load(rack, &x, n, dims);
                let mut energy = res.load_report().energy_j;
                let mut checksum = 0.0f32;
                for q in 0..queries {
                    let h = synth_uniform(dims, seed + 1 + q as u64);
                    let r = res.query(&h);
                    qcycles.push(r.rack.total_cycles);
                    energy += r.rack.energy_j;
                    checksum = r.checksum;
                }
                let load = res.load_report().clone();
                ("dot product", load, energy, format!("checksum(last): {checksum:.4}"))
            }
            Some("hist") => {
                let xs = synth_hist_samples(n, seed);
                let mut res = ResidentHistogram::load(rack, &xs);
                let mut energy = res.load_report().energy_j;
                let mut top = 0usize;
                for q in 0..queries {
                    // rotate the bin window: fresh bin edges per query
                    let lo = [24u16, 16, 8, 0][q % 4];
                    let r = res.query_at(lo);
                    qcycles.push(r.rack.total_cycles);
                    energy += r.rack.energy_j;
                    top = r.hist.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
                }
                let load = res.load_report().clone();
                ("histogram (256 bins)", load, energy, format!("top bin (last): {top}"))
            }
            Some("spmv") => {
                let a = synth_csr(n, n * 8, seed);
                let mut res = ResidentSpmv::load(rack, &a);
                let mut energy = res.load_report().energy_j;
                let mut checksum = 0.0f32;
                for q in 0..queries {
                    let mut rng = Rng::seed_from(seed + 1 + q as u64);
                    let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
                    let r = res.query(&x);
                    qcycles.push(r.rack.total_cycles);
                    energy += r.rack.energy_j;
                    checksum = r.checksum;
                }
                let load = res.load_report().clone();
                ("spmv", load, energy, format!("checksum(last): {checksum:.4}"))
            }
            Some("bfs") => bail!("bfs has no resident query path yet (the frontier mutates storage)"),
            other => bail!("unknown kernel {other:?}"),
        };
    let qsum: u64 = qcycles.iter().sum();
    let per_query = qsum as f64 / queries as f64;
    let amortized = (load.total_cycles + qsum) as f64 / queries as f64;
    println!(
        "kernel       : {name} [resident, {} queries, {} shard(s)]",
        queries, load.shards
    );
    println!(
        "load phase   : {} cycles, {} link bytes (paid once)",
        load.total_cycles, load.link_bytes
    );
    println!("query phase  : {per_query:.1} cycles/query");
    println!(
        "amortized    : {amortized:.1} cycles/query ({} at Q=1, {})",
        load.total_cycles + qcycles[0],
        crate::metrics::table::fmt_si(dev.cycles_to_seconds(amortized as u64), "s")
    );
    println!(
        "energy       : {} total (load + {} queries)",
        crate::metrics::table::fmt_si(energy_j, "J"),
        queries
    );
    println!("{summary}");
    Ok(())
}

/// Print rack-level stats for a sharded `run` (`--shards S`): the
/// slowest-shard critical path, the host-link charge, and the merged
/// totals (DESIGN.md §Sharding accounting).
fn print_rack_stats(name: &str, rs: &RackStats, dev: &DeviceModel) {
    println!("kernel       : {name} [rack of {} shards]", rs.shards);
    println!(
        "shard cycles : max {} (per shard {:?})",
        rs.max_shard_cycles,
        rs.shard_cycles()
    );
    println!(
        "host link    : {} msgs, {} bytes, {} cycles",
        rs.link_messages, rs.link_bytes, rs.link_cycles
    );
    println!(
        "total cycles : {} ({})",
        rs.total_cycles,
        crate::metrics::table::fmt_si(rs.runtime_s(dev), "s")
    );
    println!(
        "energy       : {} (link {})",
        crate::metrics::table::fmt_si(rs.energy_j, "J"),
        crate::metrics::table::fmt_si(rs.link_energy_j, "J")
    );
}

fn print_stats(name: &str, stats: &crate::controller::ExecStats, dev: &DeviceModel, flops: f64) {
    let eff = crate::model::power::efficiency(stats, dev, flops);
    println!("kernel       : {name}");
    println!("device cycles: {} ({})", stats.cycles,
        crate::metrics::table::fmt_si(eff.runtime_s, "s"));
    println!("passes       : {}", stats.passes);
    println!("throughput   : {}", crate::metrics::table::fmt_si(eff.gflops * 1e9, "FLOPS"));
    println!("energy       : {}", crate::metrics::table::fmt_si(eff.energy_j, "J"));
    println!("efficiency   : {:.2} GFLOPS/W", eff.gflops_per_w);
}

fn validate() -> Result<()> {
    use crate::runtime::Golden;
    let mut g = Golden::open_default()?;
    let (n, dims) = (512usize, 8usize);
    let x = synth_samples(n, dims, 4, 3);
    let c = synth_uniform(dims, 4);
    let layout = crate::algorithms::euclidean::EuclideanLayout::new(dims);
    let mut array = PrinsArray::single(n, layout.width as usize);
    let mut sm = StorageManager::new(n);
    let kern = crate::algorithms::EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
    let mut ctl = Controller::new(array);
    let res = kern.run(&mut ctl, &sm, &c, 1);
    let gd = g.euclidean(&x, n, dims, &c)?;
    let mut max_rel = 0f32;
    for i in 0..n {
        max_rel = max_rel.max((res.dists[0][i] - gd[i]).abs() / gd[i].abs().max(1.0));
    }
    println!("ED   : PRINS vs golden XLA kernel, max rel err {max_rel:.2e}");
    if max_rel >= 1e-4 {
        bail!("ED validation failed");
    }
    let xs = synth_hist_samples(20_000, 5);
    let mut array = PrinsArray::single(xs.len(), 40);
    let mut sm = StorageManager::new(xs.len());
    let kern = crate::algorithms::HistogramKernel::load(&mut sm, &mut array, &xs);
    let mut ctl = Controller::new(array);
    let got = kern.run(&mut ctl).hist;
    let gold = g.histogram(&xs)?;
    if got.iter().zip(&gold).any(|(&a, &b)| a as i64 != b as i64) {
        bail!("histogram validation failed");
    }
    println!("Hist : PRINS vs golden XLA kernel, exact match over 20k samples");
    println!("validate: OK");
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    let bind = args
        .iter()
        .position(|a| a == "--bind")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "127.0.0.1:7411".to_string());
    let backend = backend_flag(args);
    let server = crate::host::server::Server::spawn_with(&bind, backend)?;
    println!("prins storage appliance listening on {}", server.addr);
    println!("simulator backend: {backend:?}");
    println!(
        "protocol: PING | RACK [n] | LOAD kind ... | DATASETS | DROP id | \
         HIST n seed | DP n dims seed | ED n dims k seed | SPMV n nnz seed \
         | HIST id | DP id seed | ED id k seed | SPMV id seed | QUIT  \
         (spec: docs/PROTOCOL.md)"
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn report(args: &[String]) -> Result<()> {
    let csv = args.iter().any(|a| a == "--csv");
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    let be = backend_flag(args);
    let mut tables = Vec::new();
    match which {
        "fig12" => tables.push(figures::fig12_on(figures::DIMS, 512, be)),
        "fig13" => tables.push(figures::fig13_on(1200, be)),
        "fig14" => tables.push(figures::fig14_on(1 << 10, be)),
        "fig15" => tables.push(figures::fig15()),
        "all" => {
            tables.push(figures::fig12_on(figures::DIMS, 512, be));
            tables.push(figures::fig13_on(1200, be));
            tables.push(figures::fig14_on(1 << 10, be));
            tables.push(figures::fig15());
        }
        other => bail!("unknown report {other:?}"),
    }
    for t in tables {
        if csv {
            println!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
    }
    Ok(())
}

fn info() -> Result<()> {
    let dev = DeviceModel::default();
    println!("PRINS device model:");
    println!("  technology : {}", dev.technology);
    println!("  frequency  : {} MHz", dev.freq_hz / 1e6);
    println!("  E(compare) : {} fJ/bit", dev.e_compare_bit * 1e15);
    println!("  E(write)   : {} fJ/bit", dev.e_write_bit * 1e15);
    println!("  endurance  : {:.0e} writes", dev.endurance);
    match crate::runtime::Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts ({}):", rt.platform());
            for (name, ep) in &rt.manifest.entry_points {
                println!("  {name:<20} {} arg(s), {} output(s)", ep.args.len(), ep.outputs);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#})"),
    }
    Ok(())
}
