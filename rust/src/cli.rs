//! `prins` command line: drive the PRINS system from a shell.
//!
//!   prins run <kernel|bfs> [--n N] [--dims D] [--seed S]
//!             [--workers W] [--shards S] [--queries Q] [--batch B]
//!             [--ber B] [--fault-seed S] [--stuck N]
//!   prins validate            # PRINS vs golden XLA kernels (needs artifacts/)
//!   prins serve [--bind ADDR] [--workers W] [--pool N] [--no-shared]
//!             # TCP storage-appliance front-end (protocol: docs/PROTOCOL.md);
//!             # --pool sizes the serving worker pool, --no-shared disables
//!             # shared-read admission (serialize every request per connection)
//!   prins report <fig12|fig13|fig14|fig15|all> [--csv]
//!   prins verify [kernel|all] [--json]  # static microprogram analyzer
//!                                       # (DESIGN.md §Static verification)
//!   prins info                # device model + artifact inventory
//!
//! `run` is **registry-driven** (DESIGN.md §Kernel framework): every
//! kernel in [`crate::algorithms::kernel::registry`] — ed, dp, hist,
//! spmv, search, and whatever is registered next — is runnable by name
//! with zero per-kernel code here. BFS is the one special case: it is
//! not in the registry because its query writes the frontier back into
//! the resident rows (see the bail messages below).
//!
//! `--shards S` (2 ≤ S ≤ 64, same bound as the server's `RACK` verb)
//! runs the kernel on a [`PrinsRack`] of S shard devices with
//! cost-modeled host-side merging (DESIGN.md §Sharding) instead of one
//! device.
//!
//! `--queries Q` (Q ≥ 2) switches to the load-once / query-many
//! resident path (DESIGN.md §Resident datasets): the dataset is loaded
//! once and Q queries with fresh parameters (new centers, new
//! hyperplane, new bin edges, new x vector, new search range) run
//! against the resident rows, printing the amortization table — load
//! cost paid once, query floor per repetition.
//!
//! `--batch B` (B ≥ 2) packs B operands into every query's in-array
//! sweep (DESIGN.md §Batching & program cache): each seeded query
//! carries B search ranges / B centers, and the table gains a
//! per-operand line plus the analytic unbatched floor the packing
//! beats. Only kernels with a batched parameter stream (search, ed)
//! accept the flag; the rest refuse with a clean error.
//!
//! `--ber B` / `--fault-seed S` / `--stuck N` turn on the seeded fault
//! layer (DESIGN.md §Reliability): every read draws a bit flip with
//! probability B, N random cells are stuck, and the scrub/retry
//! recovery path runs after each query. The reply gains a fidelity
//! line; results at `--ber 0` are bit-identical to the ideal path.
//!
//! (Hand-rolled argument parsing; the vendored crate set has no clap.)

use crate::algorithms::kernel::{self, KernelEntry, ResidentDyn};
use crate::controller::Controller;
use crate::error::{bail, Result};
use crate::host::rack::{PrinsRack, RackStats};
use crate::model::figures;
use crate::rcam::{DeviceModel, ExecBackend, InterconnectModel, PrinsArray};
use crate::reliability::FaultModel;
use crate::storage::StorageManager;
use crate::workloads::*;

fn flag(args: &[String], name: &str, default: u64) -> u64 {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn flag_f64(args: &[String], name: &str, default: f64) -> f64 {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// `--ber` / `--fault-seed` / `--stuck` parsed into a [`FaultModel`],
/// or `None` when none of the three flags appears (the ideal path —
/// zero overhead, bit-identical to every prior release).
fn fault_flags(args: &[String], seed: u64) -> Option<FaultModel> {
    let requested = args
        .iter()
        .any(|a| a == "--ber" || a == "--fault-seed" || a == "--stuck");
    if !requested {
        return None;
    }
    let ber = flag_f64(args, "--ber", 0.0);
    let fault_seed = flag(args, "--fault-seed", seed);
    let stuck = flag(args, "--stuck", 0) as usize;
    Some(FaultModel::uniform(ber, fault_seed).with_random_stuck(stuck))
}

/// `--workers N` simulator backend knob: default = all cores,
/// `--workers 1` = the serial reference path. Device-model results are
/// identical either way; this only sets simulation speed.
fn backend_flag(args: &[String]) -> ExecBackend {
    crate::metrics::bench::backend_from_args(args)
}

/// CLI entry point: parse argv and dispatch to the subcommands listed in
/// the module doc (called by `src/main.rs`).
pub fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("run") => run(&args[1..]),
        Some("validate") => validate(),
        Some("serve") => serve(&args[1..]),
        Some("report") => report(&args[1..]),
        Some("verify") => verify(&args[1..]),
        Some("info") => info(),
        _ => {
            let names: Vec<&str> = kernel::registry().iter().map(|e| e.name).collect();
            eprintln!("usage: prins <run|validate|serve|report|verify|info> ...");
            eprintln!(
                "  run <{}|bfs> [--n N] [--dims D] [--seed S] \
                 [--workers W] [--shards S] [--queries Q] [--batch B] \
                 [--ber B] [--fault-seed S] [--stuck N]",
                names.join("|")
            );
            eprintln!("  validate");
            eprintln!("  serve [--bind ADDR] [--workers W] [--pool N] [--no-shared]");
            eprintln!("  report <fig12|fig13|fig14|fig15|all> [--csv] [--workers W]");
            eprintln!(
                "  verify [<{}>|all] [--json]  (static analyzer over synthesized \
                 query programs)",
                names.join("|")
            );
            eprintln!("  (--workers: simulator threads; default = cores, 1 = serial)");
            eprintln!("  (--shards: run any registered kernel on an S-device rack; default 1)");
            eprintln!(
                "  (--queries: load once, run Q queries against the resident \
                 dataset; default 1)"
            );
            eprintln!(
                "  (--batch: pack B operands into each query's sweep; \
                 search/ed only, default 1)"
            );
            eprintln!(
                "  (--ber/--fault-seed/--stuck: seeded fault injection with \
                 scrub/retry recovery; default off)"
            );
            Ok(())
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let n = flag(args, "--n", 1024) as usize;
    let dims = flag(args, "--dims", 8) as usize;
    let seed = flag(args, "--seed", 1);
    let shards = flag(args, "--shards", 1) as usize;
    if !(1..=crate::rcam::shard::MAX_SHARDS).contains(&shards) {
        bail!(
            "--shards out of range (1..={})",
            crate::rcam::shard::MAX_SHARDS
        );
    }
    let queries = flag(args, "--queries", 1) as usize;
    if queries == 0 {
        bail!("--queries must be at least 1");
    }
    let batch = flag(args, "--batch", 1) as usize;
    if batch == 0 {
        bail!("--batch must be at least 1");
    }
    let backend = backend_flag(args);
    let fault = fault_flags(args, seed);
    let dev = DeviceModel::default();
    let name = args.first().map(|s| s.as_str()).unwrap_or("");

    // BFS is deliberately outside the kernel registry: its query writes
    // the frontier back into the resident rows, which breaks both
    // framework contracts the flags below rely on.
    if name == "bfs" {
        if fault.is_some() {
            bail!(
                "bfs does not support fault injection: it runs outside the kernel \
                 framework, so the scrub/retry recovery path (which needs the \
                 framework's resident-column contract) cannot protect it"
            );
        }
        if shards > 1 {
            bail!(
                "bfs cannot run sharded: it lacks the framework's read-only-query \
                 contract — its query performs frontier write-back (each expansion \
                 rewrites visited/visited_from/dist fields of the successor's rows), \
                 and a shard-local frontier cannot see successors resident on other \
                 shards, so no registry merge operator applies; run bfs with --shards 1"
            );
        }
        if queries > 1 {
            bail!(
                "bfs cannot run resident (load-once/query-many): it lacks the \
                 framework's write-free-query capability — frontier write-back \
                 mutates the resident rows, so query #2 would start from query #1's \
                 visited/dist state instead of a fresh graph; run bfs with --queries 1"
            );
        }
        if batch > 1 {
            bail!("bfs has no batched query form; run bfs without --batch");
        }
        let g = synth_power_law(n, (dims as f64).max(2.0), 2.5, seed);
        let mut array = PrinsArray::single(g.edges(), 128).with_backend(backend);
        let mut sm = StorageManager::new(g.edges());
        let kern = crate::algorithms::BfsKernel::load(&mut sm, &mut array, &g);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, 0);
        println!(
            "levels {} iterations {} reached {}",
            res.levels,
            res.iterations,
            res.dist.iter().filter(|&&d| d != u32::MAX).count()
        );
        print_stats("bfs", &res.stats, &dev, res.iterations as f64);
        return Ok(());
    }

    // every other kernel comes from the registry — zero per-kernel code
    let Some(entry) = kernel::find_name(name) else {
        let names: Vec<&str> = kernel::registry().iter().map(|e| e.name).collect();
        bail!(
            "unknown kernel {name:?} (registered: {}, plus bfs)",
            names.join(", ")
        );
    };
    let mut rack = PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    );
    if let Some(model) = fault {
        rack = rack.with_fault(model)?;
    }
    let mut res = (entry.synth_load)(&rack, n, dims, seed);
    if queries > 1 || batch > 1 {
        return run_resident(entry, res.as_mut(), queries, seed, batch, &dev);
    }
    let out = res.query_seeded(0, seed);
    if shards > 1 {
        print_rack_stats(entry.name, &out.rack, &dev);
    } else {
        print_stats(
            entry.name,
            &out.rack.shard_stats[0],
            &dev,
            (entry.flops)(n, dims),
        );
    }
    print_fidelity(&out.fidelity);
    println!("result       : {}", out.fields);
    Ok(())
}

/// One-line fidelity summary when the fault layer is on (no-op on the
/// ideal path, keeping default output byte-identical).
fn print_fidelity(fid: &Option<crate::reliability::FidelityReport>) {
    let Some(f) = fid else { return };
    println!(
        "fidelity     : {:.6} (injected {}, detected {}, repaired {}, residual {}, \
         retries {}, overhead {} cycles)",
        f.fidelity, f.injected, f.detected, f.repaired, f.residual, f.retries, f.overhead_cycles
    );
}

/// `run --queries Q` / `--batch B`: the load-once / query-many resident
/// path, generic over the registry. The dataset is already loaded; run
/// Q queries with fresh parameters per query (the kernel's seeded
/// parameter stream, B operands per query when batched) and print the
/// amortization table.
fn run_resident(
    entry: &KernelEntry,
    res: &mut dyn ResidentDyn,
    queries: usize,
    seed: u64,
    batch: usize,
    dev: &DeviceModel,
) -> Result<()> {
    let load: RackStats = res.load_report().clone();
    let mut energy_j = load.energy_j;
    let mut qcycles = Vec::with_capacity(queries);
    let mut last_fields = String::new();
    let mut last_fid = None;
    for q in 0..queries {
        let r = if batch > 1 {
            match res.query_seeded_batch(q, seed, batch) {
                Some(r) => r,
                None => bail!(
                    "{} has no batched parameter stream; drop --batch (batching \
                     is available for kernels with a packed-operand program, \
                     e.g. search and ed)",
                    entry.name
                ),
            }
        } else {
            res.query_seeded(q, seed)
        };
        qcycles.push(r.rack.total_cycles);
        energy_j += r.rack.energy_j;
        last_fields = r.fields;
        last_fid = r.fidelity;
    }
    let name = entry.name;
    let summary = format!("result (last): {last_fields}");
    let qsum: u64 = qcycles.iter().sum();
    let per_query = qsum as f64 / queries as f64;
    let amortized = (load.total_cycles + qsum) as f64 / queries as f64;
    println!(
        "kernel       : {name} [resident, {} queries, {} shard(s)]",
        queries, load.shards
    );
    println!(
        "load phase   : {} cycles, {} link bytes (paid once)",
        load.total_cycles, load.link_bytes
    );
    println!("query phase  : {per_query:.1} cycles/query");
    if batch > 1 {
        // per-operand price of the packed sweep vs the analytic floor
        // of running the same operands one query each
        println!(
            "batching     : {batch} operands/query, {:.1} cycles/operand",
            per_query / batch as f64
        );
        if let Some(unbatched) = res.query_floor_seeded_batch(0, seed, batch) {
            println!(
                "unbatched    : {} device cycles/query if each operand ran alone",
                unbatched
            );
        }
    }
    let (hits, misses) = res.cache_stats();
    println!("plan cache   : {hits} hit(s), {misses} miss(es)");
    println!(
        "amortized    : {amortized:.1} cycles/query ({} at Q=1, {})",
        load.total_cycles + qcycles[0],
        crate::metrics::table::fmt_si(dev.cycles_to_seconds(amortized as u64), "s")
    );
    println!(
        "energy       : {} total (load + {} queries)",
        crate::metrics::table::fmt_si(energy_j, "J"),
        queries
    );
    print_fidelity(&last_fid);
    println!("{summary}");
    Ok(())
}

/// Print rack-level stats for a sharded `run` (`--shards S`): the
/// slowest-shard critical path, the host-link charge, and the merged
/// totals (DESIGN.md §Sharding accounting).
fn print_rack_stats(name: &str, rs: &RackStats, dev: &DeviceModel) {
    println!("kernel       : {name} [rack of {} shards]", rs.shards);
    println!(
        "shard cycles : max {} (per shard {:?})",
        rs.max_shard_cycles,
        rs.shard_cycles()
    );
    println!(
        "host link    : {} msgs, {} bytes, {} cycles",
        rs.link_messages, rs.link_bytes, rs.link_cycles
    );
    println!(
        "total cycles : {} ({})",
        rs.total_cycles,
        crate::metrics::table::fmt_si(rs.runtime_s(dev), "s")
    );
    println!(
        "energy       : {} (link {})",
        crate::metrics::table::fmt_si(rs.energy_j, "J"),
        crate::metrics::table::fmt_si(rs.link_energy_j, "J")
    );
}

fn print_stats(name: &str, stats: &crate::controller::ExecStats, dev: &DeviceModel, flops: f64) {
    let eff = crate::model::power::efficiency(stats, dev, flops);
    println!("kernel       : {name}");
    println!("device cycles: {} ({})", stats.cycles,
        crate::metrics::table::fmt_si(eff.runtime_s, "s"));
    println!("passes       : {}", stats.passes);
    println!("throughput   : {}", crate::metrics::table::fmt_si(eff.gflops * 1e9, "FLOPS"));
    println!("energy       : {}", crate::metrics::table::fmt_si(eff.energy_j, "J"));
    println!("efficiency   : {:.2} GFLOPS/W", eff.gflops_per_w);
}

fn validate() -> Result<()> {
    use crate::runtime::Golden;
    let mut g = Golden::open_default()?;
    let (n, dims) = (512usize, 8usize);
    let x = synth_samples(n, dims, 4, 3);
    let c = synth_uniform(dims, 4);
    let layout = crate::algorithms::euclidean::EuclideanLayout::new(dims);
    let mut array = PrinsArray::single(n, layout.width as usize);
    let mut sm = StorageManager::new(n);
    let kern = crate::algorithms::EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
    let mut ctl = Controller::new(array);
    let res = kern.run(&mut ctl, &sm, &c, 1);
    let gd = g.euclidean(&x, n, dims, &c)?;
    let mut max_rel = 0f32;
    for i in 0..n {
        max_rel = max_rel.max((res.dists[0][i] - gd[i]).abs() / gd[i].abs().max(1.0));
    }
    println!("ED   : PRINS vs golden XLA kernel, max rel err {max_rel:.2e}");
    if max_rel >= 1e-4 {
        bail!("ED validation failed");
    }
    let xs = synth_hist_samples(20_000, 5);
    let mut array = PrinsArray::single(xs.len(), 40);
    let mut sm = StorageManager::new(xs.len());
    let kern = crate::algorithms::HistogramKernel::load(&mut sm, &mut array, &xs);
    let mut ctl = Controller::new(array);
    let got = kern.run(&mut ctl).hist;
    let gold = g.histogram(&xs)?;
    if got.iter().zip(&gold).any(|(&a, &b)| a as i64 != b as i64) {
        bail!("histogram validation failed");
    }
    println!("Hist : PRINS vs golden XLA kernel, exact match over 20k samples");
    println!("validate: OK");
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    let bind = args
        .iter()
        .position(|a| a == "--bind")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "127.0.0.1:7411".to_string());
    let backend = backend_flag(args);
    let mut opts = crate::host::server::ServeOptions {
        backend,
        ..Default::default()
    };
    // --pool N: serving worker threads (distinct from --workers, the
    // per-request simulator backend knob); --no-shared: serialize every
    // request per connection (the exclusive-access baseline)
    opts.workers = flag(args, "--pool", opts.workers as u64) as usize;
    opts.shared_read = !args.iter().any(|a| a == "--no-shared");
    let server = crate::host::server::Server::spawn_opts(&bind, opts)?;
    println!("prins storage appliance listening on {}", server.addr);
    println!(
        "simulator backend: {backend:?} | serving pool: {} worker(s) | shared reads: {}",
        opts.workers,
        if opts.shared_read { "on" } else { "off" }
    );
    let one_shots: Vec<&str> = kernel::registry().iter().map(|e| e.one_shot_usage).collect();
    let queries: Vec<&str> = kernel::registry().iter().map(|e| e.query_usage).collect();
    println!(
        "protocol: PING | RACK [n] | LOAD kind ... | DATASETS | DROP id | {} | {} | QUIT  \
         (spec: docs/PROTOCOL.md)",
        one_shots.join(" | "),
        queries.join(" | ")
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn report(args: &[String]) -> Result<()> {
    let csv = args.iter().any(|a| a == "--csv");
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    let be = backend_flag(args);
    let mut tables = Vec::new();
    match which {
        "fig12" => tables.push(figures::fig12_on(figures::DIMS, 512, be)),
        "fig13" => tables.push(figures::fig13_on(1200, be)),
        "fig14" => tables.push(figures::fig14_on(1 << 10, be)),
        "fig15" => tables.push(figures::fig15()),
        "all" => {
            tables.push(figures::fig12_on(figures::DIMS, 512, be));
            tables.push(figures::fig13_on(1200, be));
            tables.push(figures::fig14_on(1 << 10, be));
            tables.push(figures::fig15());
        }
        other => bail!("unknown report {other:?}"),
    }
    for t in tables {
        if csv {
            println!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
    }
    Ok(())
}

/// `prins verify [kernel|all] [--json]`: run the static microprogram
/// analyzer (`crate::analysis`) over every registered kernel's
/// synthesized query plans — or one kernel's — across the seeded shape
/// grid, without executing a single query. `--json` prints the
/// machine-readable report the CI gate parses; either way the process
/// exits nonzero if any diagnostic fired.
fn verify(args: &[String]) -> Result<()> {
    let json = args.iter().any(|a| a == "--json");
    let target = args
        .iter()
        .map(|s| s.as_str())
        .find(|a| !a.starts_with("--"))
        .unwrap_or("all");
    let reports = if target == "all" {
        crate::analysis::verify_registry()
    } else {
        let Some(entry) = kernel::find(target) else {
            let names: Vec<&str> = kernel::registry().iter().map(|e| e.name).collect();
            bail!("unknown kernel {target:?} (registered: {})", names.join(", "));
        };
        vec![crate::analysis::verify_kernel(entry)]
    };
    let total: usize = reports.iter().map(|r| r.diagnostics.len()).sum();
    if json {
        println!("{}", crate::analysis::reports_json(&reports));
    } else {
        for r in &reports {
            println!(
                "{:<8} {} shapes, {} programs, {} instructions: {}",
                r.kernel,
                r.shapes,
                r.checked_programs,
                r.checked_instructions,
                if r.is_clean() {
                    "clean".to_string()
                } else {
                    format!("{} diagnostic(s)", r.diagnostics.len())
                }
            );
            for (ctx, d) in &r.diagnostics {
                println!("  [{ctx}] {d}");
            }
        }
    }
    if total > 0 {
        bail!("verify failed: {total} diagnostic(s) across {} kernel(s)", reports.len());
    }
    Ok(())
}

fn info() -> Result<()> {
    let dev = DeviceModel::default();
    println!("PRINS device model:");
    println!("  technology : {}", dev.technology);
    println!("  frequency  : {} MHz", dev.freq_hz / 1e6);
    println!("  E(compare) : {} fJ/bit", dev.e_compare_bit * 1e15);
    println!("  E(write)   : {} fJ/bit", dev.e_write_bit * 1e15);
    println!("  endurance  : {:.0e} writes", dev.endurance);
    match crate::runtime::Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts ({}):", rt.platform());
            for (name, ep) in &rt.manifest.entry_points {
                println!("  {name:<20} {} arg(s), {} output(s)", ep.args.len(), ep.outputs);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#})"),
    }
    Ok(())
}
