//! Multi-device PRINS rack (DESIGN.md §Sharding): N shard devices driven
//! concurrently, with host-side result merging under an explicit
//! interconnect cost model.
//!
//! The paper's scaling claim — compute capacity grows with storage size
//! because every RCAM array is a processor — only materializes as a rack
//! of SSD-resident devices. [`PrinsRack`] is the host's view of such a
//! rack: it owns the shared shard configuration (device model, simulator
//! backend, [`InterconnectModel`]), executes one closure per shard
//! concurrently ([`PrinsRack::run_shards`], one OS thread per shard, each
//! shard free to use the PR-2 threaded array backend underneath), and
//! folds per-shard [`ExecStats`] plus host-link message sizes into a
//! [`RackStats`] whose cycle/energy figures stay methodologically honest:
//!
//!   * rack kernel time = the **slowest shard** (shards run in parallel)
//!     plus **serialized host-link transfers** (one shared link);
//!   * rack energy = Σ per-shard device energy (each shard has its own
//!     controller) plus per-byte link energy.
//!
//! The per-workload sharded entry points (`histogram_sharded`,
//! `dot_sharded`, `euclidean_sharded`, `spmv_sharded`) live in
//! [`crate::algorithms`] next to their single-device twins and are
//! asserted bit-identical to them by `tests/prop_sharded_equals_single`.
//!
//! Racks are also the substrate of **resident datasets** (DESIGN.md
//! §Resident datasets): the `Resident*` wrappers in [`crate::algorithms`]
//! load a dataset onto the rack once via [`PrinsRack::run_shards`] and
//! then serve arbitrarily many queries via [`PrinsRack::query_shards`],
//! which revisits the per-shard controllers/kernels kept alive across
//! calls — the merge path and stats accounting are unchanged.

use crate::controller::ExecStats;
use crate::error::Result;
use crate::rcam::shard::{ShardPlan, CMD_BYTES};
use crate::rcam::{DeviceModel, ExecBackend, InterconnectModel, PrinsArray};
use crate::reliability::FaultModel;
use std::ops::Range;

/// Host view of a rack of PRINS shard devices: shared configuration plus
/// the concurrent shard executor and the stats-merging rules.
#[derive(Clone, Debug)]
pub struct PrinsRack {
    shards: usize,
    device: DeviceModel,
    backend: ExecBackend,
    fault: Option<FaultModel>,
    /// Host-link cost model applied to every command/result message.
    pub interconnect: InterconnectModel,
}

impl PrinsRack {
    /// A rack of `shards` devices with the default device model, serial
    /// simulator backend, and default interconnect.
    pub fn new(shards: usize) -> Self {
        Self::with_config(
            shards,
            DeviceModel::default(),
            ExecBackend::Serial,
            InterconnectModel::default(),
        )
    }

    /// Full configuration. `shards` is clamped to ≥ 1; every shard shares
    /// one device model and simulator backend (the backend only sets how
    /// fast the simulation runs — results and modeled stats are
    /// backend-invariant, as asserted by the PR-2 equivalence suites).
    pub fn with_config(
        shards: usize,
        device: DeviceModel,
        backend: ExecBackend,
        interconnect: InterconnectModel,
    ) -> Self {
        PrinsRack {
            shards: shards.max(1),
            device,
            backend,
            fault: None,
            interconnect,
        }
    }

    /// Attach a fault model: every shard array built after a resident
    /// load will inject faults from `model` (seeded per shard). The BERs
    /// are sanity-checked here so a bad experiment config fails at rack
    /// construction; the full F01 analyzer pass (stuck-cell bounds
    /// against the concrete shard shape) runs at
    /// `PrinsArray::enable_faults` time.
    pub fn with_fault(mut self, model: FaultModel) -> Result<Self> {
        for ber in [model.read_ber, model.write_ber, model.retention_ber] {
            crate::error::ensure!(
                ber.is_finite() && (0.0..1.0).contains(&ber),
                "fault model BER {} outside [0, 1)",
                ber
            );
        }
        self.fault = Some(model);
        Ok(self)
    }

    /// The attached fault model, if any.
    pub fn fault(&self) -> Option<&FaultModel> {
        self.fault.as_ref()
    }

    /// Number of shard devices in the rack.
    pub fn n_shards(&self) -> usize {
        self.shards
    }

    /// The shared per-shard device model.
    pub fn device(&self) -> &DeviceModel {
        &self.device
    }

    /// The shared simulator execution backend.
    pub fn backend(&self) -> ExecBackend {
        self.backend
    }

    /// Build one shard's array: a single-module device with this rack's
    /// device model and backend. `rows` is clamped to ≥ 1 so empty shards
    /// (more shards than rows) still construct.
    pub fn shard_array(&self, rows: usize, width: usize) -> PrinsArray {
        PrinsArray::with_device(1, rows.max(1), width, self.device.clone())
            .with_backend(self.backend)
    }

    /// Execute `f(shard_index, row_range)` for every shard of `plan`
    /// concurrently (one scoped OS thread per shard) and return the
    /// results in shard order. With one shard the closure runs inline.
    ///
    /// Each closure typically builds a shard-local array + storage
    /// manager, loads its row slice, runs the kernel, and returns
    /// `(result, ExecStats)`; shard-local arrays may themselves use the
    /// threaded execution backend — concurrent dispatchers share the
    /// process-wide worker pools safely.
    pub fn run_shards<R, F>(&self, plan: &ShardPlan, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        if plan.shards() <= 1 {
            return plan
                .ranges
                .iter()
                .cloned()
                .enumerate()
                .map(|(i, r)| f(i, r))
                .collect();
        }
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = plan
                .ranges
                .iter()
                .cloned()
                .enumerate()
                .map(|(i, r)| s.spawn(move || f(i, r)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rack shard worker panicked"))
                .collect()
        })
    }

    /// Execute `f(shard_index, &mut slot)` over every resident shard slot
    /// concurrently (one scoped OS thread per slot; inline when there is
    /// a single slot) and return the results in shard order. This is the
    /// query-phase twin of [`PrinsRack::run_shards`]: where `run_shards`
    /// builds shard state from a row-range plan (the load phase),
    /// `query_shards` revisits state that is already resident — each slot
    /// typically holds a shard's controller + loaded kernel, kept alive
    /// across queries by the generic [`crate::algorithms::Resident`]
    /// wrapper of the kernel framework.
    pub fn query_shards<S, R, F>(&self, slots: &mut [S], f: F) -> Vec<R>
    where
        S: Send,
        R: Send,
        F: Fn(usize, &mut S) -> R + Sync,
    {
        if slots.len() <= 1 {
            return slots
                .iter_mut()
                .enumerate()
                .map(|(i, s)| f(i, s))
                .collect();
        }
        std::thread::scope(|sc| {
            let f = &f;
            let handles: Vec<_> = slots
                .iter_mut()
                .enumerate()
                .map(|(i, s)| sc.spawn(move || f(i, s)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rack shard worker panicked"))
                .collect()
        })
    }

    /// Execute `f(shard_index, &slot)` over every resident shard slot
    /// concurrently and return the results in shard order — the
    /// shared-read twin of [`PrinsRack::query_shards`]: slots are
    /// borrowed immutably, so many callers can run `read_shards` over
    /// the **same** slots at the same time (the server's concurrent
    /// reader admission, DESIGN.md §Serving). Write-free kernels execute
    /// their query plans on read cursors inside `f`; anything needing
    /// `&mut` stays on `query_shards`.
    pub fn read_shards<S, R, F>(&self, slots: &[S], f: F) -> Vec<R>
    where
        S: Sync,
        R: Send,
        F: Fn(usize, &S) -> R + Sync,
    {
        if slots.len() <= 1 {
            return slots.iter().enumerate().map(|(i, s)| f(i, s)).collect();
        }
        std::thread::scope(|sc| {
            let f = &f;
            let handles: Vec<_> = slots
                .iter()
                .enumerate()
                .map(|(i, s)| sc.spawn(move || f(i, s)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rack shard worker panicked"))
                .collect()
        })
    }

    /// Fold the **load phase** of a resident dataset: per-shard load
    /// stats plus one command + dataset-payload message per shard on the
    /// host link (`payload_bytes[i]` = shard i's raw dataset bytes; the
    /// fixed command header is added here). Shared by every
    /// `Resident*::load` so the load-phase cost model cannot diverge
    /// between workloads.
    pub fn finish_load(&self, shard_stats: Vec<ExecStats>, payload_bytes: &[u64]) -> RackStats {
        assert_eq!(shard_stats.len(), payload_bytes.len());
        let msgs: Vec<u64> = payload_bytes.iter().map(|&b| CMD_BYTES + b).collect();
        self.finish(shard_stats, &msgs)
    }

    /// Fold per-shard execution stats and the host-link message sizes
    /// (bytes per message, commands and results alike) into rack-level
    /// totals. See [`RackStats`] for the accounting rules.
    pub fn finish(&self, shard_stats: Vec<ExecStats>, messages: &[u64]) -> RackStats {
        let max_shard_cycles = shard_stats.iter().map(|s| s.cycles).max().unwrap_or(0);
        let link_bytes: u64 = messages.iter().sum();
        let link_cycles = self.interconnect.link_cycles(messages, &self.device);
        let device_energy_j: f64 = shard_stats.iter().map(|s| s.energy_j(&self.device)).sum();
        let link_energy_j = self.interconnect.energy_j(link_bytes);
        RackStats {
            shards: shard_stats.len(),
            max_shard_cycles,
            link_messages: messages.len() as u64,
            link_bytes,
            link_cycles,
            total_cycles: max_shard_cycles + link_cycles,
            device_energy_j,
            link_energy_j,
            energy_j: device_energy_j + link_energy_j,
            shard_stats,
        }
    }
}

/// Rack-level execution statistics: per-shard stats plus the merged
/// cycle/energy figures under the rack's [`InterconnectModel`].
///
/// Accounting rules (DESIGN.md §Sharding):
/// `total_cycles = max(shard cycles) + Σ link transfer cycles` — shards
/// execute in parallel, host-link messages serialize on the shared link;
/// `energy_j = Σ shard energy + link bytes × E/byte` — every shard runs
/// its own controller, so static controller energy scales with shard
/// count (deliberately: that is the real cost of a rack).
#[derive(Clone, Debug)]
pub struct RackStats {
    /// Number of shard devices that executed.
    pub shards: usize,
    /// Slowest shard's kernel cycles (the rack-parallel critical path).
    pub max_shard_cycles: u64,
    /// Host-link messages charged (commands + result readbacks).
    pub link_messages: u64,
    /// Total bytes moved over the host link.
    pub link_bytes: u64,
    /// Link transfer cycles (serialized on the shared host link).
    pub link_cycles: u64,
    /// `max_shard_cycles + link_cycles` — the rack-level kernel latency.
    pub total_cycles: u64,
    /// Σ per-shard device energy \[J\] (dynamic + per-shard controller
    /// static power over each shard's own cycles).
    pub device_energy_j: f64,
    /// Host-link energy \[J\] (`link_bytes × e_per_byte`).
    pub link_energy_j: f64,
    /// `device_energy_j + link_energy_j`.
    pub energy_j: f64,
    /// The per-shard execution stats, in shard order.
    pub shard_stats: Vec<ExecStats>,
}

impl RackStats {
    /// Rack kernel latency in seconds under `dev`'s clock.
    pub fn runtime_s(&self, dev: &DeviceModel) -> f64 {
        dev.cycles_to_seconds(self.total_cycles)
    }

    /// Per-shard cycle counts, in shard order.
    pub fn shard_cycles(&self) -> Vec<u64> {
        self.shard_stats.iter().map(|s| s.cycles).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcam::EnergyLedger;

    fn stats(cycles: u64) -> ExecStats {
        ExecStats {
            cycles,
            instructions: 0,
            passes: 0,
            ledger: EnergyLedger::default(),
        }
    }

    #[test]
    fn run_shards_preserves_shard_order() {
        let rack = PrinsRack::new(4);
        let plan = ShardPlan::rows(10, 4);
        let out = rack.run_shards(&plan, |i, r| (i, r.start, r.len()));
        assert_eq!(out.len(), 4);
        for (i, (s, _start, _len)) in out.iter().enumerate() {
            assert_eq!(i, *s);
        }
        // ranges arrive exactly as planned
        for (o, r) in out.iter().zip(&plan.ranges) {
            assert_eq!((o.1, o.2), (r.start, r.len()));
        }
    }

    #[test]
    fn run_shards_runs_concurrently() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // every shard waits until all shards have started: only possible
        // if they genuinely run in parallel
        let rack = PrinsRack::new(3);
        let started = AtomicUsize::new(0);
        let plan = ShardPlan::rows(3, 3);
        rack.run_shards(&plan, |_i, _r| {
            started.fetch_add(1, Ordering::SeqCst);
            while started.load(Ordering::SeqCst) < 3 {
                std::thread::yield_now();
            }
        });
        assert_eq!(started.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn query_shards_runs_concurrently_over_resident_slots() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rack = PrinsRack::new(3);
        let mut slots = vec![10usize, 20, 30];
        let started = AtomicUsize::new(0);
        let out = rack.query_shards(&mut slots, |i, s| {
            started.fetch_add(1, Ordering::SeqCst);
            // all slots must be in flight at once (mutable, disjoint)
            while started.load(Ordering::SeqCst) < 3 {
                std::thread::yield_now();
            }
            *s += 1;
            (i, *s)
        });
        assert_eq!(out, vec![(0, 11), (1, 21), (2, 31)]);
        // state mutations persist across calls — the resident property
        let again = rack.query_shards(&mut slots, |i, s| (i, *s));
        assert_eq!(again, vec![(0, 11), (1, 21), (2, 31)]);
    }

    #[test]
    fn finish_merges_cycles_and_link_costs() {
        let rack = PrinsRack::new(2);
        let rs = rack.finish(vec![stats(100), stats(250)], &[64, 2048]);
        assert_eq!(rs.shards, 2);
        assert_eq!(rs.max_shard_cycles, 250);
        assert_eq!(rs.link_messages, 2);
        assert_eq!(rs.link_bytes, 64 + 2048);
        // default interconnect: ≥ 1000 cycles latency per message
        assert!(rs.link_cycles >= 2000, "{}", rs.link_cycles);
        assert_eq!(rs.total_cycles, rs.max_shard_cycles + rs.link_cycles);
        assert!(rs.link_energy_j > 0.0);
        assert!((rs.energy_j - rs.device_energy_j - rs.link_energy_j).abs() < 1e-18);
        assert_eq!(rs.shard_cycles(), vec![100, 250]);
    }

    #[test]
    fn free_interconnect_reduces_to_slowest_shard() {
        let rack = PrinsRack::with_config(
            2,
            DeviceModel::default(),
            ExecBackend::Serial,
            InterconnectModel::free(),
        );
        let rs = rack.finish(vec![stats(70), stats(30)], &[4096, 4096]);
        assert_eq!(rs.total_cycles, 70);
        assert_eq!(rs.link_energy_j, 0.0);
    }
}
