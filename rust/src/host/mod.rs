//! Host interface (paper §5.3): the PRINS device as the host sees it.
//!
//! `PrinsDevice` packages the controller + storage manager behind the
//! memory-mapped register protocol: the host loads datasets (which then
//! *live in PRINS* — "the datasets on which PRINS operates must reside in
//! PRINS and should not be left in the host memory"), writes kernel
//! parameters, triggers execution by kernel ID, and polls the status
//! register. A worker thread plays the PRINS controller; during kernel
//! execution the storage is not host-accessible (no coherence hardware,
//! §5.3).
//!
//! `server` exposes the same protocol over a TCP socket so external
//! processes can drive PRINS like a storage appliance: a readiness-polled
//! connection multiplexer with pipelined line framing feeds a worker
//! pool, write-free resident queries run as concurrent shared readers,
//! and a full dataset table evicts by wear-aware LRU (std::net only; the
//! vendored crate set has no tokio — DESIGN.md §Serving). The wire
//! protocol is specified in `docs/PROTOCOL.md`. `rack` scales the host
//! view out to a multi-device shard rack with cost-modeled host-side
//! merging (DESIGN.md §Sharding).

pub mod rack;
pub mod server;

use crate::algorithms::{DotKernel, EuclideanKernel, HistogramKernel};
use crate::controller::kernels::KernelId;
use crate::controller::registers::{RegisterFile, Status};
use crate::controller::{Controller, ExecStats};
use crate::rcam::{DeviceModel, ExecBackend, PrinsArray};
use crate::storage::StorageManager;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// What is currently resident in the device's storage.
enum Resident {
    None,
    Euclidean { kern: EuclideanKernel, centers_dim: usize },
    Dot { kern: DotKernel },
    Histogram { kern: HistogramKernel },
}

enum Request {
    Run { params: Vec<f64> },
    Shutdown,
}

/// Results the host reads back after Done (floats don't fit registers; the
/// paper's host reads outputs from PRINS storage — modeled as this buffer).
#[derive(Default)]
pub struct OutputBuffer {
    /// Float results (distances, dot products), kernel-specific order.
    pub f32s: Vec<f32>,
    /// Integer results (histogram bins, counts).
    pub u64s: Vec<u64>,
    /// Modeled device cycles of the kernel execution.
    pub cycles: u64,
    /// Modeled energy \[J\] of the kernel execution.
    pub energy_j: f64,
}

/// One PRINS device as the host sees it: register file, output buffer,
/// and a worker thread playing the on-device controller.
pub struct PrinsDevice {
    /// The memory-mapped register file (host/device handshake).
    pub regs: Arc<RegisterFile>,
    /// Results readable after a kernel reports Done.
    pub outputs: Arc<Mutex<OutputBuffer>>,
    tx: mpsc::Sender<Request>,
    worker: Option<std::thread::JoinHandle<()>>,
    state: Arc<Mutex<DeviceState>>,
}

struct DeviceState {
    ctl: Controller,
    sm: StorageManager,
    resident: Resident,
}

impl PrinsDevice {
    /// Bring up a device with `rows`×`width` of RCAM storage.
    pub fn new(rows: usize, width: usize) -> Self {
        Self::with_device_model(rows, width, DeviceModel::default())
    }

    /// [`PrinsDevice::new`] with an explicit device model (serial
    /// simulator backend).
    pub fn with_device_model(rows: usize, width: usize, dm: DeviceModel) -> Self {
        Self::with_config(rows, width, dm, ExecBackend::Serial)
    }

    /// Full configuration: device model + simulator execution backend
    /// (the backend only sets how fast the simulation runs; register
    /// results and output buffers are bit-identical either way).
    pub fn with_config(
        rows: usize,
        width: usize,
        dm: DeviceModel,
        backend: ExecBackend,
    ) -> Self {
        let array = PrinsArray::with_device(1, rows, width, dm).with_backend(backend);
        let state = Arc::new(Mutex::new(DeviceState {
            ctl: Controller::new(array),
            sm: StorageManager::new(rows),
            resident: Resident::None,
        }));
        let regs = Arc::new(RegisterFile::new());
        let outputs = Arc::new(Mutex::new(OutputBuffer::default()));
        let (tx, rx) = mpsc::channel::<Request>();
        let (wregs, wout, wstate) = (regs.clone(), outputs.clone(), state.clone());
        let worker = std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Shutdown => break,
                    Request::Run { params } => {
                        let ok = Self::execute(&wregs, &wout, &wstate, &params);
                        wregs.complete(ok, if ok { 0 } else { 1 });
                    }
                }
            }
        });
        PrinsDevice {
            regs,
            outputs,
            tx,
            worker: Some(worker),
            state,
        }
    }

    fn execute(
        regs: &RegisterFile,
        out: &Mutex<OutputBuffer>,
        state: &Mutex<DeviceState>,
        params: &[f64],
    ) -> bool {
        let Some(kid) = KernelId::from_u64(regs.kernel()) else {
            return false;
        };
        let mut st = state.lock().unwrap();
        let st = &mut *st;
        let dev = st.ctl.device().clone();
        let mut buf = OutputBuffer::default();
        let ok = match (&st.resident, kid) {
            (Resident::Euclidean { kern, centers_dim }, KernelId::EuclideanDistance) => {
                let dims = *centers_dim;
                let k = regs.read_param(0) as usize;
                if params.len() != k * dims {
                    return false;
                }
                let centers: Vec<f32> = params.iter().map(|&v| v as f32).collect();
                let res = kern.run(&mut st.ctl, &st.sm, &centers, k);
                for d in &res.dists {
                    buf.f32s.extend_from_slice(d);
                }
                buf.cycles = res.stats.cycles;
                buf.energy_j = res.stats.energy_j(&dev);
                true
            }
            (Resident::Dot { kern }, KernelId::DotProduct) => {
                let h: Vec<f32> = params.iter().map(|&v| v as f32).collect();
                if h.len() != kern.layout.dims {
                    return false;
                }
                let res = kern.run(&mut st.ctl, &st.sm, &h);
                buf.f32s = res.dp;
                buf.cycles = res.stats.cycles;
                buf.energy_j = res.stats.energy_j(&dev);
                true
            }
            (Resident::Histogram { kern }, KernelId::Histogram) => {
                let res = kern.run(&mut st.ctl);
                buf.u64s = res.hist;
                buf.cycles = res.stats.cycles;
                buf.energy_j = res.stats.energy_j(&dev);
                true
            }
            _ => false,
        };
        if ok {
            regs.write_result(0, buf.cycles);
            regs.write_result(1, (buf.energy_j * 1e12) as u64); // pJ
            *out.lock().unwrap() = buf;
        }
        ok
    }

    // ----- host-side dataset loading (device must be idle) --------------

    /// Load `n` × `dims` samples for the ED kernel (device must be idle).
    pub fn load_samples_for_euclidean(&self, x: &[f32], n: usize, dims: usize) {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let kern = EuclideanKernel::load(&mut st.sm, &mut st.ctl.array, x, n, dims);
        st.resident = Resident::Euclidean {
            kern,
            centers_dim: dims,
        };
    }

    /// Load `n` × `dims` vectors for the DP kernel (device must be idle).
    pub fn load_vectors_for_dot(&self, x: &[f32], n: usize, dims: usize) {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let kern = DotKernel::load(&mut st.sm, &mut st.ctl.array, x, n, dims);
        st.resident = Resident::Dot { kern };
    }

    /// Load u32 samples for the histogram kernel (device must be idle).
    pub fn load_samples_for_histogram(&self, x: &[u32]) {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let kern = HistogramKernel::load(&mut st.sm, &mut st.ctl.array, x);
        st.resident = Resident::Histogram { kern };
    }

    /// Device-model cost of loading the currently resident dataset
    /// (`None` when nothing is resident). The load phase is paid once per
    /// dataset; every subsequent `run_kernel` charges only query
    /// cycles/energy ([`OutputBuffer::cycles`]) — the load-once /
    /// query-many split of DESIGN.md §Resident datasets.
    pub fn load_report(&self) -> Option<ExecStats> {
        let st = self.state.lock().unwrap();
        match &st.resident {
            Resident::None => None,
            Resident::Euclidean { kern, .. } => Some(kern.load_stats().clone()),
            Resident::Dot { kern } => Some(kern.load_stats().clone()),
            Resident::Histogram { kern } => Some(kern.load_stats().clone()),
        }
    }

    // ----- host-side kernel invocation (register protocol) --------------

    /// Trigger a kernel and block until completion (poll loop).
    pub fn run_kernel(&self, kid: KernelId, reg_params: &[u64], data_params: &[f64]) -> Status {
        for (i, &p) in reg_params.iter().enumerate() {
            self.regs.write_param(i, p);
        }
        self.regs.trigger(kid as u64);
        self.tx
            .send(Request::Run {
                params: data_params.to_vec(),
            })
            .expect("device worker gone");
        self.regs.wait_done()
    }

    /// Read back the output buffer after Done.
    pub fn take_outputs(&self) -> OutputBuffer {
        std::mem::take(&mut *self.outputs.lock().unwrap())
    }
}

impl Drop for PrinsDevice {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::histogram_baseline;
    use crate::workloads::synth_hist_samples;

    #[test]
    fn histogram_through_register_protocol() {
        let xs = synth_hist_samples(2000, 5);
        let dev = PrinsDevice::new(2048, 64);
        dev.load_samples_for_histogram(&xs);
        let st = dev.run_kernel(KernelId::Histogram, &[], &[]);
        assert_eq!(st, Status::Done);
        let out = dev.take_outputs();
        assert_eq!(out.u64s, histogram_baseline(&xs));
        assert!(out.cycles > 0);
        assert_eq!(dev.regs.read_result(0), out.cycles);
    }

    #[test]
    fn threaded_device_matches_serial_device() {
        let xs = synth_hist_samples(3000, 9);
        let run = |backend| {
            let dev =
                PrinsDevice::with_config(4096, 64, crate::rcam::DeviceModel::default(), backend);
            dev.load_samples_for_histogram(&xs);
            assert_eq!(dev.run_kernel(KernelId::Histogram, &[], &[]), Status::Done);
            dev.take_outputs()
        };
        let s = run(ExecBackend::Serial);
        let t = run(ExecBackend::Threaded(4));
        assert_eq!(s.u64s, t.u64s);
        assert_eq!(s.cycles, t.cycles);
        assert_eq!(s.energy_j, t.energy_j);
    }

    #[test]
    fn resident_dataset_amortizes_load_across_runs() {
        let xs = synth_hist_samples(1000, 3);
        let dev = PrinsDevice::new(1024, 64);
        assert!(dev.load_report().is_none());
        dev.load_samples_for_histogram(&xs);
        let load = dev.load_report().expect("dataset resident");
        assert_eq!(load.ledger.n_write, 2 * xs.len() as u64);
        // query-many: repeated kernel runs charge only query cycles and
        // return bit-identical outputs — the dataset is never reloaded
        let mut outs = Vec::new();
        for _ in 0..3 {
            assert_eq!(dev.run_kernel(KernelId::Histogram, &[], &[]), Status::Done);
            outs.push(dev.take_outputs());
        }
        for o in &outs[1..] {
            assert_eq!(o.u64s, outs[0].u64s);
            assert_eq!(o.cycles, outs[0].cycles);
            assert!(o.cycles < load.cycles, "query floor beats the load cost");
        }
    }

    #[test]
    fn wrong_kernel_for_resident_dataset_errors() {
        let dev = PrinsDevice::new(256, 64);
        dev.load_samples_for_histogram(&[1, 2, 3]);
        let st = dev.run_kernel(KernelId::DotProduct, &[], &[]);
        assert_eq!(st, Status::Error);
    }

    #[test]
    fn dot_product_through_device() {
        let (n, dims) = (16usize, 2usize);
        let x: Vec<f32> = (0..n * dims).map(|i| i as f32 * 0.1).collect();
        let layout = crate::algorithms::dot::DotLayout::new(dims);
        let dev = PrinsDevice::new(n, layout.width as usize);
        dev.load_vectors_for_dot(&x, n, dims);
        let st = dev.run_kernel(KernelId::DotProduct, &[], &[0.5, -1.5]);
        assert_eq!(st, Status::Done);
        let out = dev.take_outputs();
        let expect = crate::algorithms::dot_baseline(&x, n, dims, &[0.5, -1.5]);
        for i in 0..n {
            assert!((out.f32s[i] - expect[i]).abs() < 1e-4, "dp[{i}]");
        }
    }
}
