//! TCP front-end for the PRINS device: a line-oriented protocol so
//! external processes (or `prins serve` + netcat) can drive the device
//! like a network-attached storage appliance.
//!
//! The full wire protocol — every verb (including the `RACK` sharding
//! forms and the resident-dataset verbs), the reply grammar, error
//! replies, and worked netcat sessions — is specified in
//! `docs/PROTOCOL.md`; keep that file authoritative. Summary:
//!
//!   PING | RACK \[n\] | LOAD | DATASETS | DROP | STATS | HIST | DP | ED
//!   | SPMV | SEARCH | QUIT
//!
//! Every kernel verb is dispatched through the **kernel registry**
//! ([`crate::algorithms::kernel::registry`]): this module contains zero
//! per-kernel code — a new registered workload (e.g. SEARCH) gets its
//! `LOAD` form, its dataset-id query form and its one-shot form without
//! touching the server. One-shot verbs load + query on a rack sized by
//! the session (`RACK <n>`, default 1 device) and report query-phase
//! stats; with ≥ 2 shards replies gain `shards=`/`link_bytes=` fields.
//!
//! **Resident datasets** (load-once / query-many, DESIGN.md §Resident
//! datasets): `LOAD <kind> ...` synthesizes a dataset server-side, loads
//! it onto a rack, and returns a dataset id; the kernel verbs' short
//! (dataset-id) forms then query the resident data without reloading —
//! repeated queries charge only query cycles. The table holds at most
//! [`MAX_DATASETS`] entries; a `LOAD` into a full table evicts the
//! least-recently-used dataset among the coldest-wear candidates and
//! reports it in a trailing `evicted=` field. `DATASETS` lists the
//! registry, `DROP <id>` frees one entry.
//!
//! **Cross-session sharing** (docs/PROTOCOL.md §Sharing): resident
//! datasets live in one server-wide [`Namespace`] every connection
//! shares — ids are globally monotonic, any connection may query, wear,
//! or `DROP` a dataset another connection loaded, and the
//! compiled-program cache and eviction recency stamps are shared too.
//! Admission is two-level and strictly FIFO ([`FairGate`]): dataset
//! queries, `DATASETS` and `STATS` enter as concurrent *shared readers*
//! of the namespace, while `LOAD`, `DROP` and `FAULTS` changes take the
//! global gate exclusively — fencing every connection at once and
//! bumping the `epoch=` stamp `DATASETS` reports — and a per-dataset
//! gate orders shared readers around exclusive queries of that dataset.
//! Tickets grant in draw order with reader batches bounded by
//! [`READER_BATCH`], so neither side can starve the other. Shard counts
//! (`RACK`) and the reply stream remain per-connection.
//!
//! **Cross-connection query coalescing**: each multiplexer sweep merges
//! compatible pending single-operand queries — same resident dataset,
//! kernel opted into `coalesce_queries` (`SEARCH`) — from any mix of
//! connections into one batched in-array sweep of at most
//! [`COALESCE_MAX`] members, scattering per-query replies back through
//! each connection's reorder buffer. Replies stay byte-identical to
//! solo dispatch (pinned by tests); only the modeled device time drops,
//! because members share one array traversal and one reduction-tree
//! drain. `STATS <id>` reports the accumulated
//! `coal_batches=`/`coal_members=`/`coal_cycles=` counters.
//!
//! Kernels with a **batched query form** (docs/PROTOCOL.md §Batched
//! queries) accept a longer dataset-id line — `SEARCH id B lo1 hi1 …`
//! (B ≥ 2) — packing B operands into one in-array sweep; dispatch is
//! still purely by arity. `STATS <id>` reports a resident dataset's
//! compiled-program cache counters (`cache_hits=`/`cache_misses=`),
//! kept out of query replies so repeated queries stay byte-identical.
//!
//! **Serving model** (DESIGN.md §Serving): one readiness-polled
//! multiplexer thread owns every connection — non-blocking accepts,
//! per-connection input/output buffers, line framing that tolerates
//! arbitrary packet splits and coalescing — and a worker pool runs the
//! simulations. Clients may pipeline many request lines on one
//! connection; replies always return in request order. Write-free
//! resident queries (kernels opting into `Kernel::SHARED_READ`) are
//! admitted as concurrent shared readers over the same resident rows —
//! across connections, not just within one — while loads, drops, and
//! every other verb run exclusively at the matching scope. (std::net
//! only; the vendored crate set has no tokio — documented in
//! Cargo.toml.)

use super::rack::{PrinsRack, RackStats};
use crate::algorithms::kernel::{find_verb, registry, QueryOut, ResidentDyn};
use crate::error::{bail, ensure, Result};
use crate::rcam::{DeviceModel, ExecBackend, InterconnectModel};
use crate::reliability::{FaultModel, FidelityReport};
use std::collections::{BTreeMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Multiplexer idle nap: when a readiness sweep moved no bytes, framed
/// no lines, and completed no work, the mux sleeps this long before the
/// next sweep (it also observes the stop flag at this cadence).
const IDLE_POLL: Duration = Duration::from_millis(1);

/// Write stall timeout: a client that stops draining its receive buffer
/// while replies are queued gets disconnected after this long instead of
/// buffering output forever (which would also make `shutdown()` slow).
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Tuning knobs of [`Server::spawn_opts`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Simulator execution backend for the per-request PRINS devices.
    /// Replies (cycles, energy, results) are bit-identical across
    /// backends; the knob only sets simulation speed per request.
    pub backend: ExecBackend,
    /// Worker threads running request simulations (≥ 1). Pipelined and
    /// cross-client requests execute concurrently up to this width.
    pub workers: usize,
    /// Admit write-free resident queries as concurrent shared readers.
    /// `false` serializes every request per connection — the
    /// exclusive-access baseline measured by `benches/throughput.rs`.
    pub shared_read: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            backend: ExecBackend::Serial,
            workers: default_workers(),
            shared_read: true,
        }
    }
}

/// Default worker-pool width: the machine's parallelism, clamped so
/// tests and small hosts stay well-behaved.
fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

/// A running TCP front-end: one multiplexer thread plus a worker pool.
pub struct Server {
    /// The resolved listen address (useful with ephemeral-port binds).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    mux: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on background threads with default options
    /// (serial simulator backend, shared reads on). Bind to port 0 for
    /// an ephemeral port (`self.addr` carries the resolved address).
    pub fn spawn(bind: &str) -> Result<Server> {
        Self::spawn_opts(bind, ServeOptions::default())
    }

    /// [`Server::spawn`] with an explicit simulator execution backend for
    /// the per-request PRINS devices.
    pub fn spawn_with(bind: &str, backend: ExecBackend) -> Result<Server> {
        Self::spawn_opts(
            bind,
            ServeOptions {
                backend,
                ..ServeOptions::default()
            },
        )
    }

    /// [`Server::spawn`] with explicit [`ServeOptions`].
    pub fn spawn_opts(bind: &str, opts: ServeOptions) -> Result<Server> {
        ensure!(opts.workers >= 1, "server needs at least one worker");
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<Done>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut workers = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let rx = job_rx.clone();
            let tx = done_tx.clone();
            let backend = opts.backend;
            workers.push(std::thread::spawn(move || worker_loop(rx, tx, backend)));
        }
        drop(done_tx); // workers hold the senders, the mux the receiver
        let stop2 = stop.clone();
        // one namespace for the whole server: every accepted connection
        // shares this resident-dataset table, gate, and fault model
        let ns = Arc::new(Namespace::default());
        let mux = std::thread::spawn(move || {
            Mux::new(listener, stop2, opts, job_tx, done_rx, ns).run();
        });
        Ok(Server {
            addr,
            stop,
            mux: Some(mux),
            workers,
        })
    }

    /// Stop accepting, then join the multiplexer AND every worker (the
    /// mux observes the stop flag at `IDLE_POLL`; dropping its job
    /// sender unblocks the workers, so this cannot hang on an idle
    /// client).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.mux.take() {
            let _ = h.join();
        }
        for h in std::mem::take(&mut self.workers) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

// ---------------------------------------------------------------------
// Worker pool: simulation happens here, off the multiplexer thread.
// ---------------------------------------------------------------------

/// Work handed to the pool: one request line, or one coalesced group.
enum Job {
    /// A single request line from one connection.
    One {
        conn: u64,
        seq: u64,
        line: String,
        sess: Arc<RwLock<Session>>,
        shared: bool,
    },
    /// A cross-connection coalesced batch: ≥ 2 compatible single-operand
    /// query lines on one dataset, executed as one in-array sweep. Every
    /// member gets its own [`Done`] so replies scatter back to each
    /// connection's reorder buffer.
    Coalesced {
        ns: Arc<Namespace>,
        dataset: u64,
        members: Vec<CoalMember>,
    },
}

/// One line of a coalesced batch and where its reply belongs.
struct CoalMember {
    conn: u64,
    seq: u64,
    line: String,
}

/// A finished request on its way back to the multiplexer.
struct Done {
    conn: u64,
    seq: u64,
    shared: bool,
    outcome: Outcome,
}

/// What the multiplexer should do with a finished request.
enum Outcome {
    /// Write this reply line.
    Line(String),
    /// Write `BYE`, drop unserved pipelined input, close after flush.
    Bye,
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, tx: Sender<Done>, backend: ExecBackend) {
    loop {
        let job = {
            let rx = rx.lock().unwrap();
            rx.recv()
        };
        let Ok(job) = job else {
            return; // server shut down: job sender dropped
        };
        match job {
            Job::One {
                conn,
                seq,
                line,
                sess,
                shared,
            } => {
                let outcome = run_one(&line, &sess, shared, backend);
                if tx.send(Done { conn, seq, shared, outcome }).is_err() {
                    return; // multiplexer gone
                }
            }
            Job::Coalesced { ns, dataset, members } => {
                run_coalesced(&ns, dataset, &members, &tx);
            }
        }
    }
}

fn run_one(line: &str, sess: &Arc<RwLock<Session>>, shared: bool, backend: ExecBackend) -> Outcome {
    if shared {
        // read lock: concurrent with every other shared reader of this
        // session; the admission rule keeps the connection's exclusive
        // requests out while we run, the namespace gate everyone else's
        let sess = sess.read().unwrap();
        match dispatch_shared(line.trim(), &sess.ns) {
            Ok(r) => Outcome::Line(r),
            Err(e) => Outcome::Line(format!("ERR {e}")),
        }
    } else {
        let mut sess = sess.write().unwrap();
        match dispatch(line.trim(), backend, &mut sess) {
            Ok(Some(r)) => Outcome::Line(r),
            Ok(None) => Outcome::Bye,
            Err(e) => Outcome::Line(format!("ERR {e}")),
        }
    }
}

/// Execute one coalesced group as a single in-array sweep
/// ([`crate::algorithms::kernel::ResidentDyn::query_args_coalesced`]).
/// Per-member replies are byte-identical to solo shared dispatch; only
/// the modeled device time (recorded in the slot's `coal_cycles`
/// counter) shrinks, because members share one traversal and one
/// reduction drain. Any reason the batch cannot run — dataset dropped
/// between the mux sweep and now, kernel without a coalesced form,
/// member parse failure — falls back to per-member [`dispatch_shared`],
/// which reproduces exactly the reply (or error) the solo path would
/// have produced.
fn run_coalesced(ns: &Namespace, dataset: u64, members: &[CoalMember], tx: &Sender<Done>) {
    let coalesced: Option<Vec<String>> = (|| {
        let _admit = ns.gate.lock_shared();
        let slot = slot_of(ns, dataset)?;
        let _slot_admit = slot.gate.lock_shared();
        let res = slot.res.read().unwrap();
        // verb + dataset id off, operand tokens stay
        let argsets: Vec<Vec<String>> = members
            .iter()
            .map(|m| m.line.split_whitespace().skip(2).map(String::from).collect())
            .collect();
        let (outs, batch_cycles) = res.query_args_coalesced(&argsets)?;
        for _ in members {
            slot.last_used.store(ns.tick(), Ordering::Relaxed);
        }
        slot.coal_batches.fetch_add(1, Ordering::Relaxed);
        slot.coal_members.fetch_add(members.len() as u64, Ordering::Relaxed);
        slot.coal_cycles.fetch_add(batch_cycles, Ordering::Relaxed);
        Some(outs.iter().map(|o| query_ok(o, dataset)).collect())
    })();
    for (i, m) in members.iter().enumerate() {
        let line = match &coalesced {
            Some(lines) => lines[i].clone(),
            None => match dispatch_shared(m.line.trim(), ns) {
                Ok(r) => r,
                Err(e) => format!("ERR {e}"),
            },
        };
        let done = Done {
            conn: m.conn,
            seq: m.seq,
            shared: true,
            outcome: Outcome::Line(line),
        };
        if tx.send(done).is_err() {
            return; // multiplexer gone
        }
    }
}

// ---------------------------------------------------------------------
// The multiplexer: readiness-polled connection state machine.
// ---------------------------------------------------------------------

/// Per-connection multiplexer state: buffered bytes in both directions,
/// framed-but-undispatched lines, completed-but-unemitted replies, and
/// the admission counters that order shared readers around exclusive
/// requests.
struct Conn {
    stream: TcpStream,
    sess: Arc<RwLock<Session>>,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    /// Framed request lines awaiting dispatch, FIFO, with their reply
    /// sequence numbers.
    pending: VecDeque<(u64, String)>,
    /// Completed replies awaiting in-order emission (reorder buffer:
    /// workers finish out of order, clients see request order).
    done: BTreeMap<u64, Outcome>,
    next_seq: u64,
    next_emit: u64,
    /// Requests dispatched to the pool and not yet completed.
    inflight: usize,
    /// An exclusive (session-mutating) request is in flight: nothing
    /// else may dispatch until it completes.
    exclusive_inflight: bool,
    /// Read side closed; close the connection once fully drained.
    eof: bool,
    /// `BYE` emitted; close once the output buffer flushes.
    bye: bool,
    dead: bool,
    last_progress: Instant,
}

impl Conn {
    fn new(stream: TcpStream, ns: Arc<Namespace>) -> Conn {
        Conn {
            stream,
            sess: Arc::new(RwLock::new(Session::with_ns(ns))),
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            pending: VecDeque::new(),
            done: BTreeMap::new(),
            next_seq: 0,
            next_emit: 0,
            inflight: 0,
            exclusive_inflight: false,
            eof: false,
            bye: false,
            dead: false,
            last_progress: Instant::now(),
        }
    }
}

struct Mux {
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
    job_tx: Sender<Job>,
    done_rx: Receiver<Done>,
    conns: BTreeMap<u64, Conn>,
    next_conn: u64,
    /// The server-wide namespace every accepted connection shares.
    ns: Arc<Namespace>,
}

impl Mux {
    fn new(
        listener: TcpListener,
        stop: Arc<AtomicBool>,
        opts: ServeOptions,
        job_tx: Sender<Job>,
        done_rx: Receiver<Done>,
        ns: Arc<Namespace>,
    ) -> Mux {
        Mux {
            listener,
            stop,
            opts,
            job_tx,
            done_rx,
            conns: BTreeMap::new(),
            next_conn: 0,
            ns,
        }
    }

    fn run(mut self) {
        while !self.stop.load(Ordering::Acquire) {
            let mut busy = self.accept_new();
            busy |= self.drain_completions();
            let ids: Vec<u64> = self.conns.keys().copied().collect();
            // ingest every connection first (frame lines, emit finished
            // replies), THEN coalesce across the freshly framed queues,
            // THEN admit the rest solo — so compatible queries arriving
            // in one sweep can merge instead of racing out one by one
            for &id in &ids {
                busy |= self.ingest_conn(id);
            }
            busy |= self.coalesce_pass();
            for &id in &ids {
                busy |= self.admit_flush_conn(id);
            }
            self.conns.retain(|_, c| !c.dead);
            if !busy {
                std::thread::sleep(IDLE_POLL);
            }
        }
    }

    fn accept_new(&mut self) -> bool {
        let mut busy = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    busy = true;
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let id = self.next_conn;
                    self.next_conn += 1;
                    self.conns.insert(id, Conn::new(stream, self.ns.clone()));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        busy
    }

    /// Route finished work back to its connection's reorder buffer.
    fn drain_completions(&mut self) -> bool {
        let mut busy = false;
        while let Ok(done) = self.done_rx.try_recv() {
            busy = true;
            let Some(c) = self.conns.get_mut(&done.conn) else {
                continue; // connection died while its request ran
            };
            c.inflight -= 1;
            if !done.shared {
                c.exclusive_inflight = false;
            }
            c.done.insert(done.seq, done.outcome);
        }
        busy
    }

    /// Ingest half of a connection's readiness sweep: pull bytes, frame
    /// lines into the pending FIFO, emit completed replies in order.
    /// Returns whether anything moved.
    fn ingest_conn(&mut self, id: u64) -> bool {
        let Some(c) = self.conns.get_mut(&id) else {
            return false;
        };
        let mut busy = false;

        // 1. Pull whatever bytes the socket has ready. Framing below
        //    tolerates any split/coalescing: bytes accumulate in `inbuf`
        //    until a newline lands.
        if !c.eof && !c.bye && !c.dead {
            let mut tmp = [0u8; 4096];
            loop {
                match c.stream.read(&mut tmp) {
                    Ok(0) => {
                        c.eof = true;
                        break;
                    }
                    Ok(n) => {
                        c.inbuf.extend_from_slice(&tmp[..n]);
                        busy = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.dead = true;
                        return true;
                    }
                }
            }
        }

        // 2. Frame complete lines into the pending queue.
        if !c.bye {
            while let Some(pos) = c.inbuf.iter().position(|&b| b == b'\n') {
                let raw: Vec<u8> = c.inbuf.drain(..=pos).collect();
                let line = String::from_utf8_lossy(&raw).into_owned();
                c.pending.push_back((c.next_seq, line));
                c.next_seq += 1;
                busy = true;
            }
            // EOF flushes a final unterminated line, like the pre-mux
            // server did.
            if c.eof && !c.inbuf.is_empty() {
                let line = String::from_utf8_lossy(&c.inbuf).into_owned();
                c.inbuf.clear();
                c.pending.push_back((c.next_seq, line));
                c.next_seq += 1;
                busy = true;
            }
        }

        // 3. Emit completed replies strictly in request order.
        while let Some(outcome) = c.done.remove(&c.next_emit) {
            c.next_emit += 1;
            busy = true;
            match outcome {
                Outcome::Line(r) => {
                    c.outbuf.extend_from_slice(r.as_bytes());
                    c.outbuf.push(b'\n');
                }
                Outcome::Bye => {
                    c.outbuf.extend_from_slice(b"BYE\n");
                    c.pending.clear(); // QUIT discards later pipelined input
                    c.bye = true;
                }
            }
        }
        busy
    }

    /// One cross-connection coalescing sweep (docs/PROTOCOL.md
    /// §Sharing): scan every live connection's pending FIFO front for a
    /// run of coalescible single-operand queries on one dataset, group
    /// the runs by dataset across connections, and hand every group of
    /// ≥ 2 members to the pool as one [`Job::Coalesced`] batch. Each
    /// connection contributes only its longest *front* run on a single
    /// dataset, so the pops below stay contiguous and per-connection
    /// reply order is preserved by the reorder buffer. Groups of one are
    /// left to the solo path — coalescing must never slow a lone query.
    fn coalesce_pass(&mut self) -> bool {
        if !self.opts.shared_read {
            return false;
        }
        // dataset id -> [(conn id, lines to take off that conn's front)]
        let mut groups: BTreeMap<u64, Vec<(u64, usize)>> = BTreeMap::new();
        for (&cid, c) in &self.conns {
            if c.bye || c.dead || c.exclusive_inflight {
                continue;
            }
            // a writer holds the session only during our own exclusive
            // job; skip the connection for this sweep
            let Ok(sess) = c.sess.try_read() else { continue };
            let mut run_ds = None;
            let mut take = 0usize;
            for (_, line) in &c.pending {
                match coalescable(line, &sess.ns) {
                    Some(ds) if run_ds.is_none() || run_ds == Some(ds) => {
                        run_ds = Some(ds);
                        take += 1;
                    }
                    _ => break,
                }
            }
            if let Some(ds) = run_ds {
                groups.entry(ds).or_default().push((cid, take));
            }
        }
        let mut busy = false;
        for (ds, mut contribs) in groups {
            let total: usize = contribs.iter().map(|&(_, n)| n).sum();
            if total < 2 {
                continue; // solo queries take the ordinary shared path
            }
            // bound the batch; trim later contributions first so every
            // connection's take stays front-contiguous
            let mut over = total.saturating_sub(COALESCE_MAX);
            for slot in contribs.iter_mut().rev() {
                let cut = over.min(slot.1);
                slot.1 -= cut;
                over -= cut;
            }
            let mut members = Vec::new();
            for (cid, take) in contribs {
                let Some(c) = self.conns.get_mut(&cid) else {
                    continue;
                };
                for _ in 0..take {
                    let (seq, line) = c.pending.pop_front().expect("counted above");
                    c.inflight += 1;
                    members.push(CoalMember { conn: cid, seq, line });
                }
            }
            busy = true;
            let job = Job::Coalesced {
                ns: self.ns.clone(),
                dataset: ds,
                members,
            };
            if self.job_tx.send(job).is_err() {
                // shutdown: the pool is gone, no Done will ever arrive
                for c in self.conns.values_mut() {
                    c.dead = true;
                }
                return true;
            }
        }
        busy
    }

    /// Admission + flush half of a connection's readiness sweep:
    /// dispatch pending requests, flush buffered replies, close drained
    /// connections. Returns whether anything moved.
    fn admit_flush_conn(&mut self, id: u64) -> bool {
        let Some(c) = self.conns.get_mut(&id) else {
            return false;
        };
        if c.dead {
            return false;
        }
        let mut busy = false;

        // 4. Admission: dispatch from the front of the FIFO. Shared
        //    readers pile up concurrently; an exclusive request waits
        //    for the connection to drain, then runs alone — so resident
        //    datasets cannot be dropped or evicted under a running
        //    shared query of the same session.
        if !c.bye && !c.dead {
            loop {
                if c.exclusive_inflight {
                    break;
                }
                let Some((_, line)) = c.pending.front() else {
                    break;
                };
                let shared = match c.sess.try_read() {
                    Ok(sess) => classify(line, &sess, self.opts.shared_read),
                    // a writer holds the session (only possible for our
                    // own exclusive job); retry next sweep
                    Err(_) => break,
                };
                if !shared && c.inflight > 0 {
                    break; // exclusive runs alone: wait for drain
                }
                let (seq, line) = c.pending.pop_front().expect("front checked above");
                c.inflight += 1;
                c.exclusive_inflight = !shared;
                busy = true;
                let job = Job::One {
                    conn: id,
                    seq,
                    line,
                    sess: c.sess.clone(),
                    shared,
                };
                if self.job_tx.send(job).is_err() {
                    c.dead = true;
                    return true;
                }
                if !shared {
                    break;
                }
            }
        }

        // 5. Flush buffered replies; detect write-stalled clients.
        while !c.outbuf.is_empty() {
            match c.stream.write(&c.outbuf) {
                Ok(0) => {
                    c.dead = true;
                    break;
                }
                Ok(n) => {
                    c.outbuf.drain(..n);
                    c.last_progress = Instant::now();
                    busy = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if c.last_progress.elapsed() >= WRITE_TIMEOUT {
                        c.dead = true;
                    }
                    break;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.dead = true;
                    break;
                }
            }
        }
        if c.outbuf.is_empty() {
            c.last_progress = Instant::now();
        }

        // 6. Close when done: after BYE flushes, or when a closed client
        //    has every pipelined request answered and flushed.
        if c.outbuf.is_empty()
            && (c.bye
                || (c.eof && c.pending.is_empty() && c.inflight == 0 && c.done.is_empty()))
        {
            c.dead = true;
        }
        busy
    }
}

// ---------------------------------------------------------------------
// The shared namespace, fair admission gates, and per-connection state.
// ---------------------------------------------------------------------

/// Capacity of the server-wide resident-dataset table (each entry holds
/// live simulated shard arrays). A `LOAD` into a full table evicts the
/// least-recently-used dataset among the coldest-wear candidates (see
/// [`evict_for_slot`]); `DROP` still frees slots explicitly.
const MAX_DATASETS: usize = 16;

/// Upper bound on one coalesced batch (mirrors the in-array batched
/// query form's `MAX_SEARCH_BATCH`): the mux never merges more pending
/// lines than one batched sweep could carry.
const COALESCE_MAX: usize = 16;

/// Bound on consecutively admitted shared readers per [`FairGate`]
/// grant batch: an exclusive ticket never waits on more than this many
/// in-flight readers draining (plus the strictly earlier tickets FIFO
/// order already owes). The tradeoff is deliberate: a pure reader
/// stream pays a drain barrier every `READER_BATCH` admissions.
const READER_BATCH: usize = 32;

/// Ticket-ordered readers/writer gate (docs/DESIGN.md §Serving).
///
/// Admission is strictly FIFO: every acquisition draws a ticket and
/// tickets are granted in draw order, so an exclusive acquisition (a
/// "writer": `LOAD`/`DROP`/`FAULTS` at namespace scope, an exclusive
/// query at dataset scope) is delayed only by tickets drawn before it —
/// an unbounded stream of later shared readers cannot starve it, and
/// symmetrically a reader queued behind a writer-heavy stream is
/// granted at its ticket, never pushed to the back. Consecutive reader
/// grants are additionally capped at [`READER_BATCH`] (the batch resets
/// when the last active reader releases, or when a writer is granted),
/// bounding the drain a writer waits out after its ticket comes up.
struct FairGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

/// Mutable state behind a [`FairGate`]'s mutex.
#[derive(Default)]
struct GateState {
    /// Next ticket to hand out.
    next_ticket: u64,
    /// The ticket currently allowed to admit (FIFO grant cursor).
    grant: u64,
    /// Shared holders currently inside the gate.
    active_readers: usize,
    /// An exclusive holder is inside the gate.
    writer_active: bool,
    /// Readers admitted since the batch last reset (see [`READER_BATCH`]).
    readers_in_batch: usize,
}

impl FairGate {
    fn new() -> FairGate {
        FairGate {
            state: Mutex::new(GateState::default()),
            cv: Condvar::new(),
        }
    }

    /// Draw the next FIFO ticket. The two-phase form (`ticket` +
    /// `lock_*_at`) exists so tests can pin grant order deterministically;
    /// `lock_shared`/`lock_exclusive` fuse the two steps.
    fn ticket(&self) -> u64 {
        let mut st = self.state.lock().unwrap();
        let t = st.next_ticket;
        st.next_ticket += 1;
        t
    }

    /// Admit as a shared reader (next free ticket).
    fn lock_shared(&self) -> GateShared<'_> {
        let t = self.ticket();
        self.lock_shared_at(t)
    }

    /// Admit as a shared reader holding ticket `t`.
    fn lock_shared_at(&self, t: u64) -> GateShared<'_> {
        let mut st = self.state.lock().unwrap();
        while !(st.grant == t && !st.writer_active && st.readers_in_batch < READER_BATCH) {
            st = self.cv.wait(st).unwrap();
        }
        st.grant += 1;
        st.readers_in_batch += 1;
        st.active_readers += 1;
        drop(st);
        // the next ticket may be another reader able to run concurrently
        self.cv.notify_all();
        GateShared { gate: self }
    }

    /// Admit exclusively (next free ticket).
    fn lock_exclusive(&self) -> GateExclusive<'_> {
        let t = self.ticket();
        self.lock_exclusive_at(t)
    }

    /// Admit exclusively holding ticket `t`: waits for its FIFO turn AND
    /// for every in-flight reader to drain.
    fn lock_exclusive_at(&self, t: u64) -> GateExclusive<'_> {
        let mut st = self.state.lock().unwrap();
        while !(st.grant == t && !st.writer_active && st.active_readers == 0) {
            st = self.cv.wait(st).unwrap();
        }
        st.grant += 1;
        st.writer_active = true;
        st.readers_in_batch = 0;
        GateExclusive { gate: self }
    }
}

/// RAII shared hold of a [`FairGate`].
struct GateShared<'a> {
    gate: &'a FairGate,
}

impl Drop for GateShared<'_> {
    fn drop(&mut self) {
        let mut st = self.gate.state.lock().unwrap();
        st.active_readers -= 1;
        if st.active_readers == 0 {
            // batch resets at full drain so a capped reader stream can
            // keep flowing once nobody is inside the gate
            st.readers_in_batch = 0;
        }
        drop(st);
        self.gate.cv.notify_all();
    }
}

/// RAII exclusive hold of a [`FairGate`].
struct GateExclusive<'a> {
    gate: &'a FairGate,
}

impl Drop for GateExclusive<'_> {
    fn drop(&mut self) {
        let mut st = self.gate.state.lock().unwrap();
        st.writer_active = false;
        drop(st);
        self.gate.cv.notify_all();
    }
}

/// One resident dataset in the server-wide table, with everything
/// concurrent access needs alongside the data itself: the dataset RwLock
/// (shared queries read, exclusive queries write), the per-dataset
/// [`FairGate`] ordering those two classes, the recency stamp the
/// wear-aware evictor reads (bumped by *any* connection's queries), and
/// the coalescing counters `STATS` reports.
struct DatasetSlot {
    res: RwLock<Box<dyn ResidentDyn>>,
    last_used: AtomicU64,
    gate: FairGate,
    /// Coalesced batches executed on this dataset.
    coal_batches: AtomicU64,
    /// Total member queries across those batches.
    coal_members: AtomicU64,
    /// Total modeled batch device cycles across those batches (per-op
    /// cost = `coal_cycles / coal_members`, compared against the solo
    /// floor by `benches/throughput.rs`).
    coal_cycles: AtomicU64,
}

impl DatasetSlot {
    fn new(res: Box<dyn ResidentDyn>, stamp: u64) -> DatasetSlot {
        DatasetSlot {
            res: RwLock::new(res),
            last_used: AtomicU64::new(stamp),
            gate: FairGate::new(),
            coal_batches: AtomicU64::new(0),
            coal_members: AtomicU64::new(0),
            coal_cycles: AtomicU64::new(0),
        }
    }
}

/// The server-wide serving state every connection shares
/// (docs/PROTOCOL.md §Sharing): the resident-dataset table with its
/// globally monotonic ids, the global admission gate, the logical clock
/// behind recency stamps, the table `epoch` (bumped by every `LOAD`,
/// `DROP`, and `FAULTS` change, reported by `DATASETS`), and the fault
/// model applied to racks built for future loads/one-shots.
struct Namespace {
    datasets: RwLock<BTreeMap<u64, Arc<DatasetSlot>>>,
    next_id: AtomicU64,
    clock: AtomicU64,
    epoch: AtomicU64,
    /// Global gate: dataset queries + `DATASETS`/`STATS` shared;
    /// `LOAD`/`DROP`/`FAULTS` changes exclusive (namespace fences).
    gate: FairGate,
    /// `FAULTS <ber> <seed> [stuck_n]` model; `None` = ideal device.
    fault: Mutex<Option<FaultModel>>,
}

impl Default for Namespace {
    fn default() -> Self {
        Namespace {
            datasets: RwLock::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            clock: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
            gate: FairGate::new(),
            fault: Mutex::new(None),
        }
    }
}

impl Namespace {
    /// Next recency stamp (atomic: concurrent shared readers tick too).
    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }
}

/// Clone one resident dataset's slot handle out of the namespace table
/// (brief table read lock; callers then lock the slot itself, never the
/// table, while running).
fn slot_of(ns: &Namespace, id: u64) -> Option<Arc<DatasetSlot>> {
    ns.datasets.read().unwrap().get(&id).cloned()
}

/// Per-connection protocol state: the shard count selected by `RACK <n>`
/// (1 = single-device, the default — still per-connection) plus the
/// handle to the shared [`Namespace`]. Direct-dispatch unit tests build
/// a default session, which owns a private fresh namespace.
struct Session {
    shards: usize,
    ns: Arc<Namespace>,
}

impl Default for Session {
    fn default() -> Self {
        Session::with_ns(Arc::new(Namespace::default()))
    }
}

impl Session {
    fn with_ns(ns: Arc<Namespace>) -> Session {
        Session { shards: 1, ns }
    }
}

/// Admission class of one request line (DESIGN.md §Serving): `true` =
/// shared reader — `PING`, or a registered kernel's dataset-id query
/// form (single or batched) against a resident dataset whose kernel
/// opted into `Kernel::SHARED_READ` and whose rack is fault-free.
/// Everything else — loads, drops, one-shots, session config, malformed
/// lines — is exclusive.
fn classify(line: &str, sess: &Session, shared_read: bool) -> bool {
    if !shared_read {
        return false;
    }
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => true,
        [verb, args @ ..] => {
            let Some(entry) = find_verb(verb) else {
                return false;
            };
            // dataset-id query form, or the longer batched form — never
            // the one-shot form, which builds a fresh rack and must run
            // exclusively
            let query = args.len() == entry.query_arity + 1;
            let batched =
                args.len() > entry.query_arity + 1 && args.len() != entry.one_shot_arity;
            if !query && !batched {
                return false;
            }
            let Ok(id) = args[0].parse::<u64>() else {
                return false;
            };
            let Some(slot) = slot_of(&sess.ns, id) else {
                return false;
            };
            // an exclusive query of this dataset may hold the slot write
            // lock right now; classify runs on the mux thread and must
            // not block, so contention falls back to the (byte-identical)
            // exclusive path
            let Ok(res) = slot.res.try_read() else {
                return false;
            };
            res.name() == entry.name && res.shared_readable()
        }
        _ => false,
    }
}

/// Mux-side test of one pending line for the cross-connection coalescer:
/// the single-operand (never batched) query form of a registered kernel
/// that opted into `coalesce_queries`, aimed at a resident
/// shared-readable dataset of that kind. Returns the dataset id the
/// line targets. Conservative on any contention — `None` only means
/// "dispatch solo this sweep", never an error.
fn coalescable(line: &str, ns: &Namespace) -> Option<u64> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    let (verb, args) = parts.split_first()?;
    let entry = find_verb(verb)?;
    if !entry.coalesce_queries || args.len() != entry.query_arity + 1 {
        return None;
    }
    let id = args[0].parse::<u64>().ok()?;
    let slot = slot_of(ns, id)?;
    let res = slot.res.try_read().ok()?;
    (res.name() == entry.name && res.shared_readable()).then_some(id)
}

/// Read-only dispatcher of the shared admission class: executes the
/// verbs [`classify`] marked shared — `PING` and write-free resident
/// queries — against the shared [`Namespace`], so readers from any
/// number of connections run concurrently (global gate shared, slot
/// gate shared, slot read lock). Must produce byte-identical replies to
/// [`dispatch`] for these verbs; the concurrency tests pin that.
fn dispatch_shared(line: &str, ns: &Namespace) -> Result<String> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => Ok("PONG".into()),
        [verb, args @ ..] => {
            let Some(entry) = find_verb(verb) else {
                bail!("unknown command");
            };
            let query = args.len() == entry.query_arity + 1;
            let batched =
                args.len() > entry.query_arity + 1 && args.len() != entry.one_shot_arity;
            ensure!(query || batched, "not a shared-readable query");
            let id: u64 = args[0].parse()?;
            let _admit = ns.gate.lock_shared();
            let Some(slot) = slot_of(ns, id) else {
                bail!("unknown dataset {id}");
            };
            let _slot_admit = slot.gate.lock_shared();
            let res = slot.res.read().unwrap();
            ensure!(
                res.name() == entry.name,
                "dataset {id} is kind {}, not {}",
                res.name(),
                entry.name
            );
            let out = if query {
                res.query_args_shared(&args[1..])?
            } else {
                res.query_args_batch_shared(&args[1..])?
            };
            // every shared read refreshes recency — batched or not, from
            // any connection — so read-hot datasets stay off the
            // eviction victim list
            slot.last_used.store(ns.tick(), Ordering::Relaxed);
            Ok(query_ok(&out, id))
        }
        _ => bail!("unknown command"),
    }
}

/// The rack a session's sharded verbs execute on: session shard count,
/// default device model + interconnect, the server's simulator backend,
/// plus the namespace fault model when `FAULTS` is active.
fn rack_for(sess: &Session, backend: ExecBackend) -> Result<PrinsRack> {
    let rack = PrinsRack::with_config(
        sess.shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    );
    let fault = sess.ns.fault.lock().unwrap().clone();
    match fault {
        Some(model) => rack.with_fault(model),
        None => Ok(rack),
    }
}

/// Key=value reply-line builder: the single place the `OK …` grammar is
/// emitted (docs/PROTOCOL.md §Reply grammar). Every verb — including
/// every registry-driven kernel verb — assembles its reply through this
/// builder, so the `query_ok`/`load_fields` grammar cannot drift between
/// verbs or kernels.
struct Reply {
    line: String,
}

impl Reply {
    /// Start an `OK` reply.
    fn ok() -> Reply {
        Reply { line: "OK".into() }
    }

    /// Append one `key=value` field.
    fn kv(mut self, key: &str, value: impl std::fmt::Display) -> Reply {
        self.line.push(' ');
        self.line.push_str(key);
        self.line.push('=');
        self.line.push_str(&value.to_string());
        self
    }

    /// Append pre-formatted fields (a kernel's verb-specific
    /// `fields` string from the registry formatter). Empty = no-op.
    fn fields(mut self, fields: &str) -> Reply {
        if !fields.is_empty() {
            self.line.push(' ');
            self.line.push_str(fields);
        }
        self
    }

    /// The finished reply line.
    fn finish(self) -> String {
        self.line
    }
}

/// Picojoule formatting of the `energy_pj=` field (one decimal, like
/// every reply since PR 3).
fn pj(energy_j: f64) -> String {
    format!("{:.1}", energy_j * 1e12)
}

/// Stats prefix + kernel fields of every kernel reply: single-device
/// grammar (per-shard device stats, no link charge) when the run used
/// one shard, rack grammar (`shards=`/`link_bytes=`) otherwise.
fn stats_reply(rs: &RackStats, fields: &str) -> Reply {
    if rs.shards >= 2 {
        Reply::ok()
            .kv("cycles", rs.total_cycles)
            .kv("energy_pj", pj(rs.energy_j))
            .fields(fields)
            .kv("shards", rs.shards)
            .kv("link_bytes", rs.link_bytes)
    } else {
        let st = &rs.shard_stats[0];
        Reply::ok()
            .kv("cycles", st.cycles)
            .kv("energy_pj", pj(st.energy_j(&DeviceModel::default())))
            .fields(fields)
    }
}

/// Append the reliability fields when the query ran on a faulty rack
/// (docs/PROTOCOL.md §Fault injection): `fidelity=` always, and a
/// `warn=residual-faults retries=` pair when corruption survived every
/// scrub/retry — graceful degradation instead of a dropped reply. On an
/// ideal rack this is a no-op, so existing replies stay byte-identical.
fn fid_reply(r: Reply, fid: &Option<FidelityReport>) -> Reply {
    let Some(f) = fid else { return r };
    let r = r.kv("fidelity", format!("{:.6}", f.fidelity));
    if f.residual > 0 {
        r.kv("warn", "residual-faults").kv("retries", f.retries)
    } else {
        r
    }
}

/// Reply line of a resident-dataset query (docs/PROTOCOL.md §Resident
/// datasets): the shared stats grammar with the trailing `dataset=`
/// marker.
fn query_ok(out: &QueryOut, id: u64) -> String {
    fid_reply(stats_reply(&out.rack, &out.fields), &out.fidelity)
        .kv("dataset", id)
        .finish()
}

/// `load_cycles=` (and, when sharded, `load_link_bytes=`) fields of a
/// `LOAD` reply — the one-time load-phase price the dataset id amortizes.
fn load_fields(rs: &RackStats) -> String {
    if rs.shards >= 2 {
        format!(
            "load_cycles={} load_link_bytes={}",
            rs.total_cycles, rs.link_bytes
        )
    } else {
        format!("load_cycles={}", rs.shard_stats[0].cycles)
    }
}

/// The `LOAD` usage string, assembled from the registry (a new kernel's
/// `LOAD` form appears here without touching the server).
fn load_usage() -> String {
    let forms: Vec<&str> = registry().iter().map(|e| e.load_usage).collect();
    format!("usage: {}", forms.join(" | "))
}

/// Make room for one more resident dataset when the table is at
/// capacity: evict the least-recently-used dataset among those with the
/// coldest wear. The victim key is (hottest-row write count, recency
/// stamp, id), minimized — so wear protection comes first (a dataset
/// whose cells are already worn is kept resident; datasets without wear
/// tracking, i.e. faulty-rack loads, count as coldest), and recency
/// breaks ties — stamps come from *every* connection's queries, so a
/// dataset kept hot purely by another connection's shared reads is not
/// a victim. Runs under the global exclusive gate (inside `LOAD`), so
/// no query is in flight on any slot. Returns the evicted id for the
/// `evicted=` reply field.
fn evict_for_slot(table: &mut BTreeMap<u64, Arc<DatasetSlot>>) -> Option<u64> {
    if table.len() < MAX_DATASETS {
        return None;
    }
    let victim = table
        .iter()
        .min_by_key(|(id, slot)| {
            (
                slot.res.read().unwrap().wear_score().unwrap_or(0),
                slot.last_used.load(Ordering::Relaxed),
                **id,
            )
        })
        .map(|(id, _)| *id)?;
    table.remove(&victim);
    Some(victim)
}

/// `LOAD <KIND> ...`: synthesize a dataset server-side via the kind's
/// registry entry, load it once onto a rack with the session's current
/// shard count, and register it under a fresh id. Every subsequent
/// dataset-id kernel verb reuses the resident rows and charges only
/// query cycles. The shard layout is fixed at `LOAD` time; later `RACK`
/// changes affect only future loads. A full table evicts wear-aware LRU
/// ([`evict_for_slot`]) and reports the victim in a trailing `evicted=`
/// field. `LOAD` fences the whole namespace: synthesis, eviction and
/// the table insert run under the global exclusive gate, so no shared
/// reader on any connection observes a half-loaded table, and the
/// `epoch` bump is atomic with the insert.
fn load_dataset(args: &[&str], backend: ExecBackend, sess: &Session) -> Result<Option<String>> {
    // kinds are case-sensitive wire verbs, exactly like the kernel verbs
    let Some(entry) = args.first().and_then(|kind| find_verb(kind)) else {
        bail!("{}", load_usage());
    };
    let ns = &sess.ns;
    let _fence = ns.gate.lock_exclusive();
    let rack = rack_for(sess, backend)?;
    let data = (entry.load)(&rack, &args[1..])?;
    // evict only after the new load synthesized successfully, so a
    // malformed LOAD can never cost a resident dataset
    let mut table = ns.datasets.write().unwrap();
    let evicted = evict_for_slot(&mut table);
    let id = ns.next_id.fetch_add(1, Ordering::Relaxed);
    let mut reply = Reply::ok()
        .kv("id", id)
        .kv("kind", data.name())
        .kv("n", data.rows())
        .kv("shards", data.load_report().shards)
        .fields(&load_fields(data.load_report()));
    if let Some(victim) = evicted {
        reply = reply.kv("evicted", victim);
    }
    let stamp = ns.tick();
    table.insert(id, Arc::new(DatasetSlot::new(data, stamp)));
    ns.epoch.fetch_add(1, Ordering::Relaxed);
    Ok(Some(reply.finish()))
}

/// A registered kernel verb, dispatched by arity (docs/PROTOCOL.md):
/// `<VERB> id params…` (the dataset-id query form) when the arg count
/// matches the kernel's query arity + 1, `<VERB> …` (the one-shot form)
/// when it matches the one-shot arity, and the **batched** dataset-id
/// form (`<VERB> id B op1 … opB`, longer than the single-query form)
/// for any other arg count that leads with a dataset id — kernels
/// without a batched grammar refuse it with a clean error. No
/// per-kernel code: parsing, synthesis and reply fields all come from
/// the registry entry.
fn kernel_verb(
    verb: &str,
    args: &[&str],
    backend: ExecBackend,
    sess: &Session,
) -> Result<Option<String>> {
    let Some(entry) = find_verb(verb) else {
        bail!("unknown command");
    };
    let ns = &sess.ns;
    if args.len() == entry.query_arity + 1 {
        // dataset-id query: no reload, query cycles only. Exclusive at
        // dataset scope (slot gate + write lock), shared at namespace
        // scope — other datasets keep serving.
        let id: u64 = args[0].parse()?;
        let _admit = ns.gate.lock_shared();
        let Some(slot) = slot_of(ns, id) else {
            bail!("unknown dataset {id}");
        };
        let _slot_fence = slot.gate.lock_exclusive();
        // the write lock (not strictly needed by `&self` queries) is the
        // signal mux-side `try_read` probes observe as contention
        let res = slot.res.write().unwrap();
        ensure!(
            res.name() == entry.name,
            "dataset {id} is kind {}, not {}",
            res.name(),
            entry.name
        );
        let out = res.query_args(&args[1..])?;
        slot.last_used.store(ns.tick(), Ordering::Relaxed);
        Ok(Some(query_ok(&out, id)))
    } else if args.len() == entry.one_shot_arity {
        let rack = rack_for(sess, backend)?;
        let out = (entry.one_shot)(&rack, args)?;
        Ok(Some(
            fid_reply(stats_reply(&out.rack, &out.fields), &out.fidelity).finish(),
        ))
    } else if args.len() > entry.query_arity + 1 && args[0].parse::<u64>().is_ok() {
        // batched dataset-id query: B operands packed into one sweep
        let id: u64 = args[0].parse()?;
        let _admit = ns.gate.lock_shared();
        let Some(slot) = slot_of(ns, id) else {
            bail!("unknown dataset {id}");
        };
        let _slot_fence = slot.gate.lock_exclusive();
        let res = slot.res.write().unwrap();
        ensure!(
            res.name() == entry.name,
            "dataset {id} is kind {}, not {}",
            res.name(),
            entry.name
        );
        let out = res.query_args_batch(&args[1..])?;
        slot.last_used.store(ns.tick(), Ordering::Relaxed);
        Ok(Some(query_ok(&out, id)))
    } else {
        bail!("usage: {} | {}", entry.one_shot_usage, entry.query_usage);
    }
}

fn dispatch(line: &str, backend: ExecBackend, sess: &mut Session) -> Result<Option<String>> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => Ok(Some("PONG".into())),
        ["QUIT"] => Ok(None),
        ["RACK"] => Ok(Some(Reply::ok().kv("shards", sess.shards).finish())),
        ["RACK", n] => {
            let n: usize = n.parse()?;
            ensure!(
                (1..=crate::rcam::shard::MAX_SHARDS).contains(&n),
                "shards out of range (1..={})",
                crate::rcam::shard::MAX_SHARDS
            );
            sess.shards = n;
            Ok(Some(Reply::ok().kv("shards", n).finish()))
        }
        // ----- resident-dataset registry (docs/PROTOCOL.md) -------------
        ["LOAD", rest @ ..] => load_dataset(rest, backend, sess),
        ["DATASETS"] => {
            let ns = &sess.ns;
            let _admit = ns.gate.lock_shared();
            let table = ns.datasets.read().unwrap();
            let mut reply = Reply::ok()
                .kv("count", table.len())
                .kv("epoch", ns.epoch.load(Ordering::Relaxed));
            for (id, slot) in table.iter() {
                let res = slot.res.read().unwrap();
                reply = reply.kv(
                    "ds",
                    format!("{id}:{}:{}:{}", res.name(), res.rows(), res.load_report().shards),
                );
            }
            Ok(Some(reply.finish()))
        }
        ["DROP", id] => {
            let id: u64 = id.parse()?;
            let ns = &sess.ns;
            // namespace fence: every connection's in-flight shared
            // readers drain before the slot disappears
            let _fence = ns.gate.lock_exclusive();
            ensure!(
                ns.datasets.write().unwrap().remove(&id).is_some(),
                "unknown dataset {id}"
            );
            ns.epoch.fetch_add(1, Ordering::Relaxed);
            Ok(Some(Reply::ok().kv("dropped", id).finish()))
        }
        // compiled-program cache + coalescing counters of one resident
        // dataset; a separate verb (not query-reply fields) so repeated
        // queries stay byte-identical for the bench equality gates
        ["STATS", id] => {
            let id: u64 = id.parse()?;
            let ns = &sess.ns;
            let _admit = ns.gate.lock_shared();
            let Some(slot) = slot_of(ns, id) else {
                bail!("unknown dataset {id}");
            };
            let (hits, misses) = slot.res.read().unwrap().cache_stats();
            Ok(Some(
                Reply::ok()
                    .kv("dataset", id)
                    .kv("cache_hits", hits)
                    .kv("cache_misses", misses)
                    .kv("coal_batches", slot.coal_batches.load(Ordering::Relaxed))
                    .kv("coal_members", slot.coal_members.load(Ordering::Relaxed))
                    .kv("coal_cycles", slot.coal_cycles.load(Ordering::Relaxed))
                    .finish(),
            ))
        }
        // ----- fault injection (docs/PROTOCOL.md §Fault injection) ------
        ["FAULTS"] => Ok(Some(match &*sess.ns.fault.lock().unwrap() {
            None => Reply::ok().kv("faults", "off").finish(),
            Some(m) => Reply::ok()
                .kv("faults", "on")
                .kv("ber", m.read_ber)
                .kv("seed", m.seed)
                .kv("stuck", m.random_stuck)
                .finish(),
        })),
        ["FAULTS", "OFF"] => {
            let ns = &sess.ns;
            // regime change = namespace fence + epoch bump, like LOAD/DROP
            let _fence = ns.gate.lock_exclusive();
            *ns.fault.lock().unwrap() = None;
            // the fault regime frames every cached plan's validity:
            // flush resident program caches so the next query
            // re-synthesizes (counters stay cumulative)
            for slot in ns.datasets.read().unwrap().values() {
                slot.res.read().unwrap().invalidate_cache();
            }
            ns.epoch.fetch_add(1, Ordering::Relaxed);
            Ok(Some(Reply::ok().kv("faults", "off").finish()))
        }
        ["FAULTS", rest @ ..] => {
            ensure!(
                rest.len() == 2 || rest.len() == 3,
                "usage: FAULTS | FAULTS OFF | FAULTS ber seed [stuck_n]"
            );
            let ber: f64 = rest[0].parse()?;
            let seed: u64 = rest[1].parse()?;
            let stuck: usize = if rest.len() == 3 { rest[2].parse()? } else { 0 };
            ensure!(
                ber.is_finite() && (0.0..1.0).contains(&ber),
                "BER {} outside [0, 1)",
                ber
            );
            // takes effect on racks built for future LOADs/one-shots;
            // already-resident datasets keep their load-time model but
            // drop their cached plans (invalidation rule: arming faults
            // is a regime change, re-synthesize on next query)
            let ns = &sess.ns;
            let _fence = ns.gate.lock_exclusive();
            *ns.fault.lock().unwrap() =
                Some(FaultModel::uniform(ber, seed).with_random_stuck(stuck));
            for slot in ns.datasets.read().unwrap().values() {
                slot.res.read().unwrap().invalidate_cache();
            }
            ns.epoch.fetch_add(1, Ordering::Relaxed);
            Ok(Some(
                Reply::ok()
                    .kv("faults", "on")
                    .kv("ber", ber)
                    .kv("seed", seed)
                    .kv("stuck", stuck)
                    .finish(),
            ))
        }
        // ----- kernel verbs: registry-driven, arity-dispatched ----------
        [verb, args @ ..] => kernel_verb(verb, args, backend, sess),
        _ => bail!("unknown command"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn ping_and_hist_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        writeln!(conn, "PING").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        line.clear();
        writeln!(conn, "HIST 500 7").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        assert!(line.contains("total=500"), "{line}");

        line.clear();
        writeln!(conn, "BOGUS").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");

        line.clear();
        writeln!(conn, "QUIT").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "BYE");
        server.shutdown();
    }

    #[test]
    fn rack_session_shards_verbs_and_is_per_connection() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };

        assert_eq!(ask(&mut conn, &mut reader, "RACK"), "OK shards=1");
        assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
        let sharded = ask(&mut conn, &mut reader, "HIST 600 7");
        assert!(sharded.contains("shards=2"), "{sharded}");
        assert!(sharded.contains("link_bytes="), "{sharded}");
        assert!(sharded.contains("total=600"), "{sharded}");
        let spmv = ask(&mut conn, &mut reader, "SPMV 48 300 3");
        assert!(spmv.contains("shards=2") && spmv.contains("checksum="), "{spmv}");
        assert!(ask(&mut conn, &mut reader, "RACK 0").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "RACK 65").starts_with("ERR"));

        // sharded histogram agrees with single-device results and a fresh
        // connection starts unsharded
        let mut conn2 = TcpStream::connect(server.addr).unwrap();
        let mut reader2 = BufReader::new(conn2.try_clone().unwrap());
        let single = ask(&mut conn2, &mut reader2, "HIST 600 7");
        assert!(!single.contains("shards="), "{single}");
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };
        assert_eq!(field(&sharded, "top_bin="), field(&single, "top_bin="));
        assert_eq!(field(&sharded, "total="), field(&single, "total="));
        // rack cycles include the host-link charge, so they exceed the
        // single device's
        let cyc = |r: &str| field(r, "cycles=").parse::<u64>().unwrap();
        assert!(cyc(&sharded) > cyc(&single), "{sharded} vs {single}");
        server.shutdown();
    }

    #[test]
    fn resident_dataset_lifecycle_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };

        // load once, query many: replies repeat bit-for-bit
        let loaded = ask(&mut conn, &mut reader, "LOAD HIST 500 7");
        assert!(loaded.starts_with("OK id=1 kind=hist n=500 shards=1"), "{loaded}");
        assert!(loaded.contains("load_cycles="), "{loaded}");
        let q1 = ask(&mut conn, &mut reader, "HIST 1");
        let q2 = ask(&mut conn, &mut reader, "HIST 1");
        assert!(q1.contains("dataset=1") && q1.contains("total=500"), "{q1}");
        assert_eq!(q1, q2, "resident queries must repeat bit-identically");
        // the resident query matches the one-shot verb's result values
        let one_shot = ask(&mut conn, &mut reader, "HIST 500 7");
        assert_eq!(field(&q1, "top_bin="), field(&one_shot, "top_bin="));
        assert_eq!(field(&q1, "total="), field(&one_shot, "total="));
        // query cost sits below the one-time load cost
        let load_cycles: u64 = field(&loaded, "load_cycles=").parse().unwrap();
        let query_cycles: u64 = field(&q1, "cycles=").parse().unwrap();
        assert!(query_cycles < load_cycles, "{q1} vs {loaded}");

        // second dataset of a different kind; registry lists both
        let dp = ask(&mut conn, &mut reader, "LOAD DP 32 4 3");
        assert!(dp.starts_with("OK id=2 kind=dp n=32"), "{dp}");
        let dpq = ask(&mut conn, &mut reader, "DP 2 9");
        assert!(dpq.contains("checksum=") && dpq.contains("dataset=2"), "{dpq}");
        // new hyperplane (new seed) on the same resident vectors
        let dpq2 = ask(&mut conn, &mut reader, "DP 2 10");
        assert_ne!(field(&dpq, "checksum="), field(&dpq2, "checksum="));
        assert_eq!(field(&dpq, "cycles="), field(&dpq2, "cycles="));
        // epoch counts the two LOADs — the namespace's change stamp
        assert_eq!(
            ask(&mut conn, &mut reader, "DATASETS"),
            "OK count=2 epoch=2 ds=1:hist:500:1 ds=2:dp:32:1"
        );

        // kind/verb mismatch and unknown ids are errors, not panics
        assert!(ask(&mut conn, &mut reader, "DP 1 5").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "HIST 9").starts_with("ERR"));

        // drop frees the id
        assert_eq!(ask(&mut conn, &mut reader, "DROP 1"), "OK dropped=1");
        assert!(ask(&mut conn, &mut reader, "HIST 1").starts_with("ERR"));
        assert_eq!(
            ask(&mut conn, &mut reader, "DATASETS"),
            "OK count=1 epoch=3 ds=2:dp:32:1"
        );
        server.shutdown();
    }

    #[test]
    fn faults_verb_lifecycle_and_fidelity_fields() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };

        // off by default; malformed configs are rejected, not applied
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS"), "OK faults=off");
        assert!(ask(&mut conn, &mut reader, "FAULTS 1.5 1").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "FAULTS x 1").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "FAULTS 0.1").starts_with("ERR"));
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS"), "OK faults=off");

        // BER=0 faults: replies gain fidelity=1.000000 and values stay exact
        assert_eq!(
            ask(&mut conn, &mut reader, "FAULTS 0 7"),
            "OK faults=on ber=0 seed=7 stuck=0"
        );
        assert_eq!(
            ask(&mut conn, &mut reader, "FAULTS"),
            "OK faults=on ber=0 seed=7 stuck=0"
        );
        let loaded = ask(&mut conn, &mut reader, "LOAD HIST 200 5");
        assert!(loaded.starts_with("OK id=1"), "{loaded}");
        let resident = ask(&mut conn, &mut reader, "HIST 1");
        assert!(resident.contains("fidelity=1.000000"), "{resident}");
        assert!(!resident.contains("warn="), "{resident}");
        let one_shot_faulty = ask(&mut conn, &mut reader, "HIST 200 5");
        assert!(one_shot_faulty.contains("fidelity=1.000000"), "{one_shot_faulty}");

        // FAULTS OFF: new racks are ideal again, but the resident dataset
        // keeps its load-time model and still reports fidelity
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS OFF"), "OK faults=off");
        let one_shot_ideal = ask(&mut conn, &mut reader, "HIST 200 5");
        assert!(!one_shot_ideal.contains("fidelity="), "{one_shot_ideal}");
        assert_eq!(
            field(&one_shot_faulty, "top_bin="),
            field(&one_shot_ideal, "top_bin=")
        );
        assert_eq!(
            field(&one_shot_faulty, "total="),
            field(&one_shot_ideal, "total=")
        );
        let resident2 = ask(&mut conn, &mut reader, "HIST 1");
        assert!(resident2.contains("fidelity="), "{resident2}");
        assert_eq!(field(&resident, "total="), field(&resident2, "total="));
        server.shutdown();
    }

    #[test]
    fn sharded_resident_dataset_replies_carry_rack_fields() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
        let loaded = ask(&mut conn, &mut reader, "LOAD SPMV 48 300 3");
        assert!(loaded.contains("shards=2") && loaded.contains("load_link_bytes="), "{loaded}");
        let q = ask(&mut conn, &mut reader, "SPMV 1 4");
        assert!(
            q.contains("shards=2") && q.contains("link_bytes=") && q.contains("dataset=1"),
            "{q}"
        );
        // datasets keep their load-time shard count even after RACK changes
        assert_eq!(ask(&mut conn, &mut reader, "RACK 1"), "OK shards=1");
        let q2 = ask(&mut conn, &mut reader, "SPMV 1 4");
        assert_eq!(q, q2, "resident layout is fixed at LOAD time");
        server.shutdown();
    }

    #[test]
    fn spmv_verb_matches_quantized_baseline_checksum() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, "SPMV 64 400 5").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        let got: f32 = line
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("checksum="))
            .unwrap()
            .parse()
            .unwrap();
        let a = crate::workloads::synth_csr(64, 400, 5);
        let mut rng = crate::workloads::Rng::seed_from(6);
        let x: Vec<f32> = (0..64).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let expect: f32 = crate::algorithms::spmv_baseline_quantized(&a, &x).iter().sum();
        assert!((got - expect).abs() < 2e-3, "{got} vs {expect}");
        server.shutdown();
    }

    #[test]
    fn threaded_server_replies_match_serial() {
        let ask = |server: &Server, req: &str| -> String {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line
        };
        let serial = Server::spawn("127.0.0.1:0").unwrap();
        let threaded =
            Server::spawn_with("127.0.0.1:0", ExecBackend::Threaded(3)).unwrap();
        for req in ["HIST 700 11", "DP 64 4 2"] {
            let a = ask(&serial, req);
            let b = ask(&threaded, req);
            assert!(a.starts_with("OK"), "{req}: {a}");
            assert_eq!(a, b, "{req}: cycles/energy/results must be backend-invariant");
        }
        serial.shutdown();
        threaded.shutdown();
    }

    #[test]
    fn pipelined_burst_replies_in_request_order() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // one write carrying a whole session: LOAD, a pipelined burst of
        // shared reads racing each other through the pool, an exclusive
        // DROP fencing them, then QUIT
        let script = "LOAD HIST 300 5\nPING\nHIST 1\nHIST 1\nHIST 1\nPING\nDROP 1\nHIST 1\nQUIT\n";
        conn.write_all(script.as_bytes()).unwrap();
        let mut replies = Vec::new();
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            replies.push(line.trim().to_string());
            line.clear();
        }
        assert_eq!(replies.len(), 9, "{replies:?}");
        assert!(replies[0].starts_with("OK id=1 kind=hist"), "{}", replies[0]);
        assert_eq!(replies[1], "PONG");
        assert!(replies[2].contains("dataset=1"), "{}", replies[2]);
        assert_eq!(replies[2], replies[3]);
        assert_eq!(replies[3], replies[4]);
        assert_eq!(replies[5], "PONG");
        assert_eq!(replies[6], "OK dropped=1");
        // the post-DROP query observes the drop: admission ordered it
        // after the exclusive request
        assert!(replies[7].starts_with("ERR"), "{}", replies[7]);
        assert_eq!(replies[8], "BYE");
        server.shutdown();
    }

    #[test]
    fn serve_options_single_worker_exclusive_only_matches_default() {
        let opts = ServeOptions {
            backend: ExecBackend::Serial,
            workers: 1,
            shared_read: false,
        };
        let strict = Server::spawn_opts("127.0.0.1:0", opts).unwrap();
        let relaxed = Server::spawn("127.0.0.1:0").unwrap();
        let session = |server: &Server| -> Vec<String> {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(b"LOAD SEARCH 400 9\nSEARCH 1 100 5000\nSEARCH 1 0 4294967295\nQUIT\n")
                .unwrap();
            let mut replies = Vec::new();
            let mut line = String::new();
            while reader.read_line(&mut line).unwrap() > 0 {
                replies.push(line.trim().to_string());
                line.clear();
            }
            replies
        };
        let a = session(&strict);
        let b = session(&relaxed);
        assert_eq!(a.len(), 4, "{a:?}");
        assert!(a[1].contains("count="), "{}", a[1]);
        assert_eq!(a, b, "shared-read admission must not change any reply byte");
        strict.shutdown();
        relaxed.shutdown();
    }

    #[test]
    fn full_table_load_evicts_and_reports_victim() {
        let mut sess = Session::default();
        let count = |sess: &Session| sess.ns.datasets.read().unwrap().len();
        let has = |sess: &Session, id: u64| sess.ns.datasets.read().unwrap().contains_key(&id);
        for _ in 0..MAX_DATASETS {
            let r = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &sess)
                .unwrap()
                .unwrap();
            assert!(!r.contains("evicted="), "{r}");
        }
        assert_eq!(count(&sess), MAX_DATASETS);
        // touch every dataset except id 2: id 2 becomes the LRU among
        // equal-wear candidates and must be the victim
        for id in 1..=MAX_DATASETS as u64 {
            if id != 2 {
                let q = dispatch(&format!("HIST {id}"), ExecBackend::Serial, &mut sess)
                    .unwrap()
                    .unwrap();
                assert!(q.starts_with("OK"), "{q}");
            }
        }
        let r = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &sess)
            .unwrap()
            .unwrap();
        assert!(r.ends_with("evicted=2"), "{r}");
        assert_eq!(count(&sess), MAX_DATASETS);
        assert!(!has(&sess, 2));
        assert!(has(&sess, 17), "ids stay monotonic");
        // a malformed LOAD into the full table must not evict anything
        assert!(load_dataset(&["HIST", "x", "3"], ExecBackend::Serial, &sess).is_err());
        assert_eq!(count(&sess), MAX_DATASETS);
    }

    #[test]
    fn batched_search_wire_form_matches_singles_and_shared_dispatch() {
        let mut sess = Session::default();
        let loaded = load_dataset(&["SEARCH", "400", "9"], ExecBackend::Serial, &sess)
            .unwrap()
            .unwrap();
        assert!(loaded.starts_with("OK id=1 kind=search"), "{loaded}");
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };
        let ask = |sess: &mut Session, req: &str| {
            dispatch(req, ExecBackend::Serial, sess).unwrap().unwrap()
        };

        let a = ask(&mut sess, "SEARCH 1 100 5000");
        let b = ask(&mut sess, "SEARCH 1 6000 40000");
        let batched = ask(&mut sess, "SEARCH 1 2 100 5000 6000 40000");
        assert_eq!(field(&batched, "batch="), "2", "{batched}");
        assert_eq!(
            field(&batched, "counts="),
            format!("{},{}", field(&a, "count="), field(&b, "count=")),
            "batched counts must match the two single-range queries"
        );
        // packing both ranges into one sweep shares the reduction-tree
        // drain: strictly cheaper than the two singles summed
        let cyc = |r: &str| field(r, "cycles=").parse::<u64>().unwrap();
        assert!(cyc(&batched) < cyc(&a) + cyc(&b), "{batched} vs {a} + {b}");

        // the shared-read path admits the batched form, stamps recency,
        // and replies byte-identically to exclusive dispatch
        assert!(classify("SEARCH 1 2 100 5000 6000 40000", &sess, true));
        assert!(!classify("SEARCH 400 9", &sess, true), "one-shots stay exclusive");
        let stamp = |sess: &Session| {
            sess.ns.datasets.read().unwrap()[&1]
                .last_used
                .load(Ordering::Relaxed)
        };
        let before = stamp(&sess);
        let shared = dispatch_shared("SEARCH 1 2 100 5000 6000 40000", &sess.ns).unwrap();
        assert_eq!(shared, batched);
        assert!(stamp(&sess) > before, "batched shared reads must refresh last_used");

        // malformed batched lines are clean errors, not panics: odd
        // operand count, B < 2, and kernels without a batched grammar
        assert!(dispatch("SEARCH 1 2 100 5000 6000", ExecBackend::Serial, &mut sess).is_err());
        assert!(dispatch("SEARCH 1 1 100 5000", ExecBackend::Serial, &mut sess).is_err());
        let hist = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &sess)
            .unwrap()
            .unwrap();
        assert!(hist.starts_with("OK id=2"), "{hist}");
        let err = dispatch("HIST 2 5 7", ExecBackend::Serial, &mut sess).unwrap_err();
        assert!(err.to_string().contains("no batched query form"), "{err}");
    }

    #[test]
    fn shared_reads_refresh_recency_for_the_evictor() {
        let mut sess = Session::default();
        for _ in 0..MAX_DATASETS {
            load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &sess).unwrap();
        }
        // exclusive-query every dataset except id 7…
        for id in 1..=MAX_DATASETS as u64 {
            if id != 7 {
                let q = dispatch(&format!("HIST {id}"), ExecBackend::Serial, &mut sess)
                    .unwrap()
                    .unwrap();
                assert!(q.starts_with("OK"), "{q}");
            }
        }
        // …id 7 stays hot through shared reads ONLY: its recency stamp
        // must come from dispatch_shared
        let r = dispatch_shared("HIST 7", &sess.ns).unwrap();
        assert!(r.starts_with("OK"), "{r}");
        let r = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &sess)
            .unwrap()
            .unwrap();
        // were shared reads not stamping last_used, id 7 would still
        // carry its load-time stamp — the oldest — and be evicted; the
        // true LRU is id 1 (first exclusive query of the touch loop)
        assert!(r.ends_with("evicted=1"), "{r}");
        assert!(
            sess.ns.datasets.read().unwrap().contains_key(&7),
            "shared-read-hot dataset evicted"
        );
    }

    #[test]
    fn stats_verb_tracks_cache_and_invalidation_forces_resynthesis() {
        let mut sess = Session::default();
        load_dataset(&["SEARCH", "300", "5"], ExecBackend::Serial, &sess).unwrap();
        let ask = |sess: &mut Session, req: &str| {
            dispatch(req, ExecBackend::Serial, sess).unwrap().unwrap()
        };
        let stats = |sess: &mut Session| -> (u64, u64) {
            let r = dispatch("STATS 1", ExecBackend::Serial, sess).unwrap().unwrap();
            let field = |key: &str| {
                r.split_whitespace()
                    .find_map(|kv| kv.strip_prefix(key))
                    .unwrap()
                    .parse::<u64>()
                    .unwrap()
            };
            (field("cache_hits="), field("cache_misses="))
        };

        assert_eq!(stats(&mut sess), (0, 0), "cache born empty");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (0, 1), "first query synthesizes");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (1, 1), "repeat query hits the cache");
        // a batched query is its own cache key
        ask(&mut sess, "SEARCH 1 2 10 20 30 40");
        ask(&mut sess, "SEARCH 1 2 10 20 30 40");
        assert_eq!(stats(&mut sess), (2, 2));
        // FAULTS (arming and disarming) flushes every resident cache:
        // the repeat of a previously-hot query must re-synthesize
        ask(&mut sess, "FAULTS 0 9");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (2, 3), "post-FAULTS query must miss");
        ask(&mut sess, "FAULTS OFF");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (2, 4));
        // DROP destroys the cache with the dataset; a reload starts cold
        assert_eq!(ask(&mut sess, "DROP 1"), "OK dropped=1");
        assert!(dispatch("STATS 1", ExecBackend::Serial, &mut sess).is_err());
        load_dataset(&["SEARCH", "300", "5"], ExecBackend::Serial, &sess).unwrap();
        assert_eq!(
            ask(&mut sess, "STATS 2"),
            "OK dataset=2 cache_hits=0 cache_misses=0 coal_batches=0 coal_members=0 coal_cycles=0"
        );
    }

    #[test]
    fn fair_gate_grants_in_ticket_order_so_writers_cannot_be_starved() {
        let gate = Arc::new(FairGate::new());
        // a lone reader stream never stalls on the batch cap: every full
        // release drains the batch and resets it
        for _ in 0..(READER_BATCH * 2 + 1) {
            drop(gate.lock_shared());
        }
        // FIFO: with the gate held exclusively, a reader ticket drawn
        // BEFORE a writer ticket is granted first once the holder leaves
        // — and symmetrically the queued writer admits right after that
        // reader drains, no matter how the threads raced to wait
        let held = gate.lock_exclusive();
        let rt = gate.ticket();
        let wt = gate.ticket();
        let (tx, rx) = channel::<&'static str>();
        let reader = {
            let gate = gate.clone();
            let tx = tx.clone();
            std::thread::spawn(move || {
                let g = gate.lock_shared_at(rt);
                tx.send("reader").unwrap();
                drop(g);
            })
        };
        let writer = {
            let gate = gate.clone();
            std::thread::spawn(move || {
                let g = gate.lock_exclusive_at(wt);
                tx.send("writer").unwrap();
                drop(g);
            })
        };
        drop(held);
        assert_eq!(rx.recv().unwrap(), "reader");
        assert_eq!(rx.recv().unwrap(), "writer");
        reader.join().unwrap();
        writer.join().unwrap();
    }

    #[test]
    fn fair_gate_bounds_concurrent_readers_per_batch() {
        let gate = Arc::new(FairGate::new());
        let held: Vec<GateShared> = (0..READER_BATCH).map(|_| gate.lock_shared()).collect();
        let (tx, rx) = channel::<()>();
        let over = {
            let gate = gate.clone();
            std::thread::spawn(move || {
                drop(gate.lock_shared());
                tx.send(()).unwrap();
            })
        };
        // the reader over the cap must wait for the batch to drain…
        assert!(
            rx.recv_timeout(Duration::from_millis(80)).is_err(),
            "reader admitted past READER_BATCH"
        );
        drop(held);
        // …and must then be admitted (no lost wakeup, no deadlock)
        rx.recv_timeout(Duration::from_secs(10))
            .expect("reader starved after batch drain");
        over.join().unwrap();
    }

    #[test]
    fn coalesced_group_replies_match_solo_shared_dispatch() {
        let sess = Session::default();
        load_dataset(&["SEARCH", "400", "9"], ExecBackend::Serial, &sess).unwrap();
        let ns = &sess.ns;
        let lines = ["SEARCH 1 100 5000", "SEARCH 1 100 5000", "SEARCH 1 6000 40000"];
        let solo: Vec<String> = lines
            .iter()
            .map(|l| dispatch_shared(l, ns).unwrap())
            .collect();
        let members: Vec<CoalMember> = lines
            .iter()
            .enumerate()
            .map(|(i, l)| CoalMember {
                conn: 1,
                seq: i as u64,
                line: (*l).into(),
            })
            .collect();
        let (tx, rx) = channel::<Done>();
        run_coalesced(ns, 1, &members, &tx);
        let mut got: Vec<Done> = (0..lines.len()).map(|_| rx.recv().unwrap()).collect();
        got.sort_by_key(|d| d.seq);
        for (d, want) in got.iter().zip(&solo) {
            assert!(d.shared);
            match &d.outcome {
                Outcome::Line(l) => assert_eq!(l, want, "coalesced reply differs from solo"),
                Outcome::Bye => panic!("BYE from a coalesced member"),
            }
        }
        // the slot recorded one batch of three with a modeled batch
        // timeline strictly below three solo sweeps (tentpole (c))
        let slot = slot_of(ns, 1).unwrap();
        assert_eq!(slot.coal_batches.load(Ordering::Relaxed), 1);
        assert_eq!(slot.coal_members.load(Ordering::Relaxed), 3);
        let batch_cycles = slot.coal_cycles.load(Ordering::Relaxed);
        assert!(batch_cycles > 0);
        let solo_cycles: u64 = solo[0]
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("cycles="))
            .unwrap()
            .parse()
            .unwrap();
        assert!(
            batch_cycles < 3 * solo_cycles,
            "batch {batch_cycles} vs 3 x solo {solo_cycles}"
        );
        // a group whose grouping key vanished (e.g. the dataset was
        // dropped between the mux sweep and execution) falls back to
        // per-member solo dispatch: byte-identical replies, and the
        // coalescing counters do not advance
        let (tx2, rx2) = channel::<Done>();
        run_coalesced(ns, 99, &members, &tx2);
        let mut fb: Vec<Done> = (0..members.len()).map(|_| rx2.recv().unwrap()).collect();
        fb.sort_by_key(|d| d.seq);
        for (d, want) in fb.iter().zip(&solo) {
            match &d.outcome {
                Outcome::Line(l) => assert_eq!(l, want, "fallback reply differs from solo"),
                Outcome::Bye => panic!("BYE from a fallback member"),
            }
        }
        assert_eq!(slot.coal_batches.load(Ordering::Relaxed), 1);
        assert_eq!(slot.coal_members.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn namespace_is_shared_across_connections() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut a = TcpStream::connect(server.addr).unwrap();
        let mut ra = BufReader::new(a.try_clone().unwrap());
        let mut b = TcpStream::connect(server.addr).unwrap();
        let mut rb = BufReader::new(b.try_clone().unwrap());
        let ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            let mut line = String::new();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };

        // connection A loads; connection B sees the dataset and queries it
        let loaded = ask(&mut a, &mut ra, "LOAD SEARCH 400 9");
        assert!(loaded.starts_with("OK id=1 kind=search"), "{loaded}");
        assert_eq!(
            ask(&mut b, &mut rb, "DATASETS"),
            "OK count=1 epoch=1 ds=1:search:400:1"
        );
        let qa = ask(&mut a, &mut ra, "SEARCH 1 100 5000");
        let qb = ask(&mut b, &mut rb, "SEARCH 1 100 5000");
        assert_eq!(qa, qb, "cross-connection replies must be byte-identical");
        // the compiled-program cache is shared too (satellite 1): A's
        // query synthesized the plan, B's identical query hit it
        let stats = ask(&mut b, &mut rb, "STATS 1");
        assert!(
            stats.contains("cache_hits=1") && stats.contains("cache_misses=1"),
            "{stats}"
        );
        // B may DROP what A loaded; A observes the fence
        assert_eq!(ask(&mut b, &mut rb, "DROP 1"), "OK dropped=1");
        assert_eq!(ask(&mut a, &mut ra, "SEARCH 1 100 5000"), "ERR unknown dataset 1");
        assert_eq!(ask(&mut a, &mut ra, "DATASETS"), "OK count=0 epoch=2");
        server.shutdown();
    }
}
