//! TCP front-end for the PRINS device: a line-oriented protocol so
//! external processes (or `prins serve` + netcat) can drive the device
//! like a network-attached storage appliance.
//!
//! The full wire protocol — every verb (including the `RACK` sharding
//! forms and the resident-dataset verbs), the reply grammar, error
//! replies, and worked netcat sessions — is specified in
//! `docs/PROTOCOL.md`; keep that file authoritative. Summary:
//!
//!   PING | RACK \[n\] | LOAD | DATASETS | DROP | HIST | DP | ED | SPMV
//!   | SEARCH | QUIT
//!
//! Every kernel verb is dispatched through the **kernel registry**
//! ([`crate::algorithms::kernel::registry`]): this module contains zero
//! per-kernel code — a new registered workload (e.g. SEARCH) gets its
//! `LOAD` form, its dataset-id query form and its one-shot form without
//! touching the server. One-shot verbs load + query on a rack sized by
//! the session (`RACK <n>`, default 1 device) and report query-phase
//! stats; with ≥ 2 shards replies gain `shards=`/`link_bytes=` fields.
//!
//! **Resident datasets** (load-once / query-many, DESIGN.md §Resident
//! datasets): `LOAD <kind> ...` synthesizes a dataset server-side, loads
//! it onto a rack resident in the session, and returns a dataset id; the
//! kernel verbs' short (dataset-id) forms then query the resident data
//! without reloading — repeated queries charge only query cycles.
//! `DATASETS` lists the session's registry, `DROP <id>` frees one entry.
//! Sessions are isolated: ids, shard counts, and resident data are
//! per-connection and die with it.
//!
//! (std::net + a thread per connection; the vendored crate set has no
//! tokio — documented in Cargo.toml.)

use super::rack::{PrinsRack, RackStats};
use crate::algorithms::kernel::{find_verb, registry, QueryOut, ResidentDyn};
use crate::error::{bail, ensure, Result};
use crate::rcam::{DeviceModel, ExecBackend, InterconnectModel};
use crate::reliability::{FaultModel, FidelityReport};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Read poll interval: connection threads wake this often to observe the
/// stop flag, so `shutdown()` can join every thread even while a client
/// holds its connection open without sending.
const READ_POLL: Duration = Duration::from_millis(50);

/// Write timeout: a client that stops draining its receive buffer gets
/// disconnected after this long instead of pinning its worker thread in
/// `write` forever (which would make `shutdown()` hang on the join).
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// A running TCP front-end: acceptor thread + one worker per connection.
pub struct Server {
    /// The resolved listen address (useful with ephemeral-port binds).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Server {
    /// Bind and serve on a background thread with the serial simulator
    /// backend. Bind to port 0 for an ephemeral port (`self.addr`
    /// carries the resolved address).
    pub fn spawn(bind: &str) -> Result<Server> {
        Self::spawn_with(bind, ExecBackend::Serial)
    }

    /// [`Server::spawn`] with an explicit simulator execution backend for
    /// the per-request PRINS devices. Replies (cycles, energy, results)
    /// are bit-identical across backends; the knob only sets simulation
    /// speed per request.
    pub fn spawn_with(bind: &str, backend: ExecBackend) -> Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let (stop2, conns2) = (stop.clone(), conns.clone());
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // accepted sockets can inherit the listener's
                        // non-blocking mode on some platforms; reset it or
                        // the timeouts below would be ineffective
                        stream.set_nonblocking(false).ok();
                        stream.set_read_timeout(Some(READ_POLL)).ok();
                        stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
                        let st = stop2.clone();
                        let h = std::thread::spawn(move || {
                            let _ = handle_conn(stream, st, backend);
                        });
                        let mut guard = conns2.lock().unwrap();
                        // reap finished workers so a long-running server
                        // does not accumulate one handle per connection
                        let mut i = 0;
                        while i < guard.len() {
                            if guard[i].is_finished() {
                                let _ = guard.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        guard.push(h);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server {
            addr,
            stop,
            handle: Some(handle),
            conns,
        })
    }

    /// Stop accepting, then join the acceptor AND every connection worker
    /// (workers poll the stop flag at `READ_POLL`, so this cannot hang on
    /// an idle client).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let workers: Vec<_> = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in workers {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Most resident datasets one session may hold at once (each holds live
/// simulated shard arrays; `DROP` frees slots).
const MAX_DATASETS: usize = 16;

/// Per-connection protocol state: the shard count selected by `RACK <n>`
/// (1 = single-device, the default) and the resident-dataset registry
/// (`LOAD`/`DATASETS`/`DROP`); see `docs/PROTOCOL.md` §Sessions.
struct Session {
    shards: usize,
    datasets: BTreeMap<u64, Box<dyn ResidentDyn>>,
    next_id: u64,
    /// Fault model applied to racks built for future loads/one-shots
    /// (`FAULTS <ber> <seed> [stuck_n]`); `None` = ideal device.
    fault: Option<FaultModel>,
}

impl Default for Session {
    fn default() -> Self {
        Session {
            shards: 1,
            datasets: BTreeMap::new(),
            next_id: 1,
            fault: None,
        }
    }
}

fn handle_conn(stream: TcpStream, stop: Arc<AtomicBool>, backend: ExecBackend) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut sess = Session::default();
    loop {
        buf.clear();
        // Accumulate one raw line; the read timeout doubles as the
        // stop-flag poll. Bytes are collected with read_until (not
        // read_line) so a timeout landing mid-multi-byte character
        // cannot drop already-consumed bytes — everything read stays
        // appended to `buf` across timeouts.
        let n = loop {
            if stop.load(Ordering::Acquire) {
                return Ok(()); // server shutting down
            }
            match reader.read_until(b'\n', &mut buf) {
                Ok(n) => break n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(e) => return Err(e.into()),
            }
        };
        if n == 0 && buf.is_empty() {
            return Ok(()); // client closed
        }
        let line = String::from_utf8_lossy(&buf);
        let reply = match dispatch(line.trim(), backend, &mut sess) {
            Ok(Some(r)) => r,
            Ok(None) => {
                writeln!(out, "BYE")?;
                return Ok(());
            }
            Err(e) => format!("ERR {e}"),
        };
        writeln!(out, "{reply}")?;
        if n == 0 {
            return Ok(()); // EOF after a final unterminated line
        }
    }
}

/// The rack a session's sharded verbs execute on: session shard count,
/// default device model + interconnect, the server's simulator backend,
/// plus the session's fault model when `FAULTS` is active.
fn rack_for(sess: &Session, backend: ExecBackend) -> Result<PrinsRack> {
    let rack = PrinsRack::with_config(
        sess.shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    );
    match &sess.fault {
        Some(model) => rack.with_fault(model.clone()),
        None => Ok(rack),
    }
}

/// Key=value reply-line builder: the single place the `OK …` grammar is
/// emitted (docs/PROTOCOL.md §Reply grammar). Every verb — including
/// every registry-driven kernel verb — assembles its reply through this
/// builder, so the `query_ok`/`load_fields` grammar cannot drift between
/// verbs or kernels.
struct Reply {
    line: String,
}

impl Reply {
    /// Start an `OK` reply.
    fn ok() -> Reply {
        Reply { line: "OK".into() }
    }

    /// Append one `key=value` field.
    fn kv(mut self, key: &str, value: impl std::fmt::Display) -> Reply {
        self.line.push(' ');
        self.line.push_str(key);
        self.line.push('=');
        self.line.push_str(&value.to_string());
        self
    }

    /// Append pre-formatted fields (a kernel's verb-specific
    /// `fields` string from the registry formatter). Empty = no-op.
    fn fields(mut self, fields: &str) -> Reply {
        if !fields.is_empty() {
            self.line.push(' ');
            self.line.push_str(fields);
        }
        self
    }

    /// The finished reply line.
    fn finish(self) -> String {
        self.line
    }
}

/// Picojoule formatting of the `energy_pj=` field (one decimal, like
/// every reply since PR 3).
fn pj(energy_j: f64) -> String {
    format!("{:.1}", energy_j * 1e12)
}

/// Stats prefix + kernel fields of every kernel reply: single-device
/// grammar (per-shard device stats, no link charge) when the run used
/// one shard, rack grammar (`shards=`/`link_bytes=`) otherwise.
fn stats_reply(rs: &RackStats, fields: &str) -> Reply {
    if rs.shards >= 2 {
        Reply::ok()
            .kv("cycles", rs.total_cycles)
            .kv("energy_pj", pj(rs.energy_j))
            .fields(fields)
            .kv("shards", rs.shards)
            .kv("link_bytes", rs.link_bytes)
    } else {
        let st = &rs.shard_stats[0];
        Reply::ok()
            .kv("cycles", st.cycles)
            .kv("energy_pj", pj(st.energy_j(&DeviceModel::default())))
            .fields(fields)
    }
}

/// Append the reliability fields when the query ran on a faulty rack
/// (docs/PROTOCOL.md §Fault injection): `fidelity=` always, and a
/// `warn=residual-faults retries=` pair when corruption survived every
/// scrub/retry — graceful degradation instead of a dropped reply. On an
/// ideal rack this is a no-op, so existing replies stay byte-identical.
fn fid_reply(r: Reply, fid: &Option<FidelityReport>) -> Reply {
    let Some(f) = fid else { return r };
    let r = r.kv("fidelity", format!("{:.6}", f.fidelity));
    if f.residual > 0 {
        r.kv("warn", "residual-faults").kv("retries", f.retries)
    } else {
        r
    }
}

/// Reply line of a resident-dataset query (docs/PROTOCOL.md §Resident
/// datasets): the shared stats grammar with the trailing `dataset=`
/// marker.
fn query_ok(out: &QueryOut, id: u64) -> String {
    fid_reply(stats_reply(&out.rack, &out.fields), &out.fidelity)
        .kv("dataset", id)
        .finish()
}

/// `load_cycles=` (and, when sharded, `load_link_bytes=`) fields of a
/// `LOAD` reply — the one-time load-phase price the dataset id amortizes.
fn load_fields(rs: &RackStats) -> String {
    if rs.shards >= 2 {
        format!(
            "load_cycles={} load_link_bytes={}",
            rs.total_cycles, rs.link_bytes
        )
    } else {
        format!("load_cycles={}", rs.shard_stats[0].cycles)
    }
}

/// The `LOAD` usage string, assembled from the registry (a new kernel's
/// `LOAD` form appears here without touching the server).
fn load_usage() -> String {
    let forms: Vec<&str> = registry().iter().map(|e| e.load_usage).collect();
    format!("usage: {}", forms.join(" | "))
}

/// `LOAD <KIND> ...`: synthesize a dataset server-side via the kind's
/// registry entry, load it once onto a rack with the session's current
/// shard count, and register it under a fresh id. Every subsequent
/// dataset-id kernel verb reuses the resident rows and charges only
/// query cycles. The shard layout is fixed at `LOAD` time; later `RACK`
/// changes affect only future loads.
fn load_dataset(
    args: &[&str],
    backend: ExecBackend,
    sess: &mut Session,
) -> Result<Option<String>> {
    if sess.datasets.len() >= MAX_DATASETS {
        // name the recovery verb and the droppable ids so a client can
        // free a slot without a round-trip to DATASETS
        let ids: Vec<String> = sess.datasets.keys().map(u64::to_string).collect();
        bail!(
            "dataset limit reached (max {}); DROP one of ids [{}] to free a slot",
            MAX_DATASETS,
            ids.join(",")
        );
    }
    // kinds are case-sensitive wire verbs, exactly like the kernel verbs
    let Some(entry) = args.first().and_then(|kind| find_verb(kind)) else {
        bail!("{}", load_usage());
    };
    let rack = rack_for(sess, backend)?;
    let data = (entry.load)(&rack, &args[1..])?;
    let id = sess.next_id;
    sess.next_id += 1;
    let reply = Reply::ok()
        .kv("id", id)
        .kv("kind", data.name())
        .kv("n", data.rows())
        .kv("shards", data.load_report().shards)
        .fields(&load_fields(data.load_report()))
        .finish();
    sess.datasets.insert(id, data);
    Ok(Some(reply))
}

/// A registered kernel verb, dispatched by arity (docs/PROTOCOL.md):
/// `<VERB> id params…` (the dataset-id query form) when the arg count
/// matches the kernel's query arity + 1, `<VERB> …` (the one-shot form)
/// when it matches the one-shot arity. No per-kernel code: parsing,
/// synthesis and reply fields all come from the registry entry.
fn kernel_verb(
    verb: &str,
    args: &[&str],
    backend: ExecBackend,
    sess: &mut Session,
) -> Result<Option<String>> {
    let Some(entry) = find_verb(verb) else {
        bail!("unknown command");
    };
    if args.len() == entry.query_arity + 1 {
        // dataset-id query: no reload, query cycles only
        let id: u64 = args[0].parse()?;
        let Some(data) = sess.datasets.get_mut(&id) else {
            bail!("unknown dataset {id}");
        };
        ensure!(
            data.name() == entry.name,
            "dataset {id} is kind {}, not {}",
            data.name(),
            entry.name
        );
        let out = data.query_args(&args[1..])?;
        Ok(Some(query_ok(&out, id)))
    } else if args.len() == entry.one_shot_arity {
        let rack = rack_for(sess, backend)?;
        let out = (entry.one_shot)(&rack, args)?;
        Ok(Some(
            fid_reply(stats_reply(&out.rack, &out.fields), &out.fidelity).finish(),
        ))
    } else {
        bail!("usage: {} | {}", entry.one_shot_usage, entry.query_usage);
    }
}

fn dispatch(line: &str, backend: ExecBackend, sess: &mut Session) -> Result<Option<String>> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => Ok(Some("PONG".into())),
        ["QUIT"] => Ok(None),
        ["RACK"] => Ok(Some(Reply::ok().kv("shards", sess.shards).finish())),
        ["RACK", n] => {
            let n: usize = n.parse()?;
            ensure!(
                (1..=crate::rcam::shard::MAX_SHARDS).contains(&n),
                "shards out of range (1..={})",
                crate::rcam::shard::MAX_SHARDS
            );
            sess.shards = n;
            Ok(Some(Reply::ok().kv("shards", n).finish()))
        }
        // ----- resident-dataset registry (docs/PROTOCOL.md) -------------
        ["LOAD", rest @ ..] => load_dataset(rest, backend, sess),
        ["DATASETS"] => {
            let mut reply = Reply::ok().kv("count", sess.datasets.len());
            for (id, e) in &sess.datasets {
                reply = reply.kv(
                    "ds",
                    format!("{id}:{}:{}:{}", e.name(), e.rows(), e.load_report().shards),
                );
            }
            Ok(Some(reply.finish()))
        }
        ["DROP", id] => {
            let id: u64 = id.parse()?;
            ensure!(sess.datasets.remove(&id).is_some(), "unknown dataset {id}");
            Ok(Some(Reply::ok().kv("dropped", id).finish()))
        }
        // ----- fault injection (docs/PROTOCOL.md §Fault injection) ------
        ["FAULTS"] => Ok(Some(match &sess.fault {
            None => Reply::ok().kv("faults", "off").finish(),
            Some(m) => Reply::ok()
                .kv("faults", "on")
                .kv("ber", m.read_ber)
                .kv("seed", m.seed)
                .kv("stuck", m.random_stuck)
                .finish(),
        })),
        ["FAULTS", "OFF"] => {
            sess.fault = None;
            Ok(Some(Reply::ok().kv("faults", "off").finish()))
        }
        ["FAULTS", rest @ ..] => {
            ensure!(
                rest.len() == 2 || rest.len() == 3,
                "usage: FAULTS | FAULTS OFF | FAULTS ber seed [stuck_n]"
            );
            let ber: f64 = rest[0].parse()?;
            let seed: u64 = rest[1].parse()?;
            let stuck: usize = if rest.len() == 3 { rest[2].parse()? } else { 0 };
            ensure!(
                ber.is_finite() && (0.0..1.0).contains(&ber),
                "BER {} outside [0, 1)",
                ber
            );
            // takes effect on racks built for future LOADs/one-shots;
            // already-resident datasets keep their load-time model
            sess.fault = Some(FaultModel::uniform(ber, seed).with_random_stuck(stuck));
            Ok(Some(
                Reply::ok()
                    .kv("faults", "on")
                    .kv("ber", ber)
                    .kv("seed", seed)
                    .kv("stuck", stuck)
                    .finish(),
            ))
        }
        // ----- kernel verbs: registry-driven, arity-dispatched ----------
        [verb, args @ ..] => kernel_verb(verb, args, backend, sess),
        _ => bail!("unknown command"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn ping_and_hist_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        writeln!(conn, "PING").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        line.clear();
        writeln!(conn, "HIST 500 7").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        assert!(line.contains("total=500"), "{line}");

        line.clear();
        writeln!(conn, "BOGUS").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");

        line.clear();
        writeln!(conn, "QUIT").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "BYE");
        server.shutdown();
    }

    #[test]
    fn rack_session_shards_verbs_and_is_per_connection() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };

        assert_eq!(ask(&mut conn, &mut reader, "RACK"), "OK shards=1");
        assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
        let sharded = ask(&mut conn, &mut reader, "HIST 600 7");
        assert!(sharded.contains("shards=2"), "{sharded}");
        assert!(sharded.contains("link_bytes="), "{sharded}");
        assert!(sharded.contains("total=600"), "{sharded}");
        let spmv = ask(&mut conn, &mut reader, "SPMV 48 300 3");
        assert!(spmv.contains("shards=2") && spmv.contains("checksum="), "{spmv}");
        assert!(ask(&mut conn, &mut reader, "RACK 0").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "RACK 65").starts_with("ERR"));

        // sharded histogram agrees with single-device results and a fresh
        // connection starts unsharded
        let mut conn2 = TcpStream::connect(server.addr).unwrap();
        let mut reader2 = BufReader::new(conn2.try_clone().unwrap());
        let single = ask(&mut conn2, &mut reader2, "HIST 600 7");
        assert!(!single.contains("shards="), "{single}");
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };
        assert_eq!(field(&sharded, "top_bin="), field(&single, "top_bin="));
        assert_eq!(field(&sharded, "total="), field(&single, "total="));
        // rack cycles include the host-link charge, so they exceed the
        // single device's
        let cyc = |r: &str| field(r, "cycles=").parse::<u64>().unwrap();
        assert!(cyc(&sharded) > cyc(&single), "{sharded} vs {single}");
        server.shutdown();
    }

    #[test]
    fn resident_dataset_lifecycle_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };

        // load once, query many: replies repeat bit-for-bit
        let loaded = ask(&mut conn, &mut reader, "LOAD HIST 500 7");
        assert!(loaded.starts_with("OK id=1 kind=hist n=500 shards=1"), "{loaded}");
        assert!(loaded.contains("load_cycles="), "{loaded}");
        let q1 = ask(&mut conn, &mut reader, "HIST 1");
        let q2 = ask(&mut conn, &mut reader, "HIST 1");
        assert!(q1.contains("dataset=1") && q1.contains("total=500"), "{q1}");
        assert_eq!(q1, q2, "resident queries must repeat bit-identically");
        // the resident query matches the one-shot verb's result values
        let one_shot = ask(&mut conn, &mut reader, "HIST 500 7");
        assert_eq!(field(&q1, "top_bin="), field(&one_shot, "top_bin="));
        assert_eq!(field(&q1, "total="), field(&one_shot, "total="));
        // query cost sits below the one-time load cost
        let load_cycles: u64 = field(&loaded, "load_cycles=").parse().unwrap();
        let query_cycles: u64 = field(&q1, "cycles=").parse().unwrap();
        assert!(query_cycles < load_cycles, "{q1} vs {loaded}");

        // second dataset of a different kind; registry lists both
        let dp = ask(&mut conn, &mut reader, "LOAD DP 32 4 3");
        assert!(dp.starts_with("OK id=2 kind=dp n=32"), "{dp}");
        let dpq = ask(&mut conn, &mut reader, "DP 2 9");
        assert!(dpq.contains("checksum=") && dpq.contains("dataset=2"), "{dpq}");
        // new hyperplane (new seed) on the same resident vectors
        let dpq2 = ask(&mut conn, &mut reader, "DP 2 10");
        assert_ne!(field(&dpq, "checksum="), field(&dpq2, "checksum="));
        assert_eq!(field(&dpq, "cycles="), field(&dpq2, "cycles="));
        assert_eq!(
            ask(&mut conn, &mut reader, "DATASETS"),
            "OK count=2 ds=1:hist:500:1 ds=2:dp:32:1"
        );

        // kind/verb mismatch and unknown ids are errors, not panics
        assert!(ask(&mut conn, &mut reader, "DP 1 5").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "HIST 9").starts_with("ERR"));

        // drop frees the id
        assert_eq!(ask(&mut conn, &mut reader, "DROP 1"), "OK dropped=1");
        assert!(ask(&mut conn, &mut reader, "HIST 1").starts_with("ERR"));
        assert_eq!(
            ask(&mut conn, &mut reader, "DATASETS"),
            "OK count=1 ds=2:dp:32:1"
        );
        server.shutdown();
    }

    #[test]
    fn faults_verb_lifecycle_and_fidelity_fields() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };

        // off by default; malformed configs are rejected, not applied
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS"), "OK faults=off");
        assert!(ask(&mut conn, &mut reader, "FAULTS 1.5 1").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "FAULTS x 1").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "FAULTS 0.1").starts_with("ERR"));
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS"), "OK faults=off");

        // BER=0 faults: replies gain fidelity=1.000000 and values stay exact
        assert_eq!(
            ask(&mut conn, &mut reader, "FAULTS 0 7"),
            "OK faults=on ber=0 seed=7 stuck=0"
        );
        assert_eq!(
            ask(&mut conn, &mut reader, "FAULTS"),
            "OK faults=on ber=0 seed=7 stuck=0"
        );
        let loaded = ask(&mut conn, &mut reader, "LOAD HIST 200 5");
        assert!(loaded.starts_with("OK id=1"), "{loaded}");
        let resident = ask(&mut conn, &mut reader, "HIST 1");
        assert!(resident.contains("fidelity=1.000000"), "{resident}");
        assert!(!resident.contains("warn="), "{resident}");
        let one_shot_faulty = ask(&mut conn, &mut reader, "HIST 200 5");
        assert!(one_shot_faulty.contains("fidelity=1.000000"), "{one_shot_faulty}");

        // FAULTS OFF: new racks are ideal again, but the resident dataset
        // keeps its load-time model and still reports fidelity
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS OFF"), "OK faults=off");
        let one_shot_ideal = ask(&mut conn, &mut reader, "HIST 200 5");
        assert!(!one_shot_ideal.contains("fidelity="), "{one_shot_ideal}");
        assert_eq!(
            field(&one_shot_faulty, "top_bin="),
            field(&one_shot_ideal, "top_bin=")
        );
        assert_eq!(
            field(&one_shot_faulty, "total="),
            field(&one_shot_ideal, "total=")
        );
        let resident2 = ask(&mut conn, &mut reader, "HIST 1");
        assert!(resident2.contains("fidelity="), "{resident2}");
        assert_eq!(field(&resident, "total="), field(&resident2, "total="));
        server.shutdown();
    }

    #[test]
    fn sharded_resident_dataset_replies_carry_rack_fields() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
        let loaded = ask(&mut conn, &mut reader, "LOAD SPMV 48 300 3");
        assert!(loaded.contains("shards=2") && loaded.contains("load_link_bytes="), "{loaded}");
        let q = ask(&mut conn, &mut reader, "SPMV 1 4");
        assert!(
            q.contains("shards=2") && q.contains("link_bytes=") && q.contains("dataset=1"),
            "{q}"
        );
        // datasets keep their load-time shard count even after RACK changes
        assert_eq!(ask(&mut conn, &mut reader, "RACK 1"), "OK shards=1");
        let q2 = ask(&mut conn, &mut reader, "SPMV 1 4");
        assert_eq!(q, q2, "resident layout is fixed at LOAD time");
        server.shutdown();
    }

    #[test]
    fn spmv_verb_matches_quantized_baseline_checksum() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, "SPMV 64 400 5").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        let got: f32 = line
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("checksum="))
            .unwrap()
            .parse()
            .unwrap();
        let a = crate::workloads::synth_csr(64, 400, 5);
        let mut rng = crate::workloads::Rng::seed_from(6);
        let x: Vec<f32> = (0..64).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let expect: f32 = crate::algorithms::spmv_baseline_quantized(&a, &x).iter().sum();
        assert!((got - expect).abs() < 2e-3, "{got} vs {expect}");
        server.shutdown();
    }

    #[test]
    fn threaded_server_replies_match_serial() {
        let ask = |server: &Server, req: &str| -> String {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line
        };
        let serial = Server::spawn("127.0.0.1:0").unwrap();
        let threaded =
            Server::spawn_with("127.0.0.1:0", ExecBackend::Threaded(3)).unwrap();
        for req in ["HIST 700 11", "DP 64 4 2"] {
            let a = ask(&serial, req);
            let b = ask(&threaded, req);
            assert!(a.starts_with("OK"), "{req}: {a}");
            assert_eq!(a, b, "{req}: cycles/energy/results must be backend-invariant");
        }
        serial.shutdown();
        threaded.shutdown();
    }
}
