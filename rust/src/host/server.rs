//! TCP front-end for the PRINS device: a line-oriented protocol so
//! external processes (or `prins serve` + netcat) can drive the device
//! like a network-attached storage appliance.
//!
//! The full wire protocol — every verb (including the `RACK` sharding
//! forms and the resident-dataset verbs), the reply grammar, error
//! replies, and worked netcat sessions — is specified in
//! `docs/PROTOCOL.md`; keep that file authoritative. Summary:
//!
//!   PING | RACK \[n\] | LOAD | DATASETS | DROP | STATS | HIST | DP | ED
//!   | SPMV | SEARCH | QUIT
//!
//! Every kernel verb is dispatched through the **kernel registry**
//! ([`crate::algorithms::kernel::registry`]): this module contains zero
//! per-kernel code — a new registered workload (e.g. SEARCH) gets its
//! `LOAD` form, its dataset-id query form and its one-shot form without
//! touching the server. One-shot verbs load + query on a rack sized by
//! the session (`RACK <n>`, default 1 device) and report query-phase
//! stats; with ≥ 2 shards replies gain `shards=`/`link_bytes=` fields.
//!
//! **Resident datasets** (load-once / query-many, DESIGN.md §Resident
//! datasets): `LOAD <kind> ...` synthesizes a dataset server-side, loads
//! it onto a rack resident in the session, and returns a dataset id; the
//! kernel verbs' short (dataset-id) forms then query the resident data
//! without reloading — repeated queries charge only query cycles. The
//! table holds at most [`MAX_DATASETS`] entries; a `LOAD` into a full
//! table evicts the least-recently-used dataset among the coldest-wear
//! candidates and reports it in a trailing `evicted=` field. `DATASETS`
//! lists the session's registry, `DROP <id>` frees one entry. Sessions
//! are isolated: ids, shard counts, and resident data are
//! per-connection and die with it.
//!
//! Kernels with a **batched query form** (docs/PROTOCOL.md §Batched
//! queries) accept a longer dataset-id line — `SEARCH id B lo1 hi1 …`
//! (B ≥ 2) — packing B operands into one in-array sweep; dispatch is
//! still purely by arity. `STATS <id>` reports a resident dataset's
//! compiled-program cache counters (`cache_hits=`/`cache_misses=`),
//! kept out of query replies so repeated queries stay byte-identical.
//!
//! **Serving model** (DESIGN.md §Serving): one readiness-polled
//! multiplexer thread owns every connection — non-blocking accepts,
//! per-connection input/output buffers, line framing that tolerates
//! arbitrary packet splits and coalescing — and a worker pool runs the
//! simulations. Clients may pipeline many request lines on one
//! connection; replies always return in request order. Write-free
//! resident queries (kernels opting into `Kernel::SHARED_READ`) are
//! admitted as concurrent *shared readers* over the same resident rows;
//! loads, drops, and every other verb take the session exclusively.
//! (std::net only; the vendored crate set has no tokio — documented in
//! Cargo.toml.)

use super::rack::{PrinsRack, RackStats};
use crate::algorithms::kernel::{find_verb, registry, QueryOut, ResidentDyn};
use crate::error::{bail, ensure, Result};
use crate::rcam::{DeviceModel, ExecBackend, InterconnectModel};
use crate::reliability::{FaultModel, FidelityReport};
use std::collections::{BTreeMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Multiplexer idle nap: when a readiness sweep moved no bytes, framed
/// no lines, and completed no work, the mux sleeps this long before the
/// next sweep (it also observes the stop flag at this cadence).
const IDLE_POLL: Duration = Duration::from_millis(1);

/// Write stall timeout: a client that stops draining its receive buffer
/// while replies are queued gets disconnected after this long instead of
/// buffering output forever (which would also make `shutdown()` slow).
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Tuning knobs of [`Server::spawn_opts`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Simulator execution backend for the per-request PRINS devices.
    /// Replies (cycles, energy, results) are bit-identical across
    /// backends; the knob only sets simulation speed per request.
    pub backend: ExecBackend,
    /// Worker threads running request simulations (≥ 1). Pipelined and
    /// cross-client requests execute concurrently up to this width.
    pub workers: usize,
    /// Admit write-free resident queries as concurrent shared readers.
    /// `false` serializes every request per connection — the
    /// exclusive-access baseline measured by `benches/throughput.rs`.
    pub shared_read: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            backend: ExecBackend::Serial,
            workers: default_workers(),
            shared_read: true,
        }
    }
}

/// Default worker-pool width: the machine's parallelism, clamped so
/// tests and small hosts stay well-behaved.
fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

/// A running TCP front-end: one multiplexer thread plus a worker pool.
pub struct Server {
    /// The resolved listen address (useful with ephemeral-port binds).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    mux: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on background threads with default options
    /// (serial simulator backend, shared reads on). Bind to port 0 for
    /// an ephemeral port (`self.addr` carries the resolved address).
    pub fn spawn(bind: &str) -> Result<Server> {
        Self::spawn_opts(bind, ServeOptions::default())
    }

    /// [`Server::spawn`] with an explicit simulator execution backend for
    /// the per-request PRINS devices.
    pub fn spawn_with(bind: &str, backend: ExecBackend) -> Result<Server> {
        Self::spawn_opts(
            bind,
            ServeOptions {
                backend,
                ..ServeOptions::default()
            },
        )
    }

    /// [`Server::spawn`] with explicit [`ServeOptions`].
    pub fn spawn_opts(bind: &str, opts: ServeOptions) -> Result<Server> {
        ensure!(opts.workers >= 1, "server needs at least one worker");
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<Done>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut workers = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let rx = job_rx.clone();
            let tx = done_tx.clone();
            let backend = opts.backend;
            workers.push(std::thread::spawn(move || worker_loop(rx, tx, backend)));
        }
        drop(done_tx); // workers hold the senders, the mux the receiver
        let stop2 = stop.clone();
        let mux = std::thread::spawn(move || {
            Mux::new(listener, stop2, opts, job_tx, done_rx).run();
        });
        Ok(Server {
            addr,
            stop,
            mux: Some(mux),
            workers,
        })
    }

    /// Stop accepting, then join the multiplexer AND every worker (the
    /// mux observes the stop flag at `IDLE_POLL`; dropping its job
    /// sender unblocks the workers, so this cannot hang on an idle
    /// client).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.mux.take() {
            let _ = h.join();
        }
        for h in std::mem::take(&mut self.workers) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

// ---------------------------------------------------------------------
// Worker pool: simulation happens here, off the multiplexer thread.
// ---------------------------------------------------------------------

/// One request line handed to the worker pool.
struct Job {
    conn: u64,
    seq: u64,
    line: String,
    sess: Arc<RwLock<Session>>,
    shared: bool,
}

/// A finished request on its way back to the multiplexer.
struct Done {
    conn: u64,
    seq: u64,
    shared: bool,
    outcome: Outcome,
}

/// What the multiplexer should do with a finished request.
enum Outcome {
    /// Write this reply line.
    Line(String),
    /// Write `BYE`, drop unserved pipelined input, close after flush.
    Bye,
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, tx: Sender<Done>, backend: ExecBackend) {
    loop {
        let job = {
            let rx = rx.lock().unwrap();
            rx.recv()
        };
        let Ok(job) = job else {
            return; // server shut down: job sender dropped
        };
        let outcome = run_job(&job, backend);
        let done = Done {
            conn: job.conn,
            seq: job.seq,
            shared: job.shared,
            outcome,
        };
        if tx.send(done).is_err() {
            return; // multiplexer gone
        }
    }
}

fn run_job(job: &Job, backend: ExecBackend) -> Outcome {
    if job.shared {
        // read lock: concurrent with every other shared reader of this
        // session; the admission rule keeps writers out while we run
        let sess = job.sess.read().unwrap();
        match dispatch_shared(job.line.trim(), &sess) {
            Ok(r) => Outcome::Line(r),
            Err(e) => Outcome::Line(format!("ERR {e}")),
        }
    } else {
        let mut sess = job.sess.write().unwrap();
        match dispatch(job.line.trim(), backend, &mut sess) {
            Ok(Some(r)) => Outcome::Line(r),
            Ok(None) => Outcome::Bye,
            Err(e) => Outcome::Line(format!("ERR {e}")),
        }
    }
}

// ---------------------------------------------------------------------
// The multiplexer: readiness-polled connection state machine.
// ---------------------------------------------------------------------

/// Per-connection multiplexer state: buffered bytes in both directions,
/// framed-but-undispatched lines, completed-but-unemitted replies, and
/// the admission counters that order shared readers around exclusive
/// requests.
struct Conn {
    stream: TcpStream,
    sess: Arc<RwLock<Session>>,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    /// Framed request lines awaiting dispatch, FIFO, with their reply
    /// sequence numbers.
    pending: VecDeque<(u64, String)>,
    /// Completed replies awaiting in-order emission (reorder buffer:
    /// workers finish out of order, clients see request order).
    done: BTreeMap<u64, Outcome>,
    next_seq: u64,
    next_emit: u64,
    /// Requests dispatched to the pool and not yet completed.
    inflight: usize,
    /// An exclusive (session-mutating) request is in flight: nothing
    /// else may dispatch until it completes.
    exclusive_inflight: bool,
    /// Read side closed; close the connection once fully drained.
    eof: bool,
    /// `BYE` emitted; close once the output buffer flushes.
    bye: bool,
    dead: bool,
    last_progress: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            sess: Arc::new(RwLock::new(Session::default())),
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            pending: VecDeque::new(),
            done: BTreeMap::new(),
            next_seq: 0,
            next_emit: 0,
            inflight: 0,
            exclusive_inflight: false,
            eof: false,
            bye: false,
            dead: false,
            last_progress: Instant::now(),
        }
    }
}

struct Mux {
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
    job_tx: Sender<Job>,
    done_rx: Receiver<Done>,
    conns: BTreeMap<u64, Conn>,
    next_conn: u64,
}

impl Mux {
    fn new(
        listener: TcpListener,
        stop: Arc<AtomicBool>,
        opts: ServeOptions,
        job_tx: Sender<Job>,
        done_rx: Receiver<Done>,
    ) -> Mux {
        Mux {
            listener,
            stop,
            opts,
            job_tx,
            done_rx,
            conns: BTreeMap::new(),
            next_conn: 0,
        }
    }

    fn run(mut self) {
        while !self.stop.load(Ordering::Acquire) {
            let mut busy = self.accept_new();
            busy |= self.drain_completions();
            let ids: Vec<u64> = self.conns.keys().copied().collect();
            for id in ids {
                busy |= self.service_conn(id);
            }
            self.conns.retain(|_, c| !c.dead);
            if !busy {
                std::thread::sleep(IDLE_POLL);
            }
        }
    }

    fn accept_new(&mut self) -> bool {
        let mut busy = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    busy = true;
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let id = self.next_conn;
                    self.next_conn += 1;
                    self.conns.insert(id, Conn::new(stream));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        busy
    }

    /// Route finished work back to its connection's reorder buffer.
    fn drain_completions(&mut self) -> bool {
        let mut busy = false;
        while let Ok(done) = self.done_rx.try_recv() {
            busy = true;
            let Some(c) = self.conns.get_mut(&done.conn) else {
                continue; // connection died while its request ran
            };
            c.inflight -= 1;
            if !done.shared {
                c.exclusive_inflight = false;
            }
            c.done.insert(done.seq, done.outcome);
        }
        busy
    }

    /// One readiness sweep over a single connection: pull bytes, frame
    /// lines, emit completed replies in order, admit pending requests,
    /// flush output. Returns whether anything moved.
    fn service_conn(&mut self, id: u64) -> bool {
        let Some(c) = self.conns.get_mut(&id) else {
            return false;
        };
        let mut busy = false;

        // 1. Pull whatever bytes the socket has ready. Framing below
        //    tolerates any split/coalescing: bytes accumulate in `inbuf`
        //    until a newline lands.
        if !c.eof && !c.bye && !c.dead {
            let mut tmp = [0u8; 4096];
            loop {
                match c.stream.read(&mut tmp) {
                    Ok(0) => {
                        c.eof = true;
                        break;
                    }
                    Ok(n) => {
                        c.inbuf.extend_from_slice(&tmp[..n]);
                        busy = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.dead = true;
                        return true;
                    }
                }
            }
        }

        // 2. Frame complete lines into the pending queue.
        if !c.bye {
            while let Some(pos) = c.inbuf.iter().position(|&b| b == b'\n') {
                let raw: Vec<u8> = c.inbuf.drain(..=pos).collect();
                let line = String::from_utf8_lossy(&raw).into_owned();
                c.pending.push_back((c.next_seq, line));
                c.next_seq += 1;
                busy = true;
            }
            // EOF flushes a final unterminated line, like the pre-mux
            // server did.
            if c.eof && !c.inbuf.is_empty() {
                let line = String::from_utf8_lossy(&c.inbuf).into_owned();
                c.inbuf.clear();
                c.pending.push_back((c.next_seq, line));
                c.next_seq += 1;
                busy = true;
            }
        }

        // 3. Emit completed replies strictly in request order.
        while let Some(outcome) = c.done.remove(&c.next_emit) {
            c.next_emit += 1;
            busy = true;
            match outcome {
                Outcome::Line(r) => {
                    c.outbuf.extend_from_slice(r.as_bytes());
                    c.outbuf.push(b'\n');
                }
                Outcome::Bye => {
                    c.outbuf.extend_from_slice(b"BYE\n");
                    c.pending.clear(); // QUIT discards later pipelined input
                    c.bye = true;
                }
            }
        }

        // 4. Admission: dispatch from the front of the FIFO. Shared
        //    readers pile up concurrently; an exclusive request waits
        //    for the connection to drain, then runs alone — so resident
        //    datasets cannot be dropped or evicted under a running
        //    shared query of the same session.
        if !c.bye && !c.dead {
            loop {
                if c.exclusive_inflight {
                    break;
                }
                let Some((_, line)) = c.pending.front() else {
                    break;
                };
                let shared = match c.sess.try_read() {
                    Ok(sess) => classify(line, &sess, self.opts.shared_read),
                    // a writer holds the session (only possible for our
                    // own exclusive job); retry next sweep
                    Err(_) => break,
                };
                if !shared && c.inflight > 0 {
                    break; // exclusive runs alone: wait for drain
                }
                let (seq, line) = c.pending.pop_front().expect("front checked above");
                c.inflight += 1;
                c.exclusive_inflight = !shared;
                busy = true;
                let job = Job {
                    conn: id,
                    seq,
                    line,
                    sess: c.sess.clone(),
                    shared,
                };
                if self.job_tx.send(job).is_err() {
                    c.dead = true;
                    return true;
                }
                if !shared {
                    break;
                }
            }
        }

        // 5. Flush buffered replies; detect write-stalled clients.
        while !c.outbuf.is_empty() {
            match c.stream.write(&c.outbuf) {
                Ok(0) => {
                    c.dead = true;
                    break;
                }
                Ok(n) => {
                    c.outbuf.drain(..n);
                    c.last_progress = Instant::now();
                    busy = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if c.last_progress.elapsed() >= WRITE_TIMEOUT {
                        c.dead = true;
                    }
                    break;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.dead = true;
                    break;
                }
            }
        }
        if c.outbuf.is_empty() {
            c.last_progress = Instant::now();
        }

        // 6. Close when done: after BYE flushes, or when a closed client
        //    has every pipelined request answered and flushed.
        if c.outbuf.is_empty()
            && (c.bye
                || (c.eof && c.pending.is_empty() && c.inflight == 0 && c.done.is_empty()))
        {
            c.dead = true;
        }
        busy
    }
}

// ---------------------------------------------------------------------
// Sessions, admission classes, and the wear-aware resident table.
// ---------------------------------------------------------------------

/// Capacity of a session's resident-dataset table (each entry holds
/// live simulated shard arrays). A `LOAD` into a full table evicts the
/// least-recently-used dataset among the coldest-wear candidates (see
/// [`evict_for_slot`]); `DROP` still frees slots explicitly.
const MAX_DATASETS: usize = 16;

/// A resident dataset plus the bookkeeping the wear-aware evictor
/// reads: a recency stamp from the session's logical clock, bumped by
/// every query that touches the dataset (atomically, because shared
/// readers touch it concurrently under the session read lock).
struct DatasetEntry {
    res: Box<dyn ResidentDyn>,
    last_used: AtomicU64,
}

/// Per-connection protocol state: the shard count selected by `RACK <n>`
/// (1 = single-device, the default) and the resident-dataset registry
/// (`LOAD`/`DATASETS`/`DROP`); see `docs/PROTOCOL.md` §Sessions.
struct Session {
    shards: usize,
    datasets: BTreeMap<u64, DatasetEntry>,
    next_id: u64,
    /// Fault model applied to racks built for future loads/one-shots
    /// (`FAULTS <ber> <seed> [stuck_n]`); `None` = ideal device.
    fault: Option<FaultModel>,
    /// Logical clock behind the `last_used` recency stamps.
    clock: AtomicU64,
}

impl Default for Session {
    fn default() -> Self {
        Session {
            shards: 1,
            datasets: BTreeMap::new(),
            next_id: 1,
            fault: None,
            clock: AtomicU64::new(0),
        }
    }
}

impl Session {
    /// Next recency stamp (atomic: concurrent shared readers tick too).
    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }
}

/// Admission class of one request line (DESIGN.md §Serving): `true` =
/// shared reader — `PING`, or a registered kernel's dataset-id query
/// form (single or batched) against a resident dataset whose kernel
/// opted into `Kernel::SHARED_READ` and whose rack is fault-free.
/// Everything else — loads, drops, one-shots, session config, malformed
/// lines — is exclusive.
fn classify(line: &str, sess: &Session, shared_read: bool) -> bool {
    if !shared_read {
        return false;
    }
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => true,
        [verb, args @ ..] => {
            let Some(entry) = find_verb(verb) else {
                return false;
            };
            // dataset-id query form, or the longer batched form — never
            // the one-shot form, which builds a fresh rack and must run
            // exclusively
            let query = args.len() == entry.query_arity + 1;
            let batched =
                args.len() > entry.query_arity + 1 && args.len() != entry.one_shot_arity;
            if !query && !batched {
                return false;
            }
            let Ok(id) = args[0].parse::<u64>() else {
                return false;
            };
            let Some(e) = sess.datasets.get(&id) else {
                return false;
            };
            e.res.name() == entry.name && e.res.shared_readable()
        }
        _ => false,
    }
}

/// Read-only dispatcher of the shared admission class: executes the
/// verbs [`classify`] marked shared — `PING` and write-free resident
/// queries — against `&Session`, so many readers run concurrently under
/// the session's read lock. Must produce byte-identical replies to
/// [`dispatch`] for these verbs; the concurrency tests pin that.
fn dispatch_shared(line: &str, sess: &Session) -> Result<String> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => Ok("PONG".into()),
        [verb, args @ ..] => {
            let Some(entry) = find_verb(verb) else {
                bail!("unknown command");
            };
            let query = args.len() == entry.query_arity + 1;
            let batched =
                args.len() > entry.query_arity + 1 && args.len() != entry.one_shot_arity;
            ensure!(query || batched, "not a shared-readable query");
            let id: u64 = args[0].parse()?;
            let Some(e) = sess.datasets.get(&id) else {
                bail!("unknown dataset {id}");
            };
            ensure!(
                e.res.name() == entry.name,
                "dataset {id} is kind {}, not {}",
                e.res.name(),
                entry.name
            );
            let out = if query {
                e.res.query_args_shared(&args[1..])?
            } else {
                e.res.query_args_batch_shared(&args[1..])?
            };
            // every shared read refreshes recency — batched or not — so
            // read-hot datasets stay off the eviction victim list
            e.last_used.store(sess.tick(), Ordering::Relaxed);
            Ok(query_ok(&out, id))
        }
        _ => bail!("unknown command"),
    }
}

/// The rack a session's sharded verbs execute on: session shard count,
/// default device model + interconnect, the server's simulator backend,
/// plus the session's fault model when `FAULTS` is active.
fn rack_for(sess: &Session, backend: ExecBackend) -> Result<PrinsRack> {
    let rack = PrinsRack::with_config(
        sess.shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    );
    match &sess.fault {
        Some(model) => rack.with_fault(model.clone()),
        None => Ok(rack),
    }
}

/// Key=value reply-line builder: the single place the `OK …` grammar is
/// emitted (docs/PROTOCOL.md §Reply grammar). Every verb — including
/// every registry-driven kernel verb — assembles its reply through this
/// builder, so the `query_ok`/`load_fields` grammar cannot drift between
/// verbs or kernels.
struct Reply {
    line: String,
}

impl Reply {
    /// Start an `OK` reply.
    fn ok() -> Reply {
        Reply { line: "OK".into() }
    }

    /// Append one `key=value` field.
    fn kv(mut self, key: &str, value: impl std::fmt::Display) -> Reply {
        self.line.push(' ');
        self.line.push_str(key);
        self.line.push('=');
        self.line.push_str(&value.to_string());
        self
    }

    /// Append pre-formatted fields (a kernel's verb-specific
    /// `fields` string from the registry formatter). Empty = no-op.
    fn fields(mut self, fields: &str) -> Reply {
        if !fields.is_empty() {
            self.line.push(' ');
            self.line.push_str(fields);
        }
        self
    }

    /// The finished reply line.
    fn finish(self) -> String {
        self.line
    }
}

/// Picojoule formatting of the `energy_pj=` field (one decimal, like
/// every reply since PR 3).
fn pj(energy_j: f64) -> String {
    format!("{:.1}", energy_j * 1e12)
}

/// Stats prefix + kernel fields of every kernel reply: single-device
/// grammar (per-shard device stats, no link charge) when the run used
/// one shard, rack grammar (`shards=`/`link_bytes=`) otherwise.
fn stats_reply(rs: &RackStats, fields: &str) -> Reply {
    if rs.shards >= 2 {
        Reply::ok()
            .kv("cycles", rs.total_cycles)
            .kv("energy_pj", pj(rs.energy_j))
            .fields(fields)
            .kv("shards", rs.shards)
            .kv("link_bytes", rs.link_bytes)
    } else {
        let st = &rs.shard_stats[0];
        Reply::ok()
            .kv("cycles", st.cycles)
            .kv("energy_pj", pj(st.energy_j(&DeviceModel::default())))
            .fields(fields)
    }
}

/// Append the reliability fields when the query ran on a faulty rack
/// (docs/PROTOCOL.md §Fault injection): `fidelity=` always, and a
/// `warn=residual-faults retries=` pair when corruption survived every
/// scrub/retry — graceful degradation instead of a dropped reply. On an
/// ideal rack this is a no-op, so existing replies stay byte-identical.
fn fid_reply(r: Reply, fid: &Option<FidelityReport>) -> Reply {
    let Some(f) = fid else { return r };
    let r = r.kv("fidelity", format!("{:.6}", f.fidelity));
    if f.residual > 0 {
        r.kv("warn", "residual-faults").kv("retries", f.retries)
    } else {
        r
    }
}

/// Reply line of a resident-dataset query (docs/PROTOCOL.md §Resident
/// datasets): the shared stats grammar with the trailing `dataset=`
/// marker.
fn query_ok(out: &QueryOut, id: u64) -> String {
    fid_reply(stats_reply(&out.rack, &out.fields), &out.fidelity)
        .kv("dataset", id)
        .finish()
}

/// `load_cycles=` (and, when sharded, `load_link_bytes=`) fields of a
/// `LOAD` reply — the one-time load-phase price the dataset id amortizes.
fn load_fields(rs: &RackStats) -> String {
    if rs.shards >= 2 {
        format!(
            "load_cycles={} load_link_bytes={}",
            rs.total_cycles, rs.link_bytes
        )
    } else {
        format!("load_cycles={}", rs.shard_stats[0].cycles)
    }
}

/// The `LOAD` usage string, assembled from the registry (a new kernel's
/// `LOAD` form appears here without touching the server).
fn load_usage() -> String {
    let forms: Vec<&str> = registry().iter().map(|e| e.load_usage).collect();
    format!("usage: {}", forms.join(" | "))
}

/// Make room for one more resident dataset when the table is at
/// capacity: evict the least-recently-used dataset among those with the
/// coldest wear. The victim key is (hottest-row write count, recency
/// stamp, id), minimized — so wear protection comes first (a dataset
/// whose cells are already worn is kept resident; datasets without wear
/// tracking, i.e. faulty-rack loads, count as coldest), and recency
/// breaks ties. Returns the evicted id for the `evicted=` reply field.
fn evict_for_slot(sess: &mut Session) -> Option<u64> {
    if sess.datasets.len() < MAX_DATASETS {
        return None;
    }
    let victim = sess
        .datasets
        .iter()
        .min_by_key(|(id, e)| {
            (
                e.res.wear_score().unwrap_or(0),
                e.last_used.load(Ordering::Relaxed),
                **id,
            )
        })
        .map(|(id, _)| *id)?;
    sess.datasets.remove(&victim);
    Some(victim)
}

/// `LOAD <KIND> ...`: synthesize a dataset server-side via the kind's
/// registry entry, load it once onto a rack with the session's current
/// shard count, and register it under a fresh id. Every subsequent
/// dataset-id kernel verb reuses the resident rows and charges only
/// query cycles. The shard layout is fixed at `LOAD` time; later `RACK`
/// changes affect only future loads. A full table evicts wear-aware LRU
/// ([`evict_for_slot`]) and reports the victim in a trailing `evicted=`
/// field.
fn load_dataset(
    args: &[&str],
    backend: ExecBackend,
    sess: &mut Session,
) -> Result<Option<String>> {
    // kinds are case-sensitive wire verbs, exactly like the kernel verbs
    let Some(entry) = args.first().and_then(|kind| find_verb(kind)) else {
        bail!("{}", load_usage());
    };
    let rack = rack_for(sess, backend)?;
    let data = (entry.load)(&rack, &args[1..])?;
    // evict only after the new load synthesized successfully, so a
    // malformed LOAD can never cost a resident dataset
    let evicted = evict_for_slot(sess);
    let id = sess.next_id;
    sess.next_id += 1;
    let mut reply = Reply::ok()
        .kv("id", id)
        .kv("kind", data.name())
        .kv("n", data.rows())
        .kv("shards", data.load_report().shards)
        .fields(&load_fields(data.load_report()));
    if let Some(victim) = evicted {
        reply = reply.kv("evicted", victim);
    }
    let stamp = sess.tick();
    sess.datasets.insert(
        id,
        DatasetEntry {
            res: data,
            last_used: AtomicU64::new(stamp),
        },
    );
    Ok(Some(reply.finish()))
}

/// A registered kernel verb, dispatched by arity (docs/PROTOCOL.md):
/// `<VERB> id params…` (the dataset-id query form) when the arg count
/// matches the kernel's query arity + 1, `<VERB> …` (the one-shot form)
/// when it matches the one-shot arity, and the **batched** dataset-id
/// form (`<VERB> id B op1 … opB`, longer than the single-query form)
/// for any other arg count that leads with a dataset id — kernels
/// without a batched grammar refuse it with a clean error. No
/// per-kernel code: parsing, synthesis and reply fields all come from
/// the registry entry.
fn kernel_verb(
    verb: &str,
    args: &[&str],
    backend: ExecBackend,
    sess: &mut Session,
) -> Result<Option<String>> {
    let Some(entry) = find_verb(verb) else {
        bail!("unknown command");
    };
    if args.len() == entry.query_arity + 1 {
        // dataset-id query: no reload, query cycles only
        let id: u64 = args[0].parse()?;
        let Some(e) = sess.datasets.get_mut(&id) else {
            bail!("unknown dataset {id}");
        };
        ensure!(
            e.res.name() == entry.name,
            "dataset {id} is kind {}, not {}",
            e.res.name(),
            entry.name
        );
        let out = e.res.query_args(&args[1..])?;
        e.last_used
            .store(sess.clock.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        Ok(Some(query_ok(&out, id)))
    } else if args.len() == entry.one_shot_arity {
        let rack = rack_for(sess, backend)?;
        let out = (entry.one_shot)(&rack, args)?;
        Ok(Some(
            fid_reply(stats_reply(&out.rack, &out.fields), &out.fidelity).finish(),
        ))
    } else if args.len() > entry.query_arity + 1 && args[0].parse::<u64>().is_ok() {
        // batched dataset-id query: B operands packed into one sweep
        let id: u64 = args[0].parse()?;
        let Some(e) = sess.datasets.get_mut(&id) else {
            bail!("unknown dataset {id}");
        };
        ensure!(
            e.res.name() == entry.name,
            "dataset {id} is kind {}, not {}",
            e.res.name(),
            entry.name
        );
        let out = e.res.query_args_batch(&args[1..])?;
        e.last_used
            .store(sess.clock.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        Ok(Some(query_ok(&out, id)))
    } else {
        bail!("usage: {} | {}", entry.one_shot_usage, entry.query_usage);
    }
}

fn dispatch(line: &str, backend: ExecBackend, sess: &mut Session) -> Result<Option<String>> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => Ok(Some("PONG".into())),
        ["QUIT"] => Ok(None),
        ["RACK"] => Ok(Some(Reply::ok().kv("shards", sess.shards).finish())),
        ["RACK", n] => {
            let n: usize = n.parse()?;
            ensure!(
                (1..=crate::rcam::shard::MAX_SHARDS).contains(&n),
                "shards out of range (1..={})",
                crate::rcam::shard::MAX_SHARDS
            );
            sess.shards = n;
            Ok(Some(Reply::ok().kv("shards", n).finish()))
        }
        // ----- resident-dataset registry (docs/PROTOCOL.md) -------------
        ["LOAD", rest @ ..] => load_dataset(rest, backend, sess),
        ["DATASETS"] => {
            let mut reply = Reply::ok().kv("count", sess.datasets.len());
            for (id, e) in &sess.datasets {
                reply = reply.kv(
                    "ds",
                    format!(
                        "{id}:{}:{}:{}",
                        e.res.name(),
                        e.res.rows(),
                        e.res.load_report().shards
                    ),
                );
            }
            Ok(Some(reply.finish()))
        }
        ["DROP", id] => {
            let id: u64 = id.parse()?;
            ensure!(sess.datasets.remove(&id).is_some(), "unknown dataset {id}");
            Ok(Some(Reply::ok().kv("dropped", id).finish()))
        }
        // compiled-program cache counters of one resident dataset; a
        // separate verb (not query-reply fields) so repeated queries
        // stay byte-identical for the throughput-bench equality gates
        ["STATS", id] => {
            let id: u64 = id.parse()?;
            let Some(e) = sess.datasets.get(&id) else {
                bail!("unknown dataset {id}");
            };
            let (hits, misses) = e.res.cache_stats();
            Ok(Some(
                Reply::ok()
                    .kv("dataset", id)
                    .kv("cache_hits", hits)
                    .kv("cache_misses", misses)
                    .finish(),
            ))
        }
        // ----- fault injection (docs/PROTOCOL.md §Fault injection) ------
        ["FAULTS"] => Ok(Some(match &sess.fault {
            None => Reply::ok().kv("faults", "off").finish(),
            Some(m) => Reply::ok()
                .kv("faults", "on")
                .kv("ber", m.read_ber)
                .kv("seed", m.seed)
                .kv("stuck", m.random_stuck)
                .finish(),
        })),
        ["FAULTS", "OFF"] => {
            sess.fault = None;
            // the fault regime frames every cached plan's validity:
            // flush resident program caches so the next query
            // re-synthesizes (counters stay cumulative)
            for e in sess.datasets.values() {
                e.res.invalidate_cache();
            }
            Ok(Some(Reply::ok().kv("faults", "off").finish()))
        }
        ["FAULTS", rest @ ..] => {
            ensure!(
                rest.len() == 2 || rest.len() == 3,
                "usage: FAULTS | FAULTS OFF | FAULTS ber seed [stuck_n]"
            );
            let ber: f64 = rest[0].parse()?;
            let seed: u64 = rest[1].parse()?;
            let stuck: usize = if rest.len() == 3 { rest[2].parse()? } else { 0 };
            ensure!(
                ber.is_finite() && (0.0..1.0).contains(&ber),
                "BER {} outside [0, 1)",
                ber
            );
            // takes effect on racks built for future LOADs/one-shots;
            // already-resident datasets keep their load-time model but
            // drop their cached plans (invalidation rule: arming faults
            // is a regime change, re-synthesize on next query)
            sess.fault = Some(FaultModel::uniform(ber, seed).with_random_stuck(stuck));
            for e in sess.datasets.values() {
                e.res.invalidate_cache();
            }
            Ok(Some(
                Reply::ok()
                    .kv("faults", "on")
                    .kv("ber", ber)
                    .kv("seed", seed)
                    .kv("stuck", stuck)
                    .finish(),
            ))
        }
        // ----- kernel verbs: registry-driven, arity-dispatched ----------
        [verb, args @ ..] => kernel_verb(verb, args, backend, sess),
        _ => bail!("unknown command"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn ping_and_hist_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        writeln!(conn, "PING").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        line.clear();
        writeln!(conn, "HIST 500 7").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        assert!(line.contains("total=500"), "{line}");

        line.clear();
        writeln!(conn, "BOGUS").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");

        line.clear();
        writeln!(conn, "QUIT").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "BYE");
        server.shutdown();
    }

    #[test]
    fn rack_session_shards_verbs_and_is_per_connection() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };

        assert_eq!(ask(&mut conn, &mut reader, "RACK"), "OK shards=1");
        assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
        let sharded = ask(&mut conn, &mut reader, "HIST 600 7");
        assert!(sharded.contains("shards=2"), "{sharded}");
        assert!(sharded.contains("link_bytes="), "{sharded}");
        assert!(sharded.contains("total=600"), "{sharded}");
        let spmv = ask(&mut conn, &mut reader, "SPMV 48 300 3");
        assert!(spmv.contains("shards=2") && spmv.contains("checksum="), "{spmv}");
        assert!(ask(&mut conn, &mut reader, "RACK 0").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "RACK 65").starts_with("ERR"));

        // sharded histogram agrees with single-device results and a fresh
        // connection starts unsharded
        let mut conn2 = TcpStream::connect(server.addr).unwrap();
        let mut reader2 = BufReader::new(conn2.try_clone().unwrap());
        let single = ask(&mut conn2, &mut reader2, "HIST 600 7");
        assert!(!single.contains("shards="), "{single}");
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };
        assert_eq!(field(&sharded, "top_bin="), field(&single, "top_bin="));
        assert_eq!(field(&sharded, "total="), field(&single, "total="));
        // rack cycles include the host-link charge, so they exceed the
        // single device's
        let cyc = |r: &str| field(r, "cycles=").parse::<u64>().unwrap();
        assert!(cyc(&sharded) > cyc(&single), "{sharded} vs {single}");
        server.shutdown();
    }

    #[test]
    fn resident_dataset_lifecycle_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };

        // load once, query many: replies repeat bit-for-bit
        let loaded = ask(&mut conn, &mut reader, "LOAD HIST 500 7");
        assert!(loaded.starts_with("OK id=1 kind=hist n=500 shards=1"), "{loaded}");
        assert!(loaded.contains("load_cycles="), "{loaded}");
        let q1 = ask(&mut conn, &mut reader, "HIST 1");
        let q2 = ask(&mut conn, &mut reader, "HIST 1");
        assert!(q1.contains("dataset=1") && q1.contains("total=500"), "{q1}");
        assert_eq!(q1, q2, "resident queries must repeat bit-identically");
        // the resident query matches the one-shot verb's result values
        let one_shot = ask(&mut conn, &mut reader, "HIST 500 7");
        assert_eq!(field(&q1, "top_bin="), field(&one_shot, "top_bin="));
        assert_eq!(field(&q1, "total="), field(&one_shot, "total="));
        // query cost sits below the one-time load cost
        let load_cycles: u64 = field(&loaded, "load_cycles=").parse().unwrap();
        let query_cycles: u64 = field(&q1, "cycles=").parse().unwrap();
        assert!(query_cycles < load_cycles, "{q1} vs {loaded}");

        // second dataset of a different kind; registry lists both
        let dp = ask(&mut conn, &mut reader, "LOAD DP 32 4 3");
        assert!(dp.starts_with("OK id=2 kind=dp n=32"), "{dp}");
        let dpq = ask(&mut conn, &mut reader, "DP 2 9");
        assert!(dpq.contains("checksum=") && dpq.contains("dataset=2"), "{dpq}");
        // new hyperplane (new seed) on the same resident vectors
        let dpq2 = ask(&mut conn, &mut reader, "DP 2 10");
        assert_ne!(field(&dpq, "checksum="), field(&dpq2, "checksum="));
        assert_eq!(field(&dpq, "cycles="), field(&dpq2, "cycles="));
        assert_eq!(
            ask(&mut conn, &mut reader, "DATASETS"),
            "OK count=2 ds=1:hist:500:1 ds=2:dp:32:1"
        );

        // kind/verb mismatch and unknown ids are errors, not panics
        assert!(ask(&mut conn, &mut reader, "DP 1 5").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "HIST 9").starts_with("ERR"));

        // drop frees the id
        assert_eq!(ask(&mut conn, &mut reader, "DROP 1"), "OK dropped=1");
        assert!(ask(&mut conn, &mut reader, "HIST 1").starts_with("ERR"));
        assert_eq!(
            ask(&mut conn, &mut reader, "DATASETS"),
            "OK count=1 ds=2:dp:32:1"
        );
        server.shutdown();
    }

    #[test]
    fn faults_verb_lifecycle_and_fidelity_fields() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };

        // off by default; malformed configs are rejected, not applied
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS"), "OK faults=off");
        assert!(ask(&mut conn, &mut reader, "FAULTS 1.5 1").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "FAULTS x 1").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "FAULTS 0.1").starts_with("ERR"));
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS"), "OK faults=off");

        // BER=0 faults: replies gain fidelity=1.000000 and values stay exact
        assert_eq!(
            ask(&mut conn, &mut reader, "FAULTS 0 7"),
            "OK faults=on ber=0 seed=7 stuck=0"
        );
        assert_eq!(
            ask(&mut conn, &mut reader, "FAULTS"),
            "OK faults=on ber=0 seed=7 stuck=0"
        );
        let loaded = ask(&mut conn, &mut reader, "LOAD HIST 200 5");
        assert!(loaded.starts_with("OK id=1"), "{loaded}");
        let resident = ask(&mut conn, &mut reader, "HIST 1");
        assert!(resident.contains("fidelity=1.000000"), "{resident}");
        assert!(!resident.contains("warn="), "{resident}");
        let one_shot_faulty = ask(&mut conn, &mut reader, "HIST 200 5");
        assert!(one_shot_faulty.contains("fidelity=1.000000"), "{one_shot_faulty}");

        // FAULTS OFF: new racks are ideal again, but the resident dataset
        // keeps its load-time model and still reports fidelity
        assert_eq!(ask(&mut conn, &mut reader, "FAULTS OFF"), "OK faults=off");
        let one_shot_ideal = ask(&mut conn, &mut reader, "HIST 200 5");
        assert!(!one_shot_ideal.contains("fidelity="), "{one_shot_ideal}");
        assert_eq!(
            field(&one_shot_faulty, "top_bin="),
            field(&one_shot_ideal, "top_bin=")
        );
        assert_eq!(
            field(&one_shot_faulty, "total="),
            field(&one_shot_ideal, "total=")
        );
        let resident2 = ask(&mut conn, &mut reader, "HIST 1");
        assert!(resident2.contains("fidelity="), "{resident2}");
        assert_eq!(field(&resident, "total="), field(&resident2, "total="));
        server.shutdown();
    }

    #[test]
    fn sharded_resident_dataset_replies_carry_rack_fields() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };
        assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
        let loaded = ask(&mut conn, &mut reader, "LOAD SPMV 48 300 3");
        assert!(loaded.contains("shards=2") && loaded.contains("load_link_bytes="), "{loaded}");
        let q = ask(&mut conn, &mut reader, "SPMV 1 4");
        assert!(
            q.contains("shards=2") && q.contains("link_bytes=") && q.contains("dataset=1"),
            "{q}"
        );
        // datasets keep their load-time shard count even after RACK changes
        assert_eq!(ask(&mut conn, &mut reader, "RACK 1"), "OK shards=1");
        let q2 = ask(&mut conn, &mut reader, "SPMV 1 4");
        assert_eq!(q, q2, "resident layout is fixed at LOAD time");
        server.shutdown();
    }

    #[test]
    fn spmv_verb_matches_quantized_baseline_checksum() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, "SPMV 64 400 5").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        let got: f32 = line
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("checksum="))
            .unwrap()
            .parse()
            .unwrap();
        let a = crate::workloads::synth_csr(64, 400, 5);
        let mut rng = crate::workloads::Rng::seed_from(6);
        let x: Vec<f32> = (0..64).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let expect: f32 = crate::algorithms::spmv_baseline_quantized(&a, &x).iter().sum();
        assert!((got - expect).abs() < 2e-3, "{got} vs {expect}");
        server.shutdown();
    }

    #[test]
    fn threaded_server_replies_match_serial() {
        let ask = |server: &Server, req: &str| -> String {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line
        };
        let serial = Server::spawn("127.0.0.1:0").unwrap();
        let threaded =
            Server::spawn_with("127.0.0.1:0", ExecBackend::Threaded(3)).unwrap();
        for req in ["HIST 700 11", "DP 64 4 2"] {
            let a = ask(&serial, req);
            let b = ask(&threaded, req);
            assert!(a.starts_with("OK"), "{req}: {a}");
            assert_eq!(a, b, "{req}: cycles/energy/results must be backend-invariant");
        }
        serial.shutdown();
        threaded.shutdown();
    }

    #[test]
    fn pipelined_burst_replies_in_request_order() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // one write carrying a whole session: LOAD, a pipelined burst of
        // shared reads racing each other through the pool, an exclusive
        // DROP fencing them, then QUIT
        let script = "LOAD HIST 300 5\nPING\nHIST 1\nHIST 1\nHIST 1\nPING\nDROP 1\nHIST 1\nQUIT\n";
        conn.write_all(script.as_bytes()).unwrap();
        let mut replies = Vec::new();
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            replies.push(line.trim().to_string());
            line.clear();
        }
        assert_eq!(replies.len(), 9, "{replies:?}");
        assert!(replies[0].starts_with("OK id=1 kind=hist"), "{}", replies[0]);
        assert_eq!(replies[1], "PONG");
        assert!(replies[2].contains("dataset=1"), "{}", replies[2]);
        assert_eq!(replies[2], replies[3]);
        assert_eq!(replies[3], replies[4]);
        assert_eq!(replies[5], "PONG");
        assert_eq!(replies[6], "OK dropped=1");
        // the post-DROP query observes the drop: admission ordered it
        // after the exclusive request
        assert!(replies[7].starts_with("ERR"), "{}", replies[7]);
        assert_eq!(replies[8], "BYE");
        server.shutdown();
    }

    #[test]
    fn serve_options_single_worker_exclusive_only_matches_default() {
        let opts = ServeOptions {
            backend: ExecBackend::Serial,
            workers: 1,
            shared_read: false,
        };
        let strict = Server::spawn_opts("127.0.0.1:0", opts).unwrap();
        let relaxed = Server::spawn("127.0.0.1:0").unwrap();
        let session = |server: &Server| -> Vec<String> {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(b"LOAD SEARCH 400 9\nSEARCH 1 100 5000\nSEARCH 1 0 4294967295\nQUIT\n")
                .unwrap();
            let mut replies = Vec::new();
            let mut line = String::new();
            while reader.read_line(&mut line).unwrap() > 0 {
                replies.push(line.trim().to_string());
                line.clear();
            }
            replies
        };
        let a = session(&strict);
        let b = session(&relaxed);
        assert_eq!(a.len(), 4, "{a:?}");
        assert!(a[1].contains("count="), "{}", a[1]);
        assert_eq!(a, b, "shared-read admission must not change any reply byte");
        strict.shutdown();
        relaxed.shutdown();
    }

    #[test]
    fn full_table_load_evicts_and_reports_victim() {
        let mut sess = Session::default();
        for _ in 0..MAX_DATASETS {
            let r = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &mut sess)
                .unwrap()
                .unwrap();
            assert!(!r.contains("evicted="), "{r}");
        }
        assert_eq!(sess.datasets.len(), MAX_DATASETS);
        // touch every dataset except id 2: id 2 becomes the LRU among
        // equal-wear candidates and must be the victim
        for id in sess.datasets.keys().copied().collect::<Vec<_>>() {
            if id != 2 {
                let q = dispatch(&format!("HIST {id}"), ExecBackend::Serial, &mut sess)
                    .unwrap()
                    .unwrap();
                assert!(q.starts_with("OK"), "{q}");
            }
        }
        let r = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &mut sess)
            .unwrap()
            .unwrap();
        assert!(r.ends_with("evicted=2"), "{r}");
        assert_eq!(sess.datasets.len(), MAX_DATASETS);
        assert!(!sess.datasets.contains_key(&2));
        assert!(sess.datasets.contains_key(&17), "ids stay monotonic");
        // a malformed LOAD into the full table must not evict anything
        assert!(load_dataset(&["HIST", "x", "3"], ExecBackend::Serial, &mut sess).is_err());
        assert_eq!(sess.datasets.len(), MAX_DATASETS);
    }

    #[test]
    fn batched_search_wire_form_matches_singles_and_shared_dispatch() {
        let mut sess = Session::default();
        let loaded = load_dataset(&["SEARCH", "400", "9"], ExecBackend::Serial, &mut sess)
            .unwrap()
            .unwrap();
        assert!(loaded.starts_with("OK id=1 kind=search"), "{loaded}");
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };
        let ask = |sess: &mut Session, req: &str| {
            dispatch(req, ExecBackend::Serial, sess).unwrap().unwrap()
        };

        let a = ask(&mut sess, "SEARCH 1 100 5000");
        let b = ask(&mut sess, "SEARCH 1 6000 40000");
        let batched = ask(&mut sess, "SEARCH 1 2 100 5000 6000 40000");
        assert_eq!(field(&batched, "batch="), "2", "{batched}");
        assert_eq!(
            field(&batched, "counts="),
            format!("{},{}", field(&a, "count="), field(&b, "count=")),
            "batched counts must match the two single-range queries"
        );
        // packing both ranges into one sweep shares the reduction-tree
        // drain: strictly cheaper than the two singles summed
        let cyc = |r: &str| field(r, "cycles=").parse::<u64>().unwrap();
        assert!(cyc(&batched) < cyc(&a) + cyc(&b), "{batched} vs {a} + {b}");

        // the shared-read path admits the batched form, stamps recency,
        // and replies byte-identically to exclusive dispatch
        assert!(classify("SEARCH 1 2 100 5000 6000 40000", &sess, true));
        assert!(!classify("SEARCH 400 9", &sess, true), "one-shots stay exclusive");
        let before = sess.datasets[&1].last_used.load(Ordering::Relaxed);
        let shared = dispatch_shared("SEARCH 1 2 100 5000 6000 40000", &sess).unwrap();
        assert_eq!(shared, batched);
        let after = sess.datasets[&1].last_used.load(Ordering::Relaxed);
        assert!(after > before, "batched shared reads must refresh last_used");

        // malformed batched lines are clean errors, not panics: odd
        // operand count, B < 2, and kernels without a batched grammar
        assert!(dispatch("SEARCH 1 2 100 5000 6000", ExecBackend::Serial, &mut sess).is_err());
        assert!(dispatch("SEARCH 1 1 100 5000", ExecBackend::Serial, &mut sess).is_err());
        let hist = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &mut sess)
            .unwrap()
            .unwrap();
        assert!(hist.starts_with("OK id=2"), "{hist}");
        let err = dispatch("HIST 2 5 7", ExecBackend::Serial, &mut sess).unwrap_err();
        assert!(err.to_string().contains("no batched query form"), "{err}");
    }

    #[test]
    fn shared_reads_refresh_recency_for_the_evictor() {
        let mut sess = Session::default();
        for _ in 0..MAX_DATASETS {
            load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &mut sess).unwrap();
        }
        // exclusive-query every dataset except id 7…
        for id in 1..=MAX_DATASETS as u64 {
            if id != 7 {
                let q = dispatch(&format!("HIST {id}"), ExecBackend::Serial, &mut sess)
                    .unwrap()
                    .unwrap();
                assert!(q.starts_with("OK"), "{q}");
            }
        }
        // …id 7 stays hot through shared reads ONLY: its recency stamp
        // must come from dispatch_shared
        let r = dispatch_shared("HIST 7", &sess).unwrap();
        assert!(r.starts_with("OK"), "{r}");
        let r = load_dataset(&["HIST", "50", "3"], ExecBackend::Serial, &mut sess)
            .unwrap()
            .unwrap();
        // were shared reads not stamping last_used, id 7 would still
        // carry its load-time stamp — the oldest — and be evicted; the
        // true LRU is id 1 (first exclusive query of the touch loop)
        assert!(r.ends_with("evicted=1"), "{r}");
        assert!(sess.datasets.contains_key(&7), "shared-read-hot dataset evicted");
    }

    #[test]
    fn stats_verb_tracks_cache_and_invalidation_forces_resynthesis() {
        let mut sess = Session::default();
        load_dataset(&["SEARCH", "300", "5"], ExecBackend::Serial, &mut sess).unwrap();
        let ask = |sess: &mut Session, req: &str| {
            dispatch(req, ExecBackend::Serial, sess).unwrap().unwrap()
        };
        let stats = |sess: &mut Session| -> (u64, u64) {
            let r = dispatch("STATS 1", ExecBackend::Serial, sess).unwrap().unwrap();
            let field = |key: &str| {
                r.split_whitespace()
                    .find_map(|kv| kv.strip_prefix(key))
                    .unwrap()
                    .parse::<u64>()
                    .unwrap()
            };
            (field("cache_hits="), field("cache_misses="))
        };

        assert_eq!(stats(&mut sess), (0, 0), "cache born empty");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (0, 1), "first query synthesizes");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (1, 1), "repeat query hits the cache");
        // a batched query is its own cache key
        ask(&mut sess, "SEARCH 1 2 10 20 30 40");
        ask(&mut sess, "SEARCH 1 2 10 20 30 40");
        assert_eq!(stats(&mut sess), (2, 2));
        // FAULTS (arming and disarming) flushes every resident cache:
        // the repeat of a previously-hot query must re-synthesize
        ask(&mut sess, "FAULTS 0 9");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (2, 3), "post-FAULTS query must miss");
        ask(&mut sess, "FAULTS OFF");
        ask(&mut sess, "SEARCH 1 100 5000");
        assert_eq!(stats(&mut sess), (2, 4));
        // DROP destroys the cache with the dataset; a reload starts cold
        assert_eq!(ask(&mut sess, "DROP 1"), "OK dropped=1");
        assert!(dispatch("STATS 1", ExecBackend::Serial, &mut sess).is_err());
        load_dataset(&["SEARCH", "300", "5"], ExecBackend::Serial, &mut sess).unwrap();
        assert_eq!(
            ask(&mut sess, "STATS 2"),
            "OK dataset=2 cache_hits=0 cache_misses=0"
        );
    }
}
