//! TCP front-end for the PRINS device: a line-oriented protocol so
//! external processes (or `prins serve` + netcat) can drive the device
//! like a network-attached storage appliance.
//!
//! The full wire protocol — every verb (including the `RACK` sharding
//! forms), the reply grammar, error replies, and worked netcat sessions —
//! is specified in `docs/PROTOCOL.md`; keep that file authoritative.
//! Summary:
//!
//!   PING | RACK \[n\] | HIST | DP | ED | SPMV | QUIT
//!
//! Kernel verbs run on a single device by default; after `RACK <n>` the
//! same verbs execute sharded over an `n`-device [`PrinsRack`] (a
//! per-connection session setting) and replies gain `shards=`/`link_bytes=`
//! fields.
//!
//! (std::net + a thread per connection; the vendored crate set has no
//! tokio — documented in Cargo.toml.)

use super::rack::{PrinsRack, RackStats};
use super::PrinsDevice;
use crate::algorithms::{
    dot_sharded, euclidean_sharded, histogram_sharded, spmv_sharded, spmv_single,
};
use crate::controller::kernels::KernelId;
use crate::controller::registers::Status;
use crate::rcam::{DeviceModel, ExecBackend, InterconnectModel};
use crate::workloads::{synth_csr, synth_hist_samples, synth_samples, synth_uniform, Rng};
use crate::error::{bail, ensure, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Read poll interval: connection threads wake this often to observe the
/// stop flag, so `shutdown()` can join every thread even while a client
/// holds its connection open without sending.
const READ_POLL: Duration = Duration::from_millis(50);

/// Write timeout: a client that stops draining its receive buffer gets
/// disconnected after this long instead of pinning its worker thread in
/// `write` forever (which would make `shutdown()` hang on the join).
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// A running TCP front-end: acceptor thread + one worker per connection.
pub struct Server {
    /// The resolved listen address (useful with ephemeral-port binds).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Server {
    /// Bind and serve on a background thread with the serial simulator
    /// backend. Bind to port 0 for an ephemeral port (`self.addr`
    /// carries the resolved address).
    pub fn spawn(bind: &str) -> Result<Server> {
        Self::spawn_with(bind, ExecBackend::Serial)
    }

    /// [`Server::spawn`] with an explicit simulator execution backend for
    /// the per-request PRINS devices. Replies (cycles, energy, results)
    /// are bit-identical across backends; the knob only sets simulation
    /// speed per request.
    pub fn spawn_with(bind: &str, backend: ExecBackend) -> Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let (stop2, conns2) = (stop.clone(), conns.clone());
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // accepted sockets can inherit the listener's
                        // non-blocking mode on some platforms; reset it or
                        // the timeouts below would be ineffective
                        stream.set_nonblocking(false).ok();
                        stream.set_read_timeout(Some(READ_POLL)).ok();
                        stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
                        let st = stop2.clone();
                        let h = std::thread::spawn(move || {
                            let _ = handle_conn(stream, st, backend);
                        });
                        let mut guard = conns2.lock().unwrap();
                        // reap finished workers so a long-running server
                        // does not accumulate one handle per connection
                        let mut i = 0;
                        while i < guard.len() {
                            if guard[i].is_finished() {
                                let _ = guard.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        guard.push(h);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server {
            addr,
            stop,
            handle: Some(handle),
            conns,
        })
    }

    /// Stop accepting, then join the acceptor AND every connection worker
    /// (workers poll the stop flag at `READ_POLL`, so this cannot hang on
    /// an idle client).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let workers: Vec<_> = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in workers {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Per-connection protocol state: the shard count selected by `RACK <n>`
/// (1 = single-device, the default; see `docs/PROTOCOL.md` §Sessions).
#[derive(Clone, Copy, Debug)]
struct Session {
    shards: usize,
}

impl Default for Session {
    fn default() -> Self {
        Session { shards: 1 }
    }
}

fn handle_conn(stream: TcpStream, stop: Arc<AtomicBool>, backend: ExecBackend) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut sess = Session::default();
    loop {
        buf.clear();
        // Accumulate one raw line; the read timeout doubles as the
        // stop-flag poll. Bytes are collected with read_until (not
        // read_line) so a timeout landing mid-multi-byte character
        // cannot drop already-consumed bytes — everything read stays
        // appended to `buf` across timeouts.
        let n = loop {
            if stop.load(Ordering::Acquire) {
                return Ok(()); // server shutting down
            }
            match reader.read_until(b'\n', &mut buf) {
                Ok(n) => break n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(e) => return Err(e.into()),
            }
        };
        if n == 0 && buf.is_empty() {
            return Ok(()); // client closed
        }
        let line = String::from_utf8_lossy(&buf);
        let reply = match dispatch(line.trim(), backend, &mut sess) {
            Ok(Some(r)) => r,
            Ok(None) => {
                writeln!(out, "BYE")?;
                return Ok(());
            }
            Err(e) => format!("ERR {e}"),
        };
        writeln!(out, "{reply}")?;
        if n == 0 {
            return Ok(()); // EOF after a final unterminated line
        }
    }
}

/// The rack a session's sharded verbs execute on: session shard count,
/// default device model + interconnect, the server's simulator backend.
fn rack_for(sess: &Session, backend: ExecBackend) -> PrinsRack {
    PrinsRack::with_config(
        sess.shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    )
}

/// Shared grammar of every sharded kernel reply (docs/PROTOCOL.md): rack
/// cycle/energy figures, then the verb-specific fields, then the rack
/// session fields — one place to change if the reply format evolves.
fn rack_ok(rs: &RackStats, fields: &str) -> String {
    format!(
        "OK cycles={} energy_pj={:.1} {fields} shards={} link_bytes={}",
        rs.total_cycles,
        rs.energy_j * 1e12,
        rs.shards,
        rs.link_bytes
    )
}

fn dispatch(line: &str, backend: ExecBackend, sess: &mut Session) -> Result<Option<String>> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["PING"] => Ok(Some("PONG".into())),
        ["QUIT"] => Ok(None),
        ["RACK"] => Ok(Some(format!("OK shards={}", sess.shards))),
        ["RACK", n] => {
            let n: usize = n.parse()?;
            ensure!(
                (1..=crate::rcam::shard::MAX_SHARDS).contains(&n),
                "shards out of range (1..={})",
                crate::rcam::shard::MAX_SHARDS
            );
            sess.shards = n;
            Ok(Some(format!("OK shards={n}")))
        }
        ["HIST", n, seed] => {
            let (n, seed): (usize, u64) = (n.parse()?, seed.parse()?);
            ensure!(n > 0 && n <= 1 << 20, "n out of range");
            let xs = synth_hist_samples(n, seed);
            if sess.shards > 1 {
                let res = histogram_sharded(&rack_for(sess, backend), &xs);
                let top = res.hist.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
                let total: u64 = res.hist.iter().sum();
                return Ok(Some(rack_ok(
                    &res.rack,
                    &format!("top_bin={top} total={total}"),
                )));
            }
            let dev = PrinsDevice::with_config(n, 64, DeviceModel::default(), backend);
            dev.load_samples_for_histogram(&xs);
            if dev.run_kernel(KernelId::Histogram, &[], &[]) != Status::Done {
                bail!("kernel error");
            }
            let o = dev.take_outputs();
            let top = o.u64s.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
            let total: u64 = o.u64s.iter().sum();
            Ok(Some(format!(
                "OK cycles={} energy_pj={:.1} top_bin={} total={}",
                o.cycles,
                o.energy_j * 1e12,
                top,
                total
            )))
        }
        ["DP", n, dims, seed] => {
            let (n, dims, seed): (usize, usize, u64) =
                (n.parse()?, dims.parse()?, seed.parse()?);
            ensure!(
                n > 0 && n <= 1 << 16 && dims > 0 && dims <= 16,
                "size out of range"
            );
            let x = synth_samples(n, dims, 4, seed);
            let h = synth_uniform(dims, seed + 1);
            if sess.shards > 1 {
                let res = dot_sharded(&rack_for(sess, backend), &x, n, dims, &h);
                return Ok(Some(rack_ok(
                    &res.rack,
                    &format!("checksum={:.4}", res.checksum),
                )));
            }
            let layout = crate::algorithms::dot::DotLayout::new(dims);
            let dev =
                PrinsDevice::with_config(n, layout.width as usize, DeviceModel::default(), backend);
            dev.load_vectors_for_dot(&x, n, dims);
            let hp: Vec<f64> = h.iter().map(|&v| v as f64).collect();
            if dev.run_kernel(KernelId::DotProduct, &[], &hp) != Status::Done {
                bail!("kernel error");
            }
            let o = dev.take_outputs();
            let checksum: f32 = o.f32s.iter().sum();
            Ok(Some(format!(
                "OK cycles={} energy_pj={:.1} checksum={:.4}",
                o.cycles,
                o.energy_j * 1e12,
                checksum
            )))
        }
        ["ED", n, dims, k, seed] => {
            let (n, dims, k, seed): (usize, usize, usize, u64) =
                (n.parse()?, dims.parse()?, k.parse()?, seed.parse()?);
            ensure!(
                n > 0 && n <= 1 << 16 && dims > 0 && dims <= 8 && k > 0 && k <= 16,
                "size out of range"
            );
            let x = synth_samples(n, dims, k, seed);
            let centers = synth_uniform(k * dims, seed + 1);
            if sess.shards > 1 {
                let res =
                    euclidean_sharded(&rack_for(sess, backend), &x, n, dims, &centers, k, 1);
                return Ok(Some(rack_ok(
                    &res.rack,
                    &format!("checksum={:.4}", res.checksum),
                )));
            }
            let layout = crate::algorithms::euclidean::EuclideanLayout::new(dims);
            let dev =
                PrinsDevice::with_config(n, layout.width as usize, DeviceModel::default(), backend);
            dev.load_samples_for_euclidean(&x, n, dims);
            let cp: Vec<f64> = centers.iter().map(|&v| v as f64).collect();
            if dev.run_kernel(KernelId::EuclideanDistance, &[k as u64], &cp) != Status::Done {
                bail!("kernel error");
            }
            let o = dev.take_outputs();
            let checksum: f32 = o.f32s.iter().sum();
            Ok(Some(format!(
                "OK cycles={} energy_pj={:.1} checksum={:.4}",
                o.cycles,
                o.energy_j * 1e12,
                checksum
            )))
        }
        ["SPMV", n, nnz, seed] => {
            let (n, nnz, seed): (usize, usize, u64) =
                (n.parse()?, nnz.parse()?, seed.parse()?);
            ensure!(
                n > 0 && n <= 1 << 14 && nnz > 0 && nnz <= 1 << 18,
                "size out of range"
            );
            let a = synth_csr(n, nnz, seed);
            let mut rng = Rng::seed_from(seed + 1);
            let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            if sess.shards > 1 {
                let res = spmv_sharded(&rack_for(sess, backend), &a, &x);
                return Ok(Some(rack_ok(
                    &res.rack,
                    &format!("checksum={:.4}", res.checksum),
                )));
            }
            let res = spmv_single(&a, &x, backend);
            let checksum: f32 = res.y.iter().sum();
            Ok(Some(format!(
                "OK cycles={} energy_pj={:.1} checksum={:.4}",
                res.stats.cycles,
                res.stats.energy_j(&DeviceModel::default()) * 1e12,
                checksum
            )))
        }
        _ => bail!("unknown command"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn ping_and_hist_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        writeln!(conn, "PING").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        line.clear();
        writeln!(conn, "HIST 500 7").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        assert!(line.contains("total=500"), "{line}");

        line.clear();
        writeln!(conn, "BOGUS").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");

        line.clear();
        writeln!(conn, "QUIT").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "BYE");
        server.shutdown();
    }

    #[test]
    fn rack_session_shards_verbs_and_is_per_connection() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            line.clear();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line.trim().to_string()
        };

        assert_eq!(ask(&mut conn, &mut reader, "RACK"), "OK shards=1");
        assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
        let sharded = ask(&mut conn, &mut reader, "HIST 600 7");
        assert!(sharded.contains("shards=2"), "{sharded}");
        assert!(sharded.contains("link_bytes="), "{sharded}");
        assert!(sharded.contains("total=600"), "{sharded}");
        let spmv = ask(&mut conn, &mut reader, "SPMV 48 300 3");
        assert!(spmv.contains("shards=2") && spmv.contains("checksum="), "{spmv}");
        assert!(ask(&mut conn, &mut reader, "RACK 0").starts_with("ERR"));
        assert!(ask(&mut conn, &mut reader, "RACK 65").starts_with("ERR"));

        // sharded histogram agrees with single-device results and a fresh
        // connection starts unsharded
        let mut conn2 = TcpStream::connect(server.addr).unwrap();
        let mut reader2 = BufReader::new(conn2.try_clone().unwrap());
        let single = ask(&mut conn2, &mut reader2, "HIST 600 7");
        assert!(!single.contains("shards="), "{single}");
        let field = |r: &str, key: &str| {
            r.split_whitespace()
                .find_map(|kv| kv.strip_prefix(key).map(str::to_string))
                .unwrap_or_default()
        };
        assert_eq!(field(&sharded, "top_bin="), field(&single, "top_bin="));
        assert_eq!(field(&sharded, "total="), field(&single, "total="));
        // rack cycles include the host-link charge, so they exceed the
        // single device's
        let cyc = |r: &str| field(r, "cycles=").parse::<u64>().unwrap();
        assert!(cyc(&sharded) > cyc(&single), "{sharded} vs {single}");
        server.shutdown();
    }

    #[test]
    fn spmv_verb_matches_quantized_baseline_checksum() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, "SPMV 64 400 5").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK cycles="), "{line}");
        let got: f32 = line
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("checksum="))
            .unwrap()
            .parse()
            .unwrap();
        let a = crate::workloads::synth_csr(64, 400, 5);
        let mut rng = crate::workloads::Rng::seed_from(6);
        let x: Vec<f32> = (0..64).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let expect: f32 = crate::algorithms::spmv_baseline_quantized(&a, &x).iter().sum();
        assert!((got - expect).abs() < 2e-3, "{got} vs {expect}");
        server.shutdown();
    }

    #[test]
    fn threaded_server_replies_match_serial() {
        let ask = |server: &Server, req: &str| -> String {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            writeln!(conn, "{req}").unwrap();
            reader.read_line(&mut line).unwrap();
            line
        };
        let serial = Server::spawn("127.0.0.1:0").unwrap();
        let threaded =
            Server::spawn_with("127.0.0.1:0", ExecBackend::Threaded(3)).unwrap();
        for req in ["HIST 700 11", "DP 64 4 2"] {
            let a = ask(&serial, req);
            let b = ask(&threaded, req);
            assert!(a.starts_with("OK"), "{req}: {a}");
            assert_eq!(a, b, "{req}: cycles/energy/results must be backend-invariant");
        }
        serial.shutdown();
        threaded.shutdown();
    }
}
