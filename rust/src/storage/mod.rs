//! Storage management unit (paper Fig. 4: "orchestrates the storage
//! operations, controlling read, write, translation, logical block
//! mapping, wear leveling, etc.").
//!
//! Datasets are allocated as row ranges with a named field layout;
//! logical dataset ids map to physical row ranges (the translation
//! layer), and wear statistics are derived from the modules' per-row
//! write counters.

pub mod remap;
pub mod wear;

pub use remap::RemapTable;

use crate::error::Result;
use crate::isa::RowLayout;
use crate::rcam::PrinsArray;
use std::collections::BTreeMap;

/// A physical row range inside the PRINS array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowRange {
    /// First physical row.
    pub start: usize,
    /// Number of rows.
    pub len: usize,
}

impl RowRange {
    /// One past the last row: `start + len`.
    pub fn end(&self) -> usize {
        self.start + self.len
    }

    /// Whether the two ranges share any row.
    pub fn overlaps(&self, other: &RowRange) -> bool {
        self.start < other.end() && other.start < self.end()
    }
}

/// Handle to an allocated dataset: rows + its row layout.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Allocation id (the translation-table key).
    pub id: u64,
    /// Physical rows backing the dataset.
    pub rows: RowRange,
    /// Named bit-field layout of each row.
    pub layout: RowLayout,
}

/// The storage management unit. Owns no array reference — it hands out
/// allocations and performs load/readout *through* a borrowed array, so
/// the controller remains the single owner of the hardware.
#[derive(Debug, Default)]
pub struct StorageManager {
    allocations: BTreeMap<u64, RowRange>,
    next_id: u64,
    total_rows: usize,
    remap: Option<RemapTable>,
}

impl StorageManager {
    /// A storage manager over an array of `total_rows` rows, nothing
    /// allocated.
    pub fn new(total_rows: usize) -> Self {
        StorageManager {
            allocations: BTreeMap::new(),
            next_id: 1,
            total_rows,
            remap: None,
        }
    }

    /// Turn on wear-leveling remap with an identity table. Off by
    /// default so existing workloads keep their exact row placement.
    pub fn enable_remap(&mut self) {
        if self.remap.is_none() {
            self.remap = Some(RemapTable::identity(self.total_rows));
        }
    }

    /// The remap table, when wear leveling is enabled.
    pub fn remap(&self) -> Option<&RemapTable> {
        self.remap.as_ref()
    }

    /// First-fit allocation of `n_rows` rows with the given layout.
    pub fn alloc(&mut self, n_rows: usize, layout: RowLayout) -> Option<Dataset> {
        let mut cursor = 0usize;
        // allocations BTreeMap is keyed by id, not ordered by row —
        // gather and sort
        let mut ranges: Vec<RowRange> = self.allocations.values().copied().collect();
        ranges.sort_by_key(|r| r.start);
        for r in ranges {
            if cursor + n_rows <= r.start {
                break;
            }
            cursor = cursor.max(r.end());
        }
        if cursor + n_rows > self.total_rows {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        let rows = RowRange {
            start: cursor,
            len: n_rows,
        };
        self.allocations.insert(id, rows);
        Some(Dataset { id, rows, layout })
    }

    /// Release a dataset's rows.
    pub fn free(&mut self, id: u64) -> bool {
        self.allocations.remove(&id).is_some()
    }

    /// Translate a logical row of a dataset to a physical row. With
    /// remap enabled, the allocation-relative row is one more level of
    /// indirection away from the cell that actually stores it.
    pub fn translate(&self, ds: &Dataset, logical: usize) -> usize {
        assert!(logical < ds.rows.len, "logical row out of range");
        let nominal = ds.rows.start + logical;
        match &self.remap {
            Some(t) => t.to_physical(nominal),
            None => nominal,
        }
    }

    /// Rows currently allocated to datasets.
    pub fn allocated_rows(&self) -> usize {
        self.allocations.values().map(|r| r.len).sum()
    }

    /// Rows still available for allocation.
    pub fn free_rows(&self) -> usize {
        self.total_rows - self.allocated_rows()
    }

    /// Invariant check: no two allocations overlap (proptest target).
    pub fn assert_disjoint(&self) {
        let mut ranges: Vec<RowRange> = self.allocations.values().copied().collect();
        ranges.sort_by_key(|r| r.start);
        for w in ranges.windows(2) {
            assert!(!w[0].overlaps(&w[1]), "overlapping allocations");
        }
    }

    // ----- load / readout helpers ---------------------------------------
    //
    // All of these resolve a named field first, so an unknown field name
    // surfaces as a recoverable `Err` (propagated from `RowLayout::get`)
    // rather than a panic inside the storage path.

    /// Load a u64 value into a field of a logical row.
    pub fn load_value(
        &self,
        array: &mut PrinsArray,
        ds: &Dataset,
        logical: usize,
        field: &str,
        value: u64,
    ) -> Result<()> {
        let f = ds.layout.get(field)?;
        let row = self.translate(ds, logical);
        array.load_row_bits(row, f.base as usize, f.width as usize, value);
        Ok(())
    }

    /// Read a field of a logical row.
    pub fn read_value(
        &self,
        array: &PrinsArray,
        ds: &Dataset,
        logical: usize,
        field: &str,
    ) -> Result<u64> {
        let f = ds.layout.get(field)?;
        let row = self.translate(ds, logical);
        Ok(array.fetch_row_bits(row, f.base as usize, f.width as usize))
    }

    /// Bulk column load: `values[i]` into `field` of logical row i.
    pub fn load_column(
        &self,
        array: &mut PrinsArray,
        ds: &Dataset,
        field: &str,
        values: &[u64],
    ) -> Result<()> {
        assert!(values.len() <= ds.rows.len);
        let f = ds.layout.get(field)?;
        for (i, &v) in values.iter().enumerate() {
            // through translate, not `rows.start + i` — bulk loads must
            // honor the remap indirection exactly like load_value
            array.load_row_bits(self.translate(ds, i), f.base as usize, f.width as usize, v);
        }
        Ok(())
    }

    /// Bulk column readout.
    pub fn read_column(
        &self,
        array: &PrinsArray,
        ds: &Dataset,
        field: &str,
        n: usize,
    ) -> Result<Vec<u64>> {
        assert!(n <= ds.rows.len);
        let f = ds.layout.get(field)?;
        Ok((0..n)
            .map(|i| {
                array.fetch_row_bits(self.translate(ds, i), f.base as usize, f.width as usize)
            })
            .collect())
    }

    // ----- wear leveling -------------------------------------------------

    /// One wear-leveling step: physically swap the all-time-hottest row
    /// with the current coldest and update the remap table so datasets
    /// never notice. The copy goes through the charged write path —
    /// leveling itself wears the two rows, and the ledger records it.
    ///
    /// Returns the swapped physical `(hot, cold)` pair, or `None` when
    /// remap is disabled, wear tracking is off, or the wear spread is
    /// too small (< 2 writes) to be worth a swap. Must only be called
    /// between queries: microprograms that shift tags across rows bake
    /// physical adjacency into the program (see `remap` module docs).
    pub fn wear_level_step(&mut self, array: &mut PrinsArray) -> Option<(usize, usize)> {
        let table = self.remap.as_mut()?;
        let mut wear: Vec<u32> = Vec::with_capacity(array.total_rows());
        for m in array.modules() {
            wear.extend_from_slice(m.wear_counters()?);
        }
        let mut hot = 0usize;
        let mut cold = 0usize;
        for (r, &w) in wear.iter().enumerate() {
            if w > wear[hot] {
                hot = r;
            }
            if w < wear[cold] {
                cold = r;
            }
        }
        if wear[hot] - wear[cold] < 2 {
            return None;
        }
        // exchange the two rows' full contents in ≤64-bit chunks:
        // host-buffered reads, charged writes
        let width = array.width();
        let mut off = 0usize;
        while off < width {
            let w = (width - off).min(64);
            let a = array.fetch_row_bits(hot, off, w);
            let b = array.fetch_row_bits(cold, off, w);
            array.load_row_bits_charged(hot, off, w, b);
            array.load_row_bits_charged(cold, off, w, a);
            off += 64;
        }
        table.swap(hot, cold);
        Some((hot, cold))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::RowLayout;

    fn layout() -> RowLayout {
        let mut l = RowLayout::new(64);
        l.alloc("v", 32);
        l
    }

    #[test]
    fn alloc_free_realloc() {
        let mut sm = StorageManager::new(1000);
        let a = sm.alloc(400, layout()).unwrap();
        let b = sm.alloc(400, layout()).unwrap();
        assert_eq!(a.rows.start, 0);
        assert_eq!(b.rows.start, 400);
        assert!(sm.alloc(400, layout()).is_none(), "only 200 rows left");
        sm.assert_disjoint();
        assert!(sm.free(a.id));
        let c = sm.alloc(300, layout()).unwrap();
        assert_eq!(c.rows.start, 0, "first-fit reuses the gap");
        sm.assert_disjoint();
        assert_eq!(sm.free_rows(), 1000 - 400 - 300);
    }

    #[test]
    fn load_read_roundtrip() {
        let mut sm = StorageManager::new(100);
        let mut array = PrinsArray::single(100, 64);
        let ds = sm.alloc(50, layout()).unwrap();
        for i in 0..50 {
            sm.load_value(&mut array, &ds, i, "v", (i * 7) as u64).unwrap();
        }
        for i in 0..50 {
            assert_eq!(sm.read_value(&array, &ds, i, "v").unwrap(), (i * 7) as u64);
        }
        let col = sm.read_column(&array, &ds, "v", 10).unwrap();
        assert_eq!(col[3], 21);
        // unknown field names surface as recoverable errors
        assert!(sm.read_value(&array, &ds, 0, "missing").is_err());
        assert!(sm.load_value(&mut array, &ds, 0, "missing", 1).is_err());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn translate_bounds_checked() {
        let mut sm = StorageManager::new(100);
        let ds = sm.alloc(10, layout()).unwrap();
        sm.translate(&ds, 10);
    }

    #[test]
    fn remap_is_transparent_and_levels_wear() {
        let mut sm = StorageManager::new(32);
        let mut array = PrinsArray::single(32, 64);
        array.enable_wear_tracking();
        sm.enable_remap();
        let ds = sm.alloc(16, layout()).unwrap();
        let vals: Vec<u64> = (0..16).map(|i| i * 11 + 1).collect();
        sm.load_column(&mut array, &ds, "v", &vals).unwrap();
        // hammer logical row 3 so one physical row runs hot
        for k in 0..20 {
            sm.load_value(&mut array, &ds, 3, "v", 34 + (k & 1)).unwrap();
        }
        sm.load_value(&mut array, &ds, 3, "v", 34).unwrap();
        let before = crate::storage::wear::wear_report(&array).unwrap();
        let swapped = sm.wear_level_step(&mut array).unwrap();
        assert_eq!(swapped.0, 3, "the hammered physical row was hottest");
        assert_eq!(
            sm.translate(&ds, 3),
            swapped.1,
            "hot logical row now lives in the formerly-cold physical row"
        );
        // the dataset still reads back exactly what it wrote
        let got = sm.read_column(&array, &ds, "v", 16).unwrap();
        let mut want = vals.clone();
        want[3] = 34;
        assert_eq!(got, want);
        sm.remap().unwrap().assert_consistent();
        // keep hammering the same logical row: writes now land on the
        // formerly-cold physical row, so max wear grows slower
        for _ in 0..20 {
            sm.load_value(&mut array, &ds, 3, "v", 35).unwrap();
        }
        let after = crate::storage::wear::wear_report(&array).unwrap();
        assert!(
            after.max_writes < before.max_writes + 20,
            "hot row stopped absorbing every write"
        );
    }

    #[test]
    fn wear_level_step_needs_remap_tracking_and_spread() {
        let mut sm = StorageManager::new(16);
        let mut array = PrinsArray::single(16, 64);
        // remap off
        assert!(sm.wear_level_step(&mut array).is_none());
        sm.enable_remap();
        // wear tracking off
        assert!(sm.wear_level_step(&mut array).is_none());
        array.enable_wear_tracking();
        // perfectly level (all zero): spread below threshold
        assert!(sm.wear_level_step(&mut array).is_none());
    }

    #[test]
    fn two_datasets_do_not_interfere() {
        let mut sm = StorageManager::new(64);
        let mut array = PrinsArray::single(64, 64);
        let d1 = sm.alloc(20, layout()).unwrap();
        let d2 = sm.alloc(20, layout()).unwrap();
        sm.load_column(&mut array, &d1, "v", &vec![7; 20]).unwrap();
        sm.load_column(&mut array, &d2, "v", &vec![9; 20]).unwrap();
        assert!(sm.read_column(&array, &d1, "v", 20).unwrap().iter().all(|&v| v == 7));
        assert!(sm.read_column(&array, &d2, "v", 20).unwrap().iter().all(|&v| v == 9));
    }
}
