//! Storage management unit (paper Fig. 4: "orchestrates the storage
//! operations, controlling read, write, translation, logical block
//! mapping, wear leveling, etc.").
//!
//! Datasets are allocated as row ranges with a named field layout;
//! logical dataset ids map to physical row ranges (the translation
//! layer), and wear statistics are derived from the modules' per-row
//! write counters.

pub mod wear;

use crate::error::Result;
use crate::isa::RowLayout;
use crate::rcam::PrinsArray;
use std::collections::BTreeMap;

/// A physical row range inside the PRINS array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowRange {
    /// First physical row.
    pub start: usize,
    /// Number of rows.
    pub len: usize,
}

impl RowRange {
    /// One past the last row: `start + len`.
    pub fn end(&self) -> usize {
        self.start + self.len
    }

    /// Whether the two ranges share any row.
    pub fn overlaps(&self, other: &RowRange) -> bool {
        self.start < other.end() && other.start < self.end()
    }
}

/// Handle to an allocated dataset: rows + its row layout.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Allocation id (the translation-table key).
    pub id: u64,
    /// Physical rows backing the dataset.
    pub rows: RowRange,
    /// Named bit-field layout of each row.
    pub layout: RowLayout,
}

/// The storage management unit. Owns no array reference — it hands out
/// allocations and performs load/readout *through* a borrowed array, so
/// the controller remains the single owner of the hardware.
#[derive(Debug, Default)]
pub struct StorageManager {
    allocations: BTreeMap<u64, RowRange>,
    next_id: u64,
    total_rows: usize,
}

impl StorageManager {
    /// A storage manager over an array of `total_rows` rows, nothing
    /// allocated.
    pub fn new(total_rows: usize) -> Self {
        StorageManager {
            allocations: BTreeMap::new(),
            next_id: 1,
            total_rows,
        }
    }

    /// First-fit allocation of `n_rows` rows with the given layout.
    pub fn alloc(&mut self, n_rows: usize, layout: RowLayout) -> Option<Dataset> {
        let mut cursor = 0usize;
        // allocations BTreeMap is keyed by id, not ordered by row —
        // gather and sort
        let mut ranges: Vec<RowRange> = self.allocations.values().copied().collect();
        ranges.sort_by_key(|r| r.start);
        for r in ranges {
            if cursor + n_rows <= r.start {
                break;
            }
            cursor = cursor.max(r.end());
        }
        if cursor + n_rows > self.total_rows {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        let rows = RowRange {
            start: cursor,
            len: n_rows,
        };
        self.allocations.insert(id, rows);
        Some(Dataset { id, rows, layout })
    }

    /// Release a dataset's rows.
    pub fn free(&mut self, id: u64) -> bool {
        self.allocations.remove(&id).is_some()
    }

    /// Translate a logical row of a dataset to a physical row.
    pub fn translate(&self, ds: &Dataset, logical: usize) -> usize {
        assert!(logical < ds.rows.len, "logical row out of range");
        ds.rows.start + logical
    }

    /// Rows currently allocated to datasets.
    pub fn allocated_rows(&self) -> usize {
        self.allocations.values().map(|r| r.len).sum()
    }

    /// Rows still available for allocation.
    pub fn free_rows(&self) -> usize {
        self.total_rows - self.allocated_rows()
    }

    /// Invariant check: no two allocations overlap (proptest target).
    pub fn assert_disjoint(&self) {
        let mut ranges: Vec<RowRange> = self.allocations.values().copied().collect();
        ranges.sort_by_key(|r| r.start);
        for w in ranges.windows(2) {
            assert!(!w[0].overlaps(&w[1]), "overlapping allocations");
        }
    }

    // ----- load / readout helpers ---------------------------------------
    //
    // All of these resolve a named field first, so an unknown field name
    // surfaces as a recoverable `Err` (propagated from `RowLayout::get`)
    // rather than a panic inside the storage path.

    /// Load a u64 value into a field of a logical row.
    pub fn load_value(
        &self,
        array: &mut PrinsArray,
        ds: &Dataset,
        logical: usize,
        field: &str,
        value: u64,
    ) -> Result<()> {
        let f = ds.layout.get(field)?;
        let row = self.translate(ds, logical);
        array.load_row_bits(row, f.base as usize, f.width as usize, value);
        Ok(())
    }

    /// Read a field of a logical row.
    pub fn read_value(
        &self,
        array: &PrinsArray,
        ds: &Dataset,
        logical: usize,
        field: &str,
    ) -> Result<u64> {
        let f = ds.layout.get(field)?;
        let row = self.translate(ds, logical);
        Ok(array.fetch_row_bits(row, f.base as usize, f.width as usize))
    }

    /// Bulk column load: `values[i]` into `field` of logical row i.
    pub fn load_column(
        &self,
        array: &mut PrinsArray,
        ds: &Dataset,
        field: &str,
        values: &[u64],
    ) -> Result<()> {
        assert!(values.len() <= ds.rows.len);
        let f = ds.layout.get(field)?;
        for (i, &v) in values.iter().enumerate() {
            array.load_row_bits(ds.rows.start + i, f.base as usize, f.width as usize, v);
        }
        Ok(())
    }

    /// Bulk column readout.
    pub fn read_column(
        &self,
        array: &PrinsArray,
        ds: &Dataset,
        field: &str,
        n: usize,
    ) -> Result<Vec<u64>> {
        assert!(n <= ds.rows.len);
        let f = ds.layout.get(field)?;
        Ok((0..n)
            .map(|i| {
                array.fetch_row_bits(ds.rows.start + i, f.base as usize, f.width as usize)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::RowLayout;

    fn layout() -> RowLayout {
        let mut l = RowLayout::new(64);
        l.alloc("v", 32);
        l
    }

    #[test]
    fn alloc_free_realloc() {
        let mut sm = StorageManager::new(1000);
        let a = sm.alloc(400, layout()).unwrap();
        let b = sm.alloc(400, layout()).unwrap();
        assert_eq!(a.rows.start, 0);
        assert_eq!(b.rows.start, 400);
        assert!(sm.alloc(400, layout()).is_none(), "only 200 rows left");
        sm.assert_disjoint();
        assert!(sm.free(a.id));
        let c = sm.alloc(300, layout()).unwrap();
        assert_eq!(c.rows.start, 0, "first-fit reuses the gap");
        sm.assert_disjoint();
        assert_eq!(sm.free_rows(), 1000 - 400 - 300);
    }

    #[test]
    fn load_read_roundtrip() {
        let mut sm = StorageManager::new(100);
        let mut array = PrinsArray::single(100, 64);
        let ds = sm.alloc(50, layout()).unwrap();
        for i in 0..50 {
            sm.load_value(&mut array, &ds, i, "v", (i * 7) as u64).unwrap();
        }
        for i in 0..50 {
            assert_eq!(sm.read_value(&array, &ds, i, "v").unwrap(), (i * 7) as u64);
        }
        let col = sm.read_column(&array, &ds, "v", 10).unwrap();
        assert_eq!(col[3], 21);
        // unknown field names surface as recoverable errors
        assert!(sm.read_value(&array, &ds, 0, "missing").is_err());
        assert!(sm.load_value(&mut array, &ds, 0, "missing", 1).is_err());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn translate_bounds_checked() {
        let mut sm = StorageManager::new(100);
        let ds = sm.alloc(10, layout()).unwrap();
        sm.translate(&ds, 10);
    }

    #[test]
    fn two_datasets_do_not_interfere() {
        let mut sm = StorageManager::new(64);
        let mut array = PrinsArray::single(64, 64);
        let d1 = sm.alloc(20, layout()).unwrap();
        let d2 = sm.alloc(20, layout()).unwrap();
        sm.load_column(&mut array, &d1, "v", &vec![7; 20]).unwrap();
        sm.load_column(&mut array, &d2, "v", &vec![9; 20]).unwrap();
        assert!(sm.read_column(&array, &d1, "v", 20).unwrap().iter().all(|&v| v == 7));
        assert!(sm.read_column(&array, &d2, "v", 20).unwrap().iter().all(|&v| v == 9));
    }
}
