//! Endurance / wear-levelling analysis (paper §3.1: endurance ≈ 1e12
//! writes today, "may suffice for only about one month"; predicted
//! 1e14–1e15 "extending PRINS endurance to a number of years").
//!
//! The modules' per-row write counters (enabled via
//! `PrinsArray::enable_wear_tracking`) feed a lifetime projection under a
//! measured write rate, reproducing the paper's month/years argument
//! quantitatively.

use crate::rcam::{DeviceModel, PrinsArray};

/// Aggregate wear statistics over the whole chain's per-row counters.
#[derive(Clone, Debug, PartialEq)]
pub struct WearReport {
    /// Writes seen by the most-written row.
    pub max_writes: u32,
    /// Mean writes per row.
    pub mean_writes: f64,
    /// Total writes across all rows.
    pub total_writes: u64,
    /// Rows covered by the report.
    pub rows: usize,
    /// max/mean imbalance; 1.0 = perfectly level
    pub imbalance: f64,
}

/// Summarize per-row wear across the whole chain. Returns None when wear
/// tracking is disabled.
pub fn wear_report(array: &PrinsArray) -> Option<WearReport> {
    let mut max = 0u32;
    let mut total = 0u64;
    let mut rows = 0usize;
    for m in array.modules() {
        let counters = m.wear_counters()?;
        rows += counters.len();
        for &c in counters {
            max = max.max(c);
            total += c as u64;
        }
    }
    // rows == 0 (an empty chain) and total == 0 (no writes yet) must
    // both yield finite, well-defined statistics — never 0/0 = NaN.
    let mean = if rows == 0 { 0.0 } else { total as f64 / rows as f64 };
    let imbalance = if mean > 0.0 { max as f64 / mean } else { 1.0 };
    debug_assert!(imbalance.is_finite());
    Some(WearReport {
        max_writes: max,
        mean_writes: mean,
        total_writes: total,
        rows,
        imbalance,
    })
}

/// Projected lifetime in seconds: the most-written cell reaches the
/// endurance limit at the observed write rate (writes of the hottest row
/// per simulated second).
pub fn projected_lifetime_s(
    report: &WearReport,
    device: &DeviceModel,
    elapsed_cycles: u64,
) -> f64 {
    // zero writes or zero elapsed time means no measurable write rate:
    // the projection is "unlimited", explicitly — not 0/0 or x/0 noise
    if report.max_writes == 0 || elapsed_cycles == 0 {
        return f64::INFINITY;
    }
    let elapsed_s = device.cycles_to_seconds(elapsed_cycles);
    if !elapsed_s.is_finite() || elapsed_s <= 0.0 {
        return f64::INFINITY;
    }
    let hottest_rate = report.max_writes as f64 / elapsed_s; // writes/s
    let life = device.endurance / hottest_rate;
    // a degenerate device model (0 or non-finite endurance/frequency)
    // must never leak NaN into reports
    if life.is_nan() {
        f64::INFINITY
    } else {
        life
    }
}

/// Render a lifetime in hours/days/years ("unlimited" for ∞).
pub fn lifetime_human(seconds: f64) -> String {
    if !seconds.is_finite() {
        return "unlimited".into();
    }
    const DAY: f64 = 86_400.0;
    const YEAR: f64 = 365.25 * DAY;
    if seconds >= YEAR {
        format!("{:.1} years", seconds / YEAR)
    } else if seconds >= DAY {
        format!("{:.1} days", seconds / DAY)
    } else {
        format!("{:.1} hours", seconds / 3_600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcam::DeviceModel;

    #[test]
    fn report_aggregates_across_modules() {
        let mut a = PrinsArray::new(2, 8, 4);
        a.enable_wear_tracking();
        // tag a single row in module 1 and write it 5 times
        a.load_row_bits(9, 0, 1, 1);
        a.compare(&[(0, true)]);
        for _ in 0..5 {
            a.write(&[(1, true)]);
        }
        let r = wear_report(&a).unwrap();
        // 5 tagged writes + 1 direct load (loads wear cells too)
        assert_eq!(r.max_writes, 6);
        assert_eq!(r.rows, 16);
        assert!(r.imbalance > 1.0);
    }

    #[test]
    fn tracking_disabled_returns_none() {
        let a = PrinsArray::new(1, 8, 4);
        assert!(wear_report(&a).is_none());
    }

    #[test]
    fn paper_endurance_scenario() {
        // The paper: sub-ns switching, continuous writes, 1e12 endurance →
        // about a month. A cell written every other cycle at 500 MHz:
        // 2.5e8 writes/s → 1e12 / 2.5e8 = 4000 s... The paper's "month"
        // assumes lower duty; here we verify the *relation*: future
        // endurance (1e14) buys exactly 100x lifetime.
        let today = DeviceModel::default();
        let future = DeviceModel::future_endurance();
        let rep = WearReport {
            max_writes: 1_000,
            mean_writes: 10.0,
            total_writes: 10_000,
            rows: 1000,
            imbalance: 100.0,
        };
        let lt_today = projected_lifetime_s(&rep, &today, 500_000_000);
        let lt_future = projected_lifetime_s(&rep, &future, 500_000_000);
        assert!((lt_future / lt_today - 100.0).abs() < 1e-6);
        assert!(lifetime_human(lt_today).contains("days") || lifetime_human(lt_today).contains("years"));
    }

    #[test]
    fn fresh_array_report_is_finite_not_nan() {
        // regression: 0 total writes used to risk 0/0 in imbalance
        let mut a = PrinsArray::new(2, 8, 4);
        a.enable_wear_tracking();
        let r = wear_report(&a).unwrap();
        assert_eq!(r.max_writes, 0);
        assert_eq!(r.mean_writes, 0.0);
        assert_eq!(r.imbalance, 1.0, "unwritten array is perfectly level");
        assert!(r.imbalance.is_finite() && r.mean_writes.is_finite());
    }

    #[test]
    fn zero_cycle_projection_is_infinite_not_nan() {
        // regression: writes observed but no elapsed time → rate is
        // undefined; the projection must be ∞, never NaN
        let rep = WearReport {
            max_writes: 5,
            mean_writes: 1.0,
            total_writes: 10,
            rows: 10,
            imbalance: 5.0,
        };
        let lt = projected_lifetime_s(&rep, &DeviceModel::default(), 0);
        assert!(lt.is_infinite() && !lt.is_nan());
        assert_eq!(lifetime_human(lt), "unlimited");
    }

    #[test]
    fn zero_writes_is_unlimited() {
        let rep = WearReport {
            max_writes: 0,
            mean_writes: 0.0,
            total_writes: 0,
            rows: 10,
            imbalance: 1.0,
        };
        assert_eq!(
            lifetime_human(projected_lifetime_s(&rep, &DeviceModel::default(), 100)),
            "unlimited"
        );
    }
}
