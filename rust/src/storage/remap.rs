//! Wear-leveling row remap (paper Fig. 4 names wear leveling among the
//! storage management unit's duties; §3.1 motivates it — endurance
//! ≈ 1e12 writes makes the *hottest* cell the lifetime bottleneck).
//!
//! The table is a logical→physical row indirection consulted by
//! [`super::StorageManager::translate`]. It starts as the identity and
//! stays off (`None`) unless [`super::StorageManager::enable_remap`] is
//! called, so every existing workload keeps its exact row placement and
//! ledger. When enabled, [`super::StorageManager::wear_level_step`]
//! rotates the all-time-hottest physical row with the current coldest:
//! the row *contents* are physically swapped (through the charged write
//! path — leveling itself wears cells, and the ledger says so) and the
//! indirection is updated so datasets never notice.
//!
//! Caveat: kernels whose microprograms move tags *between* rows (tag
//! shifts) bake physical adjacency into the program. Remapping must only
//! happen between queries, never mid-flight — the storage manager has no
//! view of in-flight programs, so the caller owns that discipline.

/// Bidirectional logical↔physical row permutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RemapTable {
    log2phys: Vec<u32>,
    phys2log: Vec<u32>,
    swaps: u64,
}

impl RemapTable {
    /// The identity permutation over `rows` rows.
    pub fn identity(rows: usize) -> Self {
        let id: Vec<u32> = (0..rows as u32).collect();
        RemapTable {
            log2phys: id.clone(),
            phys2log: id,
            swaps: 0,
        }
    }

    /// Physical row backing logical row `logical`.
    pub fn to_physical(&self, logical: usize) -> usize {
        self.log2phys[logical] as usize
    }

    /// Logical row currently living in physical row `phys`.
    pub fn to_logical(&self, phys: usize) -> usize {
        self.phys2log[phys] as usize
    }

    /// Rows covered by the table.
    pub fn rows(&self) -> usize {
        self.log2phys.len()
    }

    /// Swaps performed since creation.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Exchange the logical occupants of physical rows `pa` and `pb`.
    /// The caller is responsible for also swapping the stored contents.
    pub fn swap(&mut self, pa: usize, pb: usize) {
        let la = self.phys2log[pa] as usize;
        let lb = self.phys2log[pb] as usize;
        self.log2phys[la] = pb as u32;
        self.log2phys[lb] = pa as u32;
        self.phys2log.swap(pa, pb);
        self.swaps += 1;
    }

    /// Debug invariant: the two directions are inverse permutations.
    pub fn assert_consistent(&self) {
        for (l, &p) in self.log2phys.iter().enumerate() {
            assert_eq!(self.phys2log[p as usize] as usize, l, "remap not a bijection");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrips() {
        let t = RemapTable::identity(8);
        for r in 0..8 {
            assert_eq!(t.to_physical(r), r);
            assert_eq!(t.to_logical(r), r);
        }
        assert_eq!(t.swaps(), 0);
        t.assert_consistent();
    }

    #[test]
    fn swap_updates_both_directions() {
        let mut t = RemapTable::identity(8);
        t.swap(1, 6);
        assert_eq!(t.to_physical(1), 6);
        assert_eq!(t.to_physical(6), 1);
        assert_eq!(t.to_logical(6), 1);
        assert_eq!(t.to_logical(1), 6);
        assert_eq!(t.swaps(), 1);
        t.assert_consistent();
        // swapping through a chain stays a bijection
        t.swap(6, 3);
        t.swap(0, 1);
        t.assert_consistent();
    }

    #[test]
    fn swap_back_restores_identity() {
        let mut t = RemapTable::identity(4);
        t.swap(0, 3);
        t.swap(0, 3);
        assert_eq!(t, {
            let mut id = RemapTable::identity(4);
            id.swaps = 2;
            id
        });
    }
}
