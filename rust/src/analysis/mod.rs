//! Static verification of PRINS microprograms (`prins verify`).
//!
//! PRINS compiles every kernel into straight-line associative
//! microprograms (paper §5.2–5.3) whose correctness contracts —
//! in-bounds columns, conflict-free patterns, tag-register discipline,
//! write-free queries, analytic cycle floors — were previously checked
//! only by *executing* them and inspecting ledgers. "A Modern Primer on
//! Processing-in-Memory" (arXiv:2012.03112) names exactly this gap —
//! correctness-validation tooling for in-memory ISAs — as a PIM adoption
//! barrier. This module closes it with a dataflow analyzer and lint
//! engine over [`Program`]/[`crate::isa::Instr`] that proves the
//! contracts **without running the array**.
//!
//! Rules (DESIGN.md §Static verification has the full table):
//!
//! | rule | kind      | proves |
//! |------|-----------|--------|
//! | W01  | lint      | every referenced bit-column `<` array width |
//! | W02  | lint      | no duplicate/contradictory `(col, bit)` pairs in one pattern |
//! | T01  | dataflow  | no `Write`/`Read`/`FirstMatch` under statically-empty tags; tag shifts stay inside the chain |
//! | S01  | lint      | span classification agrees with an independent threaded-path whitelist |
//! | C01  | contract  | `write_free_queries` kernels synthesize zero `Write`/`ClearColumns` |
//! | C02  | contract  | the synthesized plan's static cycle estimate equals `query_floor_cycles` |
//! | C03  | contract  | overlay-shared kernels confine every `Write`/`ClearColumns` to scratch columns outside the resident range |
//! | F01  | config    | fault-model sanity: BERs in `[0, 1)`, finite wear coupling, stuck cells inside the array |
//!
//! Program-shape rules (W01/W02/T01/S01) run per [`Program`] via
//! [`check_program`]; kernel contracts (C01/C02) run over a
//! [`QueryPlan`] — the full set of microprograms one query executes plus
//! the cycles charged outside any program — via [`contract`]. The
//! [`driver`] walks the whole kernel registry over a seeded shape grid
//! and is surfaced as the `prins verify [kernel|all] [--json]` CLI verb,
//! the `tests/static_verify.rs` gate, and a CI job.

pub mod contract;
pub mod driver;
pub mod lattice;
pub mod rules;

pub use driver::{reports_json, verify_kernel, verify_registry, KernelReport};
pub use lattice::TagState;

use crate::isa::Program;
use crate::rcam::PrinsArray;
use std::fmt;
use std::ops::Range;

/// Identifier of one analyzer rule (see the module-level table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RuleId {
    /// Column bounds: every referenced bit-column `<` array width.
    W01,
    /// Pattern conflict: duplicate or contradictory `(col, bit)` entries
    /// inside one `Compare`/`Write` pattern.
    W02,
    /// Tag liveness: operations under statically-empty tags; tag shifts
    /// that flush the chain or fall off the fast per-module path.
    T01,
    /// Span safety: span classification must agree with an independent
    /// whitelist of threaded-path instructions.
    S01,
    /// Write freedom: `write_free_queries` kernels must synthesize query
    /// programs with zero `Write`/`ClearColumns`.
    C01,
    /// Floor consistency: the plan's static cycle estimate must equal
    /// the kernel's `query_floor_cycles` for the same shape.
    C02,
    /// Overlay write freedom: kernels that serve the scratch-overlay
    /// shared-read path (`overlay_queries = true`) must confine every
    /// query-program `Write`/`ClearColumns` to scratch columns — any
    /// mutation of a column inside the dataset's resident (stored) range
    /// would escape the cursor-local overlay.
    C03,
    /// Fault-model sanity: every bit-error rate must lie in `[0, 1)`,
    /// wear coupling must be finite and non-negative, and every explicit
    /// stuck-at cell must address a cell inside the array. Enforced by
    /// [`crate::rcam::PrinsArray::enable_faults`] before any fault is
    /// ever injected.
    F01,
}

impl RuleId {
    /// The stable rule identifier string (`"W01"`, …) used in reports.
    pub fn as_str(&self) -> &'static str {
        match self {
            RuleId::W01 => "W01",
            RuleId::W02 => "W02",
            RuleId::T01 => "T01",
            RuleId::S01 => "S01",
            RuleId::C01 => "C01",
            RuleId::C02 => "C02",
            RuleId::C03 => "C03",
            RuleId::F01 => "F01",
        }
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// How severe a diagnostic is. The registry gate fails on *any*
/// diagnostic; severity tells a human which to read first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Suspicious but possibly intentional (duplicate pattern entry with
    /// the same bit, a tag shift on the slow global-gather path).
    Warning,
    /// A definite contract violation (out-of-bounds column,
    /// contradictory pattern, write under provably-empty tags, floor
    /// drift).
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// One analyzer finding: which rule fired, how severe, where (the
/// instruction index inside the analyzed program, when the finding is
/// instruction-anchored), and a human-readable message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// The rule that fired.
    pub rule: RuleId,
    /// Severity of the finding.
    pub severity: Severity,
    /// Instruction index inside the analyzed program (`None` for
    /// program-level findings such as C02 floor drift).
    pub index: Option<usize>,
    /// Human-readable description of the finding.
    pub message: String,
}

impl Diagnostic {
    /// An instruction-anchored diagnostic.
    pub fn at(rule: RuleId, severity: Severity, index: usize, message: String) -> Self {
        Diagnostic {
            rule,
            severity,
            index: Some(index),
            message,
        }
    }

    /// A program-level diagnostic (no single offending instruction).
    pub fn global(rule: RuleId, severity: Severity, message: String) -> Self {
        Diagnostic {
            rule,
            severity,
            index: None,
            message,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.index {
            Some(i) => write!(f, "{} {} @{}: {}", self.severity, self.rule, i, self.message),
            None => write!(f, "{} {}: {}", self.severity, self.rule, self.message),
        }
    }
}

/// The geometry facts the analyzer needs about the target array —
/// everything the rules consume, decoupled from [`PrinsArray`] so
/// fixture tests can fabricate shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ArrayShape {
    /// Total rows across the daisy chain.
    pub rows: usize,
    /// Rows per RCAM module (the fast tag-shift segment length).
    pub rows_per_module: usize,
    /// Row width in bit-columns.
    pub width: usize,
}

impl ArrayShape {
    /// The shape of a live array.
    pub fn of(array: &PrinsArray) -> Self {
        ArrayShape {
            rows: array.total_rows(),
            rows_per_module: array.total_rows() / array.n_modules(),
            width: array.width(),
        }
    }
}

/// Everything one query executes, synthesized without running: the
/// microprograms in dispatch order plus the cycles the query charges
/// outside any program (pipelined reduction-tree drains, chain-hop
/// field moves). Produced by [`crate::algorithms::kernel::Kernel::query_plan`];
/// consumed by the C01/C02 contract rules.
#[derive(Clone, Debug, Default)]
pub struct QueryPlan {
    /// The query's microprograms, in dispatch order.
    pub programs: Vec<Program>,
    /// Cycles charged outside any program (drains, chain hops).
    pub extra_cycles: u64,
}

impl QueryPlan {
    /// Static cycle cost of the whole plan: Σ program estimates plus the
    /// non-program cycles. C02 pins this to `query_floor_cycles`.
    pub fn cycle_estimate(&self) -> u64 {
        self.programs
            .iter()
            .map(|p| p.cycle_estimate())
            .sum::<u64>()
            + self.extra_cycles
    }

    /// Total instructions across every program of the plan.
    pub fn n_instructions(&self) -> usize {
        self.programs.iter().map(|p| p.len()).sum()
    }
}

/// One shard's synthesized query plan together with the facts the
/// contract rules compare it against: the kernel's own analytic floor
/// for the identical shard, the shard array's geometry, and the
/// column range holding stored (resident) data.
#[derive(Clone, Debug)]
pub struct PlannedQuery {
    /// The synthesized plan.
    pub plan: QueryPlan,
    /// `Kernel::query_floor_cycles` for the same shard and parameters.
    pub floor_cycles: u64,
    /// The shard array's geometry.
    pub shape: ArrayShape,
    /// `Kernel::resident_columns` — the stored-data column range the
    /// C03 overlay contract proves query writes stay out of.
    pub resident_columns: Range<u16>,
}

/// Run every program-shape rule (W01, W02, T01, S01) over one program.
/// Returns all findings in rule order; an empty vector is a proof that
/// the program is clean under the analyzer's rule set.
pub fn check_program(prog: &Program, shape: &ArrayShape) -> Vec<Diagnostic> {
    let mut out = rules::column_bounds(prog, shape);
    out.extend(rules::pattern_conflicts(prog));
    out.extend(rules::tag_liveness(prog, shape));
    out.extend(rules::span_safety(prog));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instr;

    #[test]
    fn shape_of_live_array_matches_geometry() {
        let a = PrinsArray::new(3, 16, 40);
        let s = ArrayShape::of(&a);
        assert_eq!(
            s,
            ArrayShape {
                rows: 48,
                rows_per_module: 16,
                width: 40
            }
        );
    }

    #[test]
    fn plan_estimate_sums_programs_and_extra() {
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(0, true)])); // 1
        p.push(Instr::Write(vec![(1, true)])); // 2
        let plan = QueryPlan {
            programs: vec![p.clone(), p],
            extra_cycles: 5,
        };
        assert_eq!(plan.cycle_estimate(), 2 * 3 + 5);
        assert_eq!(plan.n_instructions(), 4);
    }

    #[test]
    fn diagnostics_render_rule_and_index() {
        let d = Diagnostic::at(RuleId::W01, Severity::Error, 7, "col 99".into());
        assert_eq!(format!("{d}"), "error W01 @7: col 99");
        let g = Diagnostic::global(RuleId::C02, Severity::Error, "drift".into());
        assert_eq!(format!("{g}"), "error C02: drift");
    }
}
