//! The program-shape rules of the static analyzer: W01 column bounds,
//! W02 pattern conflicts, T01 tag liveness, S01 span safety. Each rule
//! is a pure function `(&Program[, &ArrayShape]) -> Vec<Diagnostic>`;
//! [`super::check_program`] runs them all. F01 fault-config sanity is
//! the one non-program rule here: it checks a [`FaultModel`] against an
//! [`ArrayShape`] and gates `PrinsArray::enable_faults`.

use super::lattice::TagState;
use super::{ArrayShape, Diagnostic, RuleId, Severity};
use crate::isa::{Instr, Pat, Program};
use crate::reliability::FaultModel;

/// W01: every referenced bit-column must lie below the array width.
/// Covers pattern columns (`Compare`/`Write`), column ranges
/// (`Read`/`ClearColumns` — checked end-inclusive with overflow-safe
/// arithmetic, the static twin of the fixed `Program::max_column`), and
/// the `ReduceField` column.
pub fn column_bounds(prog: &Program, shape: &ArrayShape) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let w = shape.width;
    for (idx, instr) in prog.instrs.iter().enumerate() {
        match instr {
            Instr::Compare(p) | Instr::Write(p) => {
                for &(c, _) in p {
                    if c as usize >= w {
                        out.push(Diagnostic::at(
                            RuleId::W01,
                            Severity::Error,
                            idx,
                            format!("pattern column {c} out of bounds (width {w})"),
                        ));
                    }
                }
            }
            Instr::Read { base, width } | Instr::ClearColumns { base, width } => {
                // width == 0 references no columns (see Program::max_column)
                if *width > 0 {
                    let end = *base as usize + *width as usize - 1;
                    if end >= w {
                        out.push(Diagnostic::at(
                            RuleId::W01,
                            Severity::Error,
                            idx,
                            format!(
                                "column range [{base}, {end}] out of bounds (width {w})"
                            ),
                        ));
                    }
                }
            }
            Instr::ReduceField { col } => {
                if *col as usize >= w {
                    out.push(Diagnostic::at(
                        RuleId::W01,
                        Severity::Error,
                        idx,
                        format!("reduce column {col} out of bounds (width {w})"),
                    ));
                }
            }
            _ => {}
        }
    }
    out
}

/// W02: a key/mask pattern must bind each bit-column at most once.
/// Binding the same column twice with opposing bits is a contradiction
/// (a `Compare` can never match; a `Write` is order-dependent) and is an
/// error; a repeated identical binding is redundant and a warning.
pub fn pattern_conflicts(prog: &Program) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (idx, instr) in prog.instrs.iter().enumerate() {
        let (p, kind): (&Pat, &str) = match instr {
            Instr::Compare(p) => (p, "compare"),
            Instr::Write(p) => (p, "write"),
            _ => continue,
        };
        for (i, &(c, b)) in p.iter().enumerate() {
            if let Some(&(_, prev)) = p[..i].iter().find(|&&(c2, _)| c2 == c) {
                if prev != b {
                    out.push(Diagnostic::at(
                        RuleId::W02,
                        Severity::Error,
                        idx,
                        format!(
                            "{kind} pattern binds column {c} to both {} and {}",
                            prev as u8, b as u8
                        ),
                    ));
                } else {
                    out.push(Diagnostic::at(
                        RuleId::W02,
                        Severity::Warning,
                        idx,
                        format!("{kind} pattern binds column {c} twice"),
                    ));
                }
            }
        }
    }
    out
}

/// T01: abstract interpretation of the tag registers over the
/// [`TagState`] lattice. Flags `Write`/`Read`/`FirstMatch` executed
/// under statically-empty tags (provable no-ops / sentinel reads), tag
/// shifts of `≥ rows` hops (which flush every tag off the chain), and
/// shifts of more hops than one module holds (these fall off the fast
/// word-shift path onto the global-gather fallback).
pub fn tag_liveness(prog: &Program, shape: &ArrayShape) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut state = TagState::Unknown;
    for (idx, instr) in prog.instrs.iter().enumerate() {
        if state == TagState::Empty {
            match instr {
                Instr::Write(_) => out.push(Diagnostic::at(
                    RuleId::T01,
                    Severity::Error,
                    idx,
                    "write under statically-empty tags is a no-op".into(),
                )),
                Instr::Read { .. } => out.push(Diagnostic::at(
                    RuleId::T01,
                    Severity::Error,
                    idx,
                    "read under statically-empty tags always yields the \
                     no-match sentinel"
                        .into(),
                )),
                Instr::FirstMatch => out.push(Diagnostic::at(
                    RuleId::T01,
                    Severity::Error,
                    idx,
                    "first_match under statically-empty tags has no tag to keep".into(),
                )),
                _ => {}
            }
        }
        if let Instr::ShiftTagsUp(h) | Instr::ShiftTagsDown(h) = instr {
            let h = *h as usize;
            if h >= shape.rows {
                out.push(Diagnostic::at(
                    RuleId::T01,
                    Severity::Error,
                    idx,
                    format!(
                        "tag shift of {h} hops flushes every tag off the \
                         {}-row chain",
                        shape.rows
                    ),
                ));
            } else if h > shape.rows_per_module {
                out.push(Diagnostic::at(
                    RuleId::T01,
                    Severity::Warning,
                    idx,
                    format!(
                        "tag shift of {h} hops exceeds the {}-row module \
                         segment (global-gather slow path)",
                        shape.rows_per_module
                    ),
                ));
            }
        }
        state = state.transfer(instr, shape);
    }
    out
}

/// The threaded fast path's instruction whitelist, re-derived
/// independently of [`Instr::is_data_parallel`]: exactly the variants
/// `PrinsArray::execute_span` accepts (everything else panics there).
/// The match is exhaustive on purpose — adding an `Instr` variant forces
/// a decision here instead of silently joining either path.
fn threaded_whitelist(instr: &Instr) -> bool {
    match instr {
        Instr::Compare(_) | Instr::Write(_) | Instr::SetTagsAll | Instr::ClearColumns { .. } => {
            true
        }
        Instr::Read { .. }
        | Instr::IfMatch
        | Instr::FirstMatch
        | Instr::ReduceCount
        | Instr::ReduceField { .. }
        | Instr::ShiftTagsUp(_)
        | Instr::ShiftTagsDown(_) => false,
    }
}

/// S01: re-derive threaded-dispatch legality per span. Every
/// instruction inside a data-parallel span must be on the independent
/// whitelist (or it would panic inside `execute_span`), every
/// instruction in a serializing span must be off it (or the threaded
/// backend is leaving parallelism unused), and the spans must cover the
/// program exactly.
pub fn span_safety(prog: &Program) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut covered = 0usize;
    for span in prog.spans() {
        for (off, instr) in span.instrs.iter().enumerate() {
            let idx = covered + off;
            let legal = threaded_whitelist(instr);
            if span.data_parallel && !legal {
                out.push(Diagnostic::at(
                    RuleId::S01,
                    Severity::Error,
                    idx,
                    format!(
                        "{instr:?} classified data-parallel but is not on the \
                         threaded execute_span whitelist"
                    ),
                ));
            } else if !span.data_parallel && legal {
                out.push(Diagnostic::at(
                    RuleId::S01,
                    Severity::Error,
                    idx,
                    format!(
                        "{instr:?} is threaded-safe but classified serializing"
                    ),
                ));
            }
        }
        covered += span.instrs.len();
    }
    if covered != prog.len() {
        out.push(Diagnostic::global(
            RuleId::S01,
            Severity::Error,
            format!(
                "spans cover {covered} of {} instructions — not a partition",
                prog.len()
            ),
        ));
    }
    out
}

/// F01: sanity-check a fault model against the array it is about to be
/// installed on. A bit-error *rate* must be a probability below 1 (a
/// BER of exactly 1 would deterministically invert every access, which
/// is a different device, not a fault), wear coupling must be a finite
/// non-negative factor, and every explicitly placed stuck-at cell must
/// name a cell the array actually has. All findings are errors:
/// [`crate::rcam::PrinsArray::enable_faults`] rejects the model if this
/// returns anything at all, so a misconfigured experiment fails loudly
/// before the first draw instead of silently clamping.
pub fn fault_config(model: &FaultModel, shape: &ArrayShape) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (name, ber) in [
        ("read_ber", model.read_ber),
        ("write_ber", model.write_ber),
        ("retention_ber", model.retention_ber),
    ] {
        if !ber.is_finite() || !(0.0..1.0).contains(&ber) {
            out.push(Diagnostic::global(
                RuleId::F01,
                Severity::Error,
                format!("{name} = {ber} is not a probability in [0, 1)"),
            ));
        }
    }
    if !model.wear_coupling.is_finite() || model.wear_coupling < 0.0 {
        out.push(Diagnostic::global(
            RuleId::F01,
            Severity::Error,
            format!(
                "wear_coupling = {} must be finite and non-negative",
                model.wear_coupling
            ),
        ));
    }
    for cell in &model.stuck {
        if cell.row >= shape.rows || cell.col as usize >= shape.width {
            out.push(Diagnostic::global(
                RuleId::F01,
                Severity::Error,
                format!(
                    "stuck cell ({}, {}) outside the {}x{} array",
                    cell.row, cell.col, shape.rows, shape.width
                ),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reliability::StuckCell;

    const SHAPE: ArrayShape = ArrayShape {
        rows: 32,
        rows_per_module: 16,
        width: 16,
    };

    #[test]
    fn w01_flags_every_out_of_bounds_reference() {
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(15, true)])); // in bounds
        p.push(Instr::Write(vec![(16, true)])); // out
        p.push(Instr::Read { base: 10, width: 8 }); // [10,17] out
        p.push(Instr::ReduceField { col: 40 }); // out
        p.push(Instr::ClearColumns { base: 0, width: 16 }); // in bounds
        p.push(Instr::Read { base: 5, width: 0 }); // empty range: fine
        let d = column_bounds(&p, &SHAPE);
        assert_eq!(d.len(), 3);
        assert_eq!(
            d.iter().map(|x| x.index.unwrap()).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert!(d.iter().all(|x| x.rule == RuleId::W01));
    }

    #[test]
    fn w01_survives_u16_range_overflow() {
        let mut p = Program::new();
        p.push(Instr::ClearColumns {
            base: u16::MAX,
            width: u16::MAX,
        });
        let d = column_bounds(&p, &SHAPE);
        assert_eq!(d.len(), 1, "overflowing range is out of bounds, not a panic");
    }

    #[test]
    fn w02_separates_contradiction_from_duplicate() {
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(3, true), (3, false)])); // contradiction
        p.push(Instr::Write(vec![(4, true), (4, true)])); // duplicate
        p.push(Instr::Compare(vec![(1, true), (2, false)])); // clean
        let d = pattern_conflicts(&p);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].severity, Severity::Error);
        assert_eq!(d[1].severity, Severity::Warning);
        assert!(d.iter().all(|x| x.rule == RuleId::W02));
    }

    #[test]
    fn t01_flags_ops_under_empty_tags_and_chain_flushes() {
        let mut p = Program::new();
        p.push(Instr::SetTagsAll);
        p.push(Instr::ShiftTagsUp(32)); // >= rows: flush (error), state Empty
        p.push(Instr::Write(vec![(0, true)])); // write under empty
        p.push(Instr::Read { base: 0, width: 4 }); // read under empty
        p.push(Instr::FirstMatch); // first_match under empty
        p.push(Instr::SetTagsAll); // recovers
        p.push(Instr::Write(vec![(1, true)])); // clean again
        let d = tag_liveness(&p, &SHAPE);
        assert_eq!(d.len(), 4);
        assert_eq!(
            d.iter().map(|x| x.index.unwrap()).collect::<Vec<_>>(),
            vec![1, 2, 3, 4]
        );
    }

    #[test]
    fn t01_warns_on_cross_module_shift_only() {
        let mut p = Program::new();
        p.push(Instr::SetTagsAll);
        p.push(Instr::ShiftTagsDown(16)); // == rows_per_module: fast path
        p.push(Instr::ShiftTagsDown(17)); // > rows_per_module, < rows
        let d = tag_liveness(&p, &SHAPE);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].index, Some(2));
        assert_eq!(d[0].severity, Severity::Warning);
    }

    #[test]
    fn f01_accepts_sane_models() {
        assert!(fault_config(&FaultModel::uniform(0.0, 1), &SHAPE).is_empty());
        assert!(fault_config(&FaultModel::uniform(0.05, 7), &SHAPE).is_empty());
        let m = FaultModel::uniform(0.01, 3)
            .with_wear_coupling(1e-6)
            .with_stuck(vec![StuckCell {
                row: 31,
                col: 15,
                value: true,
            }]);
        assert!(fault_config(&m, &SHAPE).is_empty());
    }

    #[test]
    fn f01_rejects_bad_bers_and_coupling() {
        for bad in [1.0, -0.1, 2.0, f64::NAN, f64::INFINITY] {
            let d = fault_config(&FaultModel::uniform(bad, 1), &SHAPE);
            // all three BERs are set by uniform(), so all three fire
            assert_eq!(d.len(), 3, "ber {bad} must be rejected");
            assert!(d.iter().all(|x| x.rule == RuleId::F01));
            assert!(d.iter().all(|x| x.severity == Severity::Error));
        }
        let m = FaultModel::uniform(0.01, 1).with_wear_coupling(-1.0);
        assert_eq!(fault_config(&m, &SHAPE).len(), 1);
        let m = FaultModel::uniform(0.01, 1).with_wear_coupling(f64::NAN);
        assert_eq!(fault_config(&m, &SHAPE).len(), 1);
    }

    #[test]
    fn f01_rejects_out_of_bounds_stuck_cells() {
        let m = FaultModel::uniform(0.0, 1).with_stuck(vec![
            StuckCell { row: 32, col: 0, value: true },  // row == rows
            StuckCell { row: 0, col: 16, value: false }, // col == width
            StuckCell { row: 5, col: 5, value: true },   // fine
        ]);
        let d = fault_config(&m, &SHAPE);
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.rule == RuleId::F01));
    }

    #[test]
    fn s01_accepts_every_current_instruction_mix() {
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(0, true)]));
        p.push(Instr::Write(vec![(1, true)]));
        p.push(Instr::ReduceCount);
        p.push(Instr::ShiftTagsUp(2));
        p.push(Instr::SetTagsAll);
        p.push(Instr::ClearColumns { base: 0, width: 2 });
        p.push(Instr::Read { base: 0, width: 2 });
        assert!(span_safety(&p).is_empty());
        assert!(span_safety(&Program::new()).is_empty());
    }
}
