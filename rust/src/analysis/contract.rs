//! Kernel-contract rules: C01 write-freedom, C02 floor-consistency and
//! C03 overlay write-freedom.
//!
//! These rules operate on a whole [`QueryPlan`] — the synthesized set of
//! microprograms one query would dispatch — rather than on a single
//! program, because the contracts they prove are properties of the
//! query, not of any one instruction stream: a query is write-free only
//! if *none* of its programs mutate the array, and its cycle floor is
//! the sum over all of them plus the non-program cycles.

use super::{Diagnostic, QueryPlan, RuleId, Severity};
use crate::isa::Instr;
use std::ops::Range;

/// C01: prove a query plan never mutates the array. Any `Write` or
/// `ClearColumns` in any program of the plan is an error. The driver
/// applies this only to kernels whose registry entry declares
/// `write_free_queries = true`, promoting the runtime `n_write == 0`
/// ledger assertion to a static guarantee.
pub fn write_freedom(plan: &QueryPlan) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (pi, prog) in plan.programs.iter().enumerate() {
        for (idx, instr) in prog.instrs.iter().enumerate() {
            match instr {
                Instr::Write(_) => out.push(Diagnostic::at(
                    RuleId::C01,
                    Severity::Error,
                    idx,
                    format!("program {pi} of a write-free query contains a write"),
                )),
                Instr::ClearColumns { .. } => out.push(Diagnostic::at(
                    RuleId::C01,
                    Severity::Error,
                    idx,
                    format!(
                        "program {pi} of a write-free query contains a column clear"
                    ),
                )),
                _ => {}
            }
        }
    }
    out
}

/// C03: prove an overlay-shared kernel's query plan confines every
/// mutation to scratch columns. The scratch-overlay read cursor
/// ([`crate::controller::read::ReadCursor`]) serves such kernels on the
/// concurrent shared-read path by materializing written columns
/// cursor-locally — sound only if no query program ever writes a column
/// inside `resident`, the range holding stored data. A `Write` whose
/// pattern touches a resident column, or a `ClearColumns` overlapping
/// the range, is an error: that instruction's effect would have to
/// escape the overlay to be correct. The driver applies this to kernels
/// whose registry entry declares `overlay_queries = true`; writes kept
/// outside `resident` are classified cursor-local and accepted.
pub fn write_freedom_overlay(plan: &QueryPlan, resident: &Range<u16>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (pi, prog) in plan.programs.iter().enumerate() {
        for (idx, instr) in prog.instrs.iter().enumerate() {
            match instr {
                Instr::Write(p) => {
                    let stored: Vec<u16> = p
                        .iter()
                        .map(|&(col, _)| col)
                        .filter(|col| resident.contains(col))
                        .collect();
                    if !stored.is_empty() {
                        out.push(Diagnostic::at(
                            RuleId::C03,
                            Severity::Error,
                            idx,
                            format!(
                                "program {pi} of an overlay query writes stored \
                                 column(s) {stored:?} inside resident range \
                                 {}..{}",
                                resident.start, resident.end
                            ),
                        ));
                    }
                }
                Instr::ClearColumns { base, width } => {
                    let end = base.saturating_add(*width);
                    if *width > 0 && *base < resident.end && end > resident.start {
                        out.push(Diagnostic::at(
                            RuleId::C03,
                            Severity::Error,
                            idx,
                            format!(
                                "program {pi} of an overlay query clears \
                                 columns {base}..{end} overlapping resident \
                                 range {}..{}",
                                resident.start, resident.end
                            ),
                        ));
                    }
                }
                _ => {}
            }
        }
    }
    out
}

/// C02: pin the plan's static cycle estimate to the kernel's analytic
/// floor for the identical shard and parameters. A mismatch means the
/// floor formula has drifted from the microcode the kernel actually
/// emits — the exact bug class the runtime floor assertions catch today,
/// proved here without running the array.
pub fn floor_consistency(plan: &QueryPlan, floor_cycles: u64) -> Vec<Diagnostic> {
    let est = plan.cycle_estimate();
    if est != floor_cycles {
        vec![Diagnostic::global(
            RuleId::C02,
            Severity::Error,
            format!(
                "plan cycle estimate {est} != analytic query floor {floor_cycles}"
            ),
        )]
    } else {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Program;

    fn read_only_plan() -> QueryPlan {
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(0, true)])); // 1 cycle
        p.push(Instr::ReduceCount); // 1 cycle
        QueryPlan {
            programs: vec![p],
            extra_cycles: 3,
        }
    }

    #[test]
    fn c01_accepts_read_only_plans() {
        assert!(write_freedom(&read_only_plan()).is_empty());
    }

    #[test]
    fn c01_flags_writes_and_clears_with_program_index() {
        let mut w = Program::new();
        w.push(Instr::Write(vec![(0, true)]));
        w.push(Instr::ClearColumns { base: 0, width: 4 });
        let plan = QueryPlan {
            programs: vec![Program::new(), w],
            extra_cycles: 0,
        };
        let d = write_freedom(&plan);
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.rule == RuleId::C01));
        assert!(d[0].message.contains("program 1"));
        assert_eq!(d[0].index, Some(0));
        assert_eq!(d[1].index, Some(1));
    }

    #[test]
    fn c03_accepts_scratch_confined_writes() {
        // resident data in cols 0..8; the query writes/clears only 8..
        let mut p = Program::new();
        p.push(Instr::ClearColumns { base: 8, width: 4 });
        p.push(Instr::Compare(vec![(0, true), (7, false)]));
        p.push(Instr::Write(vec![(8, true), (11, false)]));
        p.push(Instr::ReduceCount);
        let plan = QueryPlan {
            programs: vec![p],
            extra_cycles: 0,
        };
        assert!(write_freedom_overlay(&plan, &(0..8)).is_empty());
    }

    #[test]
    fn c03_flags_stored_column_writes_and_overlapping_clears() {
        let mut p = Program::new();
        p.push(Instr::Write(vec![(8, true), (3, false)])); // col 3 stored
        p.push(Instr::ClearColumns { base: 6, width: 4 }); // 6..10 overlaps 0..8
        p.push(Instr::ClearColumns { base: 0, width: 0 }); // zero-width: no columns
        let plan = QueryPlan {
            programs: vec![Program::new(), p],
            extra_cycles: 0,
        };
        let d = write_freedom_overlay(&plan, &(0..8));
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.rule == RuleId::C03));
        assert!(d[0].message.contains("program 1") && d[0].message.contains("[3]"));
        assert_eq!(d[0].index, Some(0));
        assert!(d[1].message.contains("6..10"));
        assert_eq!(d[1].index, Some(1));
    }

    #[test]
    fn c02_pins_the_estimate_to_the_floor() {
        let plan = read_only_plan(); // estimate = 1 + 1 + 3 = 5
        assert!(floor_consistency(&plan, 5).is_empty());
        let d = floor_consistency(&plan, 6);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RuleId::C02);
        assert_eq!(d[0].index, None);
        assert!(d[0].message.contains('5') && d[0].message.contains('6'));
    }
}
