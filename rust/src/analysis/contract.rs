//! Kernel-contract rules: C01 write-freedom and C02 floor-consistency.
//!
//! These rules operate on a whole [`QueryPlan`] — the synthesized set of
//! microprograms one query would dispatch — rather than on a single
//! program, because the contracts they prove are properties of the
//! query, not of any one instruction stream: a query is write-free only
//! if *none* of its programs mutate the array, and its cycle floor is
//! the sum over all of them plus the non-program cycles.

use super::{Diagnostic, QueryPlan, RuleId, Severity};
use crate::isa::Instr;

/// C01: prove a query plan never mutates the array. Any `Write` or
/// `ClearColumns` in any program of the plan is an error. The driver
/// applies this only to kernels whose registry entry declares
/// `write_free_queries = true`, promoting the runtime `n_write == 0`
/// ledger assertion to a static guarantee.
pub fn write_freedom(plan: &QueryPlan) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (pi, prog) in plan.programs.iter().enumerate() {
        for (idx, instr) in prog.instrs.iter().enumerate() {
            match instr {
                Instr::Write(_) => out.push(Diagnostic::at(
                    RuleId::C01,
                    Severity::Error,
                    idx,
                    format!("program {pi} of a write-free query contains a write"),
                )),
                Instr::ClearColumns { .. } => out.push(Diagnostic::at(
                    RuleId::C01,
                    Severity::Error,
                    idx,
                    format!(
                        "program {pi} of a write-free query contains a column clear"
                    ),
                )),
                _ => {}
            }
        }
    }
    out
}

/// C02: pin the plan's static cycle estimate to the kernel's analytic
/// floor for the identical shard and parameters. A mismatch means the
/// floor formula has drifted from the microcode the kernel actually
/// emits — the exact bug class the runtime floor assertions catch today,
/// proved here without running the array.
pub fn floor_consistency(plan: &QueryPlan, floor_cycles: u64) -> Vec<Diagnostic> {
    let est = plan.cycle_estimate();
    if est != floor_cycles {
        vec![Diagnostic::global(
            RuleId::C02,
            Severity::Error,
            format!(
                "plan cycle estimate {est} != analytic query floor {floor_cycles}"
            ),
        )]
    } else {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Program;

    fn read_only_plan() -> QueryPlan {
        let mut p = Program::new();
        p.push(Instr::Compare(vec![(0, true)])); // 1 cycle
        p.push(Instr::ReduceCount); // 1 cycle
        QueryPlan {
            programs: vec![p],
            extra_cycles: 3,
        }
    }

    #[test]
    fn c01_accepts_read_only_plans() {
        assert!(write_freedom(&read_only_plan()).is_empty());
    }

    #[test]
    fn c01_flags_writes_and_clears_with_program_index() {
        let mut w = Program::new();
        w.push(Instr::Write(vec![(0, true)]));
        w.push(Instr::ClearColumns { base: 0, width: 4 });
        let plan = QueryPlan {
            programs: vec![Program::new(), w],
            extra_cycles: 0,
        };
        let d = write_freedom(&plan);
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.rule == RuleId::C01));
        assert!(d[0].message.contains("program 1"));
        assert_eq!(d[0].index, Some(0));
        assert_eq!(d[1].index, Some(1));
    }

    #[test]
    fn c02_pins_the_estimate_to_the_floor() {
        let plan = read_only_plan(); // estimate = 1 + 1 + 3 = 5
        assert!(floor_consistency(&plan, 5).is_empty());
        let d = floor_consistency(&plan, 6);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RuleId::C02);
        assert_eq!(d[0].index, None);
        assert!(d[0].message.contains('5') && d[0].message.contains('6'));
    }
}
