//! The registry-wide verification driver behind `prins verify`:
//! synthesize every registered kernel's query plans over a seeded shape
//! grid and run every rule — program-shape rules per microprogram,
//! contract rules per plan — without executing a single instruction on
//! the array. (Loading the shard datasets does run — plans are
//! synthesized *against* resident geometry — but no query ever
//! executes.)

use super::contract;
use super::{check_program, Diagnostic};
use crate::algorithms::kernel::{registry, KernelEntry};
use crate::host::rack::PrinsRack;

/// The seeded `(n, dims, shards, seed)` shape grid every kernel is
/// verified over: small/medium/large datasets, single- and multi-shard
/// racks, distinct seeds. Kept deliberately small — the grid multiplies
/// into `shapes × QUERIES_PER_SHAPE × shards × programs` checks per
/// kernel.
pub const SHAPE_GRID: &[(usize, usize, usize, u64)] =
    &[(24, 2, 1, 7), (48, 3, 2, 11), (96, 4, 1, 13)];

/// Seeded queries checked per grid shape — enough to rotate every
/// kernel's `seeded_params` stream through its distinct parameter forms
/// (hist's four bin windows, search's exact-match every fourth query).
pub const QUERIES_PER_SHAPE: usize = 4;

/// One kernel's verification report: how much was checked and every
/// diagnostic found, each paired with a context string locating the
/// shape/query/program it fired in.
pub struct KernelReport {
    /// The kernel's registry name.
    pub kernel: &'static str,
    /// Grid shapes verified.
    pub shapes: usize,
    /// Microprograms synthesized and checked.
    pub checked_programs: usize,
    /// Total instructions across those programs.
    pub checked_instructions: usize,
    /// Every finding, as `(context, diagnostic)` pairs.
    pub diagnostics: Vec<(String, Diagnostic)>,
}

impl KernelReport {
    /// Whether the kernel passed (zero diagnostics of any severity).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Verify one registered kernel over the full [`SHAPE_GRID`]: load the
/// seeded dataset on a rack of the grid's shard count, synthesize every
/// shard's query plan for [`QUERIES_PER_SHAPE`] seeded queries, and run
/// W01/W02/T01/S01 on every program plus C01 (when the entry claims
/// `write_free_queries`), C03 (when it claims `overlay_queries`) and
/// C02 on every plan.
pub fn verify_kernel(entry: &KernelEntry) -> KernelReport {
    let mut report = KernelReport {
        kernel: entry.name,
        shapes: 0,
        checked_programs: 0,
        checked_instructions: 0,
        diagnostics: Vec::new(),
    };
    for &(n, dims, shards, seed) in SHAPE_GRID {
        report.shapes += 1;
        let rack = PrinsRack::new(shards);
        let res = (entry.synth_load)(&rack, n, dims, seed);
        for q in 0..QUERIES_PER_SHAPE {
            for (s, pq) in res.query_plans_seeded(q, seed).iter().enumerate() {
                let ctx = |prog: &str| {
                    format!(
                        "n={n} dims={dims} shards={shards} seed={seed} q={q} \
                         shard={s} prog={prog}"
                    )
                };
                for (pi, prog) in pq.plan.programs.iter().enumerate() {
                    report.checked_programs += 1;
                    report.checked_instructions += prog.len();
                    for d in check_program(prog, &pq.shape) {
                        report.diagnostics.push((ctx(&pi.to_string()), d));
                    }
                }
                if entry.write_free_queries {
                    for d in contract::write_freedom(&pq.plan) {
                        report.diagnostics.push((ctx("plan"), d));
                    }
                }
                if entry.overlay_queries {
                    for d in
                        contract::write_freedom_overlay(&pq.plan, &pq.resident_columns)
                    {
                        report.diagnostics.push((ctx("plan"), d));
                    }
                }
                for d in contract::floor_consistency(&pq.plan, pq.floor_cycles) {
                    report.diagnostics.push((ctx("plan"), d));
                }
            }
        }
    }
    report
}

/// Verify every registered kernel ([`verify_kernel`] over the registry).
pub fn verify_registry() -> Vec<KernelReport> {
    registry().iter().map(verify_kernel).collect()
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render reports as the `verify --json` machine-readable document the
/// CI gate parses: an array of per-kernel objects, each with its check
/// counts and a `diagnostics` array of
/// `{context, rule, severity, index, message}` objects.
pub fn reports_json(reports: &[KernelReport]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in reports.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"kernel\": \"{}\", \"shapes\": {}, \"checked_programs\": {}, \
             \"checked_instructions\": {}, \"diagnostics\": [",
            json_escape(r.kernel),
            r.shapes,
            r.checked_programs,
            r.checked_instructions
        ));
        for (j, (ctx, d)) in r.diagnostics.iter().enumerate() {
            out.push_str(&format!(
                "\n    {{\"context\": \"{}\", \"rule\": \"{}\", \"severity\": \"{}\", \
                 \"index\": {}, \"message\": \"{}\"}}{}",
                json_escape(ctx),
                d.rule,
                d.severity,
                d.index.map_or("null".into(), |x| x.to_string()),
                json_escape(&d.message),
                if j + 1 < r.diagnostics.len() { "," } else { "\n  " }
            ));
        }
        out.push_str(if i + 1 < reports.len() { "]},\n" } else { "]}\n" });
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{RuleId, Severity};

    #[test]
    fn one_kernel_verifies_clean_over_the_grid() {
        // the full-registry sweep lives in tests/static_verify.rs; here
        // one kernel proves the driver plumbing end to end
        let entry = crate::algorithms::kernel::find_name("search").unwrap();
        let r = verify_kernel(entry);
        assert_eq!(r.kernel, "search");
        assert_eq!(r.shapes, SHAPE_GRID.len());
        assert!(r.checked_programs > 0 && r.checked_instructions > 0);
        assert!(
            r.is_clean(),
            "search diagnostics: {:?}",
            r.diagnostics
                .iter()
                .map(|(c, d)| format!("[{c}] {d}"))
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn json_report_escapes_and_structures() {
        let reports = vec![KernelReport {
            kernel: "hist",
            shapes: 3,
            checked_programs: 12,
            checked_instructions: 6144,
            diagnostics: vec![(
                "n=24 \"quoted\"".into(),
                Diagnostic::global(RuleId::C02, Severity::Error, "a\\b".into()),
            )],
        }];
        let j = reports_json(&reports);
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\"kernel\": \"hist\""));
        assert!(j.contains("\"checked_programs\": 12"));
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("a\\\\b"));
        assert!(j.contains("\"index\": null"));
    }
}
