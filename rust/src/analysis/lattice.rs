//! The tag-state abstract domain of the T01 liveness rule.
//!
//! The analyzer interprets a program over an abstraction of the array's
//! tag register file: instead of one bit per row it tracks one of four
//! summary states. The transfer function is deliberately conservative —
//! anything it cannot prove collapses to [`TagState::Unknown`], so a
//! T01 finding is always a real property of the program, never a guess.
//!
//! ```text
//!            Unknown          (any tag configuration)
//!           /   |    \
//!     AllSet FirstOnly Empty  (provable configurations)
//! ```
//!
//! Transfer rules (DESIGN.md §Static verification):
//! * `SetTagsAll` → `AllSet`; an empty-pattern `Compare` matches every
//!   row, so it is `AllSet` too; any other `Compare` → `Unknown`.
//! * `FirstMatch` keeps `Empty` empty (no tag to keep) and narrows
//!   `AllSet`/`FirstOnly` to `FirstOnly`.
//! * A tag shift of `h ≥ rows` hops pushes every tag off the end of the
//!   daisy chain → `Empty`; smaller shifts of anything non-empty →
//!   `Unknown` (edge tags are lost, interior tags move).
//! * Everything else (`Write`, `Read`, reductions, `IfMatch`,
//!   `ClearColumns`) observes or uses tags but never changes them.

use super::ArrayShape;
use crate::isa::Instr;

/// Abstract state of the array's tag registers at one program point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TagState {
    /// Every row is provably tagged.
    AllSet,
    /// Provably no row is tagged.
    Empty,
    /// At most one row is tagged (the `FirstMatch` post-state).
    FirstOnly,
    /// Nothing is known (the lattice top; also the entry state).
    Unknown,
}

impl TagState {
    /// The abstract post-state of executing `instr` in state `self` on
    /// an array of shape `shape`.
    pub fn transfer(self, instr: &Instr, shape: &ArrayShape) -> TagState {
        match instr {
            Instr::SetTagsAll => TagState::AllSet,
            Instr::Compare(p) => {
                if p.is_empty() {
                    // an empty key/mask pattern matches every row
                    TagState::AllSet
                } else {
                    TagState::Unknown
                }
            }
            Instr::FirstMatch => match self {
                TagState::Empty => TagState::Empty,
                TagState::AllSet | TagState::FirstOnly => TagState::FirstOnly,
                TagState::Unknown => TagState::Unknown,
            },
            Instr::ShiftTagsUp(h) | Instr::ShiftTagsDown(h) => {
                if self == TagState::Empty || *h as usize >= shape.rows {
                    TagState::Empty
                } else {
                    TagState::Unknown
                }
            }
            Instr::Write(_)
            | Instr::Read { .. }
            | Instr::IfMatch
            | Instr::ReduceCount
            | Instr::ReduceField { .. }
            | Instr::ClearColumns { .. } => self,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHAPE: ArrayShape = ArrayShape {
        rows: 32,
        rows_per_module: 16,
        width: 40,
    };

    #[test]
    fn set_tags_all_and_empty_compare_prove_all_set() {
        let s = TagState::Unknown;
        assert_eq!(s.transfer(&Instr::SetTagsAll, &SHAPE), TagState::AllSet);
        assert_eq!(s.transfer(&Instr::Compare(vec![]), &SHAPE), TagState::AllSet);
        assert_eq!(
            s.transfer(&Instr::Compare(vec![(0, true)]), &SHAPE),
            TagState::Unknown
        );
    }

    #[test]
    fn first_match_narrows_but_keeps_empty() {
        assert_eq!(
            TagState::AllSet.transfer(&Instr::FirstMatch, &SHAPE),
            TagState::FirstOnly
        );
        assert_eq!(
            TagState::FirstOnly.transfer(&Instr::FirstMatch, &SHAPE),
            TagState::FirstOnly
        );
        assert_eq!(
            TagState::Empty.transfer(&Instr::FirstMatch, &SHAPE),
            TagState::Empty
        );
        assert_eq!(
            TagState::Unknown.transfer(&Instr::FirstMatch, &SHAPE),
            TagState::Unknown
        );
    }

    #[test]
    fn chain_length_shift_flushes_all_tags() {
        assert_eq!(
            TagState::AllSet.transfer(&Instr::ShiftTagsUp(32), &SHAPE),
            TagState::Empty
        );
        assert_eq!(
            TagState::AllSet.transfer(&Instr::ShiftTagsDown(3), &SHAPE),
            TagState::Unknown
        );
        // empty stays empty under any shift
        assert_eq!(
            TagState::Empty.transfer(&Instr::ShiftTagsUp(1), &SHAPE),
            TagState::Empty
        );
    }

    #[test]
    fn tag_preserving_instructions_keep_the_state() {
        for s in [
            TagState::AllSet,
            TagState::Empty,
            TagState::FirstOnly,
            TagState::Unknown,
        ] {
            for i in [
                Instr::Write(vec![(0, true)]),
                Instr::Read { base: 0, width: 8 },
                Instr::IfMatch,
                Instr::ReduceCount,
                Instr::ReduceField { col: 1 },
                Instr::ClearColumns { base: 0, width: 4 },
            ] {
                assert_eq!(s.transfer(&i, &SHAPE), s, "{i:?}");
            }
        }
    }
}
