//! ECC-style scrubbing (DESIGN.md §Reliability): per-row parity checks
//! over the resident data columns, detect-and-rewrite from a host-held
//! golden copy, charged honestly to the array's cycle/energy ledger.
//!
//! The host captured the dataset bytes at load time anyway (they crossed
//! the link), so the golden copy and the per-row parity bits are free to
//! *hold* — what costs cycles is consulting the device: every scrub pass
//! re-reads each protected row through the faulty read path
//! (`PrinsArray::fetch_row_bits_faulty`, charged like any storage-path
//! read) and, on a parity mismatch, rewrites the row from golden via the
//! charged load path and verifies it with one more charged read.
//!
//! Parity is a 1-bit code: it detects any odd number of flipped bits in
//! a row and is blind to even-weight corruption — the classic ECC
//! trade-off, surfaced in [`ScrubReport`] as detection (not a guarantee).
//! Stuck-at cells defeat the rewrite (storage is corrected, the
//! observation is not) and show up as `residual`, which is what drives
//! the query-retry loop to give up and degrade gracefully.

use crate::rcam::PrinsArray;
use std::ops::Range;

/// Outcome of one scrub pass over the protected rows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Rows whose parity was checked.
    pub rows_checked: u64,
    /// Rows whose parity mismatched the golden parity (detected
    /// corruption, or read noise on the check itself).
    pub mismatches: u64,
    /// Rows rewritten from the golden copy.
    pub rewritten: u64,
    /// Rows still observing differently from golden after the rewrite
    /// (stuck cells, or fresh noise on the verify read).
    pub residual: u64,
}

/// Host-side scrubber for one array: the golden copy of the resident
/// columns plus per-row parity, captured once at load time.
#[derive(Clone, Debug)]
pub struct Scrubber {
    base: usize,
    width: usize,
    /// Per row: the protected bits in ≤64-bit chunks, low column first.
    golden: Vec<Vec<u64>>,
    /// Per row: XOR of all protected bits.
    parity: Vec<bool>,
}

/// Split a column range into ≤64-bit `(base, width)` readout chunks.
fn chunk_spans(base: usize, width: usize) -> impl Iterator<Item = (usize, usize)> {
    (0..width).step_by(64).map(move |off| (base + off, (width - off).min(64)))
}

fn parity_of(chunks: &[u64]) -> bool {
    chunks.iter().fold(0u64, |a, &c| a ^ c).count_ones() % 2 == 1
}

impl Scrubber {
    /// Capture the golden copy of `cols` across every row of `array`.
    /// Uncharged: this is the host-side mirror of data that just moved
    /// over the link during the load phase, read through the ideal
    /// storage path **before** faults are enabled.
    pub fn capture(array: &PrinsArray, cols: Range<u16>) -> Scrubber {
        let base = cols.start as usize;
        let width = (cols.end.saturating_sub(cols.start)) as usize;
        let rows = array.total_rows();
        let mut golden = Vec::with_capacity(rows);
        let mut parity = Vec::with_capacity(rows);
        for row in 0..rows {
            let chunks: Vec<u64> = chunk_spans(base, width)
                .map(|(b, w)| array.fetch_row_bits(row, b, w))
                .collect();
            parity.push(parity_of(&chunks));
            golden.push(chunks);
        }
        Scrubber {
            base,
            width,
            golden,
            parity,
        }
    }

    /// The protected column range.
    pub fn columns(&self) -> Range<u16> {
        self.base as u16..(self.base + self.width) as u16
    }

    /// One scrub pass: read every protected row through the faulty read
    /// path (charged), compare parity against golden, rewrite mismatched
    /// rows from the golden copy (charged), and verify the rewrite with
    /// one more charged read. Returns what was found and fixed.
    pub fn scrub(&self, array: &mut PrinsArray) -> ScrubReport {
        let mut rep = ScrubReport::default();
        for (row, golden) in self.golden.iter().enumerate() {
            rep.rows_checked += 1;
            let cur: Vec<u64> = chunk_spans(self.base, self.width)
                .map(|(b, w)| array.fetch_row_bits_faulty(row, b, w))
                .collect();
            if parity_of(&cur) == self.parity[row] {
                continue;
            }
            rep.mismatches += 1;
            for ((b, w), &g) in chunk_spans(self.base, self.width).zip(golden) {
                array.load_row_bits_charged(row, b, w, g);
            }
            rep.rewritten += 1;
            let after: Vec<u64> = chunk_spans(self.base, self.width)
                .map(|(b, w)| array.fetch_row_bits_faulty(row, b, w))
                .collect();
            if &after != golden {
                rep.residual += 1;
            }
        }
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reliability::{FaultModel, StuckCell};

    fn loaded(rows: usize, width: usize) -> PrinsArray {
        let mut a = PrinsArray::single(rows, width);
        for r in 0..rows {
            a.load_row_bits(r, 0, width.min(64), (r as u64).wrapping_mul(0x9E37) & 0xFFFF);
        }
        a
    }

    #[test]
    fn clean_array_scrubs_clean_but_pays_cycles() {
        let mut a = loaded(32, 40);
        let s = Scrubber::capture(&a, 0..40);
        let c0 = a.cycles;
        let rep = s.scrub(&mut a);
        assert_eq!(rep.rows_checked, 32);
        assert_eq!(rep.mismatches, 0);
        assert_eq!(rep.rewritten, 0);
        assert_eq!(rep.residual, 0);
        assert!(a.cycles > c0, "scrub reads must be charged");
    }

    #[test]
    fn single_bit_corruption_is_detected_and_repaired() {
        let mut a = loaded(16, 40);
        let s = Scrubber::capture(&a, 0..40);
        // flip one stored bit (odd-weight corruption)
        let before = a.fetch_row_bits(5, 3, 1);
        a.load_row_bits(5, 3, 1, before ^ 1);
        let rep = s.scrub(&mut a);
        assert_eq!(rep.mismatches, 1);
        assert_eq!(rep.rewritten, 1);
        assert_eq!(rep.residual, 0);
        assert_eq!(a.fetch_row_bits(5, 3, 1), before, "repaired from golden");
    }

    #[test]
    fn even_weight_corruption_evades_parity() {
        // the documented 1-bit-code limitation: two flips in one row
        // cancel in the parity and go undetected
        let mut a = loaded(16, 40);
        let s = Scrubber::capture(&a, 0..40);
        a.load_row_bits(7, 0, 1, a.fetch_row_bits(7, 0, 1) ^ 1);
        a.load_row_bits(7, 9, 1, a.fetch_row_bits(7, 9, 1) ^ 1);
        let rep = s.scrub(&mut a);
        assert_eq!(rep.mismatches, 0, "parity is blind to even weight");
    }

    #[test]
    fn stuck_cell_survives_rewrite_as_residual() {
        let mut a = loaded(16, 40);
        // golden captured from the ideal array, THEN faults with a stuck
        // cell that contradicts the stored bit
        let stored = a.fetch_row_bits(4, 2, 1) != 0;
        let s = Scrubber::capture(&a, 0..40);
        let model = FaultModel::uniform(0.0, 1).with_stuck(vec![StuckCell {
            row: 4,
            col: 2,
            value: !stored,
        }]);
        a.enable_faults(model).unwrap();
        let rep = s.scrub(&mut a);
        assert_eq!(rep.mismatches, 1, "stuck read shows as corruption");
        assert_eq!(rep.rewritten, 1);
        assert_eq!(rep.residual, 1, "rewrite cannot unstick the cell");
    }

    #[test]
    fn columns_roundtrip_and_wide_rows_chunk() {
        let a = loaded(8, 100);
        let s = Scrubber::capture(&a, 10..90);
        assert_eq!(s.columns(), 10..90);
        assert_eq!(s.golden[0].len(), 2, "80 bits → two chunks");
    }
}
