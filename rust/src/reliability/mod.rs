//! Reliability layer (DESIGN.md §Reliability): deterministic, seeded
//! fault injection for the RCAM arrays, plus the report types consumed by
//! the scrub/retry recovery machinery.
//!
//! The simulator's default device is ideal, but the paper's own endurance
//! argument (§3.1) and the RRAM literature say real resistive CAM is not:
//! ON/OFF resistance distributions are lognormal and overlap (bit errors
//! on compare/read), cells wear out and stick, and writes occasionally
//! land wrong. This module models all of that behind a zero-cost default:
//!
//! * [`FaultModel`] — the configuration: per-path bit-error rates
//!   (`read`/`write`/`retention`), optionally derived from lognormal
//!   ON/OFF overlap ([`lognormal_overlap_ber`]), stuck-at cells (explicit
//!   or seeded-random), and wear-coupled BER growth read from the
//!   per-row wear counters.
//! * [`FaultState`] — the per-array runtime state installed by
//!   `PrinsArray::enable_faults`: materialized stuck cells, the draw
//!   epoch, and the [`FaultStats`] counters.
//! * [`FidelityReport`] — what a recovered query hands back to callers
//!   (`fidelity=` on the wire; see `docs/PROTOCOL.md`).
//!
//! **Determinism.** Every corruption decision is a *stateless* draw: a
//! SplitMix64-style hash of `(seed, epoch, kind, row, col)` compared
//! against `ber · 2⁶⁴` ([`cell_hash`] / [`ber_threshold`]). There is no
//! mutable RNG stream to race on, so runs are reproducible bit-for-bit —
//! and because the threshold test is monotone in the BER, the flip set at
//! a lower BER is a subset of the flip set at a higher BER under the same
//! seed (common-random-numbers coupling, which is what makes the
//! `BENCH_fidelity.json` accuracy curves monotone by construction).
//! Arrays with faults enabled force the serial execution path
//! (`PrinsArray::is_threaded` returns false), so the epoch sequence —
//! one epoch per array operation — is identical on every backend.

pub mod scrub;

pub use scrub::{ScrubReport, Scrubber};

use crate::workloads::Rng;
use std::collections::BTreeMap;

/// Maximum automatic retries of a query whose scrub pass detected
/// corruption (the recovery path in `algorithms::Resident::query`).
pub const MAX_QUERY_RETRIES: u64 = 2;

/// Idle-cycle backoff charged before retry `k` (doubled per retry:
/// `BACKOFF_BASE_CYCLES << k`) — models the controller re-issuing the
/// query after the scrub rewrite settles.
pub const BACKOFF_BASE_CYCLES: u64 = 32;

/// Effective BER ceiling after wear coupling: beyond 0.5 a binary read
/// is anti-correlated with the stored value, which no physical drift
/// model produces.
const MAX_EFF_BER: f64 = 0.5;

const KIND_READ: u64 = 1;
const KIND_WRITE: u64 = 2;
const KIND_RETENTION: u64 = 3;
const KIND_DISTURB: u64 = 4;

/// Salt mixed into the model seed when materializing seeded-random
/// stuck-at cells, so the stuck layout and the flip draws are
/// independent streams of the same user seed.
const STUCK_SEED_SALT: u64 = 0x57AC_4CE1_15D0_0D5E;

/// A cell pinned to a fixed value: reads always observe `value`
/// regardless of what the storage holds. Stuck cells resist scrub
/// rewrites — the storage is corrected, the observation is not — which
/// is exactly how worn-out RRAM cells defeat ECC.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StuckCell {
    /// Global row index (chain-wide, as used by `PrinsArray`).
    pub row: usize,
    /// Bit-column index.
    pub col: u16,
    /// The value every read of this cell observes.
    pub value: bool,
}

/// Fault-injection configuration (validated by analyzer rule F01 before
/// `PrinsArray::enable_faults` installs it).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultModel {
    /// Per-cell probability that a compare/read observes the wrong bit
    /// (transient — storage is untouched). Must be in `[0, 1)`.
    pub read_ber: f64,
    /// Per-cell probability that a written bit lands inverted
    /// (persistent until rewritten). Must be in `[0, 1)`.
    pub write_ber: f64,
    /// Per-cell probability of an ambient retention flip applied to the
    /// resident columns before each query. Must be in `[0, 1)`.
    pub retention_ber: f64,
    /// Explicit stuck-at cells (F01 checks them against array bounds).
    pub stuck: Vec<StuckCell>,
    /// Additional seeded-random stuck-at cells materialized at
    /// `enable_faults` time (always in bounds by construction).
    pub random_stuck: usize,
    /// Wear coupling `c`: a cell with wear count `w` sees an effective
    /// BER of `ber · (1 + c·w)`, capped at 0.5. Zero disables coupling.
    pub wear_coupling: f64,
    /// Whether the recovery machinery (golden capture, per-query scrub,
    /// bounded retry) runs. Off = raw faulty device, used by the
    /// fidelity bench to measure uncorrected accuracy.
    pub recovery: bool,
    /// Seed for every draw and for stuck-cell materialization.
    pub seed: u64,
}

impl FaultModel {
    /// A uniform model: all three BERs equal to `ber`, no stuck cells,
    /// no wear coupling, recovery enabled.
    pub fn uniform(ber: f64, seed: u64) -> Self {
        FaultModel {
            read_ber: ber,
            write_ber: ber,
            retention_ber: ber,
            stuck: Vec::new(),
            random_stuck: 0,
            wear_coupling: 0.0,
            recovery: true,
            seed,
        }
    }

    /// A uniform model whose BER is derived from lognormal ON/OFF
    /// resistance-distribution overlap (see [`lognormal_overlap_ber`]),
    /// à la the HyperMetric RRAM model.
    pub fn from_lognormal(
        mu_on: f64,
        sigma_on: f64,
        mu_off: f64,
        sigma_off: f64,
        seed: u64,
    ) -> Self {
        Self::uniform(lognormal_overlap_ber(mu_on, sigma_on, mu_off, sigma_off), seed)
    }

    /// Builder: add `n` seeded-random stuck-at cells.
    pub fn with_random_stuck(mut self, n: usize) -> Self {
        self.random_stuck = n;
        self
    }

    /// Builder: add explicit stuck-at cells.
    pub fn with_stuck(mut self, cells: Vec<StuckCell>) -> Self {
        self.stuck = cells;
        self
    }

    /// Builder: enable/disable the recovery machinery.
    pub fn with_recovery(mut self, recovery: bool) -> Self {
        self.recovery = recovery;
        self
    }

    /// Builder: set the wear-coupling coefficient.
    pub fn with_wear_coupling(mut self, c: f64) -> Self {
        self.wear_coupling = c;
        self
    }
}

/// Counters accumulated by a [`FaultState`] as the array executes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Read-path draws taken (one per observed cell that was not stuck).
    pub read_draws: u64,
    /// Read-path observations that flipped.
    pub read_flips: u64,
    /// Written bits that landed inverted.
    pub write_flips: u64,
    /// Ambient retention flips applied to storage.
    pub retention_flips: u64,
    /// Post-load disturb flips applied to storage.
    pub disturb_flips: u64,
    /// Observations that hit a stuck cell (no draw taken).
    pub stuck_hits: u64,
}

impl FaultStats {
    /// Total injected faults (flips of any kind; stuck-cell hits are
    /// reported separately since they are persistent, not events).
    pub fn injected(&self) -> u64 {
        self.read_flips + self.write_flips + self.retention_flips + self.disturb_flips
    }

    /// Counter-wise difference vs. an earlier snapshot of the same
    /// monotonically growing stats.
    pub fn minus(&self, base: &FaultStats) -> FaultStats {
        FaultStats {
            read_draws: self.read_draws - base.read_draws,
            read_flips: self.read_flips - base.read_flips,
            write_flips: self.write_flips - base.write_flips,
            retention_flips: self.retention_flips - base.retention_flips,
            disturb_flips: self.disturb_flips - base.disturb_flips,
            stuck_hits: self.stuck_hits - base.stuck_hits,
        }
    }
}

/// Which ambient corruption pass is being applied (see
/// `PrinsArray::apply_retention` / `apply_disturb`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AmbientKind {
    /// Slow storage decay before a query (drawn at `retention_ber`).
    Retention,
    /// Post-load write disturb (drawn at `write_ber`).
    Disturb,
}

/// Per-array fault runtime: the model, the materialized stuck-cell map,
/// the draw epoch, and the event counters. Installed by
/// `PrinsArray::enable_faults`; one per array.
#[derive(Clone, Debug)]
pub struct FaultState {
    model: FaultModel,
    epoch: u64,
    stuck: BTreeMap<(u32, u16), bool>,
    read_t: u64,
    write_t: u64,
    retention_t: u64,
    stats: FaultStats,
}

impl FaultState {
    /// Build the runtime state for an array of `rows` × `width` cells:
    /// copies the explicit stuck cells and materializes
    /// `model.random_stuck` additional ones from the seed (distinct,
    /// always in bounds).
    pub fn new(model: FaultModel, rows: usize, width: usize) -> Self {
        let mut stuck: BTreeMap<(u32, u16), bool> = BTreeMap::new();
        for c in &model.stuck {
            stuck.insert((c.row as u32, c.col), c.value);
        }
        if model.random_stuck > 0 && rows > 0 && width > 0 {
            let total = rows * width;
            let want = total.min(stuck.len() + model.random_stuck);
            let mut rng = Rng::seed_from(model.seed ^ STUCK_SEED_SALT);
            let mut attempts = 0usize;
            while stuck.len() < want && attempts < model.random_stuck.saturating_mul(64) {
                attempts += 1;
                let row = rng.below(rows as u64) as u32;
                let col = rng.below(width as u64) as u16;
                let value = rng.next_u64() & 1 == 1;
                stuck.entry((row, col)).or_insert(value);
            }
            // deterministic fill for the (pathological) case where random
            // draws keep colliding because stuck cells nearly saturate
            // the array
            'fill: for row in 0..rows as u32 {
                for col in 0..width as u16 {
                    if stuck.len() >= want {
                        break 'fill;
                    }
                    stuck.entry((row, col)).or_insert(false);
                }
            }
        }
        let (read_t, write_t, retention_t) = (
            ber_threshold(model.read_ber),
            ber_threshold(model.write_ber),
            ber_threshold(model.retention_ber),
        );
        FaultState {
            model,
            epoch: 0,
            stuck,
            read_t,
            write_t,
            retention_t,
            stats: FaultStats::default(),
        }
    }

    /// The installed configuration.
    pub fn model(&self) -> &FaultModel {
        &self.model
    }

    /// Snapshot of the event counters.
    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// Number of materialized stuck cells (explicit + random).
    pub fn stuck_cells(&self) -> usize {
        self.stuck.len()
    }

    /// Advance the draw epoch. Called once at the start of every array
    /// operation that takes draws, so repeated identical operations see
    /// fresh (but still deterministic) noise.
    pub fn begin_op(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
    }

    /// Observe a stored cell through the read path: a stuck cell returns
    /// its pinned value (no draw); otherwise the stored bit flips with
    /// the wear-coupled read BER. Callers must invoke this for **every**
    /// cell an operation touches, with no data-dependent early exit, so
    /// the draw count is input-independent.
    pub fn observe(&mut self, row: usize, col: u16, stored: bool, wear: u32) -> bool {
        if let Some(&v) = self.stuck.get(&(row as u32, col)) {
            self.stats.stuck_hits += 1;
            return v;
        }
        self.stats.read_draws += 1;
        let t = self.coupled(self.read_t, self.model.read_ber, wear);
        if t != 0 && cell_hash(self.model.seed, self.epoch, KIND_READ, row as u64, col as u64) < t
        {
            self.stats.read_flips += 1;
            !stored
        } else {
            stored
        }
    }

    /// Whether a just-written bit lands inverted (wear-coupled write
    /// BER).
    pub fn flip_written(&mut self, row: usize, col: u16, wear: u32) -> bool {
        let t = self.coupled(self.write_t, self.model.write_ber, wear);
        if t != 0 && cell_hash(self.model.seed, self.epoch, KIND_WRITE, row as u64, col as u64) < t
        {
            self.stats.write_flips += 1;
            true
        } else {
            false
        }
    }

    /// Whether an ambient pass flips the storage cell at `(row, col)`.
    pub fn ambient(&mut self, kind: AmbientKind, row: usize, col: u16) -> bool {
        let (t, k) = match kind {
            AmbientKind::Retention => (self.retention_t, KIND_RETENTION),
            AmbientKind::Disturb => (self.write_t, KIND_DISTURB),
        };
        if t != 0 && cell_hash(self.model.seed, self.epoch, k, row as u64, col as u64) < t {
            match kind {
                AmbientKind::Retention => self.stats.retention_flips += 1,
                AmbientKind::Disturb => self.stats.disturb_flips += 1,
            }
            true
        } else {
            false
        }
    }

    /// Whether an ambient pass of `kind` can flip anything at all (lets
    /// the array skip the rows×cols sweep when the BER is zero).
    pub fn ambient_enabled(&self, kind: AmbientKind) -> bool {
        match kind {
            AmbientKind::Retention => self.retention_t != 0,
            AmbientKind::Disturb => self.write_t != 0,
        }
    }

    fn coupled(&self, base_t: u64, base_ber: f64, wear: u32) -> u64 {
        if self.model.wear_coupling <= 0.0 || wear == 0 {
            base_t
        } else {
            let eff = (base_ber * (1.0 + self.model.wear_coupling * wear as f64)).min(MAX_EFF_BER);
            ber_threshold(eff)
        }
    }
}

/// What a recovered (or raw-faulty) query hands back next to its result:
/// an a-posteriori confidence estimate plus the recovery counters. On
/// the wire this becomes the `fidelity=` reply field (PROTOCOL.md).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FidelityReport {
    /// Estimated probability that the result is unaffected by
    /// *undetected* read noise: `(1 − read_ber)^draws` over the final
    /// attempt's read draws (1.0 at BER 0).
    pub fidelity: f64,
    /// Faults injected during the query window (all kinds).
    pub injected: u64,
    /// Corrupted rows the scrub parity check detected.
    pub detected: u64,
    /// Rows rewritten from the golden copy.
    pub repaired: u64,
    /// Rows still differing from golden after the rewrite (stuck cells,
    /// or fresh noise on the verify read).
    pub residual: u64,
    /// Query retries taken after detected corruption.
    pub retries: u64,
    /// Cycles spent on recovery (scrub reads/rewrites, retry re-runs,
    /// backoff idle) — charged to the array ledger, not free.
    pub overhead_cycles: u64,
}

impl FidelityReport {
    /// Combine two shard reports: fidelities multiply (independent
    /// shards), counters sum, retries take the max (shards retry
    /// independently in parallel).
    pub fn combine(&self, o: &FidelityReport) -> FidelityReport {
        FidelityReport {
            fidelity: self.fidelity * o.fidelity,
            injected: self.injected + o.injected,
            detected: self.detected + o.detected,
            repaired: self.repaired + o.repaired,
            residual: self.residual + o.residual,
            retries: self.retries.max(o.retries),
            overhead_cycles: self.overhead_cycles + o.overhead_cycles,
        }
    }

    /// Fold an iterator of shard reports into one (None when empty).
    pub fn merge_all<I: IntoIterator<Item = FidelityReport>>(it: I) -> Option<FidelityReport> {
        it.into_iter().reduce(|a, b| a.combine(&b))
    }
}

// ----- stateless draw machinery -----------------------------------------

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless per-cell draw: a SplitMix64-finalizer hash of
/// `(seed, epoch, kind, row, col)`, uniform over `u64`. A cell flips iff
/// `cell_hash(..) < ber_threshold(ber)` — monotone in the BER, so flip
/// sets at increasing BERs nest under a fixed seed.
#[inline]
pub fn cell_hash(seed: u64, epoch: u64, kind: u64, row: u64, col: u64) -> u64 {
    const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut h = mix(seed.wrapping_add(GOLDEN));
    h = mix(h ^ epoch.wrapping_mul(GOLDEN));
    h = mix(h ^ kind.wrapping_mul(GOLDEN));
    h = mix(h ^ row.wrapping_mul(GOLDEN));
    mix(h ^ col.wrapping_mul(GOLDEN))
}

/// Map a probability to the `u64` threshold used by the flip test
/// (`ber · 2⁶⁴`, saturating). Zero for `ber ≤ 0`.
pub fn ber_threshold(ber: f64) -> u64 {
    if ber <= 0.0 {
        return 0;
    }
    // f64→u64 casts saturate, so ber ≥ 1 maps to u64::MAX (always flip)
    (ber.min(1.0) * 2f64.powi(64)) as u64
}

// ----- lognormal ON/OFF overlap → BER ------------------------------------

/// Abramowitz–Stegun 7.1.26 rational approximation of erf (|error| <
/// 1.5e-7 — far below the BERs this layer sweeps).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = ((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t
        - 0.284_496_736)
        * t
        + 0.254_829_592;
    sign * (1.0 - poly * t * (-x * x).exp())
}

fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Bit-error rate of a single-threshold read over lognormal ON/OFF
/// resistance states (the RRAM.py model): `ln R_on ~ N(mu_on, σ_on²)`
/// (low-resistance/ON), `ln R_off ~ N(mu_off, σ_off²)` (high-resistance/
/// OFF, `mu_off > mu_on`), read threshold at the log-midpoint, equal
/// state priors. Result clamped to `[0, 0.5]`.
pub fn lognormal_overlap_ber(mu_on: f64, sigma_on: f64, mu_off: f64, sigma_off: f64) -> f64 {
    assert!(sigma_on > 0.0 && sigma_off > 0.0, "sigmas must be positive");
    let t = 0.5 * (mu_on + mu_off);
    let p_on_misread = 1.0 - normal_cdf((t - mu_on) / sigma_on);
    let p_off_misread = normal_cdf((t - mu_off) / sigma_off);
    (0.5 * (p_on_misread + p_off_misread)).clamp(0.0, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_maps_probability_endpoints() {
        assert_eq!(ber_threshold(0.0), 0);
        assert_eq!(ber_threshold(-1.0), 0);
        assert_eq!(ber_threshold(1.0), u64::MAX);
        let half = ber_threshold(0.5);
        assert!((half as f64 / 2f64.powi(64) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn flip_sets_nest_under_increasing_ber() {
        // common-random-numbers coupling: every cell flipped at the low
        // BER also flips at the high BER (same seed/epoch)
        let (lo, hi) = (ber_threshold(0.01), ber_threshold(0.2));
        let mut flipped_lo = 0;
        for row in 0..64u64 {
            for col in 0..64u64 {
                let h = cell_hash(7, 3, KIND_READ, row, col);
                if h < lo {
                    flipped_lo += 1;
                    assert!(h < hi, "low-BER flip missing at high BER");
                }
            }
        }
        // 4096 cells at 1%: expect ~41 flips; just require some exist
        assert!(flipped_lo > 0, "no flips at 1% over 4096 cells");
    }

    #[test]
    fn hash_is_deterministic_and_key_sensitive() {
        assert_eq!(cell_hash(1, 2, 3, 4, 5), cell_hash(1, 2, 3, 4, 5));
        assert_ne!(cell_hash(1, 2, 3, 4, 5), cell_hash(2, 2, 3, 4, 5));
        assert_ne!(cell_hash(1, 2, 3, 4, 5), cell_hash(1, 3, 3, 4, 5));
        assert_ne!(cell_hash(1, 2, 3, 4, 5), cell_hash(1, 2, 4, 4, 5));
        assert_ne!(cell_hash(1, 2, 3, 4, 5), cell_hash(1, 2, 3, 5, 5));
        assert_ne!(cell_hash(1, 2, 3, 4, 5), cell_hash(1, 2, 3, 4, 6));
    }

    #[test]
    fn observe_at_zero_ber_is_transparent() {
        let mut st = FaultState::new(FaultModel::uniform(0.0, 42), 64, 16);
        st.begin_op();
        for row in 0..64 {
            for col in 0..16u16 {
                assert!(st.observe(row, col, true, 0));
                assert!(!st.observe(row, col, false, 0));
            }
        }
        assert_eq!(st.stats().read_flips, 0);
        assert_eq!(st.stats().read_draws, 2 * 64 * 16);
        assert!(!st.flip_written(0, 0, 0));
        assert!(!st.ambient(AmbientKind::Retention, 0, 0));
    }

    #[test]
    fn stuck_cells_override_reads_without_draws() {
        let model = FaultModel::uniform(0.0, 1).with_stuck(vec![StuckCell {
            row: 3,
            col: 5,
            value: true,
        }]);
        let mut st = FaultState::new(model, 8, 8);
        st.begin_op();
        assert!(st.observe(3, 5, false, 0), "stuck-at-1 read as 1");
        assert_eq!(st.stats().stuck_hits, 1);
        assert_eq!(st.stats().read_draws, 0);
        assert!(!st.observe(3, 6, false, 0), "neighbour unaffected");
    }

    #[test]
    fn random_stuck_materializes_distinct_in_bounds_cells() {
        let st = FaultState::new(FaultModel::uniform(0.0, 9).with_random_stuck(10), 32, 8);
        assert_eq!(st.stuck_cells(), 10);
        for &(row, col) in st.stuck.keys() {
            assert!((row as usize) < 32 && (col as usize) < 8);
        }
        // same seed → same layout; different seed → (almost surely) not
        let st2 = FaultState::new(FaultModel::uniform(0.0, 9).with_random_stuck(10), 32, 8);
        assert_eq!(st.stuck, st2.stuck);
        let st3 = FaultState::new(FaultModel::uniform(0.0, 10).with_random_stuck(10), 32, 8);
        assert_ne!(st.stuck, st3.stuck);
    }

    #[test]
    fn random_stuck_saturates_at_array_size() {
        let st = FaultState::new(FaultModel::uniform(0.0, 1).with_random_stuck(1000), 4, 4);
        assert_eq!(st.stuck_cells(), 16, "capped at rows × width");
    }

    #[test]
    fn nonzero_ber_flips_some_reads_deterministically() {
        let run = || {
            let mut st = FaultState::new(FaultModel::uniform(0.05, 11), 256, 16);
            st.begin_op();
            let mut flips = Vec::new();
            for row in 0..256 {
                for col in 0..16u16 {
                    // stored=false observed as true → a read flip
                    if st.observe(row, col, false, 0) {
                        flips.push((row, col));
                    }
                }
            }
            (flips, st.stats())
        };
        let (f1, s1) = run();
        let (f2, s2) = run();
        assert_eq!(f1, f2, "same seed, same flips");
        assert_eq!(s1, s2);
        assert!(s1.read_flips > 0, "5% BER over 4096 cells must flip");
        // loose binomial sanity: 4096 draws at 5% → ~205 ± 5σ(≈70)
        assert!((70..=350).contains(&(s1.read_flips as i64)), "{}", s1.read_flips);
    }

    #[test]
    fn wear_coupling_raises_effective_ber() {
        let model = FaultModel::uniform(0.01, 5).with_wear_coupling(10.0);
        let mut st = FaultState::new(model, 512, 8);
        st.begin_op();
        let mut cold = 0u64;
        let mut hot = 0u64;
        for row in 0..512 {
            for col in 0..8u16 {
                if st.observe(row, col, false, 0) {
                    cold += 1;
                }
            }
        }
        st.begin_op();
        for row in 0..512 {
            for col in 0..8u16 {
                if st.observe(row, col, false, 100) {
                    hot += 1;
                }
            }
        }
        // 1% vs min(0.5, 1%·1001) = 50%
        assert!(hot > cold * 10, "hot {hot} vs cold {cold}");
    }

    #[test]
    fn lognormal_overlap_behaves() {
        // 0.5·(1 + erf(0)) = 0.5 sanity through the CDF
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        // far-apart states: essentially no overlap
        assert!(lognormal_overlap_ber(0.0, 0.1, 10.0, 0.1) < 1e-12);
        // widening the distributions raises the BER
        let narrow = lognormal_overlap_ber(0.0, 0.5, 4.0, 0.5);
        let wide = lognormal_overlap_ber(0.0, 2.0, 4.0, 2.0);
        assert!(wide > narrow, "wide {wide} vs narrow {narrow}");
        // identical states: maximal confusion, clamped at 0.5
        assert!((lognormal_overlap_ber(1.0, 1.0, 1.0, 1.0) - 0.5).abs() < 1e-9);
        // always a valid probability for F01
        for (s_on, s_off) in [(0.1, 3.0), (2.5, 0.2), (1.0, 1.0)] {
            let b = lognormal_overlap_ber(0.0, s_on, 3.0, s_off);
            assert!((0.0..=0.5).contains(&b), "{b}");
        }
    }

    #[test]
    fn fidelity_reports_combine() {
        let a = FidelityReport {
            fidelity: 0.9,
            injected: 3,
            detected: 2,
            repaired: 2,
            residual: 0,
            retries: 1,
            overhead_cycles: 100,
        };
        let b = FidelityReport {
            fidelity: 0.5,
            injected: 1,
            detected: 1,
            repaired: 0,
            residual: 1,
            retries: 2,
            overhead_cycles: 50,
        };
        let c = a.combine(&b);
        assert!((c.fidelity - 0.45).abs() < 1e-12);
        assert_eq!(
            (c.injected, c.detected, c.repaired, c.residual, c.retries, c.overhead_cycles),
            (4, 3, 2, 1, 2, 150)
        );
        assert_eq!(FidelityReport::merge_all(Vec::new()), None);
        assert_eq!(FidelityReport::merge_all(vec![a]), Some(a));
    }

    #[test]
    fn stats_window_subtraction() {
        let mut st = FaultState::new(FaultModel::uniform(0.5, 3), 64, 4);
        st.begin_op();
        for row in 0..64 {
            st.observe(row, 0, false, 0);
        }
        let snap = st.stats();
        st.begin_op();
        for row in 0..64 {
            st.observe(row, 1, false, 0);
        }
        let d = st.stats().minus(&snap);
        assert_eq!(d.read_draws, 64);
        assert!(d.read_flips > 0);
    }
}
