//! Associative SEARCH — exact-match / range-count over resident keys,
//! the native CAM primitive (paper §3.1: a compare tags every matching
//! row in one cycle; the reduction tree counts the tags in one issue).
//!
//! This kernel exists to prove the kernel framework pays for itself
//! (DESIGN.md §Kernel framework): it was added **through the framework
//! alone** — this file plus one [`ENTRY`] in the registry — and acquired
//! resident datasets, rack sharding, the `SEARCH` wire verb, `LOAD
//! SEARCH`, the CLI `run search` subcommand and the bench sweeps with
//! zero kernel-specific code in the rack, server, CLI or benches.
//!
//! One u32 key per RCAM row (plus the dataset-membership valid bit, as
//! in the histogram kernel). A query is a closed range `[lo, hi]`
//! (`lo == hi` = exact match), answered associatively by the classic
//! TCAM range expansion: the range decomposes into ≤ 62 power-of-two
//! aligned prefixes ([`range_prefixes`]); each prefix is one masked
//! compare (fixed high bits only — unlisted columns are don't-care) plus
//! one reduction count. Prefixes are disjoint, so the host sums the
//! per-prefix counts. Cycles depend only on the range shape, never on
//! the key count — and counts are integers, so shard merging is a plain
//! sum (bin-add with one bin) that is bit-exact by construction.

use crate::algorithms::kernel::{
    one_shot_out, Kernel, KernelEntry, QueryOut, Resident, ResidentDyn, ShardMerge,
};
use crate::controller::{Controller, ExecStats};
use crate::error::{ensure, Result};
use crate::host::rack::PrinsRack;
use crate::isa::{Field, Instr, Program, RowLayout};
use crate::rcam::shard::ShardPlan;
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};
use crate::workloads::{synth_hist_samples, Rng};
use std::ops::Range;

/// A closed key range `[lo, hi]` (`lo == hi` = exact match) — the SEARCH
/// kernel's per-query parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchRange {
    /// Inclusive lower bound.
    pub lo: u32,
    /// Inclusive upper bound (`>= lo`).
    pub hi: u32,
}

impl SearchRange {
    /// The range `[lo, hi]` (asserts `lo <= hi`).
    pub fn new(lo: u32, hi: u32) -> Self {
        assert!(lo <= hi, "SearchRange: lo > hi");
        SearchRange { lo, hi }
    }

    /// An exact-match query for one key.
    pub fn exact(key: u32) -> Self {
        SearchRange { lo: key, hi: key }
    }
}

/// Decompose the closed range `[lo, hi]` into the minimal list of
/// power-of-two aligned prefixes `(value, fixed_bits)`: each prefix
/// covers `[value, value + 2^(32-fixed_bits) - 1]`, the prefixes are
/// disjoint, ascending, and their union is exactly `[lo, hi]`. At most
/// 62 prefixes for any u32 range — the classic TCAM range expansion.
pub fn range_prefixes(lo: u32, hi: u32) -> Vec<(u32, u32)> {
    assert!(lo <= hi);
    let mut out = Vec::new();
    let mut lo = lo as u64;
    let hi = hi as u64;
    while lo <= hi {
        // widest aligned block starting at lo that stays inside [lo, hi]
        let align = if lo == 0 { 32 } else { lo.trailing_zeros().min(32) };
        let mut k = align;
        while k > 0 && lo + (1u64 << k) - 1 > hi {
            k -= 1;
        }
        out.push((lo as u32, 32 - k));
        lo += 1u64 << k;
    }
    out
}

/// Scalar CPU baseline: keys of `xs` falling in `[lo, hi]`.
pub fn search_baseline(xs: &[u32], lo: u32, hi: u32) -> u64 {
    xs.iter().filter(|&&v| lo <= v && v <= hi).count() as u64
}

/// Loaded SEARCH dataset: one u32 key per row plus the valid bit.
///
/// Load-once / query-many: [`SearchKernel::load`] writes the keys once
/// (charged, two row writes per key like the histogram kernel); queries
/// are compare-only — zero writes, wear untouched, any number of
/// repeats bit-identical.
pub struct SearchKernel {
    /// Number of loaded keys.
    pub n: usize,
    key: Field,
    /// dataset-membership flag: unloaded (all-zero) rows must not match
    /// a range containing 0
    valid: Field,
    #[allow(dead_code)]
    ds: Dataset,
    load_stats: ExecStats,
}

impl SearchKernel {
    /// Allocate rows and load the keys (one key per row, plus the valid
    /// bit). Two charged row writes per key.
    pub fn load(sm: &mut StorageManager, array: &mut PrinsArray, xs: &[u32]) -> Self {
        let mut layout = RowLayout::new(array.width() as u16);
        let key = layout.alloc("key", 32);
        let valid = layout.alloc("valid", 1);
        let ds = sm.alloc(xs.len(), layout).expect("storage full");
        let (c0, l0) = (array.cycles, array.ledger());
        for (i, &v) in xs.iter().enumerate() {
            array.load_row_bits_charged(ds.rows.start + i, key.base as usize, 32, v as u64);
            array.load_row_bits_charged(ds.rows.start + i, valid.base as usize, 1, 1);
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        SearchKernel {
            n: xs.len(),
            key,
            valid,
            ds,
            load_stats,
        }
    }

    /// The per-range program: one masked compare + one reduction count
    /// per prefix of the TCAM range expansion.
    pub fn program(&self, r: &SearchRange) -> Program {
        let mut prog = Program::new();
        for (value, fixed) in range_prefixes(r.lo, r.hi) {
            let mut pat: Vec<(u16, bool)> = (32 - fixed..32)
                .map(|b| (self.key.base + b as u16, (value >> b) & 1 == 1))
                .collect();
            pat.push((self.valid.base, true));
            prog.push(Instr::Compare(pat));
            prog.push(Instr::ReduceCount);
        }
        prog
    }

    /// Query phase: count resident keys in `[r.lo, r.hi]`. Compare-only
    /// (zero writes); cycles depend on the range shape, not on the key
    /// count (bar the pipelined tree drain).
    pub fn query(&self, ctl: &mut Controller, r: &SearchRange) -> (u64, ExecStats) {
        ctl.begin_stats();
        let prog = self.program(r);
        let counts = ctl.execute_collect(&prog);
        // one pipelined tree-drain latency at the end of the prefix sweep
        ctl.array.charge_reduction_latency();
        let mut stats = ctl.stats();
        stats.passes = 0; // no writes in this kernel
        (counts.iter().sum(), stats)
    }
}

impl Kernel for SearchKernel {
    type Data = [u32];
    type Params = SearchRange;
    type Output = u64;

    const NAME: &'static str = "search";
    const VERB: &'static str = "SEARCH";
    const QUERY_ARITY: usize = 2;
    // query is exactly "execute program + tree drain, passes = 0", and
    // the output is the sum of the collected per-prefix ReduceCounts —
    // the shared-read contract (Kernel::SHARED_READ doc).
    const SHARED_READ: bool = true;

    fn data_rows(data: &[u32]) -> usize {
        data.len()
    }

    fn width(_data: &[u32]) -> usize {
        40
    }

    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &[u32],
        range: Range<usize>,
    ) -> Self {
        SearchKernel::load(sm, array, &data[range])
    }

    fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    fn load_payload_bytes(&self) -> u64 {
        4 * self.n as u64
    }

    fn load_writes(&self) -> u64 {
        2 * self.n as u64 // key value + valid bit per row
    }

    fn resident_columns(&self) -> Range<u16> {
        // key field plus the valid bit — the whole stored row
        self.key.base..(self.valid.base + self.valid.width)
    }

    fn query_shard(
        &self,
        ctl: &mut Controller,
        _sm: &StorageManager,
        _range: &Range<usize>,
        params: &SearchRange,
    ) -> (u64, ExecStats) {
        self.query(ctl, params)
    }

    fn query_msg_bytes(&self, _range: &Range<usize>, _params: &SearchRange) -> (u64, u64) {
        (8, 8) // lo+hi down, one u64 count back
    }

    fn query_floor_cycles(&self, array: &PrinsArray, params: &SearchRange) -> u64 {
        self.program(params).cycle_estimate() + array.reduction_latency_cycles()
    }

    fn query_plan(&self, array: &PrinsArray, params: &SearchRange) -> crate::analysis::QueryPlan {
        crate::analysis::QueryPlan {
            programs: vec![self.program(params)],
            // the final pipelined tree drain charged by query
            extra_cycles: array.reduction_latency_cycles(),
        }
    }

    fn shared_output(&self, collected: Vec<u64>) -> Option<u64> {
        Some(collected.iter().sum()) // one ReduceCount per prefix; the query sums them
    }

    fn parse_params(&self, args: &[&str]) -> Result<SearchRange> {
        let (lo, hi): (u32, u32) = (args[0].parse()?, args[1].parse()?);
        ensure!(lo <= hi, "search range: lo > hi");
        Ok(SearchRange { lo, hi })
    }

    fn seeded_params(&self, q: usize, seed: u64) -> SearchRange {
        let mut rng = Rng::seed_from(seed.wrapping_add(1 + q as u64));
        let (a, b) = (rng.next_u32(), rng.next_u32());
        if q % 4 == 3 {
            SearchRange::exact(a) // every fourth query: the exact-match form
        } else {
            SearchRange::new(a.min(b), a.max(b))
        }
    }
}

impl ShardMerge for SearchKernel {
    type Merged = u64;

    fn merge(outputs: Vec<u64>, _plan: &ShardPlan, _params: &SearchRange) -> u64 {
        outputs.iter().sum() // disjoint row partition: counts just add
    }

    fn fields(merged: &u64) -> String {
        format!("count={merged}")
    }

    fn bits(merged: &u64) -> Vec<u64> {
        vec![*merged]
    }
}

fn load_args(rack: &PrinsRack, args: &[&str]) -> Result<Box<dyn ResidentDyn>> {
    let [n, seed] = args else {
        crate::error::bail!("usage: LOAD SEARCH n seed");
    };
    let (n, seed): (usize, u64) = (n.parse()?, seed.parse()?);
    ensure!(n > 0 && n <= 1 << 20, "n out of range");
    let xs = synth_hist_samples(n, seed);
    Ok(Box::new(Resident::<SearchKernel>::load(rack, &xs)))
}

fn synth_load(rack: &PrinsRack, n: usize, _dims: usize, seed: u64) -> Box<dyn ResidentDyn> {
    Box::new(Resident::<SearchKernel>::load(
        rack,
        &synth_hist_samples(n, seed),
    ))
}

fn one_shot(rack: &PrinsRack, args: &[&str]) -> Result<QueryOut> {
    let [n, seed, lo, hi] = args else {
        crate::error::bail!("usage: SEARCH n seed lo hi");
    };
    let (n, seed): (usize, u64) = (n.parse()?, seed.parse()?);
    ensure!(n > 0 && n <= 1 << 20, "n out of range");
    let (lo, hi): (u32, u32) = (lo.parse()?, hi.parse()?);
    ensure!(lo <= hi, "search range: lo > hi");
    let xs = synth_hist_samples(n, seed);
    Ok(one_shot_out::<SearchKernel>(
        rack,
        &xs,
        &SearchRange { lo, hi },
    ))
}

/// The SEARCH kernel's registry entry — the only line of kernel-specific
/// code outside this file.
pub const ENTRY: KernelEntry = KernelEntry {
    name: SearchKernel::NAME,
    verb: SearchKernel::VERB,
    query_arity: SearchKernel::QUERY_ARITY,
    one_shot_arity: 4,
    load_usage: "LOAD SEARCH n seed",
    query_usage: "SEARCH id lo hi",
    one_shot_usage: "SEARCH n seed lo hi",
    dense: false,
    write_free_queries: true,
    bits_f32: false,
    flops: |n, _dims| n as f64, // one key comparison per resident row
    load: load_args,
    synth_load,
    one_shot,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::kernel::sharded;

    #[test]
    fn range_prefixes_partition_the_range_exactly() {
        let cases = [
            (0u32, 0u32),
            (5, 5),
            (0, u32::MAX),
            (1, 14),
            (100, 1000),
            (0x7FFF_FFFF, 0x8000_0001),
            (u32::MAX - 3, u32::MAX),
            (0, 1 << 20),
        ];
        for (lo, hi) in cases {
            let ps = range_prefixes(lo, hi);
            assert!(ps.len() <= 62, "[{lo},{hi}]: {} prefixes", ps.len());
            // ascending, disjoint, gap-free cover of [lo, hi]
            let mut next = lo as u64;
            for &(v, fixed) in &ps {
                assert_eq!(v as u64, next, "[{lo},{hi}]: gap at {v:#x}");
                let span = 1u64 << (32 - fixed);
                assert_eq!(v as u64 % span, 0, "[{lo},{hi}]: unaligned prefix");
                next = v as u64 + span;
            }
            assert_eq!(next, hi as u64 + 1, "[{lo},{hi}]: cover ends early/late");
        }
    }

    #[test]
    fn counts_match_baseline_and_exact_match_works() {
        let xs = synth_hist_samples(3000, 5);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = SearchKernel::load(&mut sm, &mut array, &xs);
        assert_eq!(kern.load_stats().ledger.n_write, 2 * xs.len() as u64);
        let mut ctl = Controller::new(array);
        for r in [
            SearchRange::new(0, u32::MAX),
            SearchRange::new(1 << 30, 3 << 30),
            SearchRange::new(12345, 12345678),
            SearchRange::exact(xs[17]),
            SearchRange::exact(xs[0] ^ 1), // likely absent key
        ] {
            let (count, stats) = kern.query(&mut ctl, &r);
            assert_eq!(count, search_baseline(&xs, r.lo, r.hi), "{r:?}");
            assert_eq!(stats.ledger.n_write, 0, "queries never write");
            assert_eq!(
                stats.cycles,
                kern.query_floor_cycles(&ctl.array, &r),
                "{r:?} off the analytic floor"
            );
        }
        // full range counts exactly the loaded keys (valid bit gates
        // unloaded all-zero rows out)
        let (all, _) = kern.query(&mut ctl, &SearchRange::new(0, u32::MAX));
        assert_eq!(all, xs.len() as u64);
    }

    #[test]
    fn cycles_independent_of_key_count() {
        let r = SearchRange::new(1000, 90_000);
        let run_n = |n: usize| {
            let xs = synth_hist_samples(n, 9);
            let mut array = PrinsArray::single(n, 40);
            let mut sm = StorageManager::new(n);
            let kern = SearchKernel::load(&mut sm, &mut array, &xs);
            let mut ctl = Controller::new(array);
            // subtract the N-dependent tree drain to compare issue cycles
            kern.query(&mut ctl, &r).1.cycles - ctl.array.reduction_latency_cycles()
        };
        assert_eq!(run_n(64), run_n(4096));
    }

    #[test]
    fn sharded_counts_bit_equal_single_device() {
        let xs = synth_hist_samples(2500, 23);
        let r = SearchRange::new(1 << 28, 7 << 28);
        let expect = search_baseline(&xs, r.lo, r.hi);
        for shards in [1usize, 2, 3, 8] {
            let rack = PrinsRack::new(shards);
            let res = sharded::<SearchKernel>(&rack, &xs, &r);
            assert_eq!(res.merged, expect, "shards={shards}");
            assert_eq!(res.rack.shards, shards);
            assert_eq!(res.rack.link_messages, 2 * shards as u64);
        }
    }

    #[test]
    fn resident_queries_repeat_bit_identically_and_rebind() {
        let xs = synth_hist_samples(1200, 31);
        let rack = PrinsRack::new(2);
        let mut res = Resident::<SearchKernel>::load(&rack, &xs);
        assert!(res.load_report().total_cycles > 0);
        let r1 = SearchRange::new(0, 1 << 31);
        let a = res.query(&r1);
        let b = res.query(&SearchRange::new(55, 99)); // new range, same keys
        let c = res.query(&r1);
        assert_eq!(a.merged, c.merged);
        assert_eq!(a.rack.total_cycles, c.rack.total_cycles);
        assert_eq!(b.merged, search_baseline(&xs, 55, 99));
        for st in &a.rack.shard_stats {
            assert_eq!(st.ledger.n_write, 0, "search queries never write");
        }
    }
}
