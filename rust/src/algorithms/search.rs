//! Associative SEARCH — exact-match / range-count over resident keys,
//! the native CAM primitive (paper §3.1: a compare tags every matching
//! row in one cycle; the reduction tree counts the tags in one issue).
//!
//! This kernel exists to prove the kernel framework pays for itself
//! (DESIGN.md §Kernel framework): it was added **through the framework
//! alone** — this file plus one [`ENTRY`] in the registry — and acquired
//! resident datasets, rack sharding, the `SEARCH` wire verb, `LOAD
//! SEARCH`, the CLI `run search` subcommand and the bench sweeps with
//! zero kernel-specific code in the rack, server, CLI or benches.
//!
//! One u32 key per RCAM row (plus the dataset-membership valid bit, as
//! in the histogram kernel). A query is a **batch** of closed ranges
//! `[lo, hi]` (`lo == hi` = exact match; batch size 1 is the classic
//! single-range query), answered associatively by the classic TCAM
//! range expansion: each range decomposes into ≤ 62 power-of-two
//! aligned prefixes ([`range_prefixes`]); each prefix is one masked
//! compare (fixed high bits only — unlisted columns are don't-care) plus
//! one reduction count. Prefixes are disjoint, so the host sums the
//! per-prefix counts per range. Batching B ranges into one sweep keeps
//! the reduction tree pipelined across the whole sweep, so the final
//! tree drain is charged **once per batch** instead of once per range —
//! per-range cycles drop strictly below the single-range analytic floor
//! at B ≥ 2 (DESIGN.md §Batching & program cache). Cycles depend only
//! on the range shapes, never on the key count — and counts are
//! integers, so shard merging is a plain element-wise sum that is
//! bit-exact by construction.

use crate::algorithms::kernel::{
    one_shot_out, Kernel, KernelEntry, QueryOut, Resident, ResidentDyn, ShardMerge,
};
use crate::analysis::QueryPlan;
use crate::controller::{Controller, ExecStats};
use crate::error::{ensure, Result};
use crate::host::rack::PrinsRack;
use crate::isa::{Field, Instr, Program, RowLayout};
use crate::rcam::shard::ShardPlan;
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};
use crate::workloads::{synth_hist_samples, Rng};
use std::ops::Range;

/// Most ranges one batched SEARCH sweep accepts (wire + CLI bound; keeps
/// one reply line and one command payload within protocol line limits).
pub const MAX_SEARCH_BATCH: usize = 16;

/// A closed key range `[lo, hi]` (`lo == hi` = exact match) — one
/// operand of a SEARCH query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchRange {
    /// Inclusive lower bound.
    pub lo: u32,
    /// Inclusive upper bound (`>= lo`).
    pub hi: u32,
}

impl SearchRange {
    /// The range `[lo, hi]` (asserts `lo <= hi`).
    pub fn new(lo: u32, hi: u32) -> Self {
        assert!(lo <= hi, "SearchRange: lo > hi");
        SearchRange { lo, hi }
    }

    /// An exact-match query for one key.
    pub fn exact(key: u32) -> Self {
        SearchRange { lo: key, hi: key }
    }
}

/// The SEARCH kernel's per-query parameter: B ranges counted in one
/// in-array sweep (one pipelined tree drain for the whole batch). Batch
/// size 1 is the classic single-range query and keeps its wire reply
/// byte-identical to the pre-batching protocol.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SearchBatch {
    /// The ranges, in operand order (non-empty, ≤ [`MAX_SEARCH_BATCH`]).
    pub ranges: Vec<SearchRange>,
}

impl SearchBatch {
    /// A single-range (unbatched) query.
    pub fn single(r: SearchRange) -> Self {
        SearchBatch { ranges: vec![r] }
    }

    /// A batch of ranges (asserts `1 ..= MAX_SEARCH_BATCH` operands).
    pub fn of(ranges: Vec<SearchRange>) -> Self {
        assert!(
            !ranges.is_empty() && ranges.len() <= MAX_SEARCH_BATCH,
            "SearchBatch: 1..={MAX_SEARCH_BATCH} ranges"
        );
        SearchBatch { ranges }
    }
}

/// Decompose the closed range `[lo, hi]` into the minimal list of
/// power-of-two aligned prefixes `(value, fixed_bits)`: each prefix
/// covers `[value, value + 2^(32-fixed_bits) - 1]`, the prefixes are
/// disjoint, ascending, and their union is exactly `[lo, hi]`. At most
/// 62 prefixes for any u32 range — the classic TCAM range expansion.
///
/// All internal arithmetic is u64 so the `hi == u32::MAX` and full-range
/// `[0, u32::MAX]` boundaries cannot overflow (`lo + 2^k - 1` and the
/// post-prefix advance both exceed u32 there); the boundary property
/// test below pins this against the scalar membership count.
pub fn range_prefixes(lo: u32, hi: u32) -> Vec<(u32, u32)> {
    assert!(lo <= hi);
    let mut out = Vec::new();
    let mut lo = lo as u64;
    let hi = hi as u64;
    while lo <= hi {
        // widest aligned block starting at lo that stays inside [lo, hi]
        let align = if lo == 0 { 32 } else { lo.trailing_zeros().min(32) };
        let mut k = align;
        while k > 0 && lo + (1u64 << k) - 1 > hi {
            k -= 1;
        }
        out.push((lo as u32, 32 - k));
        lo += 1u64 << k;
    }
    out
}

/// Scalar CPU baseline: keys of `xs` falling in `[lo, hi]`.
pub fn search_baseline(xs: &[u32], lo: u32, hi: u32) -> u64 {
    xs.iter().filter(|&&v| lo <= v && v <= hi).count() as u64
}

/// Loaded SEARCH dataset: one u32 key per row plus the valid bit.
///
/// Load-once / query-many: [`SearchKernel::load`] writes the keys once
/// (charged, two row writes per key like the histogram kernel); queries
/// are compare-only — zero writes, wear untouched, any number of
/// repeats bit-identical.
pub struct SearchKernel {
    /// Number of loaded keys.
    pub n: usize,
    key: Field,
    /// dataset-membership flag: unloaded (all-zero) rows must not match
    /// a range containing 0
    valid: Field,
    #[allow(dead_code)]
    ds: Dataset,
    load_stats: ExecStats,
}

impl SearchKernel {
    /// Allocate rows and load the keys (one key per row, plus the valid
    /// bit). Two charged row writes per key.
    pub fn load(sm: &mut StorageManager, array: &mut PrinsArray, xs: &[u32]) -> Self {
        let mut layout = RowLayout::new(array.width() as u16);
        let key = layout.alloc("key", 32);
        let valid = layout.alloc("valid", 1);
        let ds = sm.alloc(xs.len(), layout).expect("storage full");
        let (c0, l0) = (array.cycles, array.ledger());
        for (i, &v) in xs.iter().enumerate() {
            array.load_row_bits_charged(ds.rows.start + i, key.base as usize, 32, v as u64);
            array.load_row_bits_charged(ds.rows.start + i, valid.base as usize, 1, 1);
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        SearchKernel {
            n: xs.len(),
            key,
            valid,
            ds,
            load_stats,
        }
    }

    /// The per-range program: one masked compare + one reduction count
    /// per prefix of the TCAM range expansion.
    pub fn program(&self, r: &SearchRange) -> Program {
        let mut prog = Program::new();
        for (value, fixed) in range_prefixes(r.lo, r.hi) {
            let mut pat: Vec<(u16, bool)> = (32 - fixed..32)
                .map(|b| (self.key.base + b as u16, (value >> b) & 1 == 1))
                .collect();
            pat.push((self.valid.base, true));
            prog.push(Instr::Compare(pat));
            prog.push(Instr::ReduceCount);
        }
        prog
    }

    /// The batch's sweep programs, in operand order: one per range.
    pub fn batch_programs(&self, b: &SearchBatch) -> Vec<Program> {
        b.ranges.iter().map(|r| self.program(r)).collect()
    }

    /// Execute an already-synthesized sweep (one program per range) and
    /// fold each program's per-prefix counts into that range's count.
    /// Shared by the fresh and cached query paths, so the two are
    /// bit-identical by construction.
    fn run_programs(&self, ctl: &mut Controller, programs: &[Program]) -> Vec<u64> {
        programs
            .iter()
            .map(|p| ctl.execute_collect(p).iter().sum())
            .collect()
    }

    /// Query phase: count resident keys in every range of the batch.
    /// Compare-only (zero writes); cycles depend on the range shapes,
    /// not on the key count (bar the single pipelined tree drain charged
    /// once for the whole sweep).
    pub fn query(&self, ctl: &mut Controller, b: &SearchBatch) -> (Vec<u64>, ExecStats) {
        let programs = self.batch_programs(b);
        self.query_with(ctl, &programs)
    }

    fn query_with(&self, ctl: &mut Controller, programs: &[Program]) -> (Vec<u64>, ExecStats) {
        ctl.begin_stats();
        let counts = self.run_programs(ctl, programs);
        // one pipelined tree-drain latency at the end of the whole sweep
        ctl.array.charge_reduction_latency();
        let mut stats = ctl.stats();
        stats.passes = 0; // no writes in this kernel
        (counts, stats)
    }
}

impl Kernel for SearchKernel {
    type Data = [u32];
    type Params = SearchBatch;
    type Output = Vec<u64>;

    const NAME: &'static str = "search";
    const VERB: &'static str = "SEARCH";
    const QUERY_ARITY: usize = 2;
    // query is exactly "execute programs + one tree drain, passes = 0",
    // and the output is the per-range sum of the collected per-prefix
    // ReduceCounts — the shared-read contract (Kernel::SHARED_READ doc).
    const SHARED_READ: bool = true;

    fn data_rows(data: &[u32]) -> usize {
        data.len()
    }

    fn width(_data: &[u32]) -> usize {
        40
    }

    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &[u32],
        range: Range<usize>,
    ) -> Self {
        SearchKernel::load(sm, array, &data[range])
    }

    fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    fn load_payload_bytes(&self) -> u64 {
        4 * self.n as u64
    }

    fn load_writes(&self) -> u64 {
        2 * self.n as u64 // key value + valid bit per row
    }

    fn resident_columns(&self) -> Range<u16> {
        // key field plus the valid bit — the whole stored row
        self.key.base..(self.valid.base + self.valid.width)
    }

    fn query_shard(
        &self,
        ctl: &mut Controller,
        _sm: &StorageManager,
        _range: &Range<usize>,
        params: &SearchBatch,
    ) -> (Vec<u64>, ExecStats) {
        self.query(ctl, params)
    }

    fn query_msg_bytes(&self, _range: &Range<usize>, params: &SearchBatch) -> (u64, u64) {
        // lo+hi per range down, one u64 count per range back
        (8 * params.ranges.len() as u64, 8 * params.ranges.len() as u64)
    }

    fn query_floor_cycles(&self, array: &PrinsArray, params: &SearchBatch) -> u64 {
        params
            .ranges
            .iter()
            .map(|r| self.program(r).cycle_estimate())
            .sum::<u64>()
            + array.reduction_latency_cycles()
    }

    fn query_floor_unbatched_cycles(&self, array: &PrinsArray, params: &SearchBatch) -> u64 {
        // B independent single-range queries: each pays its own drain
        params
            .ranges
            .iter()
            .map(|r| self.program(r).cycle_estimate() + array.reduction_latency_cycles())
            .sum()
    }

    fn query_plan(&self, array: &PrinsArray, params: &SearchBatch) -> QueryPlan {
        QueryPlan {
            programs: self.batch_programs(params),
            // the final pipelined tree drain, charged once per sweep
            extra_cycles: array.reduction_latency_cycles(),
        }
    }

    fn shared_output(&self, params: &SearchBatch, collected: Vec<u64>) -> Option<Vec<u64>> {
        // the plan collects one ReduceCount per prefix, in range order:
        // split the flat stream back per range and sum each slice
        let mut it = collected.into_iter();
        let mut out = Vec::with_capacity(params.ranges.len());
        for r in &params.ranges {
            let k = range_prefixes(r.lo, r.hi).len();
            out.push((&mut it).take(k).sum());
        }
        Some(out)
    }

    fn params_key(&self, params: &SearchBatch) -> Option<String> {
        // the plan depends only on the range bounds (prefix expansion)
        let parts: Vec<String> = params
            .ranges
            .iter()
            .map(|r| format!("{}:{}", r.lo, r.hi))
            .collect();
        Some(parts.join(";"))
    }

    fn query_shard_planned(
        &self,
        ctl: &mut Controller,
        _sm: &StorageManager,
        _range: &Range<usize>,
        _params: &SearchBatch,
        plan: &QueryPlan,
    ) -> Option<(Vec<u64>, ExecStats)> {
        Some(self.query_with(ctl, &plan.programs))
    }

    fn parse_params(&self, args: &[&str]) -> Result<SearchBatch> {
        let (lo, hi): (u32, u32) = (args[0].parse()?, args[1].parse()?);
        ensure!(lo <= hi, "search range: lo > hi");
        Ok(SearchBatch::single(SearchRange { lo, hi }))
    }

    fn parse_batch(&self, args: &[&str]) -> Result<SearchBatch> {
        // docs/PROTOCOL.md: SEARCH id B lo1 hi1 … loB hiB, B >= 2 (the
        // B = 1 case is the classic SEARCH id lo hi form)
        ensure!(args.len() >= 3, "usage: SEARCH id B lo1 hi1 … (B >= 2)");
        let b: usize = args[0].parse()?;
        ensure!(
            (2..=MAX_SEARCH_BATCH).contains(&b),
            "search batch size must be in 2..={MAX_SEARCH_BATCH}"
        );
        ensure!(
            args.len() == 1 + 2 * b,
            "SEARCH batch of {b} takes exactly {} bounds",
            2 * b
        );
        let mut ranges = Vec::with_capacity(b);
        for pair in args[1..].chunks(2) {
            let (lo, hi): (u32, u32) = (pair[0].parse()?, pair[1].parse()?);
            ensure!(lo <= hi, "search range: lo > hi");
            ranges.push(SearchRange { lo, hi });
        }
        Ok(SearchBatch { ranges })
    }

    fn seeded_params(&self, q: usize, seed: u64) -> SearchBatch {
        let mut rng = Rng::seed_from(seed.wrapping_add(1 + q as u64));
        let (a, b) = (rng.next_u32(), rng.next_u32());
        if q % 4 == 3 {
            SearchBatch::single(SearchRange::exact(a)) // the exact-match form
        } else if q % 4 == 1 {
            // every fourth query is a 2-range batch, so the seeded stream
            // (and with it the `prins verify` shape grid) covers batched
            // plans without a separate driver
            let (c, d) = (rng.next_u32(), rng.next_u32());
            SearchBatch::of(vec![
                SearchRange::new(a.min(b), a.max(b)),
                SearchRange::new(c.min(d), c.max(d)),
            ])
        } else {
            SearchBatch::single(SearchRange::new(a.min(b), a.max(b)))
        }
    }

    fn seeded_batch(&self, q: usize, seed: u64, batch: usize) -> Option<SearchBatch> {
        if batch == 0 || batch > MAX_SEARCH_BATCH {
            return None;
        }
        let mut rng = Rng::seed_from(seed.wrapping_add(1 + q as u64));
        let ranges = (0..batch)
            .map(|_| {
                let (a, b) = (rng.next_u32(), rng.next_u32());
                SearchRange::new(a.min(b), a.max(b))
            })
            .collect();
        Some(SearchBatch { ranges })
    }
}

impl ShardMerge for SearchKernel {
    type Merged = Vec<u64>;

    fn merge(outputs: Vec<Vec<u64>>, _plan: &ShardPlan, params: &SearchBatch) -> Vec<u64> {
        // disjoint row partition: per-range counts just add
        let mut merged = vec![0u64; params.ranges.len()];
        for out in outputs {
            for (m, c) in merged.iter_mut().zip(out) {
                *m += c;
            }
        }
        merged
    }

    fn fields(merged: &Vec<u64>) -> String {
        if merged.len() == 1 {
            // byte-identical to the pre-batching single-range reply
            format!("count={}", merged[0])
        } else {
            let counts: Vec<String> = merged.iter().map(|c| c.to_string()).collect();
            format!("batch={} counts={}", merged.len(), counts.join(","))
        }
    }

    fn bits(merged: &Vec<u64>) -> Vec<u64> {
        merged.clone()
    }
}

fn load_args(rack: &PrinsRack, args: &[&str]) -> Result<Box<dyn ResidentDyn>> {
    let [n, seed] = args else {
        crate::error::bail!("usage: LOAD SEARCH n seed");
    };
    let (n, seed): (usize, u64) = (n.parse()?, seed.parse()?);
    ensure!(n > 0 && n <= 1 << 20, "n out of range");
    let xs = synth_hist_samples(n, seed);
    Ok(Box::new(Resident::<SearchKernel>::load(rack, &xs)))
}

fn synth_load(rack: &PrinsRack, n: usize, _dims: usize, seed: u64) -> Box<dyn ResidentDyn> {
    Box::new(Resident::<SearchKernel>::load(
        rack,
        &synth_hist_samples(n, seed),
    ))
}

fn one_shot(rack: &PrinsRack, args: &[&str]) -> Result<QueryOut> {
    let [n, seed, lo, hi] = args else {
        crate::error::bail!("usage: SEARCH n seed lo hi");
    };
    let (n, seed): (usize, u64) = (n.parse()?, seed.parse()?);
    ensure!(n > 0 && n <= 1 << 20, "n out of range");
    let (lo, hi): (u32, u32) = (lo.parse()?, hi.parse()?);
    ensure!(lo <= hi, "search range: lo > hi");
    let xs = synth_hist_samples(n, seed);
    Ok(one_shot_out::<SearchKernel>(
        rack,
        &xs,
        &SearchBatch::single(SearchRange { lo, hi }),
    ))
}

/// The SEARCH kernel's registry entry — the only line of kernel-specific
/// code outside this file.
pub const ENTRY: KernelEntry = KernelEntry {
    name: SearchKernel::NAME,
    verb: SearchKernel::VERB,
    query_arity: SearchKernel::QUERY_ARITY,
    one_shot_arity: 4,
    load_usage: "LOAD SEARCH n seed",
    query_usage: "SEARCH id lo hi | SEARCH id B lo1 hi1 … (B>=2)",
    one_shot_usage: "SEARCH n seed lo hi",
    dense: false,
    write_free_queries: true,
    overlay_queries: true,
    coalesce_queries: true,
    bits_f32: false,
    flops: |n, _dims| n as f64, // one key comparison per resident row
    load: load_args,
    synth_load,
    one_shot,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::kernel::sharded;

    #[test]
    fn range_prefixes_partition_the_range_exactly() {
        let cases = [
            (0u32, 0u32),
            (5, 5),
            (0, u32::MAX),
            (1, 14),
            (100, 1000),
            (0x7FFF_FFFF, 0x8000_0001),
            (u32::MAX - 3, u32::MAX),
            (u32::MAX, u32::MAX),
            (0, 1 << 20),
        ];
        for (lo, hi) in cases {
            let ps = range_prefixes(lo, hi);
            assert!(ps.len() <= 62, "[{lo},{hi}]: {} prefixes", ps.len());
            // ascending, disjoint, gap-free cover of [lo, hi]
            let mut next = lo as u64;
            for &(v, fixed) in &ps {
                assert_eq!(v as u64, next, "[{lo},{hi}]: gap at {v:#x}");
                let span = 1u64 << (32 - fixed);
                assert_eq!(v as u64 % span, 0, "[{lo},{hi}]: unaligned prefix");
                next = v as u64 + span;
            }
            assert_eq!(next, hi as u64 + 1, "[{lo},{hi}]: cover ends early/late");
        }
    }

    /// Satellite audit (ISSUE 9): the prefix expansion at the u32
    /// boundaries, pinned against the scalar reference count. For every
    /// boundary range and 200 seeded ranges, summing per-prefix scalar
    /// membership over the expansion must equal `search_baseline` — any
    /// off-by-one or overflow in the `(lo, 32 - k)` arithmetic (e.g. a
    /// u32 `lo + 2^k - 1` wrap at `hi == u32::MAX`) would double-count,
    /// drop keys, or loop forever here.
    #[test]
    fn prefix_membership_matches_scalar_baseline_at_boundaries() {
        let xs: Vec<u32> = {
            let mut v = synth_hist_samples(2000, 77);
            // plant the exact boundary keys so [u32::MAX, u32::MAX] and
            // friends count something real
            v.extend([0, 1, u32::MAX, u32::MAX - 1, 0x8000_0000]);
            v
        };
        let member = |lo: u32, hi: u32| -> u64 {
            range_prefixes(lo, hi)
                .iter()
                .map(|&(v, fixed)| {
                    let span = 1u64 << (32 - fixed);
                    let p_lo = v as u64;
                    let p_hi = p_lo + span - 1;
                    xs.iter()
                        .filter(|&&x| p_lo <= x as u64 && x as u64 <= p_hi)
                        .count() as u64
                })
                .sum()
        };
        let mut cases = vec![
            (0u32, 0u32),
            (0, u32::MAX),
            (u32::MAX, u32::MAX),
            (u32::MAX - 1, u32::MAX),
            (0, 1),
            (1, u32::MAX),
            (0x8000_0000, u32::MAX),
        ];
        let mut rng = Rng::seed_from(41);
        for _ in 0..200 {
            let (a, b) = (rng.next_u32(), rng.next_u32());
            cases.push((a.min(b), a.max(b)));
            cases.push((a, a)); // lo == hi exact form
        }
        for (lo, hi) in cases {
            assert_eq!(
                member(lo, hi),
                search_baseline(&xs, lo, hi),
                "[{lo},{hi}]: prefix membership diverged from scalar count"
            );
        }
    }

    #[test]
    fn counts_match_baseline_and_exact_match_works() {
        let xs = synth_hist_samples(3000, 5);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = SearchKernel::load(&mut sm, &mut array, &xs);
        assert_eq!(kern.load_stats().ledger.n_write, 2 * xs.len() as u64);
        let mut ctl = Controller::new(array);
        for r in [
            SearchRange::new(0, u32::MAX),
            SearchRange::new(1 << 30, 3 << 30),
            SearchRange::new(12345, 12345678),
            SearchRange::exact(xs[17]),
            SearchRange::exact(xs[0] ^ 1), // likely absent key
        ] {
            let b = SearchBatch::single(r);
            let (counts, stats) = kern.query(&mut ctl, &b);
            assert_eq!(counts, vec![search_baseline(&xs, r.lo, r.hi)], "{r:?}");
            assert_eq!(stats.ledger.n_write, 0, "queries never write");
            assert_eq!(
                stats.cycles,
                kern.query_floor_cycles(&ctl.array, &b),
                "{r:?} off the analytic floor"
            );
        }
        // full range counts exactly the loaded keys (valid bit gates
        // unloaded all-zero rows out)
        let (all, _) = kern.query(&mut ctl, &SearchBatch::single(SearchRange::new(0, u32::MAX)));
        assert_eq!(all, vec![xs.len() as u64]);
    }

    #[test]
    fn batched_sweep_matches_singles_and_beats_the_unbatched_floor() {
        let xs = synth_hist_samples(2500, 13);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = SearchKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let ranges = vec![
            SearchRange::new(0, 1 << 20),
            SearchRange::exact(xs[42]),
            SearchRange::new(1 << 30, u32::MAX),
        ];
        let batch = SearchBatch::of(ranges.clone());
        let (counts, stats) = kern.query(&mut ctl, &batch);
        // per-range counts are bit-identical to three single queries
        for (i, r) in ranges.iter().enumerate() {
            let (single, _) = kern.query(&mut ctl, &SearchBatch::single(*r));
            assert_eq!(counts[i], single[0], "{r:?}");
            assert_eq!(counts[i], search_baseline(&xs, r.lo, r.hi), "{r:?}");
        }
        // measured == batched floor, strictly below the unbatched Σ:
        // the sweep drains the reduction tree once instead of B times
        let floor = kern.query_floor_cycles(&ctl.array, &batch);
        let unbatched = kern.query_floor_unbatched_cycles(&ctl.array, &batch);
        assert_eq!(stats.cycles, floor);
        assert_eq!(
            unbatched - floor,
            2 * ctl.array.reduction_latency_cycles(),
            "batch of 3 saves exactly 2 tree drains"
        );
        assert!(stats.cycles < unbatched);
        assert_eq!(stats.ledger.n_write, 0, "batched queries never write");
    }

    #[test]
    fn cycles_independent_of_key_count() {
        let r = SearchBatch::single(SearchRange::new(1000, 90_000));
        let run_n = |n: usize| {
            let xs = synth_hist_samples(n, 9);
            let mut array = PrinsArray::single(n, 40);
            let mut sm = StorageManager::new(n);
            let kern = SearchKernel::load(&mut sm, &mut array, &xs);
            let mut ctl = Controller::new(array);
            // subtract the N-dependent tree drain to compare issue cycles
            kern.query(&mut ctl, &r).1.cycles - ctl.array.reduction_latency_cycles()
        };
        assert_eq!(run_n(64), run_n(4096));
    }

    #[test]
    fn sharded_counts_bit_equal_single_device() {
        let xs = synth_hist_samples(2500, 23);
        let r = SearchRange::new(1 << 28, 7 << 28);
        let expect = search_baseline(&xs, r.lo, r.hi);
        for shards in [1usize, 2, 3, 8] {
            let rack = PrinsRack::new(shards);
            let res = sharded::<SearchKernel>(&rack, &xs, &SearchBatch::single(r));
            assert_eq!(res.merged, vec![expect], "shards={shards}");
            assert_eq!(res.rack.shards, shards);
            assert_eq!(res.rack.link_messages, 2 * shards as u64);
        }
    }

    #[test]
    fn resident_queries_repeat_bit_identically_and_rebind() {
        let xs = synth_hist_samples(1200, 31);
        let rack = PrinsRack::new(2);
        let mut res = Resident::<SearchKernel>::load(&rack, &xs);
        assert!(res.load_report().total_cycles > 0);
        let r1 = SearchBatch::single(SearchRange::new(0, 1 << 31));
        let a = res.query(&r1);
        let b = res.query(&SearchBatch::single(SearchRange::new(55, 99)));
        let c = res.query(&r1);
        assert_eq!(a.merged, c.merged);
        assert_eq!(a.rack.total_cycles, c.rack.total_cycles);
        assert_eq!(b.merged, vec![search_baseline(&xs, 55, 99)]);
        for st in &a.rack.shard_stats {
            assert_eq!(st.ledger.n_write, 0, "search queries never write");
        }
        // the repeat of r1 was served from the compiled-program cache
        let (hits, misses) = res.cache_stats();
        assert!(hits > 0, "repeat query never hit the program cache");
        assert!(misses > 0);
    }

    #[test]
    fn shared_path_handles_batches_bit_identically() {
        let xs = synth_hist_samples(1500, 61);
        let rack = PrinsRack::new(2);
        let mut res = Resident::<SearchKernel>::load(&rack, &xs);
        let batch = SearchBatch::of(vec![
            SearchRange::new(0, 1 << 16),
            SearchRange::new(1 << 16, 1 << 24),
            SearchRange::exact(xs[7]),
            SearchRange::new(0, u32::MAX),
        ]);
        let shared = res.query_shared(&batch).expect("search is shared-readable");
        let excl = res.query(&batch);
        assert_eq!(shared.merged, excl.merged);
        assert_eq!(shared.rack.total_cycles, excl.rack.total_cycles);
        assert_eq!(shared.merged[3], xs.len() as u64, "full range counts all keys");
    }
}
