//! The unified kernel framework (DESIGN.md §Kernel framework): one
//! trait-based load/query/merge pipeline shared by every associative
//! workload, plus the registry that lets the rack, the TCP server, the
//! CLI and the benches drive *all* kernels without naming any of them.
//!
//! Before this module, every workload hand-rolled five near-identical
//! layers (layout / `load`+`query` kernel / `Sharded*Result` /
//! `Resident*` wrapper / `*_sharded` one-shot), and the server, CLI and
//! benches each repeated a per-kernel dispatch — adding a workload meant
//! re-plumbing the whole stack, which is exactly the programmability
//! wall "A Modern Primer on Processing-in-Memory" (arXiv:2012.03112)
//! warns kills PIM adoption. Now a workload implements two traits in its
//! own file and registers one [`KernelEntry`]; everything above the
//! array — sharding, resident datasets, wire verbs, CLI subcommands,
//! bench sweeps, bit-equality gates — comes for free:
//!
//!   * [`Kernel`] — the load/query contract: how to partition a dataset
//!     over shards, load one shard's slice into RCAM rows (charged), run
//!     one query against the resident rows, and price the host-link
//!     messages both phases cost;
//!   * [`ShardMerge`] — the host-side merge operator (bin-wise add,
//!     ordered concat, top-k, …) plus the wire-reply formatter and a
//!     canonical bit encoding used by the bit-equality test gates;
//!   * [`Resident<K>`] — the one generic load-once / query-many rack
//!     wrapper that replaced the four copy-pasted `Resident*` structs;
//!   * [`sharded`] — the one generic one-shot (load + single query);
//!   * [`KernelRegistry`](registry) — name → loader/parser/formatter;
//!     the server's verb dispatch, the CLI `run` subcommand and the
//!     bench sweeps iterate it instead of matching hard-coded arms.
//!
//! The SEARCH kernel ([`crate::algorithms::search`]) is the proof: it
//! ships resident, sharded, server-verb, CLI and bench support from one
//! file plus its registry entry, with zero kernel-specific code added to
//! the rack, server or CLI.

use crate::analysis::{ArrayShape, PlannedQuery, QueryPlan};
use crate::controller::read::{ProgramCache, ReadCursor};
use crate::controller::{Controller, ExecStats};
use crate::error::{bail, ensure, Result};
use crate::host::rack::{PrinsRack, RackStats};
use crate::rcam::shard::{ShardPlan, CMD_BYTES};
use crate::rcam::PrinsArray;
use crate::reliability::{FidelityReport, Scrubber, BACKOFF_BASE_CYCLES, MAX_QUERY_RETRIES};
use crate::storage::wear::{wear_report, WearReport};
use crate::storage::StorageManager;
use std::ops::Range;

/// A row-major `n × dims` f32 dataset — the load input of the dense
/// vector kernels (Euclidean distance, dot product).
pub struct FloatMatrix {
    /// The values, row-major (`x[i * dims + j]` = attribute j of row i).
    pub x: Vec<f32>,
    /// Number of rows (samples / vectors).
    pub n: usize,
    /// Attributes per row.
    pub dims: usize,
}

impl FloatMatrix {
    /// Wrap `x` as an `n × dims` matrix (length-checked).
    pub fn new(x: Vec<f32>, n: usize, dims: usize) -> Self {
        assert_eq!(x.len(), n * dims, "FloatMatrix: x.len() != n * dims");
        FloatMatrix { x, n, dims }
    }

    /// The row-major slice of rows `range`.
    pub fn rows(&self, range: &Range<usize>) -> &[f32] {
        &self.x[range.start * self.dims..range.end * self.dims]
    }
}

/// The load/query contract every associative workload implements once,
/// in its own file. All framework machinery — [`Resident`],
/// [`sharded`], the registry-driven server/CLI/bench surfaces — is
/// generic over this trait plus [`ShardMerge`].
///
/// One implementor instance is **one shard's loaded kernel**: `load_range`
/// writes that shard's slice of the dataset into RCAM rows (charged to
/// the device model), `query_shard` replays the query program against the
/// resident rows. Stored fields must be read-only to the query program so
/// repeat queries are bit-identical — the registry-driven test gates
/// (`tests/resident_datasets.rs`) assert exactly that for every
/// registered kernel.
pub trait Kernel: Sized + Send + Sync {
    /// Host-side dataset type the kernel loads (`[u32]` samples, a
    /// [`FloatMatrix`], a [`crate::workloads::Csr`], …).
    type Data: ?Sized + Sync;
    /// Per-query parameters (a hyperplane, bin edges, a search range, …).
    type Params: Sync + Send;
    /// One shard's raw query output, before the host-side merge.
    type Output: Send;

    /// Registry/CLI name (`"hist"`, `"dp"`, …) — lower-case.
    const NAME: &'static str;
    /// Wire verb (`"HIST"`, `"DP"`, …) — upper-case.
    const VERB: &'static str;
    /// Wire query parameters after the dataset id (`DP id seed` → 1).
    const QUERY_ARITY: usize;

    /// Opt-in to the shared-read concurrent query path (DESIGN.md
    /// §Serving). Two ways to qualify:
    ///
    ///   * **compare-only** (hist, search): the query is exactly
    ///     "execute the [`Kernel::query_plan`] programs, charge its
    ///     `extra_cycles`, pin `passes` to 0" with programs containing
    ///     only `Compare` / `ReduceCount` (the `prins verify` C01/C02
    ///     contracts), and the shard output is reconstructible from the
    ///     collected reductions alone ([`Kernel::shared_output`]);
    ///   * **scratch-overlay** (ed, dp): the query's microprograms write
    ///     *scratch* columns only — never the
    ///     [`Kernel::resident_columns`] (the `prins verify` overlay
    ///     C03 contract) — and the kernel implements
    ///     [`Kernel::query_shard_overlay`], executing on a
    ///     [`ReadCursor`] whose copy-on-write overlay makes those
    ///     scratch writes cursor-local.
    const SHARED_READ: bool = false;

    /// Global logical rows of `data` (samples / vectors / matrix dim).
    fn data_rows(data: &Self::Data) -> usize;

    /// Shard partition of `data`. Default: equal contiguous row ranges;
    /// override for weighted cuts (SpMV balances nonzeros, not rows).
    fn plan(data: &Self::Data, shards: usize) -> ShardPlan {
        ShardPlan::rows(Self::data_rows(data), shards)
    }

    /// Bit-columns one shard row needs for `data`.
    fn width(data: &Self::Data) -> usize;

    /// Physical RCAM rows shard `range` needs. Default `range.len()`;
    /// override when physical rows ≠ logical rows (SpMV stores nonzeros).
    fn shard_rows(data: &Self::Data, range: &Range<usize>) -> usize {
        range.len()
    }

    /// Load shard `range` of `data` into `array` (charged row writes).
    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &Self::Data,
        range: Range<usize>,
    ) -> Self;

    /// Device-model cost of this shard's load phase (paid once).
    fn load_stats(&self) -> &ExecStats;

    /// Raw dataset bytes this shard's load moved over the host link
    /// (the fixed command header is added by the rack).
    fn load_payload_bytes(&self) -> u64;

    /// Exact charged row writes this shard's load performed (one per
    /// stored field: 2·n for hist/search, n·dims for ed/dp, 4·nnz for
    /// spmv). The registry-driven test gates pin the measured load
    /// ledger to this, so a double-load regression in the generic
    /// [`Resident::load`] cannot ship silently.
    fn load_writes(&self) -> u64;

    /// Bit-columns holding this shard's *resident dataset* fields, as
    /// opposed to scratch work areas the query program overwrites
    /// anyway. This is what the reliability layer protects: the
    /// scrubber's golden copy covers exactly these columns, and ambient
    /// retention decay corrupts them between queries.
    fn resident_columns(&self) -> Range<u16>;

    /// One query against the resident shard rows. `range` is this
    /// shard's slice of the global plan (readout slicing, global row
    /// offsets). Must not rewrite stored dataset fields.
    fn query_shard(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        range: &Range<usize>,
        params: &Self::Params,
    ) -> (Self::Output, ExecStats);

    /// Host-link bytes of one query on this shard:
    /// `(command payload beyond the fixed header, result readback)`.
    fn query_msg_bytes(&self, range: &Range<usize>, params: &Self::Params) -> (u64, u64);

    /// Analytic cycle floor of one query on this shard (exact: program
    /// shape depends only on layout + params, never on data values).
    fn query_floor_cycles(&self, array: &PrinsArray, params: &Self::Params) -> u64;

    /// Synthesize — without executing — every microprogram one query
    /// with `params` would dispatch on this shard, plus the cycles the
    /// query charges outside any program (reduction-tree drains,
    /// chain-hop field moves). This is the static analyzer's view of the
    /// query (`crate::analysis`): rule C01 proves write-freedom over it
    /// and rule C02 pins its [`QueryPlan::cycle_estimate`] to
    /// [`Kernel::query_floor_cycles`] for the same shard and params.
    fn query_plan(&self, array: &PrinsArray, params: &Self::Params) -> QueryPlan;

    /// Rebuild one shard's query output from the reduction values its
    /// plan collected, in program order — the shared-read twin of
    /// [`Kernel::query_shard`]'s output half. `params` carries whatever
    /// the output grouping needs (a batched SEARCH splits the collected
    /// counts back per range). `None` (the default) means the kernel
    /// does not support the shared path.
    fn shared_output(&self, params: &Self::Params, collected: Vec<u64>) -> Option<Self::Output> {
        let _ = (params, collected);
        None
    }

    /// Canonical *params-class* string for the compiled-program cache
    /// (DESIGN.md §Batching & program cache): two params with the same
    /// key **must** synthesize identical [`Kernel::query_plan`]s on the
    /// same [`ArrayShape`]. `None` (the default) opts the kernel out of
    /// plan caching; a kernel returning `Some` must also implement
    /// [`Kernel::query_shard_planned`], or cached plans would be
    /// synthesized and counted without ever being consumed.
    fn params_key(&self, params: &Self::Params) -> Option<String> {
        let _ = params;
        None
    }

    /// Execute one query from an already-synthesized plan — the
    /// cache-hit twin of [`Kernel::query_shard`], which must produce
    /// bit-identical output and stats when `plan` equals
    /// [`Kernel::query_plan`] for the same `(array, params)`. `None`
    /// (the default) means the kernel's query path does not consume
    /// plans; the framework then falls back to [`Kernel::query_shard`].
    fn query_shard_planned(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        range: &Range<usize>,
        params: &Self::Params,
        plan: &QueryPlan,
    ) -> Option<(Self::Output, ExecStats)> {
        let _ = (ctl, sm, range, params, plan);
        None
    }

    /// Execute one query on a shared-read [`ReadCursor`] through its
    /// scratch overlay — the concurrent twin of
    /// [`Kernel::query_shard_planned`] for microcoded kernels whose
    /// programs write only scratch columns. The implementation must
    /// execute exactly `plan`'s programs via
    /// [`ReadCursor::execute_overlay`] (readout through
    /// [`ReadCursor::fetch_row_bits`]) and report
    /// [`ReadCursor::stats_microcoded`], so output and stats are
    /// bit-identical to the exclusive path on a fresh stats window.
    /// `None` (the default) means the kernel has no overlay form; the
    /// shared path then falls back to the collected-reductions route
    /// ([`Kernel::shared_output`]).
    fn query_shard_overlay(
        &self,
        cur: &mut ReadCursor<'_>,
        sm: &StorageManager,
        range: &Range<usize>,
        params: &Self::Params,
        plan: &QueryPlan,
    ) -> Option<(Self::Output, ExecStats)> {
        let _ = (cur, sm, range, params, plan);
        None
    }

    /// Parse wire query parameters (the args after the dataset id).
    fn parse_params(&self, args: &[&str]) -> Result<Self::Params>;

    /// Parse the wire **batched** query form (the args after the dataset
    /// id, e.g. `SEARCH id B lo1 hi1 …` → `["B", "lo1", "hi1", …]`) into
    /// params packing B operands into one in-array sweep. The default
    /// refuses: kernels without a packed-operand program keep their
    /// single-operand grammar only.
    fn parse_batch(&self, args: &[&str]) -> Result<Self::Params> {
        let _ = args;
        bail!("{} has no batched query form", Self::VERB)
    }

    /// Deterministic parameter stream for CLI sweeps, benches and the
    /// registry-driven test gates: query index `q` under `seed`.
    fn seeded_params(&self, q: usize, seed: u64) -> Self::Params;

    /// Deterministic **batched** parameter stream: `batch` operands for
    /// query index `q` under `seed`, packed into one sweep. `None` (the
    /// default) means the kernel has no batched form — the bench and CLI
    /// batch sweeps skip it.
    fn seeded_batch(&self, q: usize, seed: u64, batch: usize) -> Option<Self::Params> {
        let _ = (q, seed, batch);
        None
    }

    /// Analytic cycle cost of running `params`' operands **unbatched** —
    /// one independent single-operand query per operand, Σ over operands.
    /// For single-operand params this equals
    /// [`Kernel::query_floor_cycles`] (the default); for batched params
    /// it is the baseline the in-array packing must strictly beat at
    /// B ≥ 2 (the bench floor gate).
    fn query_floor_unbatched_cycles(&self, array: &PrinsArray, params: &Self::Params) -> u64 {
        self.query_floor_cycles(array, params)
    }
}

/// The host-side merge half of the pipeline: fold per-shard outputs
/// (arriving in [`ShardPlan`] order) into the global result, and present
/// it — as wire-reply fields and as a canonical bit string the
/// bit-equality gates compare across shard counts and repeat queries.
pub trait ShardMerge: Kernel {
    /// The merged global result.
    type Merged: Send;

    /// Fold per-shard outputs (plan order) into the global result.
    fn merge(outputs: Vec<Self::Output>, plan: &ShardPlan, params: &Self::Params) -> Self::Merged;

    /// Verb-specific wire reply fields (`"checksum=…"`, `"top_bin=…"`).
    fn fields(merged: &Self::Merged) -> String;

    /// Canonical bit encoding of the merged result (f32 via `to_bits`),
    /// compared verbatim by the sharded==single and repeat-query gates.
    fn bits(merged: &Self::Merged) -> Vec<u64>;
}

/// One shard's resident state: the controller owning the shard array,
/// the shard's storage manager, and the loaded kernel.
pub struct ShardSlot<K> {
    /// Controller owning this shard's array.
    pub ctl: Controller,
    /// This shard's storage manager (row translation for readout).
    pub sm: StorageManager,
    /// The shard's loaded kernel.
    pub kern: K,
    /// ECC-style scrubber over the resident columns — present only when
    /// the rack carries a fault model with recovery enabled.
    pub scrub: Option<Scrubber>,
}

/// Result of one query on a [`Resident`] dataset (or of the [`sharded`]
/// one-shot): the merged global result plus rack-level stats.
pub struct Sharded<K: ShardMerge> {
    /// The host-merged global result.
    pub merged: K::Merged,
    /// Rack-level cycle/energy statistics (slowest shard + host link).
    pub rack: RackStats,
    /// Combined reliability report across shards — `None` unless the
    /// rack carries a fault model ([`crate::host::rack::PrinsRack::with_fault`]).
    pub fidelity: Option<FidelityReport>,
}

/// A rack-resident dataset of any registered kernel: partitioned over
/// the rack by `K::plan`, loaded **once** (charged,
/// [`Resident::load_report`]), then queried arbitrarily many times —
/// each query replays `K`'s program on every shard concurrently against
/// the already-resident rows and merges host-side, charging only query
/// cycles plus per-query link messages. This single generic replaced the
/// four copy-pasted `Resident{Euclidean,Dot,Histogram,Spmv}` structs.
pub struct Resident<K: ShardMerge> {
    rack: PrinsRack,
    plan: ShardPlan,
    /// Global logical rows loaded (across all shards).
    pub n: usize,
    shards: Vec<ShardSlot<K>>,
    load: RackStats,
    /// Compiled-program cache over this dataset's shard arrays, shared
    /// by the exclusive and shared-read query paths (DESIGN.md
    /// §Batching & program cache). Born empty with the dataset, dies
    /// with it (LOAD/DROP invalidation for free); FAULTS and remap call
    /// [`ProgramCache::invalidate`] through [`ResidentDyn`].
    cache: ProgramCache,
}

impl<K: ShardMerge> Resident<K> {
    /// Load phase: partition `data` over the rack and write every
    /// shard's slice into its array once (one command + payload message
    /// per shard on the host link).
    pub fn load(rack: &PrinsRack, data: &K::Data) -> Self {
        let n = K::data_rows(data);
        let plan = K::plan(data, rack.n_shards());
        let width = K::width(data);
        let shards = rack.run_shards(&plan, |_s, r| {
            let rows = K::shard_rows(data, &r);
            let mut array = rack.shard_array(rows, width);
            // Per-row wear counters feed the server's wear-aware eviction
            // and the wear gates. They must be enabled before the first
            // load write (enabling replaces the modules wholesale), and
            // never under a fault model: fault draws are wear-coupled
            // (`FaultState::observe`), so tracking would change seeded
            // corruption replay. Counters add no cycles or ledger events.
            if rack.fault().is_none() {
                array.enable_wear_tracking();
            }
            let mut sm = StorageManager::new(array.total_rows());
            let kern = K::load_range(&mut sm, &mut array, data, r);
            // reliability layer, attached after the kernel's load-stats
            // window closed so load accounting is byte-identical with
            // and without faults: capture the golden copy through the
            // still-ideal storage path, install the fault model (F01
            // checked against this shard's concrete shape), then apply
            // write-disturb from the load burst
            let mut scrub = None;
            if let Some(model) = rack.fault() {
                let cols = kern.resident_columns();
                if model.recovery {
                    scrub = Some(Scrubber::capture(&array, cols.clone()));
                }
                array
                    .enable_faults(model.clone())
                    .expect("rack fault model rejected for shard array");
                array.apply_disturb(cols);
            }
            ShardSlot {
                ctl: Controller::new(array),
                sm,
                kern,
                scrub,
            }
        });
        let stats: Vec<ExecStats> = shards.iter().map(|s| s.kern.load_stats().clone()).collect();
        let payload: Vec<u64> = shards.iter().map(|s| s.kern.load_payload_bytes()).collect();
        let load = rack.finish_load(stats, &payload);
        Resident {
            rack: rack.clone(),
            plan,
            n,
            shards,
            load,
            cache: ProgramCache::new(),
        }
    }

    /// Device + link cost of the load phase (paid once per dataset).
    pub fn load_report(&self) -> &RackStats {
        &self.load
    }

    /// The shard partition the dataset was loaded with.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Shard 0's loaded kernel (parameter parsing/synthesis: layout
    /// facts like `dims` are identical on every shard).
    pub fn kernel(&self) -> &K {
        &self.shards[0].kern
    }

    /// Query phase: run `params` on every shard concurrently against the
    /// resident rows and merge host-side — zero load-phase writes, so
    /// repeat queries are bit-identical.
    pub fn query(&mut self, params: &K::Params) -> Sharded<K> {
        let plan = &self.plan;
        let rack = &self.rack;
        let cache = &self.cache;
        let shards = &mut self.shards;
        let runs = rack.query_shards(shards, |i, sh| {
            if sh.ctl.array.has_faults() {
                // faulty shards skip the cache entirely: the fault layer
                // mutates array state between attempts, so plans are not
                // reusable and must be synthesized per attempt
                query_shard_faulty(sh, &plan.ranges[i], params)
            } else {
                let planned = match sh.kern.params_key(params) {
                    Some(key) => {
                        let qp = cache.get_or_insert(ArrayShape::of(&sh.ctl.array), &key, || {
                            sh.kern.query_plan(&sh.ctl.array, params)
                        });
                        sh.kern
                            .query_shard_planned(&mut sh.ctl, &sh.sm, &plan.ranges[i], params, &qp)
                    }
                    None => None,
                };
                let (out, stats) = match planned {
                    Some(r) => r,
                    None => sh
                        .kern
                        .query_shard(&mut sh.ctl, &sh.sm, &plan.ranges[i], params),
                };
                (out, stats, None)
            }
        });
        let mut outs = Vec::with_capacity(runs.len());
        let mut stats = Vec::with_capacity(runs.len());
        let mut fids = Vec::new();
        for (o, s, f) in runs {
            outs.push(o);
            stats.push(s);
            fids.extend(f);
        }
        let fidelity = FidelityReport::merge_all(fids);
        let merged = K::merge(outs, plan, params);
        let mut msgs = Vec::with_capacity(2 * plan.shards());
        for (sh, rng) in self.shards.iter().zip(&self.plan.ranges) {
            let (cmd, back) = sh.kern.query_msg_bytes(rng, params);
            msgs.push(CMD_BYTES + cmd);
            msgs.push(back);
        }
        Sharded {
            merged,
            rack: self.rack.finish(stats, &msgs),
            fidelity,
        }
    }

    /// Whether this dataset can serve the shared-read concurrent query
    /// path: the kernel opted in ([`Kernel::SHARED_READ`]) and no shard
    /// carries a fault model (faulty queries mutate fault/scrub state,
    /// so they stay on the exclusive [`Resident::query`] path).
    pub fn shared_readable(&self) -> bool {
        K::SHARED_READ
            && self.rack.fault().is_none()
            && self.shards.iter().all(|sh| !sh.ctl.array.has_faults())
    }

    /// Shared-read query phase (DESIGN.md §Serving): bit-identical
    /// merged result and rack stats to [`Resident::query`], through
    /// `&self` — so any number of concurrent readers can query the same
    /// resident rows at once. Each shard synthesizes its query plan and
    /// executes it on a [`ReadCursor`], leaving the shard arrays'
    /// cycles, ledgers, tags and wear counters untouched. Returns
    /// `None` when the dataset is not [`Resident::shared_readable`].
    pub fn query_shared(&self, params: &K::Params) -> Option<Sharded<K>> {
        if !self.shared_readable() {
            return None;
        }
        let plan = &self.plan;
        let cache = &self.cache;
        let runs = self.rack.read_shards(&self.shards, |i, sh| {
            // cached plans are handed out as Arcs, so any number of
            // concurrent readers execute one synthesized plan at once
            let qp = match sh.kern.params_key(params) {
                Some(key) => cache.get_or_insert(ArrayShape::of(&sh.ctl.array), &key, || {
                    sh.kern.query_plan(&sh.ctl.array, params)
                }),
                None => std::sync::Arc::new(sh.kern.query_plan(&sh.ctl.array, params)),
            };
            let mut cur = ReadCursor::new(&sh.ctl.array);
            // microcoded kernels execute through the scratch overlay;
            // compare-only kernels fall through to collected reductions
            if let Some(r) =
                sh.kern
                    .query_shard_overlay(&mut cur, &sh.sm, &plan.ranges[i], params, &qp)
            {
                return Some(r);
            }
            let mut collected = Vec::new();
            for prog in &qp.programs {
                collected.extend(cur.execute_collect(prog).ok()?);
            }
            cur.add_cycles(qp.extra_cycles);
            let out = sh.kern.shared_output(params, collected)?;
            Some((out, cur.stats()))
        });
        let mut outs = Vec::with_capacity(runs.len());
        let mut stats = Vec::with_capacity(runs.len());
        for r in runs {
            let (o, s) = r?;
            outs.push(o);
            stats.push(s);
        }
        let merged = K::merge(outs, plan, params);
        let mut msgs = Vec::with_capacity(2 * plan.shards());
        for (sh, rng) in self.shards.iter().zip(&self.plan.ranges) {
            let (cmd, back) = sh.kern.query_msg_bytes(rng, params);
            msgs.push(CMD_BYTES + cmd);
            msgs.push(back);
        }
        Some(Sharded {
            merged,
            rack: self.rack.finish(stats, &msgs),
            fidelity: None,
        })
    }

    /// Execute many single-operand queries **coalesced** on one cursor
    /// pass per shard (DESIGN.md §Serving, cross-connection coalescing):
    /// each member's (cached) solo plan runs sequentially on one
    /// [`ReadCursor`] per shard inside a per-member stats window
    /// ([`ReadCursor::stats_since`]), so every member's merged result and
    /// [`RackStats`] are byte-identical to a solo
    /// [`Resident::query_shared`] call. The second return value is the
    /// modeled **batch device timeline** — the slowest shard's
    /// Σ member program cycles plus ONE shared reduction-tree drain —
    /// the in-array sweep the coalesced members share. At B ≥ 2 that
    /// timeline divided by B sits strictly below the single-query
    /// analytic floor whenever the kernel charges a drain (search).
    /// `None` when the dataset is not shared-readable or the kernel has
    /// no collected-reductions shared form (overlay kernels keep solo
    /// dispatch).
    pub fn query_multi_shared(&self, params_list: &[K::Params]) -> Option<(Vec<Sharded<K>>, u64)> {
        if !self.shared_readable() || params_list.is_empty() {
            return None;
        }
        let plan = &self.plan;
        let cache = &self.cache;
        let runs = self.rack.read_shards(&self.shards, |_i, sh| {
            let mut cur = ReadCursor::new(&sh.ctl.array);
            let mut members = Vec::with_capacity(params_list.len());
            let mut program_cycles = 0u64;
            let mut shared_drain = 0u64;
            for params in params_list {
                let qp = match sh.kern.params_key(params) {
                    Some(key) => cache.get_or_insert(ArrayShape::of(&sh.ctl.array), &key, || {
                        sh.kern.query_plan(&sh.ctl.array, params)
                    }),
                    None => std::sync::Arc::new(sh.kern.query_plan(&sh.ctl.array, params)),
                };
                let mark = cur.mark();
                let mut collected = Vec::new();
                for prog in &qp.programs {
                    collected.extend(cur.execute_collect(prog).ok()?);
                }
                cur.add_cycles(qp.extra_cycles);
                let out = sh.kern.shared_output(params, collected)?;
                let stats = cur.stats_since(&mark);
                program_cycles += stats.cycles - qp.extra_cycles;
                shared_drain = shared_drain.max(qp.extra_cycles);
                members.push((out, stats));
            }
            Some((members, program_cycles + shared_drain))
        });
        let mut per_shard = Vec::with_capacity(runs.len());
        let mut batch_cycles = 0u64;
        for r in runs {
            let (m, t) = r?;
            batch_cycles = batch_cycles.max(t);
            per_shard.push(m);
        }
        let mut shard_iters: Vec<_> = per_shard.into_iter().map(|v| v.into_iter()).collect();
        let mut out = Vec::with_capacity(params_list.len());
        for params in params_list {
            let mut outs = Vec::with_capacity(shard_iters.len());
            let mut stats = Vec::with_capacity(shard_iters.len());
            for it in &mut shard_iters {
                let (o, s) = it.next().expect("member count uniform across shards");
                outs.push(o);
                stats.push(s);
            }
            let merged = K::merge(outs, plan, params);
            let mut msgs = Vec::with_capacity(2 * plan.shards());
            for (sh, rng) in self.shards.iter().zip(&plan.ranges) {
                let (cmd, back) = sh.kern.query_msg_bytes(rng, params);
                msgs.push(CMD_BYTES + cmd);
                msgs.push(back);
            }
            out.push(Sharded {
                merged,
                rack: self.rack.finish(stats, &msgs),
                fidelity: None,
            });
        }
        Some((out, batch_cycles))
    }

    /// Per-shard wear reports over the resident arrays (`None` where
    /// tracking is off — faulted racks). Load-time tracking plus this
    /// accessor is what the server's eviction policy and the wear
    /// regression gates read.
    pub fn shard_wear(&self) -> Vec<Option<WearReport>> {
        self.shards
            .iter()
            .map(|sh| wear_report(&sh.ctl.array))
            .collect()
    }

    /// Eviction wear score: writes seen by the hottest row across all
    /// shards. `None` when tracking is off (faulted racks) — the
    /// eviction policy treats untracked datasets as coldest.
    pub fn wear_score(&self) -> Option<u32> {
        self.shards
            .iter()
            .map(|sh| wear_report(&sh.ctl.array).map(|r| r.max_writes))
            .try_fold(0u32, |acc, r| r.map(|v| acc.max(v)))
    }

    /// Analytic per-query cycle floor of the slowest shard for `params`.
    pub fn query_floor_cycles(&self, params: &K::Params) -> u64 {
        self.shards
            .iter()
            .map(|s| s.kern.query_floor_cycles(&s.ctl.array, params))
            .max()
            .unwrap_or(0)
    }

    /// Slowest-shard analytic cost of running `params`' operands as
    /// independent single-operand queries
    /// ([`Kernel::query_floor_unbatched_cycles`]) — the baseline a
    /// batched sweep must strictly beat at B ≥ 2.
    pub fn query_floor_unbatched(&self, params: &K::Params) -> u64 {
        self.shards
            .iter()
            .map(|s| s.kern.query_floor_unbatched_cycles(&s.ctl.array, params))
            .max()
            .unwrap_or(0)
    }

    /// Cumulative `(hits, misses)` of this dataset's compiled-program
    /// cache (both query paths count; see [`ProgramCache::stats`]).
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Drop every cached plan, forcing re-synthesis on the next query
    /// (FAULTS arming, storage remap). Counters are cumulative across
    /// invalidations.
    pub fn invalidate_cache(&self) {
        self.cache.invalidate()
    }

    /// Exact charged row writes of the whole load phase (Σ shards).
    pub fn expected_load_writes(&self) -> u64 {
        self.shards.iter().map(|s| s.kern.load_writes()).sum()
    }

    fn query_out(&mut self, params: &K::Params, want_bits: bool) -> QueryOut {
        let r = self.query(params);
        QueryOut {
            fields: K::fields(&r.merged),
            bits: if want_bits {
                K::bits(&r.merged)
            } else {
                Vec::new()
            },
            rack: r.rack,
            fidelity: r.fidelity,
        }
    }

    fn query_out_shared(&self, params: &K::Params, want_bits: bool) -> Option<QueryOut> {
        let r = self.query_shared(params)?;
        Some(QueryOut {
            fields: K::fields(&r.merged),
            bits: if want_bits {
                K::bits(&r.merged)
            } else {
                Vec::new()
            },
            rack: r.rack,
            fidelity: r.fidelity,
        })
    }
}

/// The reliability-path shard query (DESIGN.md §Reliability): ambient
/// retention decay over the resident columns, the kernel's query
/// program, then — when recovery is on — a scrub pass and bounded retry
/// with exponential backoff. The returned stats window covers the whole
/// dance (kernel attempts, every charged scrub read/rewrite, backoff
/// idle cycles), so recovery overhead lands in the rack figures instead
/// of vanishing; the kernel's own per-attempt stats are discarded.
///
/// The query degrades gracefully instead of failing: after
/// [`MAX_QUERY_RETRIES`] the last attempt's output is returned as-is and
/// the report's `residual`/`fidelity` fields say how much to trust it.
fn query_shard_faulty<K: Kernel>(
    sh: &mut ShardSlot<K>,
    range: &Range<usize>,
    params: &K::Params,
) -> (K::Output, ExecStats, Option<FidelityReport>) {
    let cols = sh.kern.resident_columns();
    let c0 = sh.ctl.array.cycles;
    let l0 = sh.ctl.array.ledger();
    let f0 = sh.ctl.array.fault_stats().unwrap_or_default();
    let read_ber = sh
        .ctl
        .array
        .fault_model()
        .map(|m| m.read_ber)
        .unwrap_or(0.0);
    sh.ctl.array.apply_retention(cols);
    let mut retries = 0u64;
    let (mut detected, mut repaired, mut residual) = (0u64, 0u64, 0u64);
    let (out, attempt_cycles, draws) = loop {
        let a0 = sh.ctl.array.cycles;
        let d0 = sh.ctl.array.fault_stats().unwrap_or_default().read_draws;
        let (out, _inner) = sh.kern.query_shard(&mut sh.ctl, &sh.sm, range, params);
        let attempt_cycles = sh.ctl.array.cycles - a0;
        let draws = sh.ctl.array.fault_stats().unwrap_or_default().read_draws - d0;
        let Some(scrubber) = &sh.scrub else {
            // recovery off: raw faulty device, single attempt
            break (out, attempt_cycles, draws);
        };
        let rep = scrubber.scrub(&mut sh.ctl.array);
        detected += rep.mismatches;
        repaired += rep.rewritten;
        residual = rep.residual;
        if rep.mismatches == 0 || retries >= MAX_QUERY_RETRIES {
            break (out, attempt_cycles, draws);
        }
        retries += 1;
        sh.ctl.array.add_idle_cycles(BACKOFF_BASE_CYCLES << retries);
    };
    let stats = ExecStats::since(&sh.ctl.array, c0, &l0);
    let injected = sh
        .ctl
        .array
        .fault_stats()
        .unwrap_or_default()
        .minus(&f0)
        .injected();
    let fidelity = FidelityReport {
        // P(every read draw of the final attempt was clean)
        fidelity: (1.0 - read_ber).powf(draws as f64),
        injected,
        detected,
        repaired,
        residual,
        retries,
        overhead_cycles: stats.cycles - attempt_cycles,
    };
    (out, stats, Some(fidelity))
}

/// The one generic one-shot: [`Resident::load`] followed by a single
/// [`Resident::query`], whose per-shard stats windows and merge path it
/// shares — one-shot and resident results cannot diverge by
/// construction. The reported [`RackStats`] cover the query phase only
/// (the load cost is on [`Resident::load_report`]).
pub fn sharded<K: ShardMerge>(rack: &PrinsRack, data: &K::Data, params: &K::Params) -> Sharded<K> {
    Resident::<K>::load(rack, data).query(params)
}

// ---------------------------------------------------------------------------
// Type-erased resident datasets + the kernel registry
// ---------------------------------------------------------------------------

/// One query's presentation bundle: the wire-reply fields, the canonical
/// bit encoding (bit-equality gates), and the rack stats.
pub struct QueryOut {
    /// Verb-specific reply fields (`"checksum=…"`, `"count=…"`).
    pub fields: String,
    /// Canonical bit encoding of the merged result
    /// ([`ShardMerge::bits`]). Populated by [`ResidentDyn::query_seeded`]
    /// (the test/bench/CLI surface); **empty** on
    /// [`ResidentDyn::query_args`] — the wire hot path only reads
    /// `fields`, so the O(result) encoding is skipped there.
    pub bits: Vec<u64>,
    /// Rack-level stats of this query.
    pub rack: RackStats,
    /// Combined reliability report — `None` on an ideal (fault-free)
    /// rack, so wire replies stay byte-identical unless faults are on.
    pub fidelity: Option<FidelityReport>,
}

/// A type-erased [`Resident`] dataset — what the server's per-session
/// dataset registry, the CLI and the bench sweeps hold, so none of them
/// name concrete kernels. `Sync` so the server's worker pool can run
/// shared-read queries ([`ResidentDyn::query_args_shared`]) from many
/// threads over one dataset at once.
pub trait ResidentDyn: Send + Sync {
    /// The kernel's registry name (`"hist"`, `"search"`, …).
    fn name(&self) -> &'static str;
    /// Global logical rows loaded.
    fn rows(&self) -> usize;
    /// Device + link cost of the load phase.
    fn load_report(&self) -> &RackStats;
    /// Exact charged row writes of the load phase (Σ shards, per
    /// [`Kernel::load_writes`]) — the test gates' load-wear anchor.
    fn expected_load_writes(&self) -> u64;
    /// One query with wire parameters (the args after the dataset id).
    /// The returned [`QueryOut::bits`] is left empty (wire hot path).
    fn query_args(&mut self, args: &[&str]) -> Result<QueryOut>;
    /// One **batched** query with wire parameters (the args after the
    /// dataset id in the batched grammar, e.g. `SEARCH id B lo1 hi1 …`).
    /// Errs for kernels without a batched form ([`Kernel::parse_batch`]).
    fn query_args_batch(&mut self, args: &[&str]) -> Result<QueryOut>;
    /// [`ResidentDyn::query_args_batch`] through the shared-read path
    /// (`&self`). Errs when the dataset is not
    /// [`ResidentDyn::shared_readable`] or has no batched form.
    fn query_args_batch_shared(&self, args: &[&str]) -> Result<QueryOut>;
    /// Whether this dataset can serve the shared-read concurrent query
    /// path ([`Resident::shared_readable`]): write-free kernel, no
    /// fault model.
    fn shared_readable(&self) -> bool;
    /// [`ResidentDyn::query_args`] through the shared-read path
    /// (`&self`, no exclusive access): bit-identical reply for
    /// write-free kernels. Errs when the dataset is not
    /// [`ResidentDyn::shared_readable`].
    fn query_args_shared(&self, args: &[&str]) -> Result<QueryOut>;
    /// Many single-operand shared queries **coalesced** on one cursor
    /// pass per shard ([`Resident::query_multi_shared`]): one argset
    /// per member (the args after the dataset id). Per-member replies
    /// are byte-identical to solo [`ResidentDyn::query_args_shared`]
    /// calls; the second value is the modeled batch device timeline.
    /// `None` when the dataset has no coalesceable shared form or any
    /// member's parameters fail to parse — callers fall back to solo
    /// dispatch.
    fn query_args_coalesced(&self, argsets: &[Vec<String>]) -> Option<(Vec<QueryOut>, u64)>;
    /// Eviction wear score: hottest-row writes across shards (`None` =
    /// tracking off; see [`Resident::wear_score`]).
    fn wear_score(&self) -> Option<u32>;
    /// One query with the deterministic `(q, seed)` parameter stream,
    /// including the canonical bit encoding ([`QueryOut::bits`]).
    fn query_seeded(&mut self, q: usize, seed: u64) -> QueryOut;
    /// One **batched** query with the deterministic `(q, seed, batch)`
    /// parameter stream ([`Kernel::seeded_batch`]), including the
    /// canonical bit encoding. `None` when the kernel has no batched
    /// form — bench and CLI batch sweeps skip it.
    fn query_seeded_batch(&mut self, q: usize, seed: u64, batch: usize) -> Option<QueryOut>;
    /// Analytic slowest-shard cost of running the `(q, seed, batch)`
    /// operands as independent single-operand queries
    /// ([`Resident::query_floor_unbatched`]) — the baseline the batched
    /// sweep's measured cycles must strictly beat at batch ≥ 2. `None`
    /// when the kernel has no batched form.
    fn query_floor_seeded_batch(&self, q: usize, seed: u64, batch: usize) -> Option<u64>;
    /// Cumulative `(hits, misses)` of the dataset's compiled-program
    /// cache ([`Resident::cache_stats`]).
    fn cache_stats(&self) -> (u64, u64);
    /// Drop every cached plan, forcing re-synthesis on the next query
    /// ([`Resident::invalidate_cache`]) — called on FAULTS arming and
    /// storage remap.
    fn invalidate_cache(&self);
    /// Analytic slowest-shard cycle floor for the `(q, seed)` parameter
    /// stream ([`Resident::query_floor_cycles`]) — the exact value the
    /// matching [`ResidentDyn::query_seeded`]'s `max_shard_cycles` must
    /// measure; the registry test gates pin the two together.
    fn query_floor_seeded(&self, q: usize, seed: u64) -> u64;
    /// Synthesize every shard's query plan for the `(q, seed)` parameter
    /// stream, without executing anything — one [`PlannedQuery`] per
    /// shard, each carrying the plan, the kernel's analytic floor for
    /// that same shard, and the shard array's geometry. This is the
    /// static analyzer's registry-wide entry point
    /// ([`crate::analysis::verify_kernel`]).
    fn query_plans_seeded(&self, q: usize, seed: u64) -> Vec<PlannedQuery>;
}

impl<K: ShardMerge + 'static> ResidentDyn for Resident<K> {
    fn name(&self) -> &'static str {
        K::NAME
    }

    fn rows(&self) -> usize {
        self.n
    }

    fn load_report(&self) -> &RackStats {
        &self.load
    }

    fn expected_load_writes(&self) -> u64 {
        Resident::expected_load_writes(self)
    }

    fn query_args(&mut self, args: &[&str]) -> Result<QueryOut> {
        ensure!(
            args.len() == K::QUERY_ARITY,
            "{} takes {} query parameter(s) after the dataset id",
            K::VERB,
            K::QUERY_ARITY
        );
        let params = self.kernel().parse_params(args)?;
        Ok(self.query_out(&params, false))
    }

    fn query_args_batch(&mut self, args: &[&str]) -> Result<QueryOut> {
        let params = self.kernel().parse_batch(args)?;
        Ok(self.query_out(&params, false))
    }

    fn query_args_batch_shared(&self, args: &[&str]) -> Result<QueryOut> {
        let params = self.kernel().parse_batch(args)?;
        match self.query_out_shared(&params, false) {
            Some(out) => Ok(out),
            None => bail!("dataset is not shared-readable"),
        }
    }

    fn shared_readable(&self) -> bool {
        Resident::shared_readable(self)
    }

    fn query_args_shared(&self, args: &[&str]) -> Result<QueryOut> {
        ensure!(
            args.len() == K::QUERY_ARITY,
            "{} takes {} query parameter(s) after the dataset id",
            K::VERB,
            K::QUERY_ARITY
        );
        let params = self.kernel().parse_params(args)?;
        match self.query_out_shared(&params, false) {
            Some(out) => Ok(out),
            None => bail!("dataset is not shared-readable"),
        }
    }

    fn query_args_coalesced(&self, argsets: &[Vec<String>]) -> Option<(Vec<QueryOut>, u64)> {
        let mut params_list = Vec::with_capacity(argsets.len());
        for args in argsets {
            if args.len() != K::QUERY_ARITY {
                return None;
            }
            let refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
            params_list.push(self.kernel().parse_params(&refs).ok()?);
        }
        let (runs, batch_cycles) = self.query_multi_shared(&params_list)?;
        let outs = runs
            .into_iter()
            .map(|r| QueryOut {
                fields: K::fields(&r.merged),
                bits: Vec::new(),
                rack: r.rack,
                fidelity: r.fidelity,
            })
            .collect();
        Some((outs, batch_cycles))
    }

    fn wear_score(&self) -> Option<u32> {
        Resident::wear_score(self)
    }

    fn query_seeded(&mut self, q: usize, seed: u64) -> QueryOut {
        let params = self.kernel().seeded_params(q, seed);
        self.query_out(&params, true)
    }

    fn query_seeded_batch(&mut self, q: usize, seed: u64, batch: usize) -> Option<QueryOut> {
        let params = self.kernel().seeded_batch(q, seed, batch)?;
        Some(self.query_out(&params, true))
    }

    fn query_floor_seeded_batch(&self, q: usize, seed: u64, batch: usize) -> Option<u64> {
        let params = self.kernel().seeded_batch(q, seed, batch)?;
        Some(Resident::query_floor_unbatched(self, &params))
    }

    fn cache_stats(&self) -> (u64, u64) {
        Resident::cache_stats(self)
    }

    fn invalidate_cache(&self) {
        Resident::invalidate_cache(self)
    }

    fn query_floor_seeded(&self, q: usize, seed: u64) -> u64 {
        let params = self.kernel().seeded_params(q, seed);
        Resident::query_floor_cycles(self, &params)
    }

    fn query_plans_seeded(&self, q: usize, seed: u64) -> Vec<PlannedQuery> {
        let params = self.kernel().seeded_params(q, seed);
        self.shards
            .iter()
            .map(|sh| PlannedQuery {
                plan: sh.kern.query_plan(&sh.ctl.array, &params),
                floor_cycles: sh.kern.query_floor_cycles(&sh.ctl.array, &params),
                shape: ArrayShape::of(&sh.ctl.array),
                resident_columns: sh.kern.resident_columns(),
            })
            .collect()
    }
}

/// One registered kernel: everything the rack, server, CLI and benches
/// need to drive it without naming it. Adding a workload = implementing
/// [`Kernel`] + [`ShardMerge`] in one file and appending one entry to
/// [`REGISTRY`].
pub struct KernelEntry {
    /// Registry/CLI name (lower-case, e.g. `"search"`).
    pub name: &'static str,
    /// Wire verb (upper-case, e.g. `"SEARCH"`).
    pub verb: &'static str,
    /// Wire query parameters after the dataset id.
    pub query_arity: usize,
    /// Wire args after the verb in the one-shot form.
    pub one_shot_arity: usize,
    /// `LOAD` grammar line (docs/PROTOCOL.md), e.g. `"LOAD HIST n seed"`.
    pub load_usage: &'static str,
    /// Dataset-id query grammar line, e.g. `"HIST id"`.
    pub query_usage: &'static str,
    /// One-shot grammar line, e.g. `"HIST n seed"`.
    pub one_shot_usage: &'static str,
    /// Whether the workload simulates every microcode pass over every
    /// row per query (the CLI/bench row caps apply to dense kernels).
    pub dense: bool,
    /// Whether queries are compare-only (zero writes — asserted by the
    /// registry-driven wear gates for kernels that claim it).
    pub write_free_queries: bool,
    /// Whether queries mutate only scratch columns outside
    /// [`Kernel::resident_columns`], qualifying for the scratch-overlay
    /// shared-read path. The C03 contract rule proves the claim
    /// statically; `write_free_queries` kernels trivially satisfy it.
    pub overlay_queries: bool,
    /// Whether the server may merge compatible single-operand wire
    /// queries from different connections into one coalesced sweep
    /// ([`ResidentDyn::query_args_coalesced`]). Only meaningful for
    /// kernels whose shared path goes through collected reductions —
    /// overlay-dispatch kernels return `None` from the coalesced entry
    /// point and must leave this `false`.
    pub coalesce_queries: bool,
    /// Whether [`ShardMerge::bits`] encodes f32 words (`to_bits`) rather
    /// than exact integers — the fidelity bench decodes accordingly
    /// (relative error vs bit-exact match).
    pub bits_f32: bool,
    /// Host-FLOP estimate of one query (CLI efficiency print).
    pub flops: fn(n: usize, dims: usize) -> f64,
    /// Server `LOAD <VERB> args…` handler: parse, synthesize, load.
    pub load: fn(&PrinsRack, &[&str]) -> Result<Box<dyn ResidentDyn>>,
    /// Canonical synthesis for the CLI, benches and test gates: a
    /// dataset of `n` rows (`dims` where meaningful) under `seed`.
    pub synth_load: fn(&PrinsRack, n: usize, dims: usize, seed: u64) -> Box<dyn ResidentDyn>,
    /// Wire one-shot handler: parse args, synthesize, load + one query.
    pub one_shot: fn(&PrinsRack, &[&str]) -> Result<QueryOut>,
}

/// The kernel registry: every workload the stack serves, in protocol
/// listing order. The server's verb dispatch, the CLI `run` subcommand,
/// the bench sweeps and the property-test gates iterate this slice —
/// none of them contain per-kernel code.
pub static REGISTRY: [KernelEntry; 5] = [
    super::histogram::ENTRY,
    super::dot::ENTRY,
    super::euclidean::ENTRY,
    super::spmv::ENTRY,
    super::search::ENTRY,
];

/// All registered kernels (see [`REGISTRY`]).
pub fn registry() -> &'static [KernelEntry] {
    &REGISTRY
}

/// Look a kernel up by registry name (`"dp"` — the CLI surface).
pub fn find_name(name: &str) -> Option<&'static KernelEntry> {
    registry().iter().find(|e| e.name == name)
}

/// Look a kernel up by wire verb (`"DP"` — the server surface; verbs are
/// case-sensitive per docs/PROTOCOL.md, so this must not match names).
pub fn find_verb(verb: &str) -> Option<&'static KernelEntry> {
    registry().iter().find(|e| e.verb == verb)
}

/// Look a kernel up by either registry name or wire verb.
pub fn find(name_or_verb: &str) -> Option<&'static KernelEntry> {
    find_name(name_or_verb).or_else(|| find_verb(name_or_verb))
}

/// Shared body of every registry `one_shot` handler: load + one query,
/// presented as a [`QueryOut`]. Wire path — [`QueryOut::bits`] is left
/// empty (only `fields` goes on the reply line).
pub fn one_shot_out<K: ShardMerge + 'static>(
    rack: &PrinsRack,
    data: &K::Data,
    params: &K::Params,
) -> QueryOut {
    let mut res = Resident::<K>::load(rack, data);
    res.query_out(params, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_verbs_and_arities_are_distinct_and_consistent() {
        let reg = registry();
        assert_eq!(reg.len(), 5, "five registered kernels");
        for (i, e) in reg.iter().enumerate() {
            assert_eq!(e.name.to_uppercase(), e.verb, "{}: verb is the upper-case name", e.name);
            assert!(
                e.one_shot_arity != e.query_arity + 1,
                "{}: one-shot and dataset-id forms must differ in arity",
                e.name
            );
            for other in &reg[i + 1..] {
                assert_ne!(e.name, other.name);
                assert_ne!(e.verb, other.verb);
            }
            assert!(find(e.name).is_some() && find(e.verb).is_some());
        }
        assert!(find("bogus").is_none());
    }

    #[test]
    fn every_registered_kernel_loads_and_queries_through_the_dyn_surface() {
        let rack = PrinsRack::new(2);
        for e in registry() {
            let mut res = (e.synth_load)(&rack, 24, 2, 7);
            assert_eq!(res.name(), e.name);
            assert_eq!(res.rows(), 24, "{}", e.name);
            assert!(res.load_report().total_cycles > 0, "{}: load charged", e.name);
            let a = res.query_seeded(0, 9);
            let b = res.query_seeded(0, 9);
            assert_eq!(a.bits, b.bits, "{}: repeat query diverged", e.name);
            assert_eq!(a.fields, b.fields, "{}", e.name);
            assert!(!a.bits.is_empty(), "{}: bits encoding empty", e.name);
            assert_eq!(a.rack.shards, 2, "{}", e.name);
        }
    }

    #[test]
    fn float_matrix_slices_rows() {
        let m = FloatMatrix::new(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], 3, 2);
        assert_eq!(m.rows(&(1..3)), &[2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn coalesced_shared_queries_match_solo_replies_and_beat_unshared_sweeps() {
        let rack = PrinsRack::new(2);
        let e = find_name("search").unwrap();
        let res = (e.synth_load)(&rack, 24, 2, 7);
        let sets: Vec<Vec<String>> = vec![
            vec!["100".into(), "5000".into()],
            vec!["100".into(), "5000".into()],
            vec!["6000".into(), "40000".into()],
        ];
        let (outs, batch_cycles) = res.query_args_coalesced(&sets).expect("search coalesces");
        assert_eq!(outs.len(), sets.len());
        let mut max_solo = 0u64;
        for (set, out) in sets.iter().zip(&outs) {
            let refs: Vec<&str> = set.iter().map(|s| s.as_str()).collect();
            let solo = res.query_args_shared(&refs).unwrap();
            // every member reply is byte-identical to its solo shared query
            assert_eq!(out.fields, solo.fields);
            assert_eq!(out.rack.total_cycles, solo.rack.total_cycles);
            assert_eq!(out.rack.max_shard_cycles, solo.rack.max_shard_cycles);
            assert_eq!(out.rack.link_bytes, solo.rack.link_bytes);
            assert_eq!(out.rack.energy_j.to_bits(), solo.rack.energy_j.to_bits());
            assert!(out.fidelity.is_none());
            max_solo = max_solo.max(solo.rack.max_shard_cycles);
        }
        assert!(batch_cycles > 0);
        assert!(
            batch_cycles < sets.len() as u64 * max_solo,
            "coalesced batch timeline {batch_cycles} must beat {} unshared sweeps of {max_solo}",
            sets.len()
        );
        // malformed member arity → the whole group falls back to solo dispatch
        assert!(res.query_args_coalesced(&[vec!["100".into()]]).is_none());
        assert!(res.query_args_coalesced(&[]).is_none());
    }
}
