//! Algorithm 3 (paper Fig. 9): fully associative 256-bin histogram.
//!
//! One sample per RCAM row. For each bin: a single compare of the bin
//! index against bits [31..24] of the sample (the CAM's native one-cycle
//! content match), then the reduction tree counts the tagged rows. Two
//! operations per bin, independent of the number of samples.

use crate::controller::{Controller, ExecStats};
use crate::isa::{Field, Instr, Program, RowLayout};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};

pub const BINS: usize = 256;

pub struct HistogramKernel {
    pub n: usize,
    sample: Field,
    /// dataset-membership flag: unloaded (all-zero) rows of the array must
    /// not be counted in bin 0 (paper §5.1: data elements are identified
    /// associatively, so membership is part of the compare pattern)
    valid: Field,
    ds: Dataset,
}

pub struct HistResult {
    pub hist: Vec<u64>,
    pub stats: ExecStats,
}

impl HistogramKernel {
    pub fn load(sm: &mut StorageManager, array: &mut PrinsArray, x: &[u32]) -> Self {
        let mut layout = RowLayout::new(array.width() as u16);
        let sample = layout.alloc("sample", 32);
        let valid = layout.alloc("valid", 1);
        let ds = sm.alloc(x.len(), layout).expect("storage full");
        for (i, &v) in x.iter().enumerate() {
            array.load_row_bits(ds.rows.start + i, sample.base as usize, 32, v as u64);
            array.load_row_bits(ds.rows.start + i, valid.base as usize, 1, 1);
        }
        HistogramKernel {
            n: x.len(),
            sample,
            valid,
            ds,
        }
    }

    /// The full histogram program: per bin, compare + reduce (Fig. 9).
    pub fn program(&self) -> Program {
        let mut prog = Program::new();
        let top_byte = self.sample.slice(24, 8); // bits [31..24]
        for bin in 0..BINS as u64 {
            let mut pat = top_byte.pattern(bin); // line 3
            pat.push((self.valid.base, true));
            prog.push(Instr::Compare(pat));
            prog.push(Instr::ReduceCount); // line 4: H_bin ← Reduction(tags)
        }
        prog
    }

    pub fn run(&self, ctl: &mut Controller) -> HistResult {
        ctl.begin_stats();
        let prog = self.program();
        let hist = ctl.execute_collect(&prog);
        // one pipelined tree-drain latency at the end of the bin sweep
        ctl.array.charge_reduction_latency();
        let mut stats = ctl.stats();
        stats.passes = 0; // no writes in this kernel
        HistResult { hist, stats }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }
}

/// Scalar CPU baseline.
pub fn histogram_baseline(x: &[u32]) -> Vec<u64> {
    let mut h = vec![0u64; BINS];
    for &v in x {
        h[(v >> 24) as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{synth_hist_samples, Rng};

    #[test]
    fn histogram_matches_baseline() {
        let xs = synth_hist_samples(5000, 17);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        assert_eq!(res.hist, histogram_baseline(&xs));
        assert_eq!(res.hist.iter().sum::<u64>(), xs.len() as u64);
    }

    #[test]
    fn two_ops_per_bin() {
        // paper: compare + reduction per bin — 2 issue cycles per bin plus
        // the final pipelined tree drain
        let xs: Vec<u32> = (0..64).collect();
        let mut array = PrinsArray::single(64, 40);
        let mut sm = StorageManager::new(64);
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        let drain = ctl.array.reduction_latency_cycles();
        assert_eq!(res.stats.cycles, 2 * BINS as u64 + drain);
    }

    #[test]
    fn cycles_independent_of_sample_count() {
        let run_n = |n: usize| {
            let mut rng = Rng::seed_from(4);
            let xs: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut array = PrinsArray::single(n, 40);
            let mut sm = StorageManager::new(n);
            let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
            let mut ctl = Controller::new(array);
            // subtract the N-dependent tree drain to compare issue cycles
            kern.run(&mut ctl).stats.cycles - ctl.array.reduction_latency_cycles()
        };
        assert_eq!(run_n(64), run_n(4096));
    }
}
