//! Algorithm 3 (paper Fig. 9): fully associative 256-bin histogram.
//!
//! One sample per RCAM row. For each bin: a single compare of the bin
//! index against bits [31..24] of the sample (the CAM's native one-cycle
//! content match), then the reduction tree counts the tagged rows. Two
//! operations per bin, independent of the number of samples.

use crate::controller::{Controller, ExecStats};
use crate::host::rack::{PrinsRack, RackStats};
use crate::isa::{Field, Instr, Program, RowLayout};
use crate::rcam::shard::{merge_histograms, ShardPlan, CMD_BYTES};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};

/// Number of histogram bins (the paper's fixed 256-bin kernel).
pub const BINS: usize = 256;

/// Loaded histogram dataset + the per-bin compare/reduce program.
pub struct HistogramKernel {
    /// Number of loaded samples.
    pub n: usize,
    sample: Field,
    /// dataset-membership flag: unloaded (all-zero) rows of the array must
    /// not be counted in bin 0 (paper §5.1: data elements are identified
    /// associatively, so membership is part of the compare pattern)
    valid: Field,
    ds: Dataset,
}

/// Result of one histogram run.
pub struct HistResult {
    /// The 256 bin counts.
    pub hist: Vec<u64>,
    /// Execution statistics of the run.
    pub stats: ExecStats,
}

impl HistogramKernel {
    /// Allocate rows and load the samples (one sample per row, plus the
    /// dataset-membership valid bit).
    pub fn load(sm: &mut StorageManager, array: &mut PrinsArray, x: &[u32]) -> Self {
        let mut layout = RowLayout::new(array.width() as u16);
        let sample = layout.alloc("sample", 32);
        let valid = layout.alloc("valid", 1);
        let ds = sm.alloc(x.len(), layout).expect("storage full");
        for (i, &v) in x.iter().enumerate() {
            array.load_row_bits(ds.rows.start + i, sample.base as usize, 32, v as u64);
            array.load_row_bits(ds.rows.start + i, valid.base as usize, 1, 1);
        }
        HistogramKernel {
            n: x.len(),
            sample,
            valid,
            ds,
        }
    }

    /// The full histogram program: per bin, compare + reduce (Fig. 9).
    pub fn program(&self) -> Program {
        let mut prog = Program::new();
        let top_byte = self.sample.slice(24, 8); // bits [31..24]
        for bin in 0..BINS as u64 {
            let mut pat = top_byte.pattern(bin); // line 3
            pat.push((self.valid.base, true));
            prog.push(Instr::Compare(pat));
            prog.push(Instr::ReduceCount); // line 4: H_bin ← Reduction(tags)
        }
        prog
    }

    /// Execute the full 256-bin program and read the counts back.
    pub fn run(&self, ctl: &mut Controller) -> HistResult {
        ctl.begin_stats();
        let prog = self.program();
        let hist = ctl.execute_collect(&prog);
        // one pipelined tree-drain latency at the end of the bin sweep
        ctl.array.charge_reduction_latency();
        let mut stats = ctl.stats();
        stats.passes = 0; // no writes in this kernel
        HistResult { hist, stats }
    }

    /// The storage allocation backing this kernel's samples.
    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }
}

/// Result of a rack-sharded histogram run.
pub struct ShardedHistResult {
    /// Bin-wise-merged histogram, bit-identical to the single-device run.
    pub hist: Vec<u64>,
    /// Rack-level cycle/energy statistics (slowest shard + host link).
    pub rack: RackStats,
}

/// Rack-sharded histogram: samples are row-range-partitioned over the
/// rack's shards, every shard runs the full Fig. 9 per-bin program on its
/// slice concurrently, and the host merges the per-shard histograms
/// bin-wise ([`merge_histograms`] — exact, since counting is
/// associative). The host link is charged one command message plus one
/// 256-bin result message per shard (DESIGN.md §Sharding).
pub fn histogram_sharded(rack: &PrinsRack, x: &[u32]) -> ShardedHistResult {
    let plan = ShardPlan::rows(x.len(), rack.n_shards());
    let runs = rack.run_shards(&plan, |_s, r| {
        let xs = &x[r];
        let mut array = rack.shard_array(xs.len(), 40);
        let mut sm = StorageManager::new(array.total_rows());
        let kern = HistogramKernel::load(&mut sm, &mut array, xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        (res.hist, res.stats)
    });
    let (hists, stats): (Vec<_>, Vec<_>) = runs.into_iter().unzip();
    let mut msgs = Vec::with_capacity(2 * plan.shards());
    for _ in 0..plan.shards() {
        msgs.push(CMD_BYTES); // kernel-invocation command
        msgs.push((BINS * 8) as u64); // per-shard histogram readback
    }
    ShardedHistResult {
        hist: merge_histograms(&hists),
        rack: rack.finish(stats, &msgs),
    }
}

/// Scalar CPU baseline.
pub fn histogram_baseline(x: &[u32]) -> Vec<u64> {
    let mut h = vec![0u64; BINS];
    for &v in x {
        h[(v >> 24) as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{synth_hist_samples, Rng};

    #[test]
    fn histogram_matches_baseline() {
        let xs = synth_hist_samples(5000, 17);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        assert_eq!(res.hist, histogram_baseline(&xs));
        assert_eq!(res.hist.iter().sum::<u64>(), xs.len() as u64);
    }

    #[test]
    fn two_ops_per_bin() {
        // paper: compare + reduction per bin — 2 issue cycles per bin plus
        // the final pipelined tree drain
        let xs: Vec<u32> = (0..64).collect();
        let mut array = PrinsArray::single(64, 40);
        let mut sm = StorageManager::new(64);
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        let drain = ctl.array.reduction_latency_cycles();
        assert_eq!(res.stats.cycles, 2 * BINS as u64 + drain);
    }

    #[test]
    fn sharded_histogram_merges_binwise() {
        let xs = synth_hist_samples(3000, 23);
        let rack = PrinsRack::new(3);
        let res = histogram_sharded(&rack, &xs);
        assert_eq!(res.hist, histogram_baseline(&xs));
        assert_eq!(res.rack.shards, 3);
        assert_eq!(res.rack.link_messages, 6);
        assert!(res.rack.total_cycles > res.rack.max_shard_cycles);
    }

    #[test]
    fn cycles_independent_of_sample_count() {
        let run_n = |n: usize| {
            let mut rng = Rng::seed_from(4);
            let xs: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut array = PrinsArray::single(n, 40);
            let mut sm = StorageManager::new(n);
            let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
            let mut ctl = Controller::new(array);
            // subtract the N-dependent tree drain to compare issue cycles
            kern.run(&mut ctl).stats.cycles - ctl.array.reduction_latency_cycles()
        };
        assert_eq!(run_n(64), run_n(4096));
    }
}
